"""Fault-tolerant attention-pool serving — recovery cost measurement.

The paper's §5 observation is that KV is recomputable from prompt +
generated tokens, so request recovery after a pool-shard failure needs no
checkpointing: quarantine the shard, evict its requests through the normal
preemption path, re-admit via recompute on the survivors. This benchmark
prices that path on the CPU-scale engine:

  * a fault-free reference run (greedy outputs recorded);
  * the same trace with an injected mid-decode shard death (+ rejoin),
    reporting recovery-latency percentiles, throughput cost vs the
    reference, and a bit-parity check of the outputs;
  * transient / corrupt / straggler scenarios, reporting retry volume and
    that NO eviction happened (transients recover in place).

Every row's ``derived`` carries ``parity=ok|BROKEN`` — the invariant the
fault-tolerance tests enforce, surfaced here so a snapshot regression is
visible in the BENCH_*.json artifacts too.
"""
from __future__ import annotations

import time

import jax

from repro.configs import registry
from repro.models import transformer
from repro.serving import (EngineConfig, FaultInjector, FaultScenario,
                           LLMEngine, Request, SamplingParams)


def _requests(n, max_new):
    return [Request(prompt=[7 + 3 * i + j for j in range(5 + i % 3)],
                    params=SamplingParams(max_new_tokens=max_new))
            for i in range(n)]


def _drain(cfg, params, econf, n_reqs, max_new, scenario=None):
    injector = FaultInjector(FaultScenario.parse(scenario)) \
        if scenario else None
    eng = LLMEngine(cfg, params, econf, fault_injector=injector)
    reqs = _requests(n_reqs, max_new)
    eng.submit(reqs)
    t0 = time.time()
    eng.run()
    wall = time.time() - t0
    return eng, [r.output for r in reqs], wall


def run(quick: bool = False):
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    econf = EngineConfig(placement="attention_pool", partition="block",
                         attention_workers=2, num_blocks=64, block_size=4,
                         max_batch=4, scheduler="preempt")
    n_reqs = 3 if quick else 6
    max_new = 10 if quick else 24

    _, ref, ref_wall = _drain(cfg, params, econf, n_reqs, max_new)

    scenarios = [
        ("shard_death", "shard_death:shard=1,step=4,rejoin=12"),
    ]
    if not quick:
        scenarios += [
            ("transient", "transient:shard=0,step=3,failures=2"),
            ("corrupt", "corrupt:shard=1,step=5"),
            ("straggle", "straggle:shard=0,step=4,delay_ms=2"),
        ]

    rows = []
    for name, spec in scenarios:
        eng, out, wall = _drain(cfg, params, econf, n_reqs, max_new, spec)
        s = eng.stats
        rec = s.recovery_percentiles()
        parity = "ok" if out == ref else "BROKEN"
        rows.append({
            "name": f"fault_recovery_{name}",
            # headline: p50 request-recovery latency (µs); transient-class
            # scenarios recover in place, so it is 0 by design there
            "us_per_call": round(rec["p50"] * 1e6),
            "derived": (
                f"parity={parity};"
                f"shard_failures={s.shard_failures};"
                f"rejoins={s.shard_rejoins};"
                f"requests_recovered={s.requests_recovered};"
                f"transient_recovered={s.transient_faults_recovered};"
                f"retries={s.fault_retries};"
                f"straggles={s.straggle_steps};"
                f"recovery_p99_ms={rec['p99'] * 1e3:.2f};"
                f"wall_overhead={wall / max(ref_wall, 1e-9) - 1:.2%}"),
        })

    # degraded-capacity serving: how much concurrency the pool loses while
    # one of two shards is quarantined (capacity halves; over-commitment
    # guards follow the surviving shards)
    eng, out, _ = _drain(cfg, params, econf, n_reqs, max_new,
                         "shard_death:shard=0,step=3,rejoin=30")
    s = eng.stats
    rows.append({
        "name": "fault_recovery_degraded_capacity",
        "us_per_call": round(s.mean_tbt * 1e6),
        "derived": (
            f"parity={'ok' if out == ref else 'BROKEN'};"
            f"mean_batch={s.mean_batch:.2f};"
            f"preemptions={s.preemptions};"
            f"steps={s.steps}"),
    })
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
