"""Shared benchmark plumbing: each bench_* module exposes run() -> list of
CSV rows (dicts). benchmarks.run executes them all and prints
``name,us_per_call,derived`` style CSV plus per-figure tables."""
from __future__ import annotations

import time
from typing import Callable, Dict, List


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3,
              **kw) -> float:
    """Median wall time of fn(*args) in seconds (CPU-scale measurements)."""
    import jax

    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(rows: List[Dict], columns: List[str]) -> None:
    print(",".join(columns))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in columns))
