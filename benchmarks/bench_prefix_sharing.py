"""Prefix sharing: the pool-memory and prefill-compute win of refcounted
copy-on-write KV blocks (``EngineConfig(prefix_sharing=True)``).

Scenario: K requests share a P-token common prompt prefix (system prompt /
few-shot template / multi-sample decoding — the highest-leverage capacity
win the paper's memory-bound attention pool can get without new hardware,
§3/§4.2). Three measurements per (K, P) point:

  * ``bytes``   — physical pool bytes after admitting all K requests:
    sharing maps each matched full block onto ONE physical copy, so
    residency approaches bytes(1 full prompt) + K·bytes(suffix) instead of
    K·bytes(prompt) (the ideal is printed next to the measurement);
  * ``admitted`` — concurrent requests a TIGHT pool admits in the first
    scheduling wave: admission charges only the unshared suffix, so the
    same memory admits strictly more requests;
  * ``ttft``    — measured TTFT with the prefill-skip (matched blocks are
    never recomputed; suffix-only prefill attends over the gathered prefix
    context) vs full prefill, outputs verified bit-identical.
"""
from __future__ import annotations

import numpy as np

from repro.configs import registry
from repro.serving import EngineConfig, LLMEngine, Request, SamplingParams
from repro.serving.worker_pool import BYTES
from repro.serving.kvcache import PagedKVCache
from repro.serving.scheduler import RequestScheduler

BLOCK_SIZE = 16


def _reqs(cfg, n, prefix, suffix_len, new_tokens, seed=1):
    rng = np.random.default_rng(seed)
    return [Request(prompt=list(prefix) +
                    rng.integers(0, cfg.vocab_size, size=suffix_len).tolist(),
                    params=SamplingParams(max_new_tokens=new_tokens))
            for _ in range(n)]


def _block_bytes(cfg) -> int:
    return (2 * cfg.num_layers * cfg.num_kv_heads * BLOCK_SIZE *
            cfg.resolved_head_dim * BYTES)


def _admission_stats(cfg, n_reqs, prefix, suffix_len, num_blocks, share):
    """Scheduler-only admission (no model): pool blocks + wave size."""
    kv = PagedKVCache(cfg, num_blocks, BLOCK_SIZE)
    sched = RequestScheduler(kv, max_batch=n_reqs, decode_headroom=0,
                             prefix_sharing=share)
    sched.submit(_reqs(cfg, n_reqs, prefix, suffix_len, 4))
    admitted = len(sched.admit())
    return admitted, kv.used_blocks


def run(quick: bool = False):
    rows = []
    cfg = registry.get_smoke_config("llama3-8b")
    bb = _block_bytes(cfg)
    K = 4 if quick else 8
    suffix_len = 8
    new_tokens = 2 if quick else 4
    rng = np.random.default_rng(0)
    sweep = (32,) if quick else (32, 96)

    import jax

    from repro.models import transformer
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)

    for P in sweep:
        prefix = rng.integers(0, cfg.vocab_size, size=P).tolist()
        prompt_blocks = -(-(P + suffix_len) // BLOCK_SIZE)
        shared_blocks = P // BLOCK_SIZE  # full blocks only

        # ---- pool bytes + admitted concurrency (scheduler-only) ----
        roomy = 4 * K * prompt_blocks
        adm_off, used_off = _admission_stats(cfg, K, prefix, suffix_len,
                                             roomy, False)
        adm_on, used_on = _admission_stats(cfg, K, prefix, suffix_len,
                                           roomy, True)
        ideal_on = prompt_blocks + (K - 1) * (prompt_blocks - shared_blocks)
        # tight pool: fits ~2 full prompts unshared
        tight = 2 * prompt_blocks
        tight_off, _ = _admission_stats(cfg, K, prefix, suffix_len, tight,
                                        False)
        tight_on, _ = _admission_stats(cfg, K, prefix, suffix_len, tight,
                                       True)

        # ---- TTFT with prefill-skip (measured engine, outputs checked) ----
        from repro.serving.stats import EngineStats
        res = {}
        for share in (False, True):
            eng = LLMEngine(cfg, params, EngineConfig(
                max_batch=K, num_blocks=roomy, block_size=BLOCK_SIZE,
                prefix_sharing=share))
            # warm-up drain compiles the prefill/suffix/decode shapes so the
            # measured pass reports steady-state TTFT, not jit compile time
            eng.submit(_reqs(cfg, K, prefix, suffix_len, new_tokens))
            eng.run()
            eng.stats = EngineStats()
            reqs = _reqs(cfg, K, prefix, suffix_len, new_tokens)
            eng.submit(reqs)
            eng.run()
            res[share] = (eng.stats.summary(), [r.output for r in reqs])
        s_on, s_off = res[True][0], res[False][0]
        identical = res[True][1] == res[False][1]

        rows.append({
            "name": f"prefix_share_K{K}_P{P}",
            "us_per_call": round(s_on["ttft_p50_s"] * 1e6),
            "derived": (
                f"requests={K};prefix_tokens={P};suffix_tokens={suffix_len};"
                f"pool_mib_off={used_off * bb / 2**20:.3f};"
                f"pool_mib_on={used_on * bb / 2**20:.3f};"
                f"pool_mib_ideal={ideal_on * bb / 2**20:.3f};"
                f"blocks_off={used_off};blocks_on={used_on};"
                f"blocks_ideal={ideal_on};"
                f"tight_admitted_off={tight_off};tight_admitted_on={tight_on};"
                f"roomy_admitted={adm_off}=={adm_on};"
                f"ttft_p50_ms_off={s_off['ttft_p50_s'] * 1e3:.1f};"
                f"ttft_p50_ms_on={s_on['ttft_p50_s'] * 1e3:.1f};"
                f"prefill_tokens_skipped={s_on['prefill_tokens_skipped']};"
                f"blocks_shared={s_on['blocks_shared']};"
                f"outputs_identical={identical}"),
        })
    return rows
