"""Benchmark harness entry point — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (deliverable d).

  PYTHONPATH=src python -m benchmarks.run [--only fig10] [--quick] \
      [--json-dir out/]

``--quick`` runs every registered benchmark at tiny shapes (modules whose
run() accepts a `quick` kwarg shrink their sweeps; the rest are already
cheap) — the CI bit-rot guard tests/test_benchmarks.py invokes it, so a
benchmark that stops importing or running fails tier-1.

``--json-dir`` additionally writes one machine-readable snapshot per
benchmark — ``BENCH_<label>.json`` with the rows, wall time, and run
metadata — so CI can archive results and runs can be diffed across
commits without parsing the CSV stream.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import os
import sys
import time

MODULES = [
    ("fig2_nonattention_roofline", "benchmarks.bench_nonattn_roofline"),
    ("fig3_attention_roofline", "benchmarks.bench_attn_roofline"),
    ("fig4_minimum_bandwidth", "benchmarks.bench_min_bandwidth"),
    ("fig10_serving_throughput", "benchmarks.bench_serving"),
    ("fig11_dop_sweep", "benchmarks.bench_dop_sweep"),
    ("fig12_latency_breakdown", "benchmarks.bench_latency_breakdown"),
    ("fig13_network_stack", "benchmarks.bench_network"),
    ("fig14_overlap_ablation", "benchmarks.bench_overlap"),
    ("sec43_pipelining", "benchmarks.bench_pipeline"),
    ("kernels_micro", "benchmarks.bench_kernels"),
    ("paged_attention", "benchmarks.bench_paged_attention"),
    ("block_sharded_attention", "benchmarks.bench_block_sharding"),
    ("prefix_sharing", "benchmarks.bench_prefix_sharing"),
    ("chunked_prefill", "benchmarks.bench_chunked_prefill"),
    ("fault_recovery", "benchmarks.bench_fault_recovery"),
    ("disagg_cluster", "benchmarks.bench_disagg_cluster"),
    ("sec7_extensions", "benchmarks.bench_extensions"),
]


def _write_snapshot(json_dir: str, label: str, rows, elapsed_s: float,
                    quick: bool) -> str:
    """One BENCH_<label>.json per benchmark: rows verbatim plus run
    metadata. Atomic-ish (write then rename) so a killed run never leaves
    a truncated snapshot behind."""
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"BENCH_{label}.json")
    doc = {
        "label": label,
        "generated_unix": time.time(),
        "quick": quick,
        "elapsed_s": round(elapsed_s, 3),
        "rows": rows,
    }
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes for every benchmark (CI bit-rot guard)")
    ap.add_argument("--json-dir", default="",
                    help="also write one BENCH_<label>.json snapshot per "
                         "benchmark into this directory")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    failures = 0
    for label, module_name in MODULES:
        if args.only and args.only not in label:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(module_name)
            kw = {}
            if args.quick and \
                    "quick" in inspect.signature(mod.run).parameters:
                kw["quick"] = True
            rows = mod.run(**kw)
            for r in rows:
                print(f"{r['name']},{r['us_per_call']},\"{r['derived']}\"")
            elapsed = time.time() - t0
            if args.json_dir:
                path = _write_snapshot(args.json_dir, label, rows, elapsed,
                                       args.quick)
                print(f"# {label}: snapshot {path}", file=sys.stderr)
            print(f"# {label}: {len(rows)} rows in {elapsed:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures += 1
            import traceback
            traceback.print_exc()
            print(f"# {label}: FAILED {e}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
