"""Benchmark harness entry point — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (deliverable d).

  PYTHONPATH=src python -m benchmarks.run [--only fig10]
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

MODULES = [
    ("fig2_nonattention_roofline", "benchmarks.bench_nonattn_roofline"),
    ("fig3_attention_roofline", "benchmarks.bench_attn_roofline"),
    ("fig4_minimum_bandwidth", "benchmarks.bench_min_bandwidth"),
    ("fig10_serving_throughput", "benchmarks.bench_serving"),
    ("fig11_dop_sweep", "benchmarks.bench_dop_sweep"),
    ("fig12_latency_breakdown", "benchmarks.bench_latency_breakdown"),
    ("fig13_network_stack", "benchmarks.bench_network"),
    ("fig14_overlap_ablation", "benchmarks.bench_overlap"),
    ("sec43_pipelining", "benchmarks.bench_pipeline"),
    ("kernels_micro", "benchmarks.bench_kernels"),
    ("paged_attention", "benchmarks.bench_paged_attention"),
    ("sec7_extensions", "benchmarks.bench_extensions"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for label, module_name in MODULES:
        if args.only and args.only not in label:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(module_name)
            rows = mod.run()
            for r in rows:
                print(f"{r['name']},{r['us_per_call']},\"{r['derived']}\"")
            print(f"# {label}: {len(rows)} rows in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures += 1
            import traceback
            traceback.print_exc()
            print(f"# {label}: FAILED {e}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
