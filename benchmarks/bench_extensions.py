"""Paper-§7 extension benchmarks: MoE expert offloading bandwidth, int8 KV
capacity effect on DOP sizing, sink-attention decode cost."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.configs import registry
from repro.core import costmodel as cm
from repro.serving.worker_pool import min_bandwidth_moe, transfer_bytes_moe


def run(quick: bool = False):
    rows = []
    h100, h20 = cm.HARDWARE["h100"], cm.HARDWARE["h20"]

    # --- MoE offload feasibility (paper §7) ---
    for arch in ("qwen3-moe-30b-a3b", "kimi-k2-1t-a32b"):
        cfg = registry.get_config(arch)
        for B in (32, 128, 512):
            bw = min_bandwidth_moe(cfg, B, 8192, h100, h20)
            rows.append({
                "name": f"ext_moe_offload_{arch}_B{B}",
                "us_per_call": 0,
                "derived": (f"min_gbs={bw/1e9:.2f};"
                            f"bytes_per_iter={transfer_bytes_moe(cfg, B)};"
                            f"under_400gbe={bw < 50e9}"),
            })

    # --- int8 KV: batch capacity per memory pool (drives Fig. 11 DOPs) ---
    for arch in ("llama3-70b", "gemma2-27b"):
        cfg = registry.get_config(arch)
        for bits in (16, 8):
            per_tok = cm.kv_bytes_per_token(cfg)
            if bits == 8:
                hd = cfg.resolved_head_dim
                per_tok = per_tok / 2 + 2 * 4 * cfg.num_layers * \
                    cfg.num_kv_heads
            b_max = int(4 * h20.mem_bytes * 0.9 / (per_tok * 8192))
            rows.append({
                "name": f"ext_int8kv_{arch}_bits{bits}",
                "us_per_call": 0,
                "derived": (f"kv_bytes_per_token={per_tok:.0f};"
                            f"max_batch_4xH20_8k={b_max}"),
            })

    # --- sinks: decode attended-token count at 524k context ---
    for name, window, sinks in (("full", 0, 0), ("sw8k", 8192, 0),
                                ("sinks", 8192, 4)):
        attended = 524288 if window == 0 else window + sinks
        rows.append({
            "name": f"ext_sinks_attended_{name}",
            "us_per_call": 0,
            "derived": f"attended_tokens={attended};"
                       f"kv_read_ratio={attended/524288:.4f}",
        })

    # measured: sink-attention decode kernel at CPU scale
    from repro.kernels import ops
    B, S, Hkv, G, hd = 2, 256 if quick else 2048, 2, 4, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, Hkv * G, hd))
    kc = jax.random.normal(key, (B, Hkv, S, hd))
    vc = jax.random.normal(key, (B, Hkv, S, hd))
    clen = jnp.full((B,), S, jnp.int32)
    t_full = time_call(ops.decode_attention, q, kc, vc, clen)
    t_sink = time_call(lambda: ops.decode_attention(
        q, kc, vc, clen, sliding_window=256))
    rows.append({"name": "ext_sinks_kernel_cpu",
                 "us_per_call": round(t_sink * 1e6, 1),
                 "derived": f"full_us={t_full*1e6:.1f}"})

    # --- speculative decoding (paper §8): measured acceptance on the
    # synthetic-corpus-trained smoke model ---
    from repro.serving.speculative import speculative_generate
    from repro.models import transformer
    tc = registry.get_smoke_config("tinyllama-1.1b")
    dc = registry.get_smoke_config("tinyllama-1.1b", num_layers=1,
                                   d_model=128, d_ff=256)
    tp = transformer.init_params(jax.random.PRNGKey(0), tc)
    dp = transformer.init_params(jax.random.PRNGKey(7), dc)
    # random-init draft = worst case (0 acceptance); draft==target = best
    # case (k+1 tokens per target call). Real deployments sit in between.
    draft_cases = (("oracle_draft", tp, tc),) if quick else (
        ("random_draft", dp, dc), ("oracle_draft", tp, tc))
    for label, d_par, d_cfg in draft_cases:
        _, st = speculative_generate(tp, tc, d_par, d_cfg, [1, 2, 3, 4],
                                     8 if quick else 16, k=4)
        rows.append({
            "name": f"ext_specdecode_{label}_k4",
            "us_per_call": 0,
            "derived": (f"acceptance={st.acceptance_rate:.2f};"
                        f"tokens_per_target_call="
                        f"{st.tokens_per_target_call:.2f};"
                        f"target_calls={st.target_calls}"),
        })
    return rows
