"""Paper §4.3 — rotational staggered pipelining: utilisation and throughput
multiplier vs number of concurrent batches (the schedule is exact, so this
is a direct computation on the validated schedule, plus kernel-level wall
time of the executable rotation demo)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import registry
from repro.core import converter, pipeline
from repro.models import blocks


def run():
    rows = []
    for n in (2, 3, 4, 6, 8):
        s = pipeline.rotational_schedule(n, 60)
        u = pipeline.utilisation(s)
        v = pipeline.validate(s)
        rows.append({
            "name": f"pipeline_n{n}",
            "us_per_call": 0,
            "derived": (f"attn_util={u['attn']:.3f};"
                        f"model0_util={u['model:0']:.3f};"
                        f"speedup={pipeline.throughput_speedup(n):.3f};"
                        f"valid={all(v.values())}"),
        })
    # executable demo wall time
    cfg = registry.get_smoke_config("llama3-8b")
    w = blocks.init_dense_block(jax.random.PRNGKey(0), cfg)
    progs, inputs = [], []
    for j in range(4):
        g = converter.build_block_graph(cfg, weights=w, batch=2)
        progs.append(converter.split_at_attention(g))
        inputs.append({"x": np.random.default_rng(j).standard_normal(
            (2, cfg.d_model)).astype(np.float32)})

    def attn_fn(j, name, env):
        vv = env["v_proj"]
        return np.repeat(vv, env["q_proj"].shape[1] // vv.shape[1], axis=1)

    t0 = time.perf_counter()
    pipeline.run_rotational(progs, inputs, attn_fn)
    dt = time.perf_counter() - t0
    rows.append({"name": "pipeline_exec_demo_4batches",
                 "us_per_call": round(dt * 1e6, 1),
                 "derived": "rotation_law_validated_in_tests=True"})
    return rows
