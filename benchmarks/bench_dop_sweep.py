"""Paper Fig. 11 — decoding throughput and hardware cost across DOPs for
Lamina and tensor-parallel sizes for vLLM; flags the best cost-efficiency
point per model (the paper's bolded configs)."""
from __future__ import annotations

from repro.configs import registry
from repro.core import costmodel as cm

MODELS = ["llama3-70b", "llama3-8b", "glm4-9b"]
DOPS = [(1, 1), (1, 2), (2, 2), (2, 4), (2, 6), (4, 4)]
TP = [1, 2, 4, 8]


def run():
    rows = []
    h100, h20 = cm.HARDWARE["h100"], cm.HARDWARE["h20"]
    for m in MODELS:
        cfg = registry.get_config(m)
        best = None
        for dop in DOPS:
            est = cm.estimate_lamina(cfg, 4096, h100, h20, dop)
            eff = est.tok_per_dollar
            if best is None or eff > best[1]:
                best = (f"lamina{dop}", eff)
            rows.append({
                "name": f"fig11_{m}_lamina_{dop[0]}x{dop[1]}",
                "us_per_call": round(est.tbt_s * 1e6),
                "derived": (f"tok_s={est.throughput_tok_s:.0f};"
                            f"cost_hr={est.cost_hr:.2f};"
                            f"tok_per_dollar={eff:.0f};B={est.batch}"),
            })
        for n in TP:
            if cm.param_count(cfg) * 2 > n * h100.mem_bytes * 0.9:
                continue  # does not fit
            est = cm.estimate_vllm(cfg, 4096, h100, n)
            if est.tok_per_dollar > best[1]:
                best = (f"vllm_tp{n}", est.tok_per_dollar)
            rows.append({
                "name": f"fig11_{m}_vllm_tp{n}",
                "us_per_call": round(est.tbt_s * 1e6),
                "derived": (f"tok_s={est.throughput_tok_s:.0f};"
                            f"cost_hr={est.cost_hr:.2f};"
                            f"tok_per_dollar={est.tok_per_dollar:.0f};"
                            f"B={est.batch}"),
            })
        rows.append({"name": f"fig11_{m}_best", "us_per_call": 0,
                     "derived": f"best={best[0]};tok_per_dollar={best[1]:.0f}"})
        # int8 quantized KV pool (§7 / kv_dtype="int8"): ~half the
        # per-token KV bytes -> ~2× the admitted batch at the same pool,
        # and ~half the per-iteration attention reads
        dop = (2, 4)
        f = cm.kv_quant_factor(cfg)
        base = cm.estimate_lamina(cfg, 4096, h100, h20, dop)
        est = cm.estimate_lamina(cfg, 4096, h100, h20, dop, kv_byte_factor=f)
        rows.append({
            "name": f"fig11_{m}_lamina_{dop[0]}x{dop[1]}_int8kv",
            "us_per_call": round(est.tbt_s * 1e6),
            "derived": (f"tok_s={est.throughput_tok_s:.0f};"
                        f"kv_byte_factor={f:.3f};"
                        f"B={est.batch};B_bf16={base.batch};"
                        f"batch_gain={est.batch/max(base.batch,1):.2f}x;"
                        f"tok_per_dollar={est.tok_per_dollar:.0f}"),
        })
    return rows
