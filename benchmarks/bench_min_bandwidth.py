"""Paper Fig. 4 — minimum interconnect bandwidth for attention offloading
(α = 0.2 latency headroom, H100 model workers + H20 attention workers)."""
from __future__ import annotations

from repro.configs import registry
from repro.core import costmodel as cm


def run():
    l70 = registry.get_config("llama3-70b")
    h100, h20 = cm.HARDWARE["h100"], cm.HARDWARE["h20"]
    rows = []
    for l in (2048, 4096, 8192):
        for B in (8, 32, 100, 200, 300):
            bw = cm.minimum_bandwidth(l70, B, l, h100, h20, alpha=0.2,
                                      dop=(1, 1))
            rows.append({
                "name": f"fig4_minbw_B{B}_l{l}",
                "us_per_call": 0,
                "derived": (f"min_gbs={bw/1e9:.2f};"
                            f"under_400gbe={bw < 50e9}"),
            })
    # paper claim: never above ~30 GB/s for B<=300
    worst = max(cm.minimum_bandwidth(l70, B, l, h100, h20, 0.2, (1, 1))
                for B in (8, 32, 100, 200, 300)
                for l in (2048, 4096, 8192))
    rows.append({"name": "fig4_claim_max_under_30gbs", "us_per_call": 0,
                 "derived": f"worst_gbs={worst/1e9:.2f};claim_ok={worst<30e9}"})
    return rows
