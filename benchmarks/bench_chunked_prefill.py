"""Chunked paged prefill: the memory and tail-latency win of
``EngineConfig(prefill_chunk_tokens=...)``.

A one-shot prefill materialises the whole prompt's KV as one dense
``(L, S, Hkv, hd)`` slab before scattering it into the pool, and runs the
entire prompt inside a single engine iteration — so a long prompt (a) caps
admission at free-slab memory and (b) head-of-line-blocks every running
decode for a full prefill's worth of wall clock. Chunking bounds both.

Three measurements (long prompt P, chunk C, a decode batch of K shorts):

  * ``slab``  — ``max_prefill_slab_tokens``: the largest dense KV slab one
    prefill call produced. One-shot: P; chunked: C — peak prefill memory is
    bounded by the CHUNK size, not the prompt (the pallas chunk kernel
    additionally streams the prefix context in place; the jnp reference
    gathers one layer's prefix at a time). Outputs verified bit-identical.
  * ``tbt``   — decode token-gap p99/max across the running shorts while
    the long prompt prefills mid-flight: unchunked, one iteration swallows
    the whole prefill and every short stalls for it; chunked, each
    iteration runs at most one C-token chunk alongside the decode batch.
  * ``admission`` — a TIGHT pool mostly held by running requests: chunked
    admission charges only the first chunk, so the long prompt is admitted
    steps earlier (completing incrementally as blocks free up) instead of
    waiting head-of-line for the whole allocation.
"""
from __future__ import annotations

import numpy as np

from repro.configs import registry
from repro.serving import EngineConfig, LLMEngine, Request, SamplingParams
from repro.serving.worker_pool import BYTES

BLOCK_SIZE = 16


def _slab_mib(cfg, tokens: int) -> float:
    return (2 * cfg.num_layers * cfg.num_kv_heads * cfg.resolved_head_dim *
            tokens * BYTES) / 2**20


def _shorts(cfg, k, prompt_len, new_tokens, seed=1):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=prompt_len).tolist(),
                    params=SamplingParams(max_new_tokens=new_tokens))
            for _ in range(k)]


def _long(cfg, prompt_len, new_tokens, seed=2):
    rng = np.random.default_rng(seed)
    return Request(prompt=rng.integers(0, cfg.vocab_size,
                                       size=prompt_len).tolist(),
                   params=SamplingParams(max_new_tokens=new_tokens))


def _decode_gaps(reqs) -> np.ndarray:
    """All wall-clock gaps between consecutive tokens of each request."""
    gaps = []
    for r in reqs:
        gaps.extend(b - a for a, b in zip(r.token_times, r.token_times[1:]))
    return np.asarray(gaps) if gaps else np.zeros((1,))


def _mixed_run(cfg, params, chunk, P, K, num_blocks, new_tokens):
    """K shorts decoding; the long prompt arrives mid-flight. Runs the
    workload twice — the first pass compiles every prefill/chunk/decode
    shape the measured pass will hit, so gaps are steady-state, not jit."""
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=K + 1, num_blocks=num_blocks, block_size=BLOCK_SIZE,
        prefill_chunk_tokens=chunk))
    for measured in (False, True):
        shorts = _shorts(cfg, K, 24, new_tokens)
        eng.submit(shorts)
        eng.step(); eng.step()
        long_req = _long(cfg, P, 4)
        eng.submit(long_req)
        eng.run()
        if measured:
            return eng, shorts, long_req


def run(quick: bool = False):
    import jax

    from repro.models import transformer

    rows = []
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    # the prompt must be long enough that its one-shot prefill dominates an
    # engine iteration (CPU decode steps carry ~10s-of-ms host overhead)
    P = 512 if quick else 2048
    C = 64
    K = 2 if quick else 4
    new_tokens = 8 if quick else 24

    # ---- slab + mixed-load decode gaps (roomy pool) ----
    res = {}
    for chunk in (None, C):
        eng, shorts, long_req = _mixed_run(cfg, params, chunk, P, K,
                                           num_blocks=256,
                                           new_tokens=new_tokens)
        gaps = _decode_gaps(shorts)
        res[chunk] = {
            "stats": eng.stats.summary(),
            "outputs": [r.output for r in shorts] + [long_req.output],
            "gap_p99_ms": float(np.percentile(gaps, 99) * 1e3),
            "gap_max_ms": float(gaps.max() * 1e3),
        }
    s_on, s_off = res[C], res[None]
    identical = s_on["outputs"] == s_off["outputs"]
    slab_on = s_on["stats"]["max_prefill_slab_tokens"]
    slab_off = s_off["stats"]["max_prefill_slab_tokens"]
    rows.append({
        "name": f"chunked_prefill_P{P}_C{C}",
        "us_per_call": round(s_on["gap_p99_ms"] * 1e3),
        "derived": (
            f"prompt_tokens={P};chunk_tokens={C};decode_batch={K};"
            f"slab_tokens_off={slab_off};slab_tokens_on={slab_on};"
            f"slab_mib_off={_slab_mib(cfg, slab_off):.3f};"
            f"slab_mib_on={_slab_mib(cfg, slab_on):.3f};"
            f"chunks_run={s_on['stats']['prefill_chunks_run']};"
            f"decode_gap_p99_ms_off={s_off['gap_p99_ms']:.1f};"
            f"decode_gap_p99_ms_on={s_on['gap_p99_ms']:.1f};"
            f"decode_gap_max_ms_off={s_off['gap_max_ms']:.1f};"
            f"decode_gap_max_ms_on={s_on['gap_max_ms']:.1f};"
            f"outputs_identical={identical}"),
    })

    # ---- admission into a tight pool (most blocks held by decoders) ----
    # the shorts retire a few steps after the long prompt arrives: one-shot
    # admission waits head-of-line for the WHOLE allocation to free up;
    # chunked admission charges only the first chunk and grows into blocks
    # as they are released (stalling a chunk when the decode batch needs
    # the free blocks first)
    P_adm = 192                        # admission is about blocks, not ms
    long_blocks = -(-P_adm // BLOCK_SIZE)
    tight = long_blocks + 4            # decoders leave < long_blocks free
    adm = {}
    for chunk in (None, C):
        eng = LLMEngine(cfg, params, EngineConfig(
            max_batch=K + 1, num_blocks=tight, block_size=BLOCK_SIZE,
            prefill_chunk_tokens=chunk))
        eng.submit(_shorts(cfg, K, 24, 6))
        eng.step(); eng.step()
        long_req = _long(cfg, P_adm, 4)
        free_at_submit = len(eng.kv.free)
        eng.submit(long_req)
        eng.run()
        steps = {e.kind: e.step for e in eng.event_log
                 if e.rid == long_req.rid}
        adm[chunk] = {"wait": steps["admit"] - steps["submit"],
                      "free": free_at_submit,
                      "done": len(long_req.output) == 4}
    rows.append({
        "name": f"chunked_admission_P{P_adm}_pool{tight}",
        "us_per_call": adm[C]["wait"],
        "derived": (
            f"prompt_blocks={long_blocks};pool_blocks={tight};"
            f"free_blocks_at_submit={adm[C]['free']};"
            f"admit_wait_steps_off={adm[None]['wait']};"
            f"admit_wait_steps_on={adm[C]['wait']};"
            f"completed_off={adm[None]['done']};"
            f"completed_on={adm[C]['done']}"),
    })
    return rows
