"""Disaggregated cluster: prefix-affinity routing vs random over K
prefill/decode replica pairs (serving/cluster/).

Scenario: G prefix families × m requests each (system prompts / few-shot
templates), two identical waves per routing policy — a cold wave that
populates each prefill engine's retained donors, then a measured warm
wave. Affinity routing concentrates each family on ONE replica, whose
retained donors serve the shared prefix from residency; random routing
(the baseline) scatters families across the fleet, so most followers
re-prefill their prefix. Observables, asserted not just printed:

  * ``prefill_tokens_skipped`` — affinity must beat random;
  * warm TTFT p50 — skipped prefix compute shows up as faster first
    tokens (asserted at full scale, reported at --quick CI scale where
    shared-runner timing noise would make the assert flaky);
  * parity — every cluster run's greedy outputs are bit-identical to a
    single-engine run of the same workload (the handoff is exact).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.serving import (DisaggConfig, EngineConfig, LLMEngine, Request,
                           SamplingParams)
from repro.serving.cluster import DisaggCluster
from repro.serving.stats import EngineStats

BLOCK_SIZE = 8


def _grouped(cfg, groups, per, prefix_tokens, suffix, new, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(groups):
        common = rng.integers(0, cfg.vocab_size, size=prefix_tokens).tolist()
        for _ in range(per):
            reqs.append(Request(
                prompt=common +
                rng.integers(0, cfg.vocab_size, size=suffix).tolist(),
                params=SamplingParams(max_new_tokens=new)))
    return reqs


def _warm_ttft_p50(cluster) -> float:
    """p50 TTFT of the measured wave, aggregated over the fleet (requests
    retire — and observe their TTFT — on their decode replica)."""
    agg = EngineStats()
    for r in cluster.registry:
        agg.request_ttfts.extend(r.decode.stats.request_ttfts)
    return agg.ttft_percentiles()["p50"]


def run(quick: bool = False):
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    K = 2 if quick else 4
    groups = 3 if quick else 4
    per = 3 if quick else 4
    prefix_tokens = 32 if quick else 96
    suffix, new = 8, 2 if quick else 4
    econf = EngineConfig(placement="attention_pool", partition="head",
                         attention_workers=2, max_batch=8, num_blocks=256,
                         block_size=BLOCK_SIZE, prefix_sharing=True)
    workload = dict(groups=groups, per=per, prefix_tokens=prefix_tokens,
                    suffix=suffix, new=new, seed=0)

    # single-engine parity reference for the measured wave's workload
    ref = _grouped(cfg, **workload)
    eng = LLMEngine(cfg, params, econf)
    eng.submit(ref)
    eng.run()
    ref_out = [r.output for r in ref]

    results = {}
    for policy in ("affinity", "random"):
        cluster = DisaggCluster(
            cfg, params, econf, replicas=K, routing=policy,
            disagg=DisaggConfig(transfer_blocks_per_step=4))
        # cold wave: compiles every shape and leaves retained donors
        cluster.submit(_grouped(cfg, **workload))
        cluster.run()
        for r in cluster.registry:
            r.prefill.stats = EngineStats()
            r.decode.stats = EngineStats()
        measured = cluster.submit(_grouped(cfg, **workload))
        cluster.run()
        if [r.output for r in measured] != ref_out:
            raise AssertionError(
                f"{policy} cluster outputs diverged from the single-engine "
                f"reference — the handoff must be bit-exact")
        results[policy] = (cluster.summary(), _warm_ttft_p50(cluster))

    s_aff, ttft_aff = results["affinity"]
    s_rand, ttft_rand = results["random"]
    if s_aff["prefill_tokens_skipped"] <= s_rand["prefill_tokens_skipped"]:
        raise AssertionError(
            f"affinity routing must skip more prefill than random: "
            f"{s_aff['prefill_tokens_skipped']} <= "
            f"{s_rand['prefill_tokens_skipped']}")
    if not quick and ttft_aff >= ttft_rand:
        raise AssertionError(
            f"warm TTFT p50 under affinity routing must beat random: "
            f"{ttft_aff:.4f}s >= {ttft_rand:.4f}s")

    # int8 quantized KV pool through the same cluster: scales ride the
    # handoff payloads with their blocks, so the wire bytes per handed-off
    # block drop to (hd+4)/(hd·e) of bf16 — asserted against the bf16
    # affinity run (identical workload => identical blocks transferred).
    econf8 = econf.replace(kv_dtype="int8")
    ref8 = _grouped(cfg, **workload)
    eng8 = LLMEngine(cfg, params, econf8)
    eng8.submit(ref8)
    eng8.run()
    cluster = DisaggCluster(cfg, params, econf8, replicas=K,
                            routing="affinity",
                            disagg=DisaggConfig(transfer_blocks_per_step=4))
    cluster.submit(_grouped(cfg, **workload))
    cluster.run()
    for r in cluster.registry:
        r.prefill.stats = EngineStats()
        r.decode.stats = EngineStats()
    measured8 = cluster.submit(_grouped(cfg, **workload))
    cluster.run()
    if [r.output for r in measured8] != [r.output for r in ref8]:
        raise AssertionError(
            "int8 cluster outputs diverged from the int8 single-engine "
            "reference — the quantized handoff must be exact (scales ride "
            "with their blocks)")
    s8, ttft8 = cluster.summary(), _warm_ttft_p50(cluster)
    s_aff_bytes = results["affinity"][0]["kv_bytes_transferred"]
    wire_ratio = s8["kv_bytes_transferred"] / max(s_aff_bytes, 1)
    if wire_ratio > 0.55:
        raise AssertionError(
            f"int8 handoff must at least ~halve kv_bytes_transferred: "
            f"ratio={wire_ratio:.3f} ({s8['kv_bytes_transferred']} vs "
            f"{s_aff_bytes} bf16)")

    rows = [{
        "name": f"disagg_cluster_K{K}_int8kv",
        "us_per_call": round(ttft8 * 1e6),
        "derived": (
            f"replicas={K};warm_ttft_p50_ms={ttft8 * 1e3:.1f};"
            f"handoffs_completed={s8['handoffs_completed']};"
            f"kv_bytes_transferred={s8['kv_bytes_transferred']};"
            f"bf16_kv_bytes_transferred={s_aff_bytes};"
            f"wire_ratio={wire_ratio:.3f};"
            f"prefill_tokens_skipped={s8['prefill_tokens_skipped']};"
            f"outputs_identical=True"),
    }]
    for policy, (s, ttft) in results.items():
        rows.append({
            "name": f"disagg_cluster_K{K}_{policy}",
            "us_per_call": round(ttft * 1e6),
            "derived": (
                f"replicas={K};groups={groups};per_group={per};"
                f"prefix_tokens={prefix_tokens};"
                f"warm_ttft_p50_ms={ttft * 1e3:.1f};"
                f"prefill_tokens_skipped={s['prefill_tokens_skipped']};"
                f"router_affinity_hits={s['router_affinity_hits']};"
                f"blocks_shared={s['blocks_shared']};"
                f"handoffs_completed={s['handoffs_completed']};"
                f"kv_bytes_transferred={s['kv_bytes_transferred']};"
                f"handoff_p50_ms={s['handoff_p50_s'] * 1e3:.2f};"
                f"handoff_p99_ms={s['handoff_p99_s'] * 1e3:.2f};"
                f"outputs_identical=True"),
        })
    return rows
