"""Paper Fig. 13 — GPU-to-GPU ping-pong RTT and bandwidth across network
stacks (FHBN vs NCCL vs Gloo), from the calibrated NetworkStack model, plus
the TPU-native comparison point (compiler-scheduled ICI collectives).

A real CPU-measured column times jax device-to-device copies as the
in-container stand-in for the wire (documented as illustrative only)."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import time_call
from repro.core import costmodel as cm

SIZES = [1 << 10, 1 << 14, 1 << 18, 1 << 22, 1 << 26, 1 << 30]


def run():
    rows = []
    for stack_name in ("fhbn", "nccl", "nccl_no_gdr", "gloo", "xla_ici"):
        stack = cm.NETWORK_STACKS[stack_name]
        for size in SIZES:
            rtt = cm.pingpong_rtt_us(stack, size)
            eff_gbs = 2 * size / (rtt * 1e-6) / 1e9
            rows.append({
                "name": f"fig13_{stack_name}_{size}",
                "us_per_call": round(rtt, 1),
                "derived": f"effective_gbs={eff_gbs:.2f}",
            })
    # headline claims
    f, n = cm.NETWORK_STACKS["fhbn"], cm.NETWORK_STACKS["nccl"]
    small = cm.pingpong_rtt_us(f, 1024) / cm.pingpong_rtt_us(n, 1024)
    rows.append({"name": "fig13_claim_small_rtt", "us_per_call":
                 round(cm.pingpong_rtt_us(f, 1024), 1),
                 "derived": f"fhbn_vs_nccl={small:.2f};claim_~0.5={small<0.55}"})
    rows.append({"name": "fig13_claim_line_rate", "us_per_call": 0,
                 "derived": f"fhbn_peak_frac={f.peak_gbs/50.0:.3f}"})

    # CPU stand-in: on-host copy timing (illustrative)
    x = jnp.ones((1 << 20,), jnp.uint8)
    t = time_call(lambda a: a + 1, x)
    rows.append({"name": "fig13_cpu_standin_1MiB", "us_per_call":
                 round(t * 1e6, 1), "derived": "illustrative_only=True"})
    return rows
