"""Paged vs dense-gather decode attention: wall time at CPU scale plus the
analytic per-step KV bytes each path moves (the quantity that matters on
TPU — the paper's §3 point is that decode attention is memory-bound, so the
per-step traffic IS the speed).

Dense-gather path (the old engine hot path): every iteration copies the
paged pool into a dense padded slab (pool read + slab write), transposes it
to head-major (read + write) and streams it through the kernel (read) —
five passes over 2·L·B·pad·Hkv·hd·e bytes. Paged path: the kernel walks the
block pool in place through the table — one read of the allocated live
blocks plus one token write. The sweep reports both byte counts and the
reduction factor (acceptance: ≥2×).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.configs import registry
from repro.models.attention import (decode_attention_partial_jnp,
                                    paged_decode_attention_partial_jnp)
from repro.serving.kvcache import PagedKVCache

E = 2  # bf16/fp16 wire/storage bytes per element (paper Table 2 "e")


def _dense_gather_step(kv, ids, pad, q):
    k, v, lens = kv.gather(ids, pad)                  # pool -> dense slab
    kh = jnp.swapaxes(k, 2, 3)                        # -> head-major
    vh = jnp.swapaxes(v, 2, 3)
    return decode_attention_partial_jnp(q, kh[0], vh[0], lens).a


def _paged_step(kv, ids, q):
    tables, lens = kv.block_table_batch(ids)
    skw = {} if kv.k_scale is None else dict(k_scale=kv.k_scale[0],
                                             v_scale=kv.v_scale[0])
    return paged_decode_attention_partial_jnp(
        q, kv.k_pool[0], kv.v_pool[0], jnp.asarray(tables),
        jnp.asarray(lens), **skw).a


def run(quick: bool = False):
    rows = []
    cfg = registry.get_smoke_config("llama3-8b")
    Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    H = cfg.num_heads
    L = cfg.num_layers
    rng = np.random.default_rng(0)
    for B, S in [(2, 64)] if quick else [(2, 64), (4, 128), (8, 256)]:
        bs = 16
        kv = PagedKVCache(cfg, num_blocks=B * (S // bs) + 8, block_size=bs)
        lens = [int(x) for x in
                rng.integers(max(1, S // 4), S + 1, size=B)]
        lens[0] = S  # the padded slab is sized by the longest sequence
        for sid, n in enumerate(lens):
            kv.allocate(sid, n)
            kv.write_prefill(
                sid,
                jnp.asarray(rng.standard_normal((L, Hkv, n, hd)), cfg.dtype),
                jnp.asarray(rng.standard_normal((L, Hkv, n, hd)), cfg.dtype))
        ids = list(range(B))
        q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
        pad = -(-S // bs) * bs

        t_dense = time_call(lambda: _dense_gather_step(kv, ids, pad, q))
        t_paged = time_call(lambda: _paged_step(kv, ids, q))

        # analytic per-step KV bytes, full L-layer step, k+v
        slab = 2 * L * B * pad * Hkv * hd * E
        dense_bytes = 5 * slab          # gather r+w, transpose r+w, kernel r
        live = 2 * L * sum(-(-n // bs) * bs for n in lens) * Hkv * hd * E
        token_w = 2 * L * B * Hkv * hd * E
        paged_bytes = live + token_w    # kernel read of live blocks + write
        ratio = dense_bytes / paged_bytes
        rows.append({
            "name": f"paged_attn_B{B}_S{S}",
            "us_per_call": round(t_paged * 1e6, 1),
            "derived": (f"dense_us={t_dense*1e6:.0f};"
                        f"dense_step_kv_mib={dense_bytes/2**20:.2f};"
                        f"paged_step_kv_mib={paged_bytes/2**20:.2f};"
                        f"bytes_reduction={ratio:.1f}x")})

        # int8 quantized pool over the same paged walk: the kernel streams
        # 1-byte values + one fp32 scale per token-head and dequantizes in
        # the score/PV products — per-step bytes drop to (hd+4)/(hd·E) of
        # the bf16 paged path (asserted ≥ ~2×)
        kv8 = PagedKVCache(cfg, num_blocks=B * (S // bs) + 8, block_size=bs,
                           kv_dtype="int8")
        rng8 = np.random.default_rng(0)
        for sid, n in enumerate(lens):
            kv8.allocate(sid, n)
            kv8.write_prefill(
                sid,
                jnp.asarray(rng8.standard_normal((L, Hkv, n, hd)),
                            cfg.dtype),
                jnp.asarray(rng8.standard_normal((L, Hkv, n, hd)),
                            cfg.dtype))
        t_int8 = time_call(lambda: _paged_step(kv8, ids, q))
        alloc = sum(-(-n // bs) * bs for n in lens)
        int8_bytes = 2 * L * alloc * Hkv * (hd + 4) + \
            2 * L * B * Hkv * (hd + 4)
        q_ratio = paged_bytes / int8_bytes
        if q_ratio < 1.8:
            raise AssertionError(
                f"int8 pool must cut per-step paged KV bytes ~2×: got "
                f"{q_ratio:.2f}x ({int8_bytes} vs {paged_bytes})")
        rows.append({
            "name": f"paged_attn_int8_B{B}_S{S}",
            "us_per_call": round(t_int8 * 1e6, 1),
            "derived": (f"bf16_paged_us={t_paged*1e6:.0f};"
                        f"int8_step_kv_mib={int8_bytes/2**20:.2f};"
                        f"paged_step_kv_mib={paged_bytes/2**20:.2f};"
                        f"int8_reduction={q_ratio:.2f}x")})
    return rows
