"""Paper Fig. 14 — resource-utilisation overlapping ablation (§4.2.2).

The overlap hides (a) the KV-projection + send-KV time behind the prev-token
attention and (b) part of the network time behind compute. The latency model
prices both; the GQA effect the paper reports (LLaMA-65B 13.2% vs LLaMA3-70B
3.5% — 8× smaller KV leaves less to hide) falls out of the G term.

The `exactness` rows execute the repo's real overlapped attention
(combine(prev, new)) vs single-shot attention and report the max deviation —
the correctness side of the ablation."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import costmodel as cm
from repro.models.common import ModelConfig

# LLaMA-65B (paper Table 3: MHA, G=1)
LLAMA65 = ModelConfig(name="llama-65b", family="dense", num_layers=80,
                      d_model=8192, num_heads=64, num_kv_heads=64,
                      head_dim=128, d_ff=22016, vocab_size=32000,
                      source="paper Table 3")


def _overlap_gain(cfg, B, l, dop):
    h100, h20 = cm.HARDWARE["h100"], cm.HARDWARE["h20"]
    fhbn = cm.NETWORK_STACKS["fhbn"]
    t_m = cm.mtime(cfg, B, h100, dop[0])
    t_a = cm.atime(cfg, B, l, h20, dop[1])
    # hideable share: the kv fraction of the per-layer transfer plus the
    # prev-attention compute that proceeds while kv is in flight
    G = cfg.gqa_group
    kv_frac = (2.0 / G) / (2.0 + 2.0 / G)
    t_net = cm.network_time_per_iteration(cfg, B, fhbn, 0.0)
    tbt_off = t_m + t_a + t_net
    hidden = kv_frac * t_net + min(t_a * kv_frac, 0.2 * t_a)
    tbt_on = tbt_off - hidden
    return tbt_off, tbt_on, 1.0 - tbt_on / tbt_off


def run():
    rows = []
    for cfg, dop in ((LLAMA65, (2, 2)),
                     (registry.get_config("llama3-70b"), (2, 4))):
        for B in (32, 128, 256, 512):
            off, on, gain = _overlap_gain(cfg, B, 4096, dop)
            rows.append({
                "name": f"fig14_{cfg.name}_B{B}",
                "us_per_call": round(on * 1e6),
                "derived": (f"tbt_off_ms={off*1e3:.2f};"
                            f"tbt_on_ms={on*1e3:.2f};gain={gain:.3f};"
                            f"G={cfg.gqa_group}"),
            })
    # claim: MHA model gains substantially more than GQA model
    g65 = _overlap_gain(LLAMA65, 512, 4096, (2, 2))[2]
    g70 = _overlap_gain(registry.get_config("llama3-70b"), 512, 4096,
                        (2, 4))[2]
    rows.append({"name": "fig14_claim_gqa_effect", "us_per_call": 0,
                 "derived": f"gain65={g65:.3f};gain70={g70:.3f};"
                            f"ratio={g65/max(g70,1e-9):.1f}"})

    # exactness of the overlapped (split) attention vs single-shot
    from repro.core import combine as C
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (4, 2, 4, 64))
    k = jax.random.normal(rng, (4, 2, 4, 33, 64))
    v = jax.random.normal(rng, (4, 2, 4, 33, 64))
    p_prev = C.partial_attention(q, k[..., :-1, :], v[..., :-1, :])
    p_new = C.partial_attention(q, k[..., -1:, :], v[..., -1:, :])
    split = C.finalize(C.combine(p_prev, p_new))
    full = C.finalize(C.partial_attention(q, k, v))
    err = float(jnp.max(jnp.abs(split - full)))
    rows.append({"name": "fig14_overlap_exactness", "us_per_call": 0,
                 "derived": f"max_err={err:.2e};bit_exact_math=True"})
    return rows
