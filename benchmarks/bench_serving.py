"""Paper Fig. 10 / Tables 4-5 — serving throughput, TBT, and mean batch for
Lamina vs vLLM on the four production traces at equal hardware cost.

Two layers of evidence:
  * `model`: the calibrated analytical estimator (costmodel) at the paper's
    real scales — equal-cost configs from Table 5, trace means from Table 4;
  * `measured`: the unified LLMEngine (this repo) under both placements —
    ``homogeneous`` (vLLM baseline) vs ``attention_pool`` (Lamina) —
    running the scaled traces on CPU with a reduced model: identical
    scheduling, identical tokens, different operator placement. Latency
    percentiles come from ``EngineStats.summary()``.
"""
from __future__ import annotations

import jax

from repro.configs import registry
from repro.core import costmodel as cm
from repro.data import traces
from repro.models import transformer
from repro.serving import EngineConfig, LLMEngine

# paper Table 5 equal-cost configs
CONFIGS = {
    "llama3-70b": {"dop": (2, 4), "vllm_gpus": 4},
}


def run(quick: bool = False):
    rows = []
    h100, h20 = cm.HARDWARE["h100"], cm.HARDWARE["h20"]
    for model_name, hw in CONFIGS.items():
        mcfg = registry.get_config(model_name)
        for trace_name, spec in traces.TRACES.items():
            seq = spec.mean_prompt + spec.mean_gen / 2
            v = cm.estimate_vllm(mcfg, seq, h100, hw["vllm_gpus"])
            l = cm.estimate_lamina(mcfg, seq, h100, h20, hw["dop"])
            gain = l.throughput_tok_s / v.throughput_tok_s - 1
            rows.append({
                "name": f"fig10_model_{model_name}_{trace_name}",
                "us_per_call": round(l.tbt_s * 1e6),
                "derived": (
                    f"vllm_tok_s={v.throughput_tok_s:.0f};"
                    f"lamina_tok_s={l.throughput_tok_s:.0f};"
                    f"gain={gain:.2%};batch_ratio={l.batch/max(v.batch,1):.2f};"
                    f"vllm_B={v.batch};lamina_B={l.batch};"
                    f"lamina_tbt_ms={l.tbt_s*1e3:.1f};"
                    f"vllm_tbt_ms={v.tbt_s*1e3:.1f}"),
            })

    # measured CPU-scale engines on one trace: the unified LLMEngine under
    # both placements (homogeneous = vLLM baseline, attention_pool = Lamina)
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    n_reqs = 3 if quick else 12
    for trace_name in ("azure-conv",) if quick else ("azure-conv",
                                                     "azure-code"):
        res, outs = {}, {}
        for engine_name, placement in (("vllm", "homogeneous"),
                                       ("lamina", "attention_pool")):
            reqs = traces.generate(trace_name, n_reqs, cfg.vocab_size,
                                   scale=0.01, seed=0)
            eng = LLMEngine(cfg, params, EngineConfig(
                placement=placement, max_batch=8, num_blocks=256))
            eng.submit(reqs)
            res[engine_name] = eng.run().summary()
            outs[engine_name] = [r.output for r in reqs]
        lam = res["lamina"]
        rows.append({
            "name": f"fig10_measured_{trace_name}",
            "us_per_call": round(lam["mean_tbt_s"] * 1e6),
            "derived": (
                f"vllm_tok_s={res['vllm']['throughput_tok_s']:.1f};"
                f"lamina_tok_s={lam['throughput_tok_s']:.1f};"
                f"vllm_batch={res['vllm']['mean_batch']:.2f};"
                f"lamina_batch={lam['mean_batch']:.2f};"
                f"lamina_ttft_p50_ms={lam['ttft_p50_s']*1e3:.1f};"
                f"lamina_ttft_p90_ms={lam['ttft_p90_s']*1e3:.1f};"
                f"lamina_tbt_p50_ms={lam['tbt_p50_s']*1e3:.1f};"
                f"lamina_tbt_p90_ms={lam['tbt_p90_s']*1e3:.1f};"
                f"blocks_shared={lam['blocks_shared']};"
                f"prefill_tokens_skipped={lam['prefill_tokens_skipped']};"
                f"prefill_chunks_run={lam['prefill_chunks_run']};"
                f"max_prefill_slab_tokens={lam['max_prefill_slab_tokens']};"
                f"outputs_identical={outs['vllm'] == outs['lamina']}"),
        })

        # the same trace under the int8 quantized KV pool: identical
        # scheduling/tokens, pool stored int8 + fp32 scale sidecars with
        # dequant fused into the attention kernels. Resident pool bytes
        # AND per-step KV read bytes must drop ~2× or better (exact
        # factor: (hd + 4) / (hd·e) per token-head) — asserted, not just
        # printed.
        reqs = traces.generate(trace_name, n_reqs, cfg.vocab_size,
                               scale=0.01, seed=0)
        eng = LLMEngine(cfg, params, EngineConfig(
            placement="attention_pool", max_batch=8, num_blocks=256,
            kv_dtype="int8"))
        eng.submit(reqs)
        s8 = eng.run().summary()
        res_ratio = (s8["kv_pool_bytes_resident"] /
                     lam["kv_pool_bytes_resident"])
        read_ratio = (s8["kv_bytes_read_per_step"] /
                      max(lam["kv_bytes_read_per_step"], 1e-9))
        if res_ratio > 0.55 or read_ratio > 0.55:
            raise AssertionError(
                f"int8 KV pool must at least ~halve resident and per-step "
                f"read bytes: resident_ratio={res_ratio:.3f}, "
                f"read_ratio={read_ratio:.3f}")
        match_bf16 = [r.output for r in reqs] == outs["lamina"]
        rows.append({
            "name": f"fig10_measured_int8kv_{trace_name}",
            "us_per_call": round(s8["mean_tbt_s"] * 1e6),
            "derived": (
                f"tok_s={s8['throughput_tok_s']:.1f};"
                f"kv_resident_mib={s8['kv_pool_bytes_resident']/2**20:.2f};"
                f"bf16_resident_mib="
                f"{lam['kv_pool_bytes_resident']/2**20:.2f};"
                f"resident_ratio={res_ratio:.3f};"
                f"read_bytes_per_step={s8['kv_bytes_read_per_step']:.0f};"
                f"read_ratio={read_ratio:.3f};"
                f"outputs_match_bf16={match_bf16}"),
        })

        # the same trace through the disaggregated prefill/decode split
        # (serving/cluster/): 2 replica pairs behind the affinity router,
        # KV handed off block-granularly — transfer volume, handoff
        # latency, and routing hits are the new observables
        from repro.serving.cluster import DisaggCluster
        reqs = traces.generate(trace_name, n_reqs, cfg.vocab_size,
                               scale=0.01, seed=0)
        cluster = DisaggCluster(cfg, params, EngineConfig(
            placement="attention_pool", max_batch=8, num_blocks=256),
            replicas=2)
        cluster.submit(reqs)
        cluster.run()
        s = cluster.summary()
        rows.append({
            "name": f"fig10_measured_disagg_{trace_name}",
            "us_per_call": round(s["handoff_p50_s"] * 1e6),
            "derived": (
                f"replicas={s['replicas']};routing={s['routing']};"
                f"kv_bytes_transferred={s['kv_bytes_transferred']};"
                f"handoffs_completed={s['handoffs_completed']};"
                f"handoff_p50_ms={s['handoff_p50_s'] * 1e3:.2f};"
                f"handoff_p90_ms={s['handoff_p90_s'] * 1e3:.2f};"
                f"router_affinity_hits={s['router_affinity_hits']};"
                f"handoff_retries={s['handoff_retries']};"
                f"outputs_identical="
                f"{[r.output for r in reqs] == outs['lamina']}"),
        })
    return rows
