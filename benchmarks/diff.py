"""Diff two benchmark snapshot sets (the ``BENCH_<label>.json`` files
``benchmarks.run --json-dir`` writes): flag per-row timing regressions.

  PYTHONPATH=src python -m benchmarks.diff BASELINE_DIR CURRENT_DIR \
      [--threshold 0.15] [--fail-on-regression] [--only fig10] \
      [--require fig10_measured_int8kv_azure-conv ...]

Rows are matched (label, name); a row whose ``us_per_call`` grew by more
than ``--threshold`` (default 15%) over the baseline is a REGRESSION,
one that shrank by more is an improvement, the band between is noise.
Rows with a zero/absent baseline timing (derived-only measurements) are
compared for presence only. Added and removed rows/labels are reported
informationally — coverage changes are a review surface, not a failure.

``--require NAME`` (repeatable) asserts that a row named NAME exists in
the CURRENT snapshots — exit 1 when any required row is missing,
regardless of ``--fail-on-regression``. CI uses it to pin
coverage-critical rows (e.g. the int8 KV-pool measurements) so a
benchmark silently dropping them cannot pass as "0 regressions".

Exit status: 0, or 1 with ``--fail-on-regression`` when any regression
was flagged (CI wires this against the committed ``benchmarks/baseline``
snapshots, non-blocking — runner timing variance is real) or when a
``--require`` row is absent (always blocking).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, Tuple


def load_snapshots(dirname: str) -> Dict[str, dict]:
    """label -> snapshot doc for every BENCH_*.json in `dirname`."""
    docs = {}
    for path in sorted(glob.glob(os.path.join(dirname, "BENCH_*.json"))):
        with open(path) as f:
            doc = json.load(f)
        docs[doc.get("label", os.path.basename(path))] = doc
    if not docs:
        raise FileNotFoundError(f"no BENCH_*.json snapshots in {dirname!r}")
    return docs


def _rows(doc: dict) -> Dict[str, dict]:
    return {r["name"]: r for r in doc.get("rows", [])}


def diff_rows(base: Dict[str, dict], cur: Dict[str, dict],
              threshold: float, only: str = ""
              ) -> Tuple[list, list, list, list]:
    """Returns (regressions, improvements, added, removed); each entry is
    (label, name, base_us, cur_us, rel_delta)."""
    regressions, improvements, added, removed = [], [], [], []
    labels = sorted(set(base) | set(cur))
    for label in labels:
        if only and only not in label:
            continue
        brows = _rows(base[label]) if label in base else {}
        crows = _rows(cur[label]) if label in cur else {}
        for name in sorted(set(brows) | set(crows)):
            if name not in brows:
                added.append((label, name))
                continue
            if name not in crows:
                removed.append((label, name))
                continue
            b = float(brows[name].get("us_per_call") or 0)
            c = float(crows[name].get("us_per_call") or 0)
            if b <= 0:
                continue        # derived-only row: presence already checked
            rel = c / b - 1.0
            entry = (label, name, b, c, rel)
            if rel > threshold:
                regressions.append(entry)
            elif rel < -threshold:
                improvements.append(entry)
    return regressions, improvements, added, removed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_*.json snapshot directories")
    ap.add_argument("baseline", help="directory of baseline snapshots")
    ap.add_argument("current", help="directory of current snapshots")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="relative us_per_call growth that counts as a "
                         "regression (default 0.15 = +15%%)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 when any regression is flagged")
    ap.add_argument("--only", default="",
                    help="restrict to labels containing this substring")
    ap.add_argument("--require", action="append", default=[],
                    metavar="NAME",
                    help="row name that MUST exist in the current "
                         "snapshots (repeatable); exit 1 if missing")
    args = ap.parse_args(argv)

    base = load_snapshots(args.baseline)
    cur = load_snapshots(args.current)
    regressions, improvements, added, removed = diff_rows(
        base, cur, args.threshold, args.only)

    present = {name for doc in cur.values() for name in _rows(doc)}
    missing = [name for name in args.require if name not in present]

    print("status,label,name,base_us,cur_us,delta")
    for tag, entries in (("REGRESSION", regressions),
                         ("improvement", improvements)):
        for label, name, b, c, rel in entries:
            print(f"{tag},{label},{name},{b:.0f},{c:.0f},{rel:+.1%}")
    for label, name in added:
        print(f"added,{label},{name},,,")
    for label, name in removed:
        print(f"removed,{label},{name},,,")
    for name in missing:
        print(f"MISSING_REQUIRED,,{name},,,")
    print(f"# {len(regressions)} regression(s) over "
          f"{args.threshold:.0%}, {len(improvements)} improvement(s), "
          f"{len(added)} added, {len(removed)} removed, "
          f"{len(missing)} required row(s) missing", file=sys.stderr)
    if missing:
        return 1
    if regressions and args.fail_on_regression:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
