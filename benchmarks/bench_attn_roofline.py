"""Paper Fig. 3 — attention operator latency + MBU vs batch/seq/hardware.

Model columns use MTIME/ATIME (paper §2.2.2) for H100 vs H20; the measured
column times the repo's real decode-attention kernel (interpret mode) at
CPU scale, confirming latency ∝ B·l (bandwidth-bound, batching doesn't help
arithmetic intensity)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.configs import registry
from repro.core import costmodel as cm
from repro.kernels import ops

POINTS = [(4, 2048), (16, 2048), (64, 2048), (16, 8192), (64, 8192),
          (128, 8192)]


def run():
    l70 = registry.get_config("llama3-70b")
    rows = []
    key = jax.random.PRNGKey(0)
    Hkv, G, hd = 2, 4, 64
    for B, l in POINTS:
        t_h100 = cm.atime(l70, B, l, cm.HARDWARE["h100"], efficiency=1.0)
        t_h20 = cm.atime(l70, B, l, cm.HARDWARE["h20"], efficiency=1.0)
        mbu = cm.mbu_attention(l70, B, l, cm.HARDWARE["h20"])
        # measured: reduced shapes, scaled sequence
        Bs, ls = min(B, 8), min(l, 512)
        q = jax.random.normal(key, (Bs, Hkv * G, hd))
        kc = jax.random.normal(key, (Bs, Hkv, ls, hd))
        vc = jax.random.normal(key, (Bs, Hkv, ls, hd))
        clen = jnp.full((Bs,), ls, jnp.int32)
        t_meas = time_call(ops.decode_attention, q, kc, vc, clen)
        rows.append({
            "name": f"fig3_attn_B{B}_l{l}",
            "us_per_call": round(t_meas * 1e6, 1),
            "derived": (f"h100_ms={t_h100*1e3:.2f};h20_ms={t_h20*1e3:.2f};"
                        f"mbu={mbu:.3f}"),
        })
    return rows
