"""Kernel-level microbenchmarks: Pallas (interpret) vs jnp-oracle wall time
at CPU scale + the analytic VMEM working set per BlockSpec tile (the
quantity that matters on real TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.kernels import ref


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    # decode attention
    B, S, Hkv, G, hd, block_k = 4, 1024, 2, 4, 128, 512
    q = jax.random.normal(key, (B, Hkv * G, hd), jnp.float32)
    kc = jax.random.normal(key, (B, Hkv, S, hd), jnp.float32)
    vc = jax.random.normal(key, (B, Hkv, S, hd), jnp.float32)
    clen = jnp.full((B,), S, jnp.int32)
    t_ref = time_call(
        lambda: ref.decode_attention_ref(q.reshape(B, Hkv, G, hd), kc, vc,
                                         clen))
    vmem_kib = (2 * block_k * hd * 2 + G * hd * 4 + 2 * G * 128 * 4) / 1024
    rows.append({"name": "kernel_decode_attn_ref",
                 "us_per_call": round(t_ref * 1e6, 1),
                 "derived": f"S={S};vmem_per_tile_kib={vmem_kib:.0f}"})
    # rwkv6
    Bs, Ss, H, P = 2, 256, 4, 64
    r = jax.random.normal(key, (Bs, Ss, H, P)) * 0.5
    k2 = jax.random.normal(key, (Bs, Ss, H, P)) * 0.5
    v2 = jax.random.normal(key, (Bs, Ss, H, P)) * 0.5
    w2 = jax.nn.sigmoid(jax.random.normal(key, (Bs, Ss, H, P))) * 0.5 + 0.5
    u2 = jax.random.normal(key, (H, P)) * 0.3
    t_ref = time_call(lambda: ref.rwkv6_scan_ref(r, k2, v2, w2, u2))
    rows.append({"name": "kernel_rwkv6_ref",
                 "us_per_call": round(t_ref * 1e6, 1),
                 "derived": f"state_vmem_kib={P*P*4/1024:.0f}"})
    # ssm
    N = 64
    x = jax.random.normal(key, (Bs, Ss, H, P)) * 0.5
    Bi = jax.random.normal(key, (Bs, Ss, N)) * 0.5
    Ci = jax.random.normal(key, (Bs, Ss, N)) * 0.5
    a = jax.nn.sigmoid(jax.random.normal(key, (Bs, Ss, H))) * 0.5 + 0.4
    t_ref = time_call(lambda: ref.ssm_scan_ref(x, None, Bi, Ci, a))
    rows.append({"name": "kernel_ssm_ref",
                 "us_per_call": round(t_ref * 1e6, 1),
                 "derived": f"state_vmem_kib={H*P*N*4/1024:.0f}"})
    return rows
