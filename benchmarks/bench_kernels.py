"""Kernel-level microbenchmarks: Pallas (interpret) vs jnp-oracle wall time
at CPU scale + the analytic VMEM working set per BlockSpec tile (the
quantity that matters on real TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.kernels import ref


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    # decode attention
    B, S, Hkv, G, hd, block_k = 4, 1024, 2, 4, 128, 512
    q = jax.random.normal(key, (B, Hkv * G, hd), jnp.float32)
    kc = jax.random.normal(key, (B, Hkv, S, hd), jnp.float32)
    vc = jax.random.normal(key, (B, Hkv, S, hd), jnp.float32)
    clen = jnp.full((B,), S, jnp.int32)
    t_ref = time_call(
        lambda: ref.decode_attention_ref(q.reshape(B, Hkv, G, hd), kc, vc,
                                         clen))
    vmem_kib = (2 * block_k * hd * 2 + G * hd * 4 + 2 * G * 128 * 4) / 1024
    rows.append({"name": "kernel_decode_attn_ref",
                 "us_per_call": round(t_ref * 1e6, 1),
                 "derived": f"S={S};vmem_per_tile_kib={vmem_kib:.0f}"})
    # int8 paged decode with fused dequant: the kernel streams 1-byte K/V
    # tiles + one fp32 scale per token-head and folds the scales into the
    # score/PV products — per-tile VMEM drops to ~half the bf16 tile
    from repro.models import kv_quant
    bs_blk = 128
    nb = S // bs_blk
    kq, ks = kv_quant.quantize_kv(kc)
    vq, vs = kv_quant.quantize_kv(vc)
    k_pool = jnp.swapaxes(kq, 0, 1).reshape(Hkv, B * nb, bs_blk, hd)
    v_pool = jnp.swapaxes(vq, 0, 1).reshape(Hkv, B * nb, bs_blk, hd)
    ks_pool = jnp.swapaxes(ks, 0, 1).reshape(Hkv, B * nb, bs_blk)
    vs_pool = jnp.swapaxes(vs, 0, 1).reshape(Hkv, B * nb, bs_blk)
    bt = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    t_int8 = time_call(
        lambda: ref.paged_decode_attention_int8_ref(
            q.reshape(B, Hkv, G, hd), k_pool, v_pool, ks_pool, vs_pool,
            bt, clen))
    vmem8_kib = (2 * bs_blk * hd * 1 + 2 * bs_blk * 4 + G * hd * 4 +
                 2 * G * 128 * 4) / 1024
    vmem16_kib = (2 * bs_blk * hd * 2 + G * hd * 4 + 2 * G * 128 * 4) / 1024
    rows.append({"name": "kernel_decode_attn_int8_ref",
                 "us_per_call": round(t_int8 * 1e6, 1),
                 "derived": (f"S={S};block={bs_blk};"
                             f"vmem_per_tile_kib={vmem8_kib:.0f};"
                             f"bf16_tile_kib={vmem16_kib:.0f}")})
    # rwkv6
    Bs, Ss, H, P = 2, 256, 4, 64
    r = jax.random.normal(key, (Bs, Ss, H, P)) * 0.5
    k2 = jax.random.normal(key, (Bs, Ss, H, P)) * 0.5
    v2 = jax.random.normal(key, (Bs, Ss, H, P)) * 0.5
    w2 = jax.nn.sigmoid(jax.random.normal(key, (Bs, Ss, H, P))) * 0.5 + 0.5
    u2 = jax.random.normal(key, (H, P)) * 0.3
    t_ref = time_call(lambda: ref.rwkv6_scan_ref(r, k2, v2, w2, u2))
    rows.append({"name": "kernel_rwkv6_ref",
                 "us_per_call": round(t_ref * 1e6, 1),
                 "derived": f"state_vmem_kib={P*P*4/1024:.0f}"})
    # ssm
    N = 64
    x = jax.random.normal(key, (Bs, Ss, H, P)) * 0.5
    Bi = jax.random.normal(key, (Bs, Ss, N)) * 0.5
    Ci = jax.random.normal(key, (Bs, Ss, N)) * 0.5
    a = jax.nn.sigmoid(jax.random.normal(key, (Bs, Ss, H))) * 0.5 + 0.4
    t_ref = time_call(lambda: ref.ssm_scan_ref(x, None, Bi, Ci, a))
    rows.append({"name": "kernel_ssm_ref",
                 "us_per_call": round(t_ref * 1e6, 1),
                 "derived": f"state_vmem_kib={H*P*N*4/1024:.0f}"})
    return rows
