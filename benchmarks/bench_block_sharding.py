"""Cross-chip KV partitioning on ONE long request (the `long_500k` shape,
CPU-scaled): per-chip KV-byte balance and decode-step latency for the three
paged pool partitions — block vs head vs request.

The scenario the block partition exists for: a single sequence whose KV
exceeds one memory device. Request-level puts the whole sequence on one
worker (B = 1 — the paper's load-imbalance pathology at its worst);
head-level divides bytes evenly but caps the parallelism at Hkv and leaves
every worker walking the FULL sequence length; block-level round-robins the
sequence's pool blocks across workers, so each chip reads ~1/n of the live
KV (within one block of even — `PagedKVCache.block_table_shards`) and the
§4.2.2 psum-combine merges exactly. Reported per-chip bytes are live-token
KV reads for one full L-layer decode step; latency is the CPU-scale
attend_paged wall time (one layer), shape-comparable across partitions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.configs import registry
from repro.models import transformer
from repro.serving import EngineConfig, LLMEngine
from repro.serving.worker_pool import BYTES
from repro.serving.kvcache import PagedKVCache

N_WORKERS = 4
BLOCK_SIZE = 16
FULL_S = 524_288  # the real long_500k length the scenario stands in for


def _per_chip_bytes(partition: str, kv: PagedKVCache, n_tokens: int,
                    n: int) -> list:
    """Live-token KV bytes each worker reads per full decode step."""
    cfg = kv.cfg
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    per_tok = 2 * hd * BYTES * L  # k+v, per kv head
    if partition == "block":
        return [int(t) * cfg.num_kv_heads * per_tok
                for t in kv.shard_live_tokens()]
    if partition == "head":
        return [n_tokens * (cfg.num_kv_heads // n) * per_tok] * n
    # request: B = 1 — the whole sequence lands on worker 0
    return [n_tokens * cfg.num_kv_heads * per_tok] + [0] * (n - 1)


def run(quick: bool = False):
    rows = []
    cfg = registry.get_smoke_config("llama3-8b")
    Hkv, hd, H = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_heads
    S = 512 if quick else 4096  # CPU-scale stand-in for 524288
    nb = -(-S // BLOCK_SIZE)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, H, hd)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((1, Hkv, hd)), jnp.float32)
    vn = jnp.asarray(rng.standard_normal((1, Hkv, hd)), jnp.float32)

    # the unified facade assembles the placement: cache sharding, worker
    # pool, and partition all come from one declarative EngineConfig
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    num_blocks = nb + N_WORKERS + (-(nb + N_WORKERS) % N_WORKERS)
    for partition in ("block", "head", "request"):
        eng = LLMEngine(cfg, params, EngineConfig(
            placement="attention_pool", partition=partition,
            attention_workers=N_WORKERS, num_blocks=num_blocks,
            block_size=BLOCK_SIZE, max_batch=1))
        kv, pool = eng.kv, eng.pool
        assert kv.n_shards == (N_WORKERS if partition == "block" else 1)
        kv.allocate(0, S)
        kv.k_pool = jnp.asarray(
            rng.standard_normal(kv.k_pool.shape), jnp.float32)
        kv.v_pool = jnp.asarray(
            rng.standard_normal(kv.v_pool.shape), jnp.float32)
        tables, lens = kv.block_table_batch([0])
        bt, clen = jnp.asarray(tables), jnp.asarray(lens)
        extra = {}
        if partition == "block":
            # compacted per-shard tables: each worker walks only its ~1/n
            # of the live blocks (the engine hot path does the same)
            lt, lp, _ = kv.block_table_shards([0])
            extra = dict(shard_tables=jnp.asarray(lt),
                         shard_positions=jnp.asarray(lp))
        step = jax.jit(lambda q, kp, vp, bt, clen, kn, vn, pool=pool:
                       pool.attend_paged(q, kp, vp, bt, clen, kn, vn,
                                         **extra))
        t = time_call(step, q, kv.k_pool[0], kv.v_pool[0], bt, clen, kn, vn)

        chips = _per_chip_bytes(partition, kv, S, N_WORKERS)
        balance = max(chips) / max(sum(chips) / len(chips), 1e-9)
        spread = ";".join(f"{c / 2**20:.2f}" for c in chips)
        rows.append({
            "name": f"block_shard_long1_{partition}",
            "us_per_call": round(t * 1e6, 1),
            "derived": (f"S={S}(stand-in for {FULL_S});workers={N_WORKERS};"
                        f"per_chip_kv_mib={spread};"
                        f"max_over_mean={balance:.2f};"
                        f"chips_holding_kv="
                        f"{sum(1 for c in chips if c > 0)}")})
    return rows
