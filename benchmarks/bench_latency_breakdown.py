"""Paper Fig. 12 — token-generation latency breakdown (model worker time,
attention worker time, network time) across batch sizes at fixed context,
rotational pipelining disabled (as the paper does for this figure)."""
from __future__ import annotations

from repro.configs import registry
from repro.core import costmodel as cm


def run():
    rows = []
    h100, h20 = cm.HARDWARE["h100"], cm.HARDWARE["h20"]
    fhbn = cm.NETWORK_STACKS["fhbn"]
    for model_name, dop in (("llama3-70b", (2, 4)),):
        cfg = registry.get_config(model_name)
        for l in (4096, 8192):
            for B in (16, 64, 128, 256, 512):
                t_m = cm.mtime(cfg, B, h100, dop[0])
                t_a = cm.atime(cfg, B, l, h20, dop[1])
                t_n = cm.network_time_per_iteration(cfg, B, fhbn,
                                                    overlap_fraction=0.0)
                tbt = t_m + t_a + t_n
                rows.append({
                    "name": f"fig12_{model_name}_l{l}_B{B}",
                    "us_per_call": round(tbt * 1e6),
                    "derived": (f"model_ms={t_m*1e3:.2f};"
                                f"attn_ms={t_a*1e3:.2f};"
                                f"net_ms={t_n*1e3:.2f};"
                                f"model_frac={t_m/tbt:.2f}"),
                })
    return rows
