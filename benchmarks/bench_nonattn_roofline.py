"""Paper Fig. 2 — non-attention operator latency + MFU vs batch size.

Two columns per point: the paper's H100 roofline-model projection (the
dotted lines in Fig. 2, from core/costmodel) and a *measured* CPU-scale
latency of the real non-attention computation (reduced llama3 layer) to show
the same bandwidth-bound -> compute-bound transition shape."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.configs import registry
from repro.core import costmodel as cm

BATCHES = [1, 4, 16, 64, 128, 256, 512, 1024]


def run():
    l70 = registry.get_config("llama3-70b")
    h100 = cm.HARDWARE["h100"]
    rows = []
    # measured CPU micro-kernel: one decode iteration of QKVO+FFN GEMMs
    cfg = registry.get_smoke_config("llama3-8b", d_model=512, d_ff=2048)
    key = jax.random.PRNGKey(0)
    w1 = jax.random.normal(key, (cfg.d_model, cfg.d_ff), jnp.float32)
    w2 = jax.random.normal(key, (cfg.d_ff, cfg.d_model), jnp.float32)

    @jax.jit
    def nonattn(x):
        return jax.nn.silu(x @ w1) @ w2

    for B in BATCHES:
        t_model = cm.mtime(l70, B, h100, efficiency=1.0)
        mfu = cm.mfu_nonattention(l70, B, h100)
        x = jax.random.normal(key, (B, cfg.d_model), jnp.float32)
        t_meas = time_call(nonattn, x)
        rows.append({
            "name": f"fig2_nonattn_B{B}",
            "us_per_call": round(t_meas * 1e6, 1),
            "derived": f"h100_model_ms={t_model*1e3:.2f};mfu={mfu:.3f}",
        })
    return rows
