"""Repo-root pytest bootstrap: make ``python -m pytest -x -q`` work from a
fresh checkout with no ``PYTHONPATH=src`` prefix and no install step.

(An editable install — ``pip install -e .`` — gives the same importability
plus the ``repro-serve`` console entrypoint; see pyproject.toml. This shim
keeps tier-1 runnable either way.)
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))
