"""Refcounted prefix sharing end to end: bit-identical greedy streams with
sharing on vs off for every placement/partition, prefill-skip accounting,
the block-granular prefix index, admission charging only the unshared
suffix, and preemption interplay (evicting a sharer or a donor never
corrupts anyone)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer
from repro.serving import (EngineConfig, LLMEngine, Request,
                           RequestScheduler, SamplingParams, State)
from repro.serving.kvcache import PagedKVCache
from repro.serving.scheduler import PrefixIndex


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _common(cfg, n=40, seed=0):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, size=n).tolist()


def _family(cfg, common, tails=(5, 6, 7, 8), new=8, seed=42):
    """Requests sharing `common` as a prompt prefix, distinct suffixes."""
    r = np.random.default_rng(seed)
    return [Request(prompt=list(common) +
                    r.integers(0, cfg.vocab_size, size=t).tolist(),
                    params=SamplingParams(max_new_tokens=new))
            for t in tails]


# ======================================================================
# model layer: suffix prefill is bit-identical to the full prefill
# ======================================================================

@pytest.mark.parametrize("arch", ["llama3-8b", "gemma2-27b"])
def test_prefill_suffix_bit_parity(arch):
    """Suffix queries over gathered prefix context reproduce the full
    prefill EXACTLY — logits and suffix KV — including gemma2's local
    windows, attention sinks, and logit softcap."""
    cfg = registry.get_smoke_config(arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    S, P = 37, 16
    toks = rng.integers(0, cfg.vocab_size, size=(1, S))
    logits_full, cache = transformer.prefill(
        params, cfg, {"tokens": jnp.asarray(toks, jnp.int32)}, max_seq=S)
    kp, vp = cache["k"][:, :, :, :P], cache["v"][:, :, :, :P]
    logits_suf, c2 = transformer.prefill_suffix(
        params, cfg, {"tokens": jnp.asarray(toks[:, P:], jnp.int32)}, kp, vp)
    np.testing.assert_array_equal(np.asarray(logits_full),
                                  np.asarray(logits_suf))
    np.testing.assert_array_equal(np.asarray(cache["k"][:, :, :, P:]),
                                  np.asarray(c2["k"]))
    np.testing.assert_array_equal(np.asarray(cache["v"][:, :, :, P:]),
                                  np.asarray(c2["v"]))
    assert int(c2["len"][0]) == S


def test_prefill_suffix_rejects_non_kv_families():
    cfg = registry.get_smoke_config("rwkv6-7b")
    with pytest.raises(ValueError, match="family"):
        transformer.prefill_suffix(None, cfg, {}, None, None)


# ======================================================================
# tentpole acceptance: greedy streams bit-identical, sharing on vs off,
# for all three placements and head/request/block partitions
# ======================================================================

@pytest.mark.parametrize("placement,partition,workers", [
    ("homogeneous", "head", 2),
    ("attention_pool", "head", 2),
    ("attention_pool", "request", 4),
    ("attention_pool", "block", 4),
])
def test_sharing_parity_across_placements(setup, placement, partition,
                                          workers):
    cfg, params = setup
    common = _common(cfg)
    res = {}
    for share in (False, True):
        reqs = _family(cfg, common)
        eng = LLMEngine(cfg, params, EngineConfig(
            placement=placement, partition=partition,
            attention_workers=workers, max_batch=4, num_blocks=64,
            block_size=16, prefix_sharing=share))
        eng.submit(reqs)
        eng.run()
        res[share] = ([r.output for r in reqs], eng.stats, eng.kv)
    assert res[True][0] == res[False][0]       # bit-identical greedy streams
    stats_on, kv_on = res[True][1], res[True][2]
    assert stats_on.blocks_shared == 6         # 3 sharers x 2 full blocks
    assert stats_on.prefill_tokens_skipped == 96
    assert res[False][1].blocks_shared == 0
    assert kv_on.used_blocks == 0              # everything released
    assert kv_on.refcounts == {}


def test_sharing_reduces_resident_pool_blocks(setup):
    """Mid-flight the shared run holds bytes(1 prefix) + K·bytes(suffix),
    the unshared run K·bytes(full prompt)."""
    cfg, params = setup
    common = _common(cfg)
    used = {}
    for share in (False, True):
        reqs = _family(cfg, common, new=4)
        eng = LLMEngine(cfg, params, EngineConfig(
            max_batch=4, num_blocks=64, block_size=16,
            prefix_sharing=share))
        eng.submit(reqs)
        eng.step()
        used[share] = eng.kv.used_blocks
        eng.run()
    # 4 prompts of 45-48 tokens: 12+ blocks unshared; shared: one 2-block
    # prefix + 4 private tails
    assert used[True] < used[False]
    assert used[False] - used[True] == 6       # 3 sharers x 2 blocks saved


def test_moe_offload_shares_memory_but_recomputes(setup):
    """MoE capacity dispatch couples a routing group's tokens, so suffix
    prefill is not bit-stable — the engine shares pool MEMORY (blocks
    mapped, suffix-only write, donor never rewritten) but recomputes the
    full prompt: outputs identical, blocks shared, zero tokens skipped."""
    cfg = registry.get_smoke_config("qwen3-moe-30b-a3b").replace(
        capacity_factor=64.0)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    common = _common(cfg, n=20, seed=3)
    res = {}
    for share in (False, True):
        reqs = _family(cfg, common, tails=(3, 4), new=5, seed=9)
        eng = LLMEngine(cfg, params, EngineConfig(
            placement="moe_offload", attention_workers=2, expert_workers=2,
            max_batch=2, num_blocks=64, block_size=8, prefix_sharing=share))
        eng.submit(reqs)
        eng.run()
        res[share] = ([r.output for r in reqs], eng.stats)
    assert res[True][0] == res[False][0]
    assert res[True][1].blocks_shared == 2     # 1 sharer x 2 full blocks
    assert res[True][1].prefill_tokens_skipped == 0


def test_gemma2_windowed_softcap_sharing_parity():
    """Sliding windows + sinks + softcap + post-norms through the suffix
    prefill: sharing must stay bit-identical on the most exotic config."""
    cfg = registry.get_smoke_config("gemma2-27b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    common = _common(cfg, n=70, seed=2)        # longer than the 64 window
    res = {}
    for share in (False, True):
        reqs = _family(cfg, common, tails=(4, 9), new=8, seed=5)
        eng = LLMEngine(cfg, params, EngineConfig(
            placement="attention_pool", max_batch=2, num_blocks=64,
            block_size=16, prefix_sharing=share))
        eng.submit(reqs)
        eng.run()
        res[share] = [r.output for r in reqs]
    assert res[True] == res[False]


# ======================================================================
# prefix index (block-granular trie)
# ======================================================================

def test_prefix_index_match_register_unregister():
    idx = PrefixIndex(block_size=4)
    idx.register(1, list(range(10)))           # 2 full blocks indexed
    donor, n = idx.match(list(range(10)))
    assert (donor, n) == (1, 8)                # deepest full-block prefix
    donor, n = idx.match(list(range(6)))
    assert (donor, n) == (1, 4)
    donor, n = idx.match([9, 9, 9, 9])
    assert (donor, n) == (None, 0)
    # a second registrant deepens the index; donor picks the smallest rid
    idx.register(2, list(range(16)))
    donor, n = idx.match(list(range(16)))
    assert (donor, n) == (2, 16)               # only rid 2 covers 4 blocks
    donor, n = idx.match(list(range(8)))
    assert donor == 1                          # min(1, 2) at depth 2
    idx.unregister(1)
    donor, n = idx.match(list(range(8)))
    assert (donor, n) == (2, 8)
    idx.unregister(2)
    assert len(idx) == 0
    assert idx.match(list(range(16))) == (None, 0)


def test_admission_charges_only_unshared_suffix(setup):
    """A tight pool admits MORE concurrent requests with sharing: only the
    suffix counts against the free list."""
    cfg, _ = setup
    common = _common(cfg, n=32)
    admitted = {}
    for share in (False, True):
        kv = PagedKVCache(cfg, num_blocks=8, block_size=16)
        sched = RequestScheduler(kv, max_batch=8, decode_headroom=0,
                                 prefix_sharing=share)
        sched.submit(_family(cfg, common, tails=(8, 8, 8, 8), new=4))
        admitted[share] = len(sched.admit())
        if share:
            # every sharer: 2 shared blocks + 1 private suffix block
            assert kv.used_blocks == 3 + (admitted[True] - 1)
    assert admitted[False] == 2                # 8 blocks / 3-block prompts
    assert admitted[True] == 4                 # suffix-only charging


def test_match_capped_one_block_short_of_stored(setup):
    """A fully-matching prompt still prefalls at least one token: the match
    is capped a block short of the stored length (the last prompt token's
    logits seed sampling)."""
    cfg, _ = setup
    kv = PagedKVCache(cfg, num_blocks=16, block_size=4)
    sched = RequestScheduler(kv, max_batch=4, prefix_sharing=True)
    prompt = list(range(1, 9))                 # exactly 2 full blocks
    a, b = (Request(prompt=list(prompt),
                    params=SamplingParams(max_new_tokens=2))
            for _ in range(2))
    sched.submit([a, b])
    assert sched.admit() == [a, b]
    assert sched.shared_prefix_tokens(a.rid) == 0
    assert sched.shared_prefix_tokens(b.rid) == 4   # capped below 8
    assert kv.tables[b.rid][0] == kv.tables[a.rid][0]
    assert kv.tables[b.rid][1] != kv.tables[a.rid][1]


# ======================================================================
# preemption interplay: evicting sharers/donors never corrupts anyone
# ======================================================================

def test_preempt_with_sharing_matches_uncontended(setup):
    """Pool pressure forces evictions among prefix-sharing requests; every
    stream still finishes bit-identical to an uncontended run, and the pool
    drains to zero with empty refcounts."""
    cfg, params = setup
    common = _common(cfg, n=16, seed=7)

    def mk():
        return _family(cfg, common, tails=(2, 2, 2), new=16, seed=11)

    ref = mk()
    e_ref = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=64,
                                                block_size=8,
                                                prefix_sharing=True))
    e_ref.submit(ref)
    e_ref.run()
    assert e_ref.stats.preemptions == 0

    tight = mk()
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=4, num_blocks=10, block_size=8, scheduler="preempt",
        decode_headroom=2, prefix_sharing=True))
    eng.submit(tight)
    eng.run(max_steps=2000)
    assert eng.stats.preemptions > 0
    assert [r.output for r in tight] == [r.output for r in ref]
    assert eng.kv.used_blocks == 0
    assert eng.kv.refcounts == {}


def test_preempt_evicted_sharer_leaves_donor_intact(setup):
    """Directly evict a sharing recipient mid-flight: the donor's blocks
    and bytes are untouched (refcounts drop, nothing freed out from under
    it) and the donor finishes exactly like an unshared solo run."""
    cfg, params = setup
    common = _common(cfg, n=32, seed=4)
    solo = _family(cfg, common, tails=(5,), new=8, seed=13)[0]
    e0 = LLMEngine(cfg, params, EngineConfig(max_batch=2, num_blocks=64,
                                             block_size=16))
    e0.submit(solo)
    e0.run()

    donor, sharer = _family(cfg, common, tails=(5, 6), new=8, seed=13)
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=2, num_blocks=64, block_size=16, scheduler="preempt",
        prefix_sharing=True))
    eng.submit([donor, sharer])
    eng.step()                                  # both admitted + 1 decode
    assert eng.sched.shared_prefix_tokens(sharer.rid) == 32
    donor_blocks = list(eng.kv.tables[donor.rid])
    eng.sched.preempt(sharer)                   # evict the recipient
    assert eng.kv.tables[donor.rid] == donor_blocks
    assert all(eng.kv.refcounts[b] == 1 for b in donor_blocks)
    eng.run()                                   # sharer re-admits, finishes
    assert donor.state == State.FINISHED
    assert sharer.state == State.FINISHED
    assert donor.output == solo.output
    assert eng.kv.used_blocks == 0


def test_donor_retires_while_sharer_lives(setup):
    """The donor finishes first: its refcounts drop but shared physical
    blocks survive through the sharer, which keeps decoding on them and
    matches its own solo run bit-for-bit."""
    cfg, params = setup
    common = _common(cfg, n=32, seed=8)
    reqs = _family(cfg, common, tails=(5, 6), new=10, seed=17)
    donor, sharer = reqs
    solo = Request(prompt=list(sharer.prompt),
                   params=SamplingParams(max_new_tokens=10))
    e0 = LLMEngine(cfg, params, EngineConfig(max_batch=2, num_blocks=64,
                                             block_size=16))
    e0.submit(solo)
    e0.run()
    donor.params.max_new_tokens = 2             # donor retires early
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=2, num_blocks=64, block_size=16, prefix_sharing=True))
    eng.submit(reqs)
    eng.run()
    assert donor.state == State.FINISHED and sharer.state == State.FINISHED
    assert sharer.output == solo.output
    assert eng.kv.used_blocks == 0 and eng.kv.refcounts == {}


def test_second_wave_matches_index_of_running_request(setup):
    """A request submitted AFTER the first wave is admitted still matches
    the running donor's registered blocks (the index persists for the
    donor's lifetime)."""
    cfg, params = setup
    common = _common(cfg, n=32, seed=12)
    first = _family(cfg, common, tails=(4,), new=12, seed=19)[0]
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=4, num_blocks=64, block_size=16, prefix_sharing=True))
    eng.submit(first)
    eng.step()
    late = _family(cfg, common, tails=(6,), new=4, seed=23)[0]
    eng.submit(late)
    eng.run()
    assert eng.stats.blocks_shared == 2
    assert eng.stats.prefill_tokens_skipped == 32
    solo = _family(cfg, common, tails=(6,), new=4, seed=23)[0]
    e2 = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=64,
                                             block_size=16))
    e2.submit(solo)
    e2.run()
    assert late.output == solo.output


# ======================================================================
# surface: stats + config
# ======================================================================

def test_sharing_counters_in_summary(setup):
    cfg, params = setup
    reqs = _family(cfg, _common(cfg), new=2)
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=4, num_blocks=64, block_size=16, prefix_sharing=True))
    eng.submit(reqs)
    s = eng.run().summary()
    assert s["blocks_shared"] == 6
    assert s["prefill_tokens_skipped"] == 96
    off = EngineConfig()
    assert off.prefix_sharing is False         # default stays off
