"""Fault-tolerant attention-pool serving: shard fault injection, detection
(healthy → suspect → dead with bounded retry), and bit-exact request
recovery via the §5 preempt-and-recompute path.

The headline invariant is the parity matrix: greedy outputs through an
injected mid-decode shard failure are BIT-IDENTICAL to the fault-free run,
for attention_pool × {head, request, block} partitions, with prefix
sharing and chunked prefill enabled. Plus: transient/corrupt/straggler
scenarios, the shard-masked allocator's invariants under hypothesis,
degraded-capacity PoolExhausted context, the always-on non-finite-logits
guard, and graceful cancellation.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import registry
from repro.models import transformer
from repro.serving import (CorruptedLogitsError, EngineConfig, FaultEvent,
                           FaultInjector, FaultScenario, LLMEngine,
                           PagedKVCache, PoolExhausted, Request,
                           SamplingParams, SchedulingStalled,
                           ShardHealthTracker, State)
from repro.serving.faults import DEAD, HEALTHY, SUSPECT
from repro.serving.kvcache import OutOfBlocks


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, lens=(9, 14, 6), new=10, prefix=6, seed=0):
    """Requests sharing a common prompt prefix (exercises prefix sharing
    through recovery) with per-request suffixes."""
    rng = np.random.default_rng(seed)
    common = rng.integers(0, cfg.vocab_size, size=prefix).tolist()
    return [Request(prompt=common +
                    rng.integers(0, cfg.vocab_size, size=n).tolist(),
                    params=SamplingParams(max_new_tokens=new))
            for n in lens]


def _econf(partition, **kw):
    base = dict(placement="attention_pool", partition=partition,
                attention_workers=2, num_blocks=64, block_size=4,
                max_batch=4, scheduler="preempt", prefix_sharing=True,
                prefill_chunk_tokens=8)
    # head/request partitions default to an unsharded pool — shard it
    # explicitly so there is a shard boundary to kill
    if partition != "block":
        base["kv_shards"] = 2
    base.update(kw)
    return EngineConfig(**base)


def _run(cfg, params, econf, scenario=None, **reqkw):
    injector = FaultInjector(FaultScenario.parse(scenario)) \
        if scenario else None
    eng = LLMEngine(cfg, params, econf, fault_injector=injector)
    reqs = _reqs(cfg, **reqkw)
    eng.submit(reqs)
    eng.run()
    return eng, [r.output for r in reqs]


# ======================================================================
# tentpole: the parity matrix — bit-exact recovery through shard death
# ======================================================================

@pytest.mark.parametrize("partition", ["head", "request", "block"])
def test_shard_death_bit_parity(setup, partition):
    """Mid-decode shard death (+ later rejoin): greedy outputs are
    bit-identical to the fault-free run across every pool partition, with
    prefix sharing AND chunked prefill enabled."""
    cfg, params = setup
    econf = _econf(partition)
    _, ref = _run(cfg, params, econf)
    eng, out = _run(cfg, params, econf,
                    scenario="shard_death:shard=1,step=5,rejoin=14")
    assert out == ref
    s = eng.stats
    assert s.shard_failures == 1
    assert s.shard_rejoins == 1
    assert s.requests_recovered >= 1
    assert len(s.recovery_latencies) == s.requests_recovered
    kinds = [e.kind for e in eng.event_log]
    for k in ("shard_suspect", "retry", "shard_down", "shard_up",
              "recover"):
        assert k in kinds, f"missing {k} event"
    down = next(e for e in eng.event_log if e.kind == "shard_down")
    assert down.rid == -1 and down.info["shard"] == 1
    assert down.info["victims"], "a mid-decode death must name victims"
    # after rejoin the pool is whole again
    assert eng.kv.quarantined_shards == ()
    assert eng.kv.capacity_blocks == econf.num_blocks


def test_shard_death_without_rejoin_still_recovers(setup):
    """No replacement hardware: victims still recover onto the surviving
    shard (capacity stays degraded) and outputs stay bit-identical."""
    cfg, params = setup
    econf = _econf("block")
    _, ref = _run(cfg, params, econf)
    eng, out = _run(cfg, params, econf,
                    scenario="shard_death:shard=0,step=4")
    assert out == ref
    assert eng.stats.shard_failures == 1
    assert eng.stats.shard_rejoins == 0
    assert eng.kv.quarantined_shards == (0,)
    assert eng.kv.capacity_blocks == econf.num_blocks // 2
    # the dead shard holds no live request's blocks after recovery
    assert eng.kv.seqs_on_shard(0) == []


def test_transient_fault_recovers_without_eviction(setup):
    """A blip below the retry budget: the shard recovers in place — no
    preemption, no quarantine, parity intact."""
    cfg, params = setup
    econf = _econf("block")
    ref_eng, ref = _run(cfg, params, econf)
    eng, out = _run(cfg, params, econf,
                    scenario="transient:shard=0,step=3,failures=2")
    assert out == ref
    s = eng.stats
    assert s.transient_faults_recovered == 1
    assert s.fault_retries == 2
    assert s.shard_failures == 0
    assert s.preemptions == ref_eng.stats.preemptions
    assert eng.kv.quarantined_shards == ()


def test_corrupt_partial_retries_bit_identically(setup):
    """NaN in the merged decode output: the engine re-runs the
    deterministic step (nothing was committed) — outputs bit-identical,
    the faulty shard goes suspect then recovers."""
    cfg, params = setup
    econf = _econf("block")
    _, ref = _run(cfg, params, econf)
    eng, out = _run(cfg, params, econf, scenario="corrupt:shard=1,step=6")
    assert out == ref
    s = eng.stats
    assert s.transient_faults_recovered == 1
    assert s.shard_failures == 0
    kinds = [e.kind for e in eng.event_log]
    assert "shard_suspect" in kinds and "recover" in kinds


def test_corrupt_past_retry_budget_kills_shard(setup):
    """Corruption that never clears exhausts the retry budget: the shard
    is declared dead and its requests recover — parity still holds."""
    cfg, params = setup
    econf = _econf("block", fault_retry_limit=2)
    _, ref = _run(cfg, params, econf)
    eng, out = _run(cfg, params, econf,
                    scenario="corrupt:shard=1,step=5,failures=5")
    assert out == ref
    assert eng.stats.shard_failures == 1
    assert eng.kv.quarantined_shards == (1,)


def test_straggler_is_observed_not_evicted(setup):
    cfg, params = setup
    econf = _econf("block")
    _, ref = _run(cfg, params, econf)
    eng, out = _run(cfg, params, econf,
                    scenario="straggle:shard=0,step=4,delay_ms=1")
    assert out == ref
    s = eng.stats
    assert s.straggle_steps == 1
    assert s.shard_failures == 0 and s.preemptions == 0
    sus = [e for e in eng.event_log if e.kind == "shard_suspect"]
    assert sus and sus[0].info["cause"] == "straggler"


def test_multi_fault_scenario_parity(setup):
    """Everything at once: transient, straggle, corruption, then a death
    with rejoin — outputs still bit-identical."""
    cfg, params = setup
    econf = _econf("block")
    _, ref = _run(cfg, params, econf)
    eng, out = _run(
        cfg, params, econf,
        scenario="transient:shard=0,step=2;straggle:shard=1,step=3,"
                 "delay_ms=1;corrupt:shard=0,step=4;"
                 "shard_death:shard=1,step=6,rejoin=15")
    assert out == ref
    assert eng.stats.shard_failures == 1
    assert eng.stats.transient_faults_recovered == 2


def test_recovery_stats_in_summary(setup):
    cfg, params = setup
    eng, _ = _run(cfg, params, _econf("block"),
                  scenario="shard_death:shard=1,step=5,rejoin=14")
    s = eng.stats.summary()
    for key in ("shard_failures", "shard_rejoins", "fault_retries",
                "transient_faults_recovered", "straggle_steps",
                "requests_recovered", "recovery_p50_s", "recovery_p99_s"):
        assert key in s
    assert s["shard_failures"] == 1
    assert s["recovery_p50_s"] >= 0.0


# ======================================================================
# health state machine
# ======================================================================

def test_health_tracker_state_machine():
    h = ShardHealthTracker(2, retry_limit=3)
    assert h.state(0) == HEALTHY
    assert h.strike(0) == SUSPECT
    assert h.strike(0) == SUSPECT
    h.clear(0)                      # retry succeeded before the limit
    assert h.state(0) == HEALTHY and h.strikes(0) == 0
    for _ in range(3):
        st_ = h.strike(0)
    assert st_ == DEAD and h.is_dead(0)
    h.clear(0)                      # clear never resurrects the dead
    assert h.is_dead(0)
    assert h.strike(0) == DEAD
    h.mark_up(0)                    # rejoin does
    assert h.state(0) == HEALTHY and h.strikes(0) == 0
    assert h.dead_shards == []
    with pytest.raises(ValueError):
        ShardHealthTracker(2, retry_limit=0)


# ======================================================================
# scenario parsing / injector determinism
# ======================================================================

def test_scenario_parse_inline_and_json(tmp_path):
    sc = FaultScenario.parse(
        "shard_death:shard=1,step=6,rejoin=20;"
        "corrupt:shard=0,step=9,failures=2;"
        "straggle:shard=1,step=3,delay_ms=5")
    assert [e.kind for e in sc] == ["straggle", "shard_death", "corrupt"]
    assert sc.events[1].rejoin_step == 20
    assert sc.events[0].delay_s == pytest.approx(5e-3)

    path = tmp_path / "scenario.json"
    path.write_text(json.dumps([
        {"kind": "shard_death", "shard": 0, "step": 4, "rejoin_step": 9},
        {"kind": "transient", "shard": 1, "step": 2},
    ]))
    sc2 = FaultScenario.parse(str(path))
    assert len(sc2) == 2 and sc2.events[1].kind == "shard_death"


def test_scenario_validation_errors():
    with pytest.raises(ValueError):
        FaultEvent("meteor_strike", 0, 1)
    with pytest.raises(ValueError):
        FaultEvent("shard_death", 0, 5, rejoin_step=5)   # rejoin <= death
    with pytest.raises(ValueError):
        FaultEvent("shard_death", 0, 0)                  # steps are 1-based
    with pytest.raises(ValueError):
        FaultScenario.parse("")
    with pytest.raises(ValueError):
        FaultScenario.parse("corrupt:shard=0,step=2,zorp=1")
    with pytest.raises(ValueError):                      # one life per shard
        FaultInjector(FaultScenario.parse(
            "shard_death:shard=0,step=2;shard_death:shard=0,step=9"))


def test_injector_probe_and_budget():
    inj = FaultInjector(FaultScenario.parse(
        "shard_death:shard=1,step=3,rejoin=7;"
        "transient:shard=0,step=2,failures=2"))
    assert inj.probe(1, 2)                    # alive before the death step
    assert not inj.probe(1, 3)
    assert not inj.probe(1, 6)                # dead until rejoin
    assert inj.probe(1, 7)                    # back at the rejoin step
    assert inj.rejoins(7) == [1]
    assert inj.pending_rejoins(5) and not inj.pending_rejoins(7)
    # the transient's budget burns down probe by probe, then clears
    assert not inj.probe(0, 2)
    assert not inj.probe(0, 2)
    assert inj.probe(0, 2)


def test_injector_filter_decode_consumes_budget():
    inj = FaultInjector(FaultScenario.parse("corrupt:shard=1,step=4"))
    clean = jnp.zeros((2, 8), jnp.float32)
    out, shard = inj.filter_decode(4, clean)
    assert shard == 1 and bool(jnp.isnan(out).all())
    out2, shard2 = inj.filter_decode(4, clean)   # budget spent: clean again
    assert shard2 is None and bool(jnp.isfinite(out2).all())


def test_random_scenario_deterministic():
    a = FaultScenario.random(7, n_shards=2, horizon=20)
    b = FaultScenario.random(7, n_shards=2, horizon=20)
    assert a.events == b.events
    assert FaultScenario.random(8, 2, 20).events != a.events


# ======================================================================
# shard-masked allocator: quarantine/rejoin invariants (hypothesis)
# ======================================================================

def _sharded_cache(num_blocks=32, block_size=4, n_shards=4):
    cfg = registry.get_smoke_config("llama3-8b")
    return PagedKVCache(cfg, num_blocks, block_size, n_shards=n_shards)


@settings(deadline=None, max_examples=25)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["alloc", "share", "append", "free",
                               "quarantine", "rejoin"]),
              st.integers(0, 5), st.integers(1, 24)),
    min_size=1, max_size=50))
def test_shard_masked_allocator_invariants(ops):
    """Random quarantine/rejoin interleaved with alloc/share/append/free:
    refcounts stay the single source of truth, no block is lost or doubly
    free, and a quarantined shard's free list never shrinks (nothing is
    allocated from it while masked)."""
    kv = _sharded_cache()
    n_shards, total = kv.n_shards, kv.num_blocks
    for kind, sid, n in ops:
        shard = sid % n_shards
        try:
            if kind == "alloc" and sid not in kv.tables:
                kv.allocate(sid, n)
            elif kind == "share":
                src, dst = sid, sid + 100
                if src in kv.tables and dst not in kv.tables \
                        and kv.lengths[src] >= 1:
                    kv.share_blocks(src, dst,
                                    max(1, min(n, kv.lengths[src])))
            elif kind == "append" and sid in kv.tables:
                kv.append_token(sid)
            elif kind == "free" and sid in kv.tables:
                kv.free_seq(sid)
            elif kind == "quarantine":
                pre = len(kv._free_shard[shard])
                kv.quarantine_shard(shard)
                assert len(kv._free_shard[shard]) == pre
            elif kind == "rejoin":
                kv.rejoin_shard(shard)
        except OutOfBlocks:
            pass
        # ---- invariants after every op ----
        referenced = {b for t in kv.tables.values() for b in t}
        all_free = [b for s in kv._free_shard for b in s]
        # refcounts: value == number of tables referencing the block
        for b, rc in kv.refcounts.items():
            assert rc == sum(b in t for t in kv.tables.values())
            assert rc >= 1
        assert referenced == set(kv.refcounts)
        # conservation: referenced + free == every block, no overlap
        assert len(all_free) == len(set(all_free)), "block doubly free"
        assert set(all_free).isdisjoint(referenced)
        assert len(all_free) + len(referenced) == total
        # masking: quarantined shards contribute nothing allocatable
        for q in kv.quarantined_shards:
            assert all(kv.shard_of(b) != q for b in kv.free)
        assert kv.num_free == len(kv.free)
        assert kv.capacity_blocks == \
            kv.blocks_per_shard * len(kv.live_shards)


def test_quarantined_shard_never_allocated_and_balance_holds():
    kv = _sharded_cache(num_blocks=32, block_size=4, n_shards=4)
    kv.quarantine_shard(2)
    kv.allocate(1, 24)                     # 6 blocks over 3 live shards
    placed = [kv.shard_of(b) for b in kv.tables[1]]
    assert 2 not in placed
    counts = {s: placed.count(s) for s in kv.live_shards}
    assert max(counts.values()) - min(counts.values()) <= 1, \
        "shard-masked round-robin lost balance over survivors"
    # rejoin restores the shard to the rotation
    kv.rejoin_shard(2)
    kv.allocate(2, 16)                     # 4 blocks over 4 live shards
    placed2 = {kv.shard_of(b) for b in kv.tables[2]}
    assert 2 in placed2


def test_all_shards_quarantined_raises():
    kv = _sharded_cache(num_blocks=16, block_size=4, n_shards=2)
    kv.quarantine_shard(0)
    kv.quarantine_shard(1)
    with pytest.raises(OutOfBlocks, match="quarantined"):
        kv.allocate(1, 4)
    with pytest.raises(ValueError):
        kv.quarantine_shard(5)


# ======================================================================
# degraded-capacity exhaustion context (satellite 6)
# ======================================================================

def test_pool_exhausted_carries_degraded_context():
    kv = _sharded_cache(num_blocks=16, block_size=4, n_shards=2)
    kv.quarantine_shard(1)
    with pytest.raises(PoolExhausted) as ei:
        kv.allocate(1, 64)                 # needs 16 > 8 surviving blocks
    e = ei.value
    assert e.degraded
    assert e.quarantined_shards == (1,)
    assert e.live_shards == (0,)
    assert "DEGRADED" in str(e)


def test_healthy_pool_exhausted_not_degraded():
    kv = _sharded_cache(num_blocks=16, block_size=4, n_shards=2)
    with pytest.raises(PoolExhausted) as ei:
        kv.allocate(1, 100)
    assert not ei.value.degraded
    assert ei.value.quarantined_shards == ()
    assert "DEGRADED" not in str(ei.value)


def test_stall_after_unrecoverable_death_names_degradation(setup):
    """Both block-partition shards gone except capacity too small for the
    waiting head and no rejoin scheduled: SchedulingStalled (not a spin)
    and the message names the quarantine."""
    cfg, params = setup
    econf = _econf("block", num_blocks=16, prefix_sharing=False,
                   prefill_chunk_tokens=None)
    inj = FaultInjector(FaultScenario.parse("shard_death:shard=0,step=2"))
    eng = LLMEngine(cfg, params, econf, fault_injector=inj)
    # head needs more than one shard's 8 blocks: 30 tokens + headroom
    eng.submit([Request(prompt=list(range(1, 31)),
                        params=SamplingParams(max_new_tokens=4))])
    with pytest.raises(SchedulingStalled, match="DEGRADED"):
        eng.run()


# ======================================================================
# non-finite logits guard (satellite 1)
# ======================================================================

def test_corrupted_logits_error_names_request_and_step(setup):
    cfg, params = setup
    eng = LLMEngine(cfg, params, EngineConfig(num_blocks=32, block_size=4))
    req = Request(prompt=[1, 2, 3], params=SamplingParams(max_new_tokens=4))
    eng._step_no = 7
    bad = jnp.full((1, cfg.vocab_size), jnp.nan, jnp.float32)
    with pytest.raises(CorruptedLogitsError) as ei:
        eng._sample([req], bad)
    assert ei.value.rids == (req.rid,)
    assert ei.value.step == 7
    assert str(req.rid) in str(ei.value) and "step 7" in str(ei.value)


def test_finite_logits_pass_guard(setup):
    cfg, params = setup
    eng = LLMEngine(cfg, params, EngineConfig(num_blocks=32, block_size=4))
    req = Request(prompt=[1, 2, 3], params=SamplingParams(max_new_tokens=4))
    ok = jnp.zeros((1, cfg.vocab_size), jnp.float32)
    tok = eng._sample([req], ok)
    assert tok.shape == (1,)


# ======================================================================
# graceful cancellation (satellite 2's engine-side half)
# ======================================================================

def test_cancel_all_drains_cleanly(setup):
    cfg, params = setup
    eng = LLMEngine(cfg, params, _econf("block"))
    reqs = _reqs(cfg, lens=(8, 12), new=50)
    handles = eng.submit(reqs)
    for _ in range(3):
        eng.step()
    partial = [list(r.output) for r in reqs]
    assert any(partial), "requests should have tokens before cancel"
    n = eng.cancel_all()
    assert n == 2
    assert all(r.state == State.FINISHED for r in reqs)
    assert [r.output for r in reqs] == partial     # outputs kept, not wiped
    assert not eng.has_work()
    assert eng.kv.tables == {}                     # every block released
    assert eng.kv.num_free == eng.kv.capacity_blocks
    fins = [e for e in eng.event_log if e.kind == "finish"]
    assert len(fins) == 2
    assert all(e.info.get("cancelled") for e in fins)
    # handle iteration terminates without driving the engine further
    assert list(handles[0]) == partial[0]
    assert eng.cancel_all() == 0                   # idempotent


def test_fault_config_validation():
    with pytest.raises(ValueError):
        EngineConfig(fault_retry_limit=0)
    with pytest.raises(ValueError):
        EngineConfig(fault_retry_backoff_s=-1.0)
