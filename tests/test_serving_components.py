"""Additional serving-substrate properties: sampler distribution/determinism,
scheduler FCFS + memory safety, request lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import registry
from repro.serving.kvcache import PagedKVCache
from repro.serving.request import Request, SamplingParams, State
from repro.serving.sampler import sample
from repro.serving.scheduler import RequestScheduler


def test_greedy_sampling_is_argmax():
    logits = jnp.asarray([[1.0, 5.0, 2.0], [0.5, 0.1, 9.0]])
    out = sample(logits, jax.random.PRNGKey(0), temperature=0.0)
    assert out.tolist() == [1, 2]


def test_temperature_sampling_matches_distribution():
    logits = jnp.log(jnp.asarray([[0.7, 0.2, 0.1]]))
    counts = np.zeros(3)
    key = jax.random.PRNGKey(0)
    for i in range(400):
        key, sub = jax.random.split(key)
        counts[int(sample(logits, sub, temperature=1.0)[0])] += 1
    freq = counts / counts.sum()
    np.testing.assert_allclose(freq, [0.7, 0.2, 0.1], atol=0.08)


def test_top_k_restricts_support():
    logits = jnp.asarray([[5.0, 4.0, -1.0, -2.0, -3.0]])
    key = jax.random.PRNGKey(0)
    seen = set()
    for i in range(100):
        key, sub = jax.random.split(key)
        seen.add(int(sample(logits, sub, temperature=1.0, top_k=2)[0]))
    assert seen <= {0, 1}


@settings(deadline=None, max_examples=25)
@given(prompts=st.lists(st.integers(1, 40), min_size=1, max_size=10),
       max_batch=st.integers(1, 6), blocks=st.integers(4, 40))
def test_scheduler_never_overcommits(prompts, max_batch, blocks):
    cfg = registry.get_smoke_config("llama3-8b")
    kv = PagedKVCache(cfg, num_blocks=blocks, block_size=8)
    sched = RequestScheduler(kv, max_batch=max_batch)
    reqs = [Request(prompt=list(range(n)),
                    params=SamplingParams(max_new_tokens=1))
            for n in prompts]
    sched.submit(reqs)
    admitted = sched.admit()
    # invariants: batch cap, memory cap, FCFS prefix admission
    assert len(sched.running) <= max_batch
    assert kv.used_blocks <= blocks
    assert admitted == sched.running  # first admission takes a prefix
    assert [r.rid for r in admitted] == [r.rid for r in reqs[:len(admitted)]]
    # finishing everything releases all blocks
    for r in list(sched.running):
        r.state = State.FINISHED
    sched.retire_finished()
    assert kv.used_blocks == 0


def test_request_lifecycle_and_tbt():
    r = Request(prompt=[1, 2, 3], params=SamplingParams(max_new_tokens=3))
    assert not r.done()
    for t in (5, 6, 7):
        r.record_token(t)
    assert r.done() and r.state == State.FINISHED
    assert r.output == [5, 6, 7]
    assert r.total_len == 6
    assert r.first_token_s is not None and r.finish_s is not None
    assert r.tbt_s() >= 0.0


def test_eos_terminates_early():
    r = Request(prompt=[1], params=SamplingParams(max_new_tokens=10,
                                                  eos_token=99))
    r.record_token(5)
    assert not r.done()
    r.record_token(99)
    assert r.done()
    assert len(r.output) == 2
