"""Int8 quantized KV pool: kernel parity + accuracy contracts.

The bit-parity contract is pinned with an EAGER REPLAY harness: the REAL
Pallas kernel bodies are driven per grid cell through `_Ref` shims (with
``pl.program_id`` patched to the replayed cell), so every op runs eagerly —
its own deterministic XLA program — exactly like the eager mirror refs in
``kernels/ref.py``. That makes the comparison compiler-independent:
interpret-mode ``pallas_call`` compiles the whole grid as one program, and
XLA CPU's fusion-context-dependent FMA contraction / reduction order then
produces ~1-ulp drift against ANY independently-compiled reference (the
chunk kernel demonstrably so), which would pin compiler behaviour, not
kernel semantics. The replay pins the kernel's op sequence itself: the int8
kernels match the int8 jnp references BIT-EXACTLY, tile for tile.

The interpret-mode wrappers are then held to the refs at tight tolerances
(decode happens to be bit-exact here too; the chunk wrapper is allclose for
the reason above), and the accuracy contract vs the bf16/fp32 path is
cosine >= 0.999 on unit-scale inputs plus greedy-token agreement end to end
(tests/test_int8_kvpool.py covers the pool/engine side).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.kernels import paged_decode_attention as pda
from repro.kernels import paged_prefill_attention as ppa
from repro.kernels import ref
from repro.models import kv_quant

DEC_KW = [("plain", {}),
          ("window", {"sliding_window": 24}),
          ("window+sinks", {"sliding_window": 24, "attention_sinks": 4}),
          ("softcap", {"logit_softcap": 30.0})]
CHUNK_CASES = [("plain", 3, 24, {}),
               ("empty-prefix", 0, 24, {}),
               ("window+sinks", 3, 24,
                {"sliding_window": 20, "attention_sinks": 2}),
               ("softcap-ragged", 3, 19, {"logit_softcap": 30.0})]


# ---------------------------------------------------------------------------
# eager replay harness
# ---------------------------------------------------------------------------
class _Ref:
    """Minimal pl.Ref stand-in over a jnp array (eager load/store)."""

    def __init__(self, a):
        self.a = jnp.asarray(a)

    def __getitem__(self, idx):
        return self.a[idx]

    def __setitem__(self, idx, val):
        self.a = self.a.at[idx].set(val)

    @property
    def dtype(self):
        return self.a.dtype

    @property
    def shape(self):
        return self.a.shape

    def __jax_array__(self):
        return self.a


class _PID:
    """Context manager patching pl.program_id to the replayed grid cell
    (pl.when natively accepts the resulting python-bool conditions)."""

    def __init__(self):
        self.ids = (0, 0, 0)

    def __enter__(self):
        self._orig = pl.program_id
        pl.program_id = lambda i: self.ids[i]
        return self

    def __exit__(self, *a):
        pl.program_id = self._orig


def replay_decode(q, k_pool, v_pool, ks, vs, bt, bp, cl, **kw):
    """Drive _paged_decode_kernel_int8 per (b, h, kb) grid cell, eagerly,
    feeding exactly the operand tiles the BlockSpecs would map in."""
    B, Hkv, G, hd = q.shape
    bs = k_pool.shape[2]
    nb = bt.shape[1]
    kern = functools.partial(pda._paged_decode_kernel_int8,
                             block_size=bs, nb=nb,
                             sliding_window=kw.get("sliding_window", 0),
                             attention_sinks=kw.get("attention_sinks", 0),
                             logit_softcap=kw.get("logit_softcap", 0.0))
    o = jnp.zeros((B, Hkv, G, hd), q.dtype)
    with _PID() as pid:
        for b in range(B):
            for h in range(Hkv):
                acc = _Ref(jnp.zeros((G, hd), jnp.float32))
                m = _Ref(jnp.zeros((G, 128), jnp.float32))
                ell = _Ref(jnp.zeros((G, 128), jnp.float32))
                o_r = _Ref(jnp.zeros((1, 1, G, hd), q.dtype))
                lo_r = _Ref(jnp.zeros((1, 1, G, 128), jnp.float32))
                mo_r = _Ref(jnp.zeros((1, 1, G, 128), jnp.float32))
                for kb in range(nb):
                    pid.ids = (b, h, kb)
                    blk = int(bt[b, kb])
                    kern(_Ref(bt), _Ref(bp), _Ref(cl),
                         _Ref(q[b:b + 1, h:h + 1]),
                         _Ref(k_pool[h:h + 1, blk:blk + 1]),
                         _Ref(v_pool[h:h + 1, blk:blk + 1]),
                         _Ref(ks[h:h + 1, blk:blk + 1]),
                         _Ref(vs[h:h + 1, blk:blk + 1]),
                         o_r, lo_r, mo_r, acc, m, ell)
                o = o.at[b, h].set(o_r.a[0, 0])
    return o


def replay_chunk(q, k_pool, v_pool, ks, vs, bt, kc, vc, **kw):
    """Drive _paged_prefill_chunk_kernel_int8 per (h, kb) grid cell,
    mirroring the wrapper's chunk padding/reshape and index maps."""
    C, H, hd = q.shape
    Hkv, _, bs, _ = k_pool.shape
    G = H // Hkv
    nb = bt.shape[0]
    nc = -(-C // bs)
    pad = nc * bs - C
    kcm = jnp.swapaxes(kc, 0, 1)
    vcm = jnp.swapaxes(vc, 0, 1)
    if pad:
        kcm = jnp.pad(kcm, ((0, 0), (0, pad), (0, 0)))
        vcm = jnp.pad(vcm, ((0, 0), (0, pad), (0, 0)))
    kcm = kcm.reshape(Hkv, nc, bs, hd)
    vcm = vcm.reshape(Hkv, nc, bs, hd)
    qg = q.reshape(C, Hkv, G, hd).transpose(1, 2, 0, 3).reshape(
        Hkv, G * C, hd)
    btp = bt if nb else jnp.zeros((1,), jnp.int32)
    clamp = max(nb - 1, 0)
    nsteps = nb + nc
    kern = functools.partial(ppa._paged_prefill_chunk_kernel_int8,
                             block_size=bs, chunk_len=C, prefix_blocks=nb,
                             total_len=nb * bs + C, nsteps=nsteps,
                             sliding_window=kw.get("sliding_window", 0),
                             attention_sinks=kw.get("attention_sinks", 0),
                             logit_softcap=kw.get("logit_softcap", 0.0))
    out = jnp.zeros((Hkv, G * C, hd), q.dtype)
    with _PID() as pid:
        for h in range(Hkv):
            acc = _Ref(jnp.zeros((G * C, hd), jnp.float32))
            m = _Ref(jnp.zeros((G * C, 128), jnp.float32))
            ell = _Ref(jnp.zeros((G * C, 128), jnp.float32))
            o_r = _Ref(jnp.zeros((1, G * C, hd), q.dtype))
            for kb in range(nsteps):
                pid.ids = (h, kb)
                blk = int(btp[min(kb, clamp)])
                ci = max(kb - nb, 0)
                kern(_Ref(btp),
                     _Ref(qg[h:h + 1]),
                     _Ref(k_pool[h:h + 1, blk:blk + 1]),
                     _Ref(v_pool[h:h + 1, blk:blk + 1]),
                     _Ref(ks[h:h + 1, blk:blk + 1]),
                     _Ref(vs[h:h + 1, blk:blk + 1]),
                     _Ref(kcm[h:h + 1, ci:ci + 1]),
                     _Ref(vcm[h:h + 1, ci:ci + 1]),
                     o_r, acc, m, ell)
            out = out.at[h].set(o_r.a[0])
    return out.reshape(Hkv, G, C, hd).transpose(2, 0, 1, 3).reshape(C, H, hd)


def _rand_int8_pool(rng, Hkv, num_blocks, bs, hd):
    k_pool = jnp.asarray(rng.integers(-127, 128, (Hkv, num_blocks, bs, hd)),
                         jnp.int8)
    v_pool = jnp.asarray(rng.integers(-127, 128, (Hkv, num_blocks, bs, hd)),
                         jnp.int8)
    ks = jnp.asarray(rng.uniform(0.001, 0.1, (Hkv, num_blocks, bs)),
                     jnp.float32)
    vs = jnp.asarray(rng.uniform(0.001, 0.1, (Hkv, num_blocks, bs)),
                     jnp.float32)
    return k_pool, v_pool, ks, vs


# ---------------------------------------------------------------------------
# bit-exact replay parity (the kernel contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("case,kw", DEC_KW, ids=[c for c, _ in DEC_KW])
def test_decode_kernel_replay_bit_exact(case, kw):
    rng = np.random.default_rng(hash(case) % 2**32)
    B, Hkv, G, hd, bs, num_blocks, nb = 3, 2, 4, 64, 16, 32, 4
    kp, vp, ks, vs = _rand_int8_pool(rng, Hkv, num_blocks, bs, hd)
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, hd)), jnp.float32)
    bt = jnp.asarray(np.stack([rng.choice(num_blocks, nb, replace=False)
                               for _ in range(B)]), jnp.int32)
    cl = jnp.asarray(rng.integers(1, nb * bs + 1, (B,)), jnp.int32)
    bp = pda.default_block_positions(B, nb, bs)
    got = replay_decode(q, kp, vp, ks, vs, bt, bp, cl, **kw)
    want = ref.paged_decode_attention_int8_ref(q, kp, vp, ks, vs, bt, cl,
                                               **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("case,nb_c,C,kw", CHUNK_CASES,
                         ids=[c[0] for c in CHUNK_CASES])
def test_chunk_kernel_replay_bit_exact(case, nb_c, C, kw):
    rng = np.random.default_rng(hash(case) % 2**32)
    Hkv, G, hd, bs, num_blocks = 2, 4, 64, 16, 32
    kp, vp, ks, vs = _rand_int8_pool(rng, Hkv, num_blocks, bs, hd)
    q = jnp.asarray(rng.standard_normal((C, Hkv * G, hd)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((C, Hkv, hd)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((C, Hkv, hd)), jnp.float32)
    bt = jnp.asarray(rng.choice(num_blocks, nb_c, replace=False), jnp.int32)
    got = replay_chunk(q, kp, vp, ks, vs, bt, kc, vc, **kw)
    want = ref.paged_prefill_chunk_attention_int8_ref(q, kp, vp, ks, vs, bt,
                                                      kc, vc, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# interpret-mode wrappers against the refs (wiring: specs/index maps)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("case,kw", DEC_KW, ids=[c for c, _ in DEC_KW])
def test_decode_wrapper_interpret_matches_ref(case, kw):
    rng = np.random.default_rng(1 + hash(case) % 2**32)
    B, Hkv, G, hd, bs, num_blocks, nb = 3, 2, 4, 64, 16, 32, 4
    kp, vp, ks, vs = _rand_int8_pool(rng, Hkv, num_blocks, bs, hd)
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, hd)), jnp.float32)
    bt = jnp.asarray(np.stack([rng.choice(num_blocks, nb, replace=False)
                               for _ in range(B)]), jnp.int32)
    cl = jnp.asarray(rng.integers(1, nb * bs + 1, (B,)), jnp.int32)
    got = pda.paged_decode_attention(q, kp, vp, bt, cl, k_scale=ks,
                                     v_scale=vs, interpret=True, **kw)
    want = jax.jit(functools.partial(ref.paged_decode_attention_int8_ref,
                                     **kw))(q, kp, vp, ks, vs, bt, cl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_wrapper_custom_block_positions():
    rng = np.random.default_rng(7)
    B, Hkv, G, hd, bs, num_blocks, nb = 2, 2, 4, 64, 16, 32, 4
    kp, vp, ks, vs = _rand_int8_pool(rng, Hkv, num_blocks, bs, hd)
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, hd)), jnp.float32)
    bt = jnp.asarray(np.stack([rng.choice(num_blocks, nb, replace=False)
                               for _ in range(B)]), jnp.int32)
    cl = jnp.asarray(rng.integers(1, nb * bs + 1, (B,)), jnp.int32)
    bp = pda.default_block_positions(B, nb, bs)
    got = pda.paged_decode_attention(q, kp, vp, bt, cl, block_positions=bp,
                                     k_scale=ks, v_scale=vs, interpret=True)
    want = jax.jit(ref.paged_decode_attention_int8_ref)(
        q, kp, vp, ks, vs, bt, cl, block_positions=bp)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("case,nb_c,C,kw", CHUNK_CASES,
                         ids=[c[0] for c in CHUNK_CASES])
def test_chunk_wrapper_interpret_matches_ref(case, nb_c, C, kw):
    rng = np.random.default_rng(2 + hash(case) % 2**32)
    Hkv, G, hd, bs, num_blocks = 2, 4, 64, 16, 32
    kp, vp, ks, vs = _rand_int8_pool(rng, Hkv, num_blocks, bs, hd)
    q = jnp.asarray(rng.standard_normal((C, Hkv * G, hd)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((C, Hkv, hd)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((C, Hkv, hd)), jnp.float32)
    bt = jnp.asarray(rng.choice(num_blocks, nb_c, replace=False), jnp.int32)
    got = ppa.paged_prefill_chunk_attention(q, kp, vp, bt, kc, vc,
                                            k_scale=ks, v_scale=vs,
                                            interpret=True, **kw)
    want = ref.paged_prefill_chunk_attention_int8_ref(q, kp, vp, ks, vs, bt,
                                                      kc, vc, **kw)
    # interpret-mode pallas_call compiles the whole grid as one XLA program;
    # cross-program FMA/reduction-order variance bounds this at ~ulp level
    # (the REPLAY tests above carry the bit-exactness contract)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_decode_jnp_backend_matches_ref():
    """The jnp dispatcher path (dense gather + per-token scales) agrees
    with the fused int8 reference at float tolerance."""
    rng = np.random.default_rng(11)
    B, Hkv, G, hd, bs, num_blocks, nb = 3, 2, 4, 64, 16, 32, 4
    kp, vp, ks, vs = _rand_int8_pool(rng, Hkv, num_blocks, bs, hd)
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, hd)), jnp.float32)
    bt = jnp.asarray(np.stack([rng.choice(num_blocks, nb, replace=False)
                               for _ in range(B)]), jnp.int32)
    cl = jnp.asarray(rng.integers(1, nb * bs + 1, (B,)), jnp.int32)
    got = pda.paged_decode_attention_jnp(q, kp, vp, bt, cl, k_scale=ks,
                                         v_scale=vs)
    want = ref.paged_decode_attention_int8_ref(q, kp, vp, ks, vs, bt, cl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# accuracy vs the unquantized path (cosine >= 0.999 on unit-scale inputs)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kw", [{}, {"sliding_window": 24},
                                {"logit_softcap": 30.0}],
                         ids=["plain", "window", "softcap"])
def test_int8_cosine_vs_fp_oracle(kw):
    rng = np.random.default_rng(21)
    B, Hkv, G, hd, bs, nb = 3, 2, 4, 64, 16, 4
    num_blocks = B * nb
    kf = jnp.asarray(rng.standard_normal((Hkv, num_blocks, bs, hd)),
                     jnp.float32)
    vf = jnp.asarray(rng.standard_normal((Hkv, num_blocks, bs, hd)),
                     jnp.float32)
    kq, ks = kv_quant.quantize_kv(kf)
    vq, vs = kv_quant.quantize_kv(vf)
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, hd)), jnp.float32)
    bt = jnp.asarray(rng.permutation(num_blocks)[:B * nb].reshape(B, nb),
                     jnp.int32)
    cl = jnp.asarray(rng.integers(1, nb * bs + 1, (B,)), jnp.int32)
    got = pda.paged_decode_attention(q, kq, vq, bt, cl, k_scale=ks,
                                     v_scale=vs, interpret=True, **kw)
    want = pda.paged_decode_attention_jnp(q, kf, vf, bt, cl, **kw)
    g = np.asarray(got, np.float64).reshape(-1, hd)
    w = np.asarray(want, np.float64).reshape(-1, hd)
    cos = (g * w).sum(-1) / np.maximum(
        np.linalg.norm(g, axis=-1) * np.linalg.norm(w, axis=-1), 1e-30)
    assert cos.min() >= 0.999, f"min cosine {cos.min()}"


def test_quantize_roundtrip_extremes():
    """quantize_kv maps max-abs to ±127 and round-trips to <= 1/254
    relative error per token-head (symmetric per-token-head max-abs)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 5, 16, 64)) * 10.0, jnp.float32)
    xq, s = kv_quant.quantize_kv(x)
    assert int(jnp.abs(xq).max()) == 127
    back = kv_quant.dequantize_kv(xq, s)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    err = jnp.abs(back - x) / jnp.maximum(amax, 1e-8)
    assert float(err.max()) <= 1.0 / 254 + 1e-6
