"""Benchmark bit-rot guard: `benchmarks.run --quick` must execute EVERY
registered benchmark at tiny shapes and exit 0 — a benchmark that stops
importing or running fails tier-1 here, not at paper-figure time."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow  # CI's smoke job runs `benchmarks.run --quick` directly
def test_quick_mode_runs_every_registered_benchmark():
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT]))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--quick"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=1200)
    assert out.returncode == 0, out.stderr[-4000:]
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.run import MODULES
    finally:
        sys.path.pop(0)
    for label, _ in MODULES:
        assert f"# {label}:" in out.stderr, f"{label} did not run"
        assert "FAILED" not in out.stderr
    # CSV rows came out (header + at least one row per module)
    lines = [l for l in out.stdout.splitlines() if l.strip()]
    assert lines[0] == "name,us_per_call,derived"
    assert len(lines) > len(MODULES)
    # the new block-sharding scenario reports all three partitions
    for part in ("block", "head", "request"):
        assert any(l.startswith(f"block_shard_long1_{part}") for l in lines)
