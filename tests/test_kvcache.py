"""Paged KV cache: hypothesis-driven allocator invariants + data movement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import registry
from repro.serving.kvcache import OutOfBlocks, PagedKVCache


def _cache(num_blocks=32, block_size=4):
    cfg = registry.get_smoke_config("llama3-8b")
    return PagedKVCache(cfg, num_blocks, block_size)


@settings(deadline=None, max_examples=30)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["alloc", "append", "free"]),
              st.integers(0, 7), st.integers(1, 40)),
    min_size=1, max_size=60))
def test_allocator_invariants(ops):
    kv = _cache()
    total = kv.num_blocks
    for kind, sid, n in ops:
        try:
            if kind == "alloc" and sid not in kv.tables:
                kv.allocate(sid, n)
            elif kind == "append" and sid in kv.tables:
                kv.append_token(sid)
            elif kind == "free" and sid in kv.tables:
                kv.free_seq(sid)
        except OutOfBlocks:
            pass
        # invariants after every op:
        owned = [b for t in kv.tables.values() for b in t]
        assert len(owned) == len(set(owned)), "block owned twice"
        assert len(owned) + len(kv.free) == total, "blocks leaked"
        assert set(owned).isdisjoint(kv.free)
        for s, ln in kv.lengths.items():
            assert len(kv.tables[s]) * kv.block_size >= ln, \
                "capacity below token count"


def test_out_of_blocks_raises_and_preserves_state():
    kv = _cache(num_blocks=4, block_size=4)
    kv.allocate(1, 12)  # 3 blocks
    with pytest.raises(OutOfBlocks):
        kv.allocate(2, 12)
    assert 2 not in kv.tables
    assert len(kv.free) == 1
    kv.free_seq(1)
    assert len(kv.free) == 4


def test_write_gather_roundtrip():
    kv = _cache(num_blocks=16, block_size=4)
    cfg = kv.cfg
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(0)
    lens = {1: 7, 2: 10}
    data = {}
    for sid, n in lens.items():
        kv.allocate(sid, n)
        # prefill hands the pool HEAD-MAJOR (L, Hkv, S, hd) — no transpose
        k = jnp.asarray(rng.standard_normal((L, Hkv, n, hd)), cfg.dtype)
        v = jnp.asarray(rng.standard_normal((L, Hkv, n, hd)), cfg.dtype)
        kv.write_prefill(sid, k, v)
        data[sid] = (k, v)
    # append one token each: allocator bookkeeping + ONE batched scatter
    k1 = jnp.asarray(rng.standard_normal((L, 2, Hkv, hd)), cfg.dtype)
    v1 = jnp.asarray(rng.standard_normal((L, 2, Hkv, hd)), cfg.dtype)
    positions = [lens[sid] for sid in (1, 2)]
    for sid in lens:
        kv.append_token(sid)
    kv.write_tokens([1, 2], k1, v1, positions)
    for i, sid in enumerate((1, 2)):
        data[sid] = (jnp.concatenate([data[sid][0], k1[:, i, :, None]], 2),
                     jnp.concatenate([data[sid][1], v1[:, i, :, None]], 2))
    pad = 12
    k, v, out_lens = kv.gather([1, 2], pad)
    assert k.shape == (L, 2, pad, Hkv, hd)  # gather stays seq-major (oracle)
    for i, sid in enumerate([1, 2]):
        n = lens[sid] + 1
        assert int(out_lens[i]) == n
        np.testing.assert_array_equal(
            np.asarray(k[:, i, :n]),
            np.asarray(jnp.swapaxes(data[sid][0], 1, 2)))
        np.testing.assert_array_equal(
            np.asarray(v[:, i, :n]),
            np.asarray(jnp.swapaxes(data[sid][1], 1, 2)))


def test_write_token_single_matches_batched():
    """Per-sequence write_token (compat path) lands in the same slots as the
    batched write_tokens scatter."""
    cfg = registry.get_smoke_config("llama3-8b")
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(1)
    a, b = PagedKVCache(cfg, 16, 4), PagedKVCache(cfg, 16, 4)
    for kv in (a, b):
        kv.allocate(7, 5)
        kv.allocate(9, 3)
    k1 = jnp.asarray(rng.standard_normal((L, 2, Hkv, hd)), cfg.dtype)
    v1 = jnp.asarray(rng.standard_normal((L, 2, Hkv, hd)), cfg.dtype)
    for kv in (a, b):
        kv.append_token(7)
        kv.append_token(9)
    a.write_tokens([7, 9], k1, v1, [5, 3])
    b.write_token(7, k1[:, 0], v1[:, 0], 5)
    b.write_token(9, k1[:, 1], v1[:, 1], 3)
    np.testing.assert_array_equal(np.asarray(a.k_pool), np.asarray(b.k_pool))
    np.testing.assert_array_equal(np.asarray(a.v_pool), np.asarray(b.v_pool))


# ---------------------------------------------------------------------------
# Cross-chip block sharding (n_shards > 1): round-robin placement
# ---------------------------------------------------------------------------
def _sharded_cache(num_blocks=32, block_size=4, n_shards=4):
    cfg = registry.get_smoke_config("llama3-8b")
    return PagedKVCache(cfg, num_blocks, block_size, n_shards=n_shards)


def test_shards_must_divide_num_blocks():
    with pytest.raises(ValueError):
        _sharded_cache(num_blocks=30, n_shards=4)


def test_round_robin_spans_shards_within_one_block():
    """A single long sequence's blocks land round-robin: every shard holds
    KV and the per-shard live-token counts differ by at most one block —
    the `long_500k`-spans-chips acceptance criterion."""
    kv = _sharded_cache(num_blocks=64, block_size=4, n_shards=4)
    kv.allocate(0, 101)  # 26 blocks over 4 shards
    toks = kv.shard_live_tokens([0])
    assert (toks > 0).all()
    assert toks.max() - toks.min() <= kv.block_size
    assert toks.sum() == 101
    # appends keep the rotation going
    for _ in range(23):
        kv.append_token(0)
    toks = kv.shard_live_tokens([0])
    assert toks.max() - toks.min() <= kv.block_size
    assert toks.sum() == 124


def test_block_table_shards_local_ids_and_positions():
    """Local tables index each shard's contiguous pool slice; positions are
    the slot's global base; pad slots carry POS_PAD; the union reconstructs
    the global table exactly."""
    from repro.serving.kvcache import POS_PAD

    kv = _sharded_cache(num_blocks=32, block_size=4, n_shards=4)
    kv.allocate(0, 37)
    kv.allocate(1, 6)
    ids = [0, 1]
    lt, lp, st = kv.block_table_shards(ids)
    npb = kv.blocks_per_shard
    assert lt.shape == lp.shape and lt.shape[:2] == (4, 2)
    seen = {sid: {} for sid in ids}
    for s in range(4):
        for i, sid in enumerate(ids):
            for j in range(lt.shape[2]):
                if lp[s, i, j] == POS_PAD:
                    continue
                assert 0 <= lt[s, i, j] < npb
                slot = lp[s, i, j] // kv.block_size
                seen[sid][slot] = s * npb + int(lt[s, i, j])
    for sid in ids:
        assert [seen[sid][j] for j in range(len(kv.tables[sid]))] == \
            kv.tables[sid]
    # live-token accounting sums to the sequence lengths
    np.testing.assert_array_equal(st.sum(0), [37, 6])


def test_freed_blocks_return_to_owner_shard():
    kv = _sharded_cache(num_blocks=32, block_size=4, n_shards=4)
    kv.allocate(0, 40)
    kv.allocate(1, 24)
    kv.free_seq(0)
    kv.free_seq(1)
    npb = kv.blocks_per_shard
    for s, free in enumerate(kv._free_shard):
        assert len(free) == npb
        assert all(b // npb == s for b in free)


@settings(deadline=None, max_examples=20)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["alloc", "append", "free"]),
              st.integers(0, 7), st.integers(1, 40)),
    min_size=1, max_size=60))
def test_sharded_allocator_invariants(ops):
    """The base allocator invariants hold under shard-aware round-robin,
    plus: every free block sits in its owner shard's free list."""
    kv = _sharded_cache(num_blocks=32, block_size=4, n_shards=4)
    total = kv.num_blocks
    npb = kv.blocks_per_shard
    for kind, sid, n in ops:
        try:
            if kind == "alloc" and sid not in kv.tables:
                kv.allocate(sid, n)
            elif kind == "append" and sid in kv.tables:
                kv.append_token(sid)
            elif kind == "free" and sid in kv.tables:
                kv.free_seq(sid)
        except OutOfBlocks:
            pass
        owned = [b for t in kv.tables.values() for b in t]
        assert len(owned) == len(set(owned)), "block owned twice"
        assert len(owned) + len(kv.free) == total, "blocks leaked"
        assert set(owned).isdisjoint(kv.free)
        for s in range(kv.n_shards):
            assert all(b // npb == s for b in kv._free_shard[s])
        for s_id, ln in kv.lengths.items():
            assert len(kv.tables[s_id]) * kv.block_size >= ln
