"""Paged KV cache: hypothesis-driven allocator invariants + data movement."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import registry
from repro.serving.kvcache import OutOfBlocks, PagedKVCache


def _cache(num_blocks=32, block_size=4):
    cfg = registry.get_smoke_config("llama3-8b")
    return PagedKVCache(cfg, num_blocks, block_size)


@settings(deadline=None, max_examples=30)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["alloc", "append", "free"]),
              st.integers(0, 7), st.integers(1, 40)),
    min_size=1, max_size=60))
def test_allocator_invariants(ops):
    kv = _cache()
    total = kv.num_blocks
    for kind, sid, n in ops:
        try:
            if kind == "alloc" and sid not in kv.tables:
                kv.allocate(sid, n)
            elif kind == "append" and sid in kv.tables:
                kv.append_token(sid)
            elif kind == "free" and sid in kv.tables:
                kv.free_seq(sid)
        except OutOfBlocks:
            pass
        # invariants after every op:
        owned = [b for t in kv.tables.values() for b in t]
        assert len(owned) == len(set(owned)), "block owned twice"
        assert len(owned) + len(kv.free) == total, "blocks leaked"
        assert set(owned).isdisjoint(kv.free)
        for s, ln in kv.lengths.items():
            assert len(kv.tables[s]) * kv.block_size >= ln, \
                "capacity below token count"


def test_out_of_blocks_raises_and_preserves_state():
    kv = _cache(num_blocks=4, block_size=4)
    kv.allocate(1, 12)  # 3 blocks
    with pytest.raises(OutOfBlocks):
        kv.allocate(2, 12)
    assert 2 not in kv.tables
    assert len(kv.free) == 1
    kv.free_seq(1)
    assert len(kv.free) == 4


def test_write_gather_roundtrip():
    kv = _cache(num_blocks=16, block_size=4)
    cfg = kv.cfg
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(0)
    lens = {1: 7, 2: 10}
    data = {}
    for sid, n in lens.items():
        kv.allocate(sid, n)
        # prefill hands the pool HEAD-MAJOR (L, Hkv, S, hd) — no transpose
        k = jnp.asarray(rng.standard_normal((L, Hkv, n, hd)), cfg.dtype)
        v = jnp.asarray(rng.standard_normal((L, Hkv, n, hd)), cfg.dtype)
        kv.write_prefill(sid, k, v)
        data[sid] = (k, v)
    # append one token each: allocator bookkeeping + ONE batched scatter
    k1 = jnp.asarray(rng.standard_normal((L, 2, Hkv, hd)), cfg.dtype)
    v1 = jnp.asarray(rng.standard_normal((L, 2, Hkv, hd)), cfg.dtype)
    positions = [lens[sid] for sid in (1, 2)]
    for sid in lens:
        kv.append_token(sid)
    kv.write_tokens([1, 2], k1, v1, positions)
    for i, sid in enumerate((1, 2)):
        data[sid] = (jnp.concatenate([data[sid][0], k1[:, i, :, None]], 2),
                     jnp.concatenate([data[sid][1], v1[:, i, :, None]], 2))
    pad = 12
    k, v, out_lens = kv.gather([1, 2], pad)
    assert k.shape == (L, 2, pad, Hkv, hd)  # gather stays seq-major (oracle)
    for i, sid in enumerate([1, 2]):
        n = lens[sid] + 1
        assert int(out_lens[i]) == n
        np.testing.assert_array_equal(
            np.asarray(k[:, i, :n]),
            np.asarray(jnp.swapaxes(data[sid][0], 1, 2)))
        np.testing.assert_array_equal(
            np.asarray(v[:, i, :n]),
            np.asarray(jnp.swapaxes(data[sid][1], 1, 2)))


def test_write_token_single_matches_batched():
    """Per-sequence write_token (compat path) lands in the same slots as the
    batched write_tokens scatter."""
    cfg = registry.get_smoke_config("llama3-8b")
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(1)
    a, b = PagedKVCache(cfg, 16, 4), PagedKVCache(cfg, 16, 4)
    for kv in (a, b):
        kv.allocate(7, 5)
        kv.allocate(9, 3)
    k1 = jnp.asarray(rng.standard_normal((L, 2, Hkv, hd)), cfg.dtype)
    v1 = jnp.asarray(rng.standard_normal((L, 2, Hkv, hd)), cfg.dtype)
    for kv in (a, b):
        kv.append_token(7)
        kv.append_token(9)
    a.write_tokens([7, 9], k1, v1, [5, 3])
    b.write_token(7, k1[:, 0], v1[:, 0], 5)
    b.write_token(9, k1[:, 1], v1[:, 1], 3)
    np.testing.assert_array_equal(np.asarray(a.k_pool), np.asarray(b.k_pool))
    np.testing.assert_array_equal(np.asarray(a.v_pool), np.asarray(b.v_pool))


# ---------------------------------------------------------------------------
# Cross-chip block sharding (n_shards > 1): round-robin placement
# ---------------------------------------------------------------------------
def _sharded_cache(num_blocks=32, block_size=4, n_shards=4):
    cfg = registry.get_smoke_config("llama3-8b")
    return PagedKVCache(cfg, num_blocks, block_size, n_shards=n_shards)


def test_shards_must_divide_num_blocks():
    with pytest.raises(ValueError):
        _sharded_cache(num_blocks=30, n_shards=4)


def test_round_robin_spans_shards_within_one_block():
    """A single long sequence's blocks land round-robin: every shard holds
    KV and the per-shard live-token counts differ by at most one block —
    the `long_500k`-spans-chips acceptance criterion."""
    kv = _sharded_cache(num_blocks=64, block_size=4, n_shards=4)
    kv.allocate(0, 101)  # 26 blocks over 4 shards
    toks = kv.shard_live_tokens([0])
    assert (toks > 0).all()
    assert toks.max() - toks.min() <= kv.block_size
    assert toks.sum() == 101
    # appends keep the rotation going
    for _ in range(23):
        kv.append_token(0)
    toks = kv.shard_live_tokens([0])
    assert toks.max() - toks.min() <= kv.block_size
    assert toks.sum() == 124


def test_block_table_shards_local_ids_and_positions():
    """Local tables index each shard's contiguous pool slice; positions are
    the slot's global base; pad slots carry POS_PAD; the union reconstructs
    the global table exactly."""
    from repro.serving.kvcache import POS_PAD

    kv = _sharded_cache(num_blocks=32, block_size=4, n_shards=4)
    kv.allocate(0, 37)
    kv.allocate(1, 6)
    ids = [0, 1]
    lt, lp, st = kv.block_table_shards(ids)
    npb = kv.blocks_per_shard
    assert lt.shape == lp.shape and lt.shape[:2] == (4, 2)
    seen = {sid: {} for sid in ids}
    for s in range(4):
        for i, sid in enumerate(ids):
            for j in range(lt.shape[2]):
                if lp[s, i, j] == POS_PAD:
                    continue
                assert 0 <= lt[s, i, j] < npb
                slot = lp[s, i, j] // kv.block_size
                seen[sid][slot] = s * npb + int(lt[s, i, j])
    for sid in ids:
        assert [seen[sid][j] for j in range(len(kv.tables[sid]))] == \
            kv.tables[sid]
    # live-token accounting sums to the sequence lengths
    np.testing.assert_array_equal(st.sum(0), [37, 6])


def test_freed_blocks_return_to_owner_shard():
    kv = _sharded_cache(num_blocks=32, block_size=4, n_shards=4)
    kv.allocate(0, 40)
    kv.allocate(1, 24)
    kv.free_seq(0)
    kv.free_seq(1)
    npb = kv.blocks_per_shard
    for s, free in enumerate(kv._free_shard):
        assert len(free) == npb
        assert all(b // npb == s for b in free)


# ---------------------------------------------------------------------------
# Prefix sharing: refcounts, share_blocks, copy-on-write
# ---------------------------------------------------------------------------
def _check_ref_invariants(kv):
    """The refcount invariants that replace exclusive ownership."""
    refs = {}
    for t in kv.tables.values():
        for b in t:
            refs[b] = refs.get(b, 0) + 1
    assert refs == kv.refcounts, "refcount != live table references"
    assert len(refs) + len(kv.free) == kv.num_blocks, "blocks leaked"
    assert set(refs).isdisjoint(kv.free)
    npb = kv.blocks_per_shard
    for s in range(kv.n_shards):
        assert all(b // npb == s for b in kv._free_shard[s])
    for sid, ln in kv.lengths.items():
        assert len(kv.tables[sid]) * kv.block_size >= ln


def test_share_blocks_refcounts_and_free_order():
    kv = _cache(num_blocks=16, block_size=4)
    kv.allocate(0, 10)                       # 3 blocks
    assert kv.share_blocks(0, 1, 8) == 2     # 2 full blocks, no pool cost
    assert kv.used_blocks == 3               # physical, shared counted once
    assert [kv.refcounts[b] for b in kv.tables[0]] == [2, 2, 1]
    assert kv.tables[1] == kv.tables[0][:2]
    kv.allocate(1, 14)                       # extend: 2 shared + 2 private
    assert len(kv.tables[1]) == 4 and kv.used_blocks == 5
    _check_ref_invariants(kv)
    # donor frees first: shared blocks survive through the recipient
    donor_blocks = list(kv.tables[0])
    kv.free_seq(0)
    assert kv.refcounts[donor_blocks[0]] == 1
    assert donor_blocks[2] in kv.free        # donor-private block released
    assert donor_blocks[0] not in kv.free
    _check_ref_invariants(kv)
    kv.free_seq(1)
    assert len(kv.free) == kv.num_blocks
    assert kv.refcounts == {}


def test_share_blocks_validates_range_and_double_alloc():
    kv = _cache(num_blocks=8, block_size=4)
    kv.allocate(0, 6)
    with pytest.raises(ValueError):
        kv.share_blocks(0, 1, 7)             # beyond donor's stored tokens
    with pytest.raises(ValueError):
        kv.share_blocks(0, 1, 0)
    kv.share_blocks(0, 1, 4)
    with pytest.raises(AssertionError):
        kv.share_blocks(0, 1, 4)             # dst already allocated


def test_cow_fork_parity_vs_unshared_oracle():
    """Fork a sequence at a NON-aligned point (partial tail shared), let
    both sides append divergent tokens: pool contents must match two
    independent caches written with the same data, and the donor's bytes
    must never change."""
    cfg = registry.get_smoke_config("llama3-8b")
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(5)

    def tok(seed):
        r = np.random.default_rng(seed)
        return (jnp.asarray(r.standard_normal((L, Hkv, hd)), cfg.dtype),
                jnp.asarray(r.standard_normal((L, Hkv, hd)), cfg.dtype))

    shared = PagedKVCache(cfg, 16, 4)
    oracle = PagedKVCache(cfg, 16, 4)
    n0 = 6                                   # 1 full block + 2-token tail
    k = jnp.asarray(rng.standard_normal((L, Hkv, n0, hd)), cfg.dtype)
    v = jnp.asarray(rng.standard_normal((L, Hkv, n0, hd)), cfg.dtype)
    shared.allocate(0, n0)
    shared.write_prefill(0, k, v)
    shared.share_blocks(0, 1, n0)            # fork: partial tail shared too
    assert shared.used_blocks == 2
    oracle.allocate(0, n0)
    oracle.write_prefill(0, k, v)
    oracle.allocate(1, n0)
    oracle.write_prefill(1, k, v)
    # both sides diverge: different tokens at position 6. The FIRST writer
    # needs a fresh block (CoW fork); afterwards the tail is private on
    # both sides and the second write goes in place.
    for i, (sid, seed) in enumerate(((0, 10), (1, 11))):
        for kvc in (shared, oracle):
            expect = 1 if (kvc is shared and i == 0) else 0
            assert kvc.blocks_to_append(sid) == expect
            kvc.append_token(sid)
            ka, va = tok(seed)
            kvc.write_token(sid, ka, va, n0)
    assert shared.cow_forks == 1             # exactly the partial tail
    assert shared.used_blocks == 3           # full block still shared once
    _check_ref_invariants(shared)
    for sid in (0, 1):
        ks, vs, _ = shared.gather([sid], 8)
        ko, vo, _ = oracle.gather([sid], 8)
        np.testing.assert_array_equal(np.asarray(ks), np.asarray(ko))
        np.testing.assert_array_equal(np.asarray(vs), np.asarray(vo))


def test_borrower_prefill_cow_never_corrupts_donor():
    """A borrower re-prefilling over still-shared blocks (divergent write)
    forks them; the donor's bytes are untouched. The ORIGINAL allocator's
    write goes through in place — it is the canonical fill recipients that
    shared within the same admission wave are waiting on."""
    cfg = registry.get_smoke_config("llama3-8b")
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(6)
    kv = _cache(num_blocks=16, block_size=4)
    kv.allocate(0, 8)
    kv.share_blocks(0, 1, 8)                 # borrow BEFORE the donor fill
    k0 = jnp.asarray(rng.standard_normal((L, Hkv, 8, hd)), cfg.dtype)
    v0 = jnp.asarray(rng.standard_normal((L, Hkv, 8, hd)), cfg.dtype)
    kv.write_prefill(0, k0, v0)              # donor fill: NO fork, in place
    assert kv.cow_forks == 0
    assert kv.tables[1] == kv.tables[0]
    # borrower diverges with a full re-prefill: fork, donor intact
    k1 = jnp.asarray(rng.standard_normal((L, Hkv, 8, hd)), cfg.dtype)
    v1 = jnp.asarray(rng.standard_normal((L, Hkv, 8, hd)), cfg.dtype)
    kv.write_prefill(1, k1, v1)
    assert kv.cow_forks == 2
    assert set(kv.tables[1]).isdisjoint(kv.tables[0])
    kd, vd, _ = kv.gather([0], 8)
    np.testing.assert_array_equal(
        np.asarray(kd[:, 0]), np.asarray(jnp.swapaxes(k0, 1, 2)))
    kb, _, _ = kv.gather([1], 8)
    np.testing.assert_array_equal(
        np.asarray(kb[:, 0]), np.asarray(jnp.swapaxes(k1, 1, 2)))
    _check_ref_invariants(kv)


def test_gather_prefix_roundtrips_write_prefill():
    """gather_prefix returns the head-major (L, Hkv, P, hd) prefix exactly
    as write_prefill stored it — the layout contract the engine's fused
    suffix-prefill gather (LLMEngine._suffix_prefill) relies on — and a
    recipient's gather through SHARED blocks sees the donor's bytes."""
    cfg = registry.get_smoke_config("llama3-8b")
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(9)
    kv = _cache(num_blocks=16, block_size=4)
    kv.allocate(0, 11)
    k = jnp.asarray(rng.standard_normal((L, Hkv, 11, hd)), cfg.dtype)
    v = jnp.asarray(rng.standard_normal((L, Hkv, 11, hd)), cfg.dtype)
    kv.write_prefill(0, k, v)
    kv.share_blocks(0, 1, 8)
    kp, vp = kv.gather_prefix(1, 8)          # through the SHARED table
    assert kp.shape == (L, Hkv, 8, hd)
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(k[:, :, :8]))
    np.testing.assert_array_equal(np.asarray(vp), np.asarray(v[:, :, :8]))
    with pytest.raises(ValueError, match="block-aligned"):
        kv.gather_prefix(0, 6)


def test_shared_accounting_counts_physical_blocks_once():
    kv = _sharded_cache(num_blocks=32, block_size=4, n_shards=4)
    kv.allocate(0, 16)                       # 4 blocks round-robin
    kv.share_blocks(0, 1, 16)
    kv.allocate(1, 20)                       # +1 private block
    assert kv.used_blocks == 5
    assert kv.unique_live_tokens() == 20
    assert int(kv.shard_live_tokens().sum()) == 20
    lt, lp, st_ = kv.block_table_shards([0, 1])
    assert int(st_.sum()) == 20              # shared blocks counted once
    # per-sequence tables still BOTH walk the shared blocks (reads)
    assert lt.shape[1] == 2
    # partial-tail share (fork): resident tokens use the DEEPEST fill among
    # sharers regardless of batch order — same rule everywhere
    kv2 = _cache(num_blocks=16, block_size=4)
    kv2.allocate(0, 6)
    kv2.share_blocks(0, 1, 5)
    for order in ([0, 1], [1, 0]):
        _, _, st2 = kv2.block_table_shards(order)
        assert int(st2.sum()) == 6
    assert kv2.unique_live_tokens() == 6
    assert int(kv2.shard_live_tokens().sum()) == 6


@settings(deadline=None, max_examples=30)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["alloc", "append", "free", "share"]),
              st.integers(0, 5), st.integers(1, 30)),
    min_size=1, max_size=80))
def test_refcount_invariants_under_interleaved_share_append_free(ops):
    """The tentpole's allocator invariant: arbitrary interleavings of
    allocate / share_blocks / append_token (CoW) / free_seq keep refcounts
    exactly equal to live table references, never leak or double-free a
    block, and keep every free block in its owner shard's list."""
    kv = _sharded_cache(num_blocks=32, block_size=4, n_shards=2)
    for kind, sid, n in ops:
        try:
            if kind == "alloc" and sid not in kv.tables:
                kv.allocate(sid, n)
            elif kind == "append" and sid in kv.tables:
                kv.append_token(sid)
            elif kind == "free" and sid in kv.tables:
                kv.free_seq(sid)
            elif kind == "share" and sid in kv.tables:
                dst = (sid + 1) % 6
                if dst not in kv.tables and n <= kv.lengths[sid]:
                    kv.share_blocks(sid, dst, n)
        except OutOfBlocks:
            pass
        _check_ref_invariants(kv)


@settings(deadline=None, max_examples=20)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["alloc", "append", "free"]),
              st.integers(0, 7), st.integers(1, 40)),
    min_size=1, max_size=60))
def test_sharded_allocator_invariants(ops):
    """The base allocator invariants hold under shard-aware round-robin,
    plus: every free block sits in its owner shard's free list."""
    kv = _sharded_cache(num_blocks=32, block_size=4, n_shards=4)
    total = kv.num_blocks
    npb = kv.blocks_per_shard
    for kind, sid, n in ops:
        try:
            if kind == "alloc" and sid not in kv.tables:
                kv.allocate(sid, n)
            elif kind == "append" and sid in kv.tables:
                kv.append_token(sid)
            elif kind == "free" and sid in kv.tables:
                kv.free_seq(sid)
        except OutOfBlocks:
            pass
        owned = [b for t in kv.tables.values() for b in t]
        assert len(owned) == len(set(owned)), "block owned twice"
        assert len(owned) + len(kv.free) == total, "blocks leaked"
        assert set(owned).isdisjoint(kv.free)
        for s in range(kv.n_shards):
            assert all(b // npb == s for b in kv._free_shard[s])
        for s_id, ln in kv.lengths.items():
            assert len(kv.tables[s_id]) * kv.block_size >= ln
