"""Property tests for the §4.2.2 partial-softmax combine identity — the
mathematical core of attention offloading, the flash-decode kernel, and the
sequence-parallel sharding."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import combine as C


def _softmax_attention(q, k, v):
    s = (q @ k.T) / np.sqrt(q.shape[-1])
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return p @ v


@settings(deadline=None, max_examples=40)
@given(
    n=st.integers(2, 24),
    hd=st.sampled_from([4, 16]),
    cut=st.data(),
    seed=st.integers(0, 2**16),
)
def test_two_way_split_matches_full(n, hd, cut, seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((hd,)).astype(np.float32)
    k = rng.standard_normal((n, hd)).astype(np.float32)
    v = rng.standard_normal((n, hd)).astype(np.float32)
    i = cut.draw(st.integers(1, n - 1))
    p1 = C.partial_attention(jnp.asarray(q), jnp.asarray(k[:i]),
                             jnp.asarray(v[:i]))
    p2 = C.partial_attention(jnp.asarray(q), jnp.asarray(k[i:]),
                             jnp.asarray(v[i:]))
    got = np.asarray(C.finalize(C.combine(p1, p2)))
    want = _softmax_attention(q[None], k, v)[0]
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@settings(deadline=None, max_examples=25)
@given(
    n=st.integers(3, 30),
    parts=st.integers(2, 5),
    seed=st.integers(0, 2**16),
    permute=st.booleans(),
)
def test_many_way_split_associative_commutative(n, parts, seed, permute):
    """combine() over any disjoint partition, in any merge order."""
    rng = np.random.default_rng(seed)
    hd = 8
    q = rng.standard_normal((hd,)).astype(np.float32)
    k = rng.standard_normal((n, hd)).astype(np.float32)
    v = rng.standard_normal((n, hd)).astype(np.float32)
    cuts = sorted(rng.choice(np.arange(1, n), size=min(parts - 1, n - 1),
                             replace=False))
    segments = np.split(np.arange(n), cuts)
    partials = [C.partial_attention(jnp.asarray(q), jnp.asarray(k[idx]),
                                    jnp.asarray(v[idx]))
                for idx in segments if len(idx)]
    if permute:
        order = rng.permutation(len(partials))
        partials = [partials[i] for i in order]
    got = np.asarray(C.finalize(C.combine_many(partials)))
    want = _softmax_attention(q[None], k, v)[0]
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_empty_subset_is_identity():
    rng = np.random.default_rng(0)
    hd, n = 8, 6
    q = jnp.asarray(rng.standard_normal((hd,)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((n, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((n, hd)), jnp.float32)
    full = C.partial_attention(q, k, v)
    empty = C.partial_attention(q, k, v, mask=jnp.zeros((n,), bool))
    merged = C.combine(full, empty)
    np.testing.assert_allclose(np.asarray(C.finalize(merged)),
                               np.asarray(C.finalize(full)), atol=1e-6)


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**16), extreme=st.sampled_from([40.0, 80.0]))
def test_numerical_stability_large_logits(seed, extreme):
    """Partials with wildly different maxima must still merge stably."""
    rng = np.random.default_rng(seed)
    hd = 8
    q = rng.standard_normal((hd,)).astype(np.float32) * extreme
    k = rng.standard_normal((10, hd)).astype(np.float32)
    v = rng.standard_normal((10, hd)).astype(np.float32)
    p1 = C.partial_attention(jnp.asarray(q), jnp.asarray(k[:5]),
                             jnp.asarray(v[:5]))
    p2 = C.partial_attention(jnp.asarray(q), jnp.asarray(k[5:]),
                             jnp.asarray(v[5:]))
    got = np.asarray(C.finalize(C.combine(p1, p2)))
    assert np.all(np.isfinite(got))
    want = _softmax_attention(q[None].astype(np.float64),
                              k.astype(np.float64), v.astype(np.float64))[0]
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)
