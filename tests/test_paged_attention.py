"""Paged flash-decode attention: kernel (interpret) + jnp reference parity
against the dense oracle across ragged lengths / GQA / window+sinks /
softcap; §4.2.2 partial-merge; and the end-to-end pool invariant that
`write_tokens` + paged attention == `gather()` + dense attention under
random alloc/append/free interleavings (deterministic sweep + hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import registry
from repro.core import combine as C
from repro.kernels import ref
from repro.kernels.paged_decode_attention import (paged_decode_attention,
                                                 paged_decode_attention_jnp,
                                                 paged_gather_dense)
from repro.models.attention import (decode_attention_partial_jnp,
                                    paged_decode_attention_partial_jnp)
from repro.serving.kvcache import PagedKVCache


def _rand_paged(seed, B, Hkv, G, hd, bs, nb, spare_blocks=3):
    """Random pool + per-seq block tables with distinct blocks + ragged
    lengths. Returns (q, k_pool, v_pool, block_tables, cache_len)."""
    NB = B * nb + spare_blocks
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd))
    k_pool = jax.random.normal(ks[1], (Hkv, NB, bs, hd))
    v_pool = jax.random.normal(ks[2], (Hkv, NB, bs, hd))
    bt = jax.random.permutation(ks[3], NB)[:B * nb].reshape(B, nb)
    bt = bt.astype(jnp.int32)
    clen = jax.random.randint(ks[4], (B,), 1, nb * bs + 1)
    return q, k_pool, v_pool, bt, clen


@pytest.mark.parametrize("B,Hkv,G,hd,bs,nb", [
    (1, 1, 1, 64, 16, 4),
    (2, 2, 4, 64, 16, 3),       # GQA groups
    (3, 4, 8, 128, 8, 5),       # many small blocks
    (2, 8, 2, 128, 32, 2),
    (1, 2, 16, 64, 16, 7),      # big GQA group, ragged
])
def test_paged_kernel_matches_dense_oracle(B, Hkv, G, hd, bs, nb):
    q, kp, vp, bt, clen = _rand_paged(B * hd + nb, B, Hkv, G, hd, bs, nb)
    out = paged_decode_attention(q, kp, vp, bt, clen, interpret=True)
    kc, vc = paged_gather_dense(kp, vp, bt)
    want = ref.decode_attention_ref(q, kc, vc, clen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # the jnp reference path agrees too
    want2 = paged_decode_attention_jnp(q, kp, vp, bt, clen)
    np.testing.assert_allclose(np.asarray(want2), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("sw,sinks,cap", [
    (20, 0, 0.0), (0, 0, 30.0), (17, 4, 0.0), (11, 2, 50.0)])
def test_paged_kernel_window_sinks_softcap(sw, sinks, cap):
    B, Hkv, G, hd, bs, nb = 2, 2, 4, 64, 16, 4
    q, kp, vp, bt, clen = _rand_paged(7, B, Hkv, G, hd, bs, nb)
    out = paged_decode_attention(q, kp, vp, bt, clen, sliding_window=sw,
                                 attention_sinks=sinks, logit_softcap=cap,
                                 interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, clen,
                                          sliding_window=sw,
                                          attention_sinks=sinks,
                                          logit_softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_kernel_partials_merge():
    """The paged kernel's (o, l, m) triple must merge per §4.2.2: attention
    over [0, n) == combine(paged partial over [0, n-1), new token)."""
    B, Hkv, G, hd, bs, nb = 2, 2, 4, 64, 16, 4
    q, kp, vp, bt, clen = _rand_paged(3, B, Hkv, G, hd, bs, nb)
    clen = jnp.maximum(clen, 2)
    kc, vc = paged_gather_dense(kp, vp, bt)
    want = ref.decode_attention_ref(q, kc, vc, clen)
    o, l, m = paged_decode_attention(q, kp, vp, bt, clen - 1,
                                     interpret=True, return_partials=True)
    p_prev = C.Partial(a=o.astype(jnp.float32) * l[..., None], s=l, m=m)
    b = jnp.arange(B)
    p_new = C.partial_attention(q, kc[b, :, clen - 1][:, :, None, None],
                                vc[b, :, clen - 1][:, :, None, None])
    merged = C.finalize(C.combine(p_prev, p_new))
    np.testing.assert_allclose(np.asarray(merged), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_partial_backend_matches_dense_partial():
    """models.attention paged 'jnp' backend == dense partial over the
    gathered view (the engines' hot-path contract)."""
    B, Hkv, G, hd, bs, nb = 3, 4, 2, 64, 8, 5
    q, kp, vp, bt, clen = _rand_paged(11, B, Hkv, G, hd, bs, nb)
    qf = q.reshape(B, Hkv * G, hd)
    kc, vc = paged_gather_dense(kp, vp, bt)
    for kw in ({}, {"sliding_window": 9, "attention_sinks": 2},
               {"logit_softcap": 25.0}):
        p_paged = paged_decode_attention_partial_jnp(qf, kp, vp, bt, clen,
                                                     **kw)
        p_dense = decode_attention_partial_jnp(qf, kc, vc, clen, **kw)
        for a, b in zip(p_paged, p_dense):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# Pool-level end-to-end invariant
# ---------------------------------------------------------------------------
def _run_pool_ops(ops, seed=0):
    """Drive a PagedKVCache through (kind, sid, n) ops, mirroring contents
    host-side; after every decode-like append the token lands via the
    batched write_tokens. Returns (kv, mirror: sid -> (k, v) head-major)."""
    from repro.serving.kvcache import OutOfBlocks

    cfg = registry.get_smoke_config("llama3-8b")
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    kv = PagedKVCache(cfg, num_blocks=32, block_size=4)
    rng = np.random.default_rng(seed)
    mirror = {}
    for kind, sid, n in ops:
        try:
            if kind == "alloc" and sid not in kv.tables:
                kv.allocate(sid, n)
                k = jnp.asarray(rng.standard_normal((L, Hkv, n, hd)),
                                cfg.dtype)
                v = jnp.asarray(rng.standard_normal((L, Hkv, n, hd)),
                                cfg.dtype)
                kv.write_prefill(sid, k, v)
                mirror[sid] = (k, v)
            elif kind == "append" and sid in kv.tables:
                pos = kv.lengths[sid]
                kv.append_token(sid)
                k1 = jnp.asarray(rng.standard_normal((L, 1, Hkv, hd)),
                                 cfg.dtype)
                v1 = jnp.asarray(rng.standard_normal((L, 1, Hkv, hd)),
                                 cfg.dtype)
                kv.write_tokens([sid], k1, v1, [pos])
                mirror[sid] = (
                    jnp.concatenate([mirror[sid][0],
                                     jnp.swapaxes(k1, 1, 2)], 2),
                    jnp.concatenate([mirror[sid][1],
                                     jnp.swapaxes(v1, 1, 2)], 2))
            elif kind == "free" and sid in kv.tables:
                kv.free_seq(sid)
                del mirror[sid]
        except OutOfBlocks:
            pass
    return kv, mirror


def _assert_paged_equals_dense(kv, mirror, seed=0):
    """For the live batch: block_table_batch + paged attention must equal
    gather() + dense attention — per layer, both jnp and kernel paths."""
    ids = sorted(kv.tables)
    if not ids:
        return
    cfg = kv.cfg
    Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    G = cfg.num_heads // Hkv
    B = len(ids)
    tables, lens = kv.block_table_batch(ids)
    bt, ln = jnp.asarray(tables), jnp.asarray(lens)
    pad = int(tables.shape[1]) * kv.block_size
    kd, vd, _ = kv.gather(ids, pad)   # dense oracle (L, B, pad, Hkv, hd)
    q = jax.random.normal(jax.random.PRNGKey(seed), (B, Hkv, G, hd))
    for layer in (0, kd.shape[0] - 1):
        want = ref.decode_attention_ref(
            q, jnp.swapaxes(kd[layer], 1, 2).astype(jnp.float32),
            jnp.swapaxes(vd[layer], 1, 2).astype(jnp.float32), ln)
        got_jnp = paged_decode_attention_jnp(
            q, kv.k_pool[layer].astype(jnp.float32),
            kv.v_pool[layer].astype(jnp.float32), bt, ln)
        np.testing.assert_allclose(np.asarray(got_jnp), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        got_krn = paged_decode_attention(
            q, kv.k_pool[layer].astype(jnp.float32),
            kv.v_pool[layer].astype(jnp.float32), bt, ln, interpret=True)
        np.testing.assert_allclose(np.asarray(got_krn), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
    # and the pool contents round-trip exactly (head-major mirror)
    for i, sid in enumerate(ids):
        n = kv.lengths[sid]
        np.testing.assert_array_equal(
            np.asarray(kd[:, i, :n]),
            np.asarray(jnp.swapaxes(mirror[sid][0], 1, 2)))


def test_paged_equals_dense_after_deterministic_interleaving():
    rng = np.random.default_rng(42)
    ops = []
    for _ in range(60):
        kind = rng.choice(["alloc", "append", "append", "free"])
        ops.append((str(kind), int(rng.integers(0, 6)),
                    int(rng.integers(1, 20))))
    kv, mirror = _run_pool_ops(ops, seed=1)
    _assert_paged_equals_dense(kv, mirror, seed=2)


@settings(deadline=None, max_examples=15)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["alloc", "append", "append", "free"]),
              st.integers(0, 5), st.integers(1, 20)),
    min_size=1, max_size=40))
def test_paged_equals_dense_hypothesis(ops):
    kv, mirror = _run_pool_ops(ops, seed=3)
    _assert_paged_equals_dense(kv, mirror, seed=4)


# ---------------------------------------------------------------------------
# Block-sharded partials: positions-aware kernel/jnp paths
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sw,sinks,cap", [
    (0, 0, 0.0), (20, 0, 0.0), (17, 4, 0.0), (11, 2, 30.0)])
def test_block_sharded_partials_merge_to_oracle(sw, sinks, cap):
    """Split a table's blocks over n shards (contiguous pool slices, masked
    foreign slots with POS_PAD positions); per-shard partials — Pallas
    kernel with block_positions AND the positions-aware jnp partial — must
    combine_many to the full-table oracle, window/sinks included."""
    from repro.kernels.paged_decode_attention import (POS_PAD,
                                                     paged_decode_attention)
    from repro.models.attention import paged_decode_attention_partial_pos_jnp

    B, Hkv, G, hd, bs, nb, n = 2, 2, 4, 64, 16, 5, 3
    NB = 24  # divisible by n
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd))
    kp = jax.random.normal(ks[1], (Hkv, NB, bs, hd))
    vp = jax.random.normal(ks[2], (Hkv, NB, bs, hd))
    bt = jax.random.permutation(ks[3], NB)[:B * nb].reshape(B, nb)
    bt = bt.astype(jnp.int32)
    clen = jnp.array([nb * bs, 37], jnp.int32)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, clen,
                                          sliding_window=sw,
                                          attention_sinks=sinks,
                                          logit_softcap=cap)
    npb = NB // n
    base = jnp.arange(nb, dtype=jnp.int32)[None, :] * bs
    owner, local = bt // npb, bt % npb
    parts_k, parts_j = [], []
    qf = q.reshape(B, Hkv * G, hd)
    for s in range(n):
        pos = jnp.where(owner == s, base, POS_PAD)
        sl = slice(s * npb, (s + 1) * npb)
        o, l, m = paged_decode_attention(
            q, kp[:, sl], vp[:, sl], local, clen, block_positions=pos,
            sliding_window=sw, attention_sinks=sinks, logit_softcap=cap,
            interpret=True, return_partials=True)
        parts_k.append(C.Partial(a=o.astype(jnp.float32) * l[..., None],
                                 s=l, m=m))
        parts_j.append(paged_decode_attention_partial_pos_jnp(
            qf, kp[:, sl], vp[:, sl], local, pos, clen, window_total=clen,
            sliding_window=sw, attention_sinks=sinks, logit_softcap=cap))
    got_k = C.finalize(C.combine_many(parts_k))
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    got_j = C.finalize(C.combine_many(parts_j)).reshape(B, Hkv, G, hd)
    np.testing.assert_allclose(np.asarray(got_j), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_empty_shard_partial_is_combine_identity():
    """A shard owning zero of a sequence's blocks (routine under block
    sharding) must contribute the identity partial."""
    from repro.kernels.paged_decode_attention import (POS_PAD,
                                                     paged_decode_attention)
    from repro.models.attention import paged_decode_attention_partial_pos_jnp

    B, Hkv, G, hd, bs, nb = 1, 2, 2, 64, 8, 3
    q, kp, vp, bt, clen = _rand_paged(9, B, Hkv, G, hd, bs, nb)
    pos_all_pad = jnp.full_like(bt, POS_PAD)
    o, l, m = paged_decode_attention(q, kp, vp, bt, clen,
                                     block_positions=pos_all_pad,
                                     interpret=True, return_partials=True)
    assert float(jnp.max(l)) == 0.0
    assert float(jnp.max(o.astype(jnp.float32))) == 0.0
    p_empty = paged_decode_attention_partial_pos_jnp(
        q.reshape(B, Hkv * G, hd), kp, vp, bt, pos_all_pad, clen)
    assert float(jnp.max(p_empty.s)) == 0.0
    assert np.all(np.asarray(p_empty.m) == -np.inf)
    # merging the empty partial into a real one changes nothing
    full = paged_decode_attention_partial_pos_jnp(
        q.reshape(B, Hkv * G, hd), kp, vp, bt,
        jnp.arange(nb, dtype=jnp.int32)[None, :] * bs, clen)
    merged = C.finalize(C.combine(full, p_empty))
    np.testing.assert_allclose(np.asarray(merged),
                               np.asarray(C.finalize(full)), atol=1e-6)


@pytest.mark.parametrize("sw,sinks", [(1, 0), (1, 3), (2, 0)])
def test_pallas_backend_matches_jnp_at_tiny_windows(sw, sinks):
    """Serving-contract window mapping: sliding_window=1 means only the
    incoming token is in-window (stored prefix reduces to the sinks) — the
    pallas partial backend must agree with the jnp one, not silently drop
    the mask (kernel sw=0 means 'no window')."""
    import repro.kernels.ops as ops
    from repro.models.attention import paged_decode_attention_partial_jnp

    B, Hkv, G, hd, bs, nb = 2, 2, 2, 64, 8, 3
    q, kp, vp, bt, clen = _rand_paged(13, B, Hkv, G, hd, bs, nb)
    qf = q.reshape(B, Hkv * G, hd)
    kw = dict(sliding_window=sw, attention_sinks=sinks)
    p_jnp = paged_decode_attention_partial_jnp(qf, kp, vp, bt, clen, **kw)
    p_pal = ops._pallas_paged_decode_partial_backend(qf, kp, vp, bt, clen,
                                                     **kw)
    # compare finalized outputs merged with nothing: a/s may differ in
    # normalisation base (m) but finalize(a/s) must agree; guard the empty
    # case (sw=1, sinks=0 -> s == 0 on both)
    np.testing.assert_allclose(np.asarray(p_pal.s), np.asarray(p_jnp.s),
                               atol=2e-5, rtol=2e-5)
    denom_j = np.maximum(np.asarray(p_jnp.s), 1e-30)[..., None]
    denom_p = np.maximum(np.asarray(p_pal.s), 1e-30)[..., None]
    np.testing.assert_allclose(np.asarray(p_pal.a) / denom_p,
                               np.asarray(p_jnp.a) / denom_j,
                               atol=2e-5, rtol=2e-5)
