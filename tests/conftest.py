import os
import sys

# tests run on the single real CPU device; ONLY the sharding tests ask for
# more via the xdist-safe subprocess helper (never set the device-count flag
# globally — the dry-run owns that, see launch/dryrun.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
