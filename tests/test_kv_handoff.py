"""Block-granular KV handoff (`PagedKVCache.export_seqs` / `import_seqs`):
the prefill→decode wire unit of the disaggregated cluster.

Hypothesis property: export→import round-trips EXACTLY — destination
tables isomorphic to the source tables under the returned src→dst block
mapping, refcounts equal to the referencing-table-entry count (so shared
prefixes stay shared on the destination pool), and every physical block's
pool bytes bit-identical — with each refcount-shared/CoW block crossing
the wire ONCE per physical block, across source/destination pools with
different shard counts.

Plus the interruption path: a decode-side shard death mid-transfer
(serving/faults.py injection, `transfer_blocks_per_step=1` stretching the
landing window) resets and retries the import with greedy outputs still
bit-identical, and exhausting the retry budget raises a contextual
:class:`HandoffError` (rid, replica, blocks in flight, stage — the PR 6
``PoolExhausted`` convention).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import registry
from repro.models import transformer
from repro.serving import (DisaggConfig, EngineConfig, FaultInjector,
                           FaultScenario, LLMEngine, PagedKVCache,
                           PoolExhausted, Request, SamplingParams)
from repro.serving.cluster import (DecodeEngine, DisaggCluster,
                                   HandoffError, PrefillEngine)


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _randomise(kv, seed):
    """Fill the pool with recognisable (non-zero) content so bit-exact
    comparisons are meaningful."""
    rng = np.random.default_rng(seed)
    kv.k_pool = jnp.asarray(rng.standard_normal(kv.k_pool.shape),
                            kv.k_pool.dtype)
    kv.v_pool = jnp.asarray(rng.standard_normal(kv.v_pool.shape),
                            kv.v_pool.dtype)


# ======================================================================
# the round-trip property
# ======================================================================
@settings(deadline=None, max_examples=12)
@given(data=st.data())
def test_export_import_roundtrip_exact(setup, data):
    """tables/refcounts/lengths/bytes survive the wire exactly, shared
    blocks transfer once, across differing shard geometries."""
    cfg, _ = setup
    bs = 4
    n_seqs = data.draw(st.integers(1, 4), label="n_seqs")
    lens = [data.draw(st.integers(1, 40), label=f"len{i}")
            for i in range(n_seqs)]
    src_shards = data.draw(st.sampled_from([1, 2, 4]), label="src_shards")
    dst_shards = data.draw(st.sampled_from([1, 2, 4]), label="dst_shards")
    src = PagedKVCache(cfg, num_blocks=64, block_size=bs,
                       n_shards=src_shards)
    src.allocate(0, lens[0])
    for i in range(1, n_seqs):
        shared = data.draw(st.integers(0, min(lens[0], lens[i])),
                           label=f"shared{i}")
        if shared > 0:
            src.share_blocks(0, i, shared)   # prefix sharing on the wire
            if lens[i] > shared:
                src.allocate(i, lens[i])     # extend past the prefix
        else:
            src.allocate(i, lens[i])
    # a CoW fork on a shared tail exercises the forked-block case too
    for i in range(1, n_seqs):
        if data.draw(st.booleans(), label=f"grow{i}"):
            src.append_token(i)
    _randomise(src, seed=sum(lens))

    sids = list(range(n_seqs))
    payload = src.export_seqs(sids)

    # every referenced physical block appears EXACTLY once on the wire
    unique_phys = {b for sid in sids for b in src.tables[sid]}
    assert len(payload.block_ids) == len(set(payload.block_ids))
    assert set(payload.block_ids) == unique_phys
    assert payload.n_blocks == len(unique_phys)
    assert payload.k_blocks.shape[2] == payload.n_blocks
    # shared prefixes make the wire smaller than the sum of table lengths
    total_entries = sum(len(src.tables[sid]) for sid in sids)
    assert payload.n_blocks <= total_entries

    dst = PagedKVCache(cfg, num_blocks=64, block_size=bs,
                       n_shards=dst_shards)
    mapping = dst.import_seqs(payload)
    assert set(mapping) == unique_phys
    assert dst.used_blocks == payload.n_blocks

    # tables isomorphic under the mapping; lengths preserved
    for sid in sids:
        assert dst.tables[sid] == [mapping[b] for b in src.tables[sid]]
        assert dst.lengths[sid] == src.lengths[sid]
    # refcounts == number of referencing table entries (sharing survives)
    refs = {}
    for sid in sids:
        for b in dst.tables[sid]:
            refs[b] = refs.get(b, 0) + 1
    assert {b: dst.refcounts[b] for b in refs} == refs
    # pool bytes bit-identical block-by-block
    sk, sv = np.asarray(src.k_pool), np.asarray(src.v_pool)
    dk, dv = np.asarray(dst.k_pool), np.asarray(dst.v_pool)
    for sb, db in mapping.items():
        assert (sk[:, :, sb] == dk[:, :, db]).all()
        assert (sv[:, :, sb] == dv[:, :, db]).all()


def test_export_unknown_seq_rejected(setup):
    cfg, _ = setup
    kv = PagedKVCache(cfg, num_blocks=16, block_size=4)
    with pytest.raises(ValueError, match="no table"):
        kv.export_seqs([7])


def test_import_rejects_block_size_mismatch(setup):
    cfg, _ = setup
    src = PagedKVCache(cfg, num_blocks=16, block_size=4)
    src.allocate(0, 10)
    payload = src.export_seqs([0])
    dst = PagedKVCache(cfg, num_blocks=16, block_size=8)
    with pytest.raises(ValueError, match="block_size"):
        dst.prealloc_handoff(payload)


def test_import_rejects_existing_rid(setup):
    cfg, _ = setup
    src = PagedKVCache(cfg, num_blocks=16, block_size=4)
    src.allocate(0, 10)
    payload = src.export_seqs([0])
    dst = PagedKVCache(cfg, num_blocks=16, block_size=4)
    dst.allocate(0, 4)      # rid collision on the destination
    with pytest.raises(ValueError, match="already has a table"):
        dst.prealloc_handoff(payload)


def test_prealloc_is_all_or_nothing(setup):
    """A destination pool that cannot cover the payload raises contextual
    PoolExhausted and allocates NOTHING (no partial tables, no leaked
    blocks)."""
    cfg, _ = setup
    src = PagedKVCache(cfg, num_blocks=32, block_size=4)
    src.allocate(0, 40)     # 10 blocks
    payload = src.export_seqs([0])
    dst = PagedKVCache(cfg, num_blocks=8, block_size=4)
    free_before = dst.num_free
    with pytest.raises(PoolExhausted) as ei:
        dst.prealloc_handoff(payload)
    assert ei.value.rid == 0
    assert dst.num_free == free_before
    assert dst.tables == {}


# ======================================================================
# transfer interrupted by shard death (serving/faults.py injection)
# ======================================================================
def _reqs(cfg, lens=(18, 25), new=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=n).tolist(),
                    params=SamplingParams(max_new_tokens=new))
            for n in lens]


def _econf(**kw):
    base = dict(placement="attention_pool", partition="head",
                attention_workers=2, kv_shards=2, num_blocks=64,
                block_size=4, max_batch=4)
    base.update(kw)
    return EngineConfig(**base)


def test_transfer_interrupted_by_shard_death_recovers(setup):
    """A decode-side shard death mid-transfer (1 block/step stretches the
    landing window across steps) frees the partial import, requeues the
    handoff, and retries onto the survivors — greedy outputs stay
    bit-identical to a fault-free single engine."""
    cfg, params = setup
    econf = _econf()
    ref = _reqs(cfg)
    eng = LLMEngine(cfg, params, econf)
    eng.submit(ref)
    eng.run()

    reqs = _reqs(cfg)
    injector = FaultInjector(
        FaultScenario.parse("shard_death:shard=1,step=3"))
    cluster = DisaggCluster(
        cfg, params, econf, replicas=1,
        disagg=DisaggConfig(transfer_blocks_per_step=1),
        decode_faults={0: injector})
    cluster.submit(reqs)
    cluster.run()
    assert [r.output for r in reqs] == [r.output for r in ref]
    dec = cluster.registry[0].decode
    assert dec.stats.handoff_retries >= 1
    retries = [e for e in dec.event_log if e.kind == "handoff_retry"]
    assert retries and all(e.info["blocks_lost"] > 0 for e in retries)
    assert dec.kv.quarantined_shards == (1,)
    # all retried imports landed whole despite the lost blocks
    assert dec.stats.handoffs_completed == len(reqs)


def test_transfer_retry_budget_exhaustion_raises_contextual(setup):
    """max_transfer_attempts=1: the first mid-transfer shard death burns
    the whole budget — HandoffError with rid/replica/blocks-in-flight."""
    cfg, params = setup
    reqs = _reqs(cfg)
    injector = FaultInjector(
        FaultScenario.parse("shard_death:shard=1,step=3"))
    cluster = DisaggCluster(
        cfg, params, _econf(), replicas=1,
        disagg=DisaggConfig(transfer_blocks_per_step=1,
                            max_transfer_attempts=1),
        decode_faults={0: injector})
    cluster.submit(reqs)
    with pytest.raises(HandoffError) as ei:
        cluster.run()
    err = ei.value
    assert err.stage == "transfer"
    assert err.replica == 0
    assert err.rid in {r.rid for r in reqs}
    assert err.blocks_in_flight > 0
    assert "shard death" in str(err)


def test_oversized_handoff_fails_fast_at_enqueue(setup):
    """A payload that can never fit the decode pool (even empty) is
    rejected at enqueue with full context, not queued forever."""
    cfg, params = setup
    prefill = PrefillEngine(cfg, params, _econf())
    decode = DecodeEngine(
        cfg, params, EngineConfig(num_blocks=4, block_size=4, max_batch=4))
    prefill.on_handoff = decode.enqueue_handoff
    req = _reqs(cfg, lens=(30,))[0]      # 8 blocks > 4-block decode pool
    prefill.submit(req)
    with pytest.raises(HandoffError) as ei:
        prefill.run()
    assert ei.value.stage == "enqueue"
    assert ei.value.rid == req.rid
    assert ei.value.blocks_in_flight == 8
    assert "can never fit" in str(ei.value)
