"""Disaggregated cluster (serving/cluster/): prefill/decode split parity,
the decode-side handoff lifecycle, and prefix-affinity routing.

The headline invariant is the parity matrix: greedy outputs through the
prefill-engine → decode-engine block handoff are BIT-IDENTICAL to a
single-engine run, for attention_pool × {head, request, block}, with
prefix sharing AND chunked prefill enabled and the transfer stretched
over multiple steps. Plus: the decode engine never prefills (prebuilt
batches via ``admit_prefilled``), queue lifecycle event ordering, sticky
prefix-affinity routing with unhealthy-replica fallback, and cluster
summary aggregation.
"""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer
from repro.serving import (DisaggConfig, EngineConfig, LLMEngine, Request,
                           SamplingParams, State)
from repro.serving.cluster import (DecodeEngine, DisaggCluster,
                                   PrefillEngine, fnv1a_tokens,
                                   prefix_route_key)


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _grouped_reqs(cfg, groups=3, per=3, prefix=8, suffix=6, new=6, seed=0):
    """`groups` prefix families × `per` members each — the shared leading
    blocks exercise prefix sharing locally and affinity routing globally."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(groups):
        common = rng.integers(0, cfg.vocab_size, size=prefix).tolist()
        for _ in range(per):
            reqs.append(Request(
                prompt=common +
                rng.integers(0, cfg.vocab_size, size=suffix).tolist(),
                params=SamplingParams(max_new_tokens=new)))
    return reqs


def _econf(partition="head", **kw):
    base = dict(placement="attention_pool", partition=partition,
                attention_workers=2, num_blocks=64, block_size=4,
                max_batch=4, prefix_sharing=True, prefill_chunk_tokens=8)
    if partition != "block":
        base["kv_shards"] = 2
    base.update(kw)
    return EngineConfig(**base)


# ======================================================================
# tentpole: the parity matrix — bit-exact through the handoff
# ======================================================================
@pytest.mark.parametrize("partition", ["head", "request", "block"])
def test_handoff_bit_parity(setup, partition):
    """Single engine vs 1-replica cluster (same config, prefix sharing +
    chunked prefill on, transfer stretched to 2 blocks/step): greedy
    outputs bit-identical across every pool partition."""
    cfg, params = setup
    econf = _econf(partition)
    ref = _grouped_reqs(cfg)
    eng = LLMEngine(cfg, params, econf)
    eng.submit(ref)
    eng.run()

    reqs = _grouped_reqs(cfg)
    cluster = DisaggCluster(cfg, params, econf, replicas=1,
                            disagg=DisaggConfig(transfer_blocks_per_step=2))
    cluster.submit(reqs)
    cluster.run()
    assert cluster.finished
    assert [r.output for r in reqs] == [r.output for r in ref]
    summary = cluster.summary()
    assert summary["handoffs_completed"] == len(reqs)
    assert summary["kv_bytes_transferred"] > 0


def test_decode_engine_never_prefills(setup):
    """Every request joins the decode batch PREBUILT: the decode engine
    runs no prefill forward (no slab, no admit/chunk events) — only
    handoff admissions."""
    cfg, params = setup
    cluster = DisaggCluster(cfg, params, _econf(), replicas=1,
                            disagg=DisaggConfig(transfer_blocks_per_step=2))
    reqs = cluster.submit(_grouped_reqs(cfg))
    cluster.run()
    dec = cluster.registry[0].decode
    assert dec.stats.max_prefill_slab_tokens == 0
    kinds = {e.kind for e in dec.event_log}
    assert "admit" not in kinds and "chunk" not in kinds
    admits = [e for e in dec.event_log if e.kind == "handoff_admit"]
    assert {e.rid for e in admits} == {r.rid for r in reqs}
    assert dec.stats.tokens_generated > 0


def test_handoff_lifecycle_event_order(setup):
    """Per request, the decode engine's lifecycle events run strictly
    handoff_recv → prealloc → transfer_done → handoff_admit."""
    cfg, params = setup
    cluster = DisaggCluster(cfg, params, _econf(), replicas=1,
                            disagg=DisaggConfig(transfer_blocks_per_step=1))
    reqs = cluster.submit(_grouped_reqs(cfg, groups=2, per=2))
    cluster.run()
    dec = cluster.registry[0].decode
    for r in reqs:
        stages = [e.kind for e in dec.event_log if e.rid == r.rid
                  and e.kind in ("handoff_recv", "prealloc",
                                 "transfer_done", "handoff_admit")]
        assert stages == ["handoff_recv", "prealloc", "transfer_done",
                          "handoff_admit"], (r.rid, stages)
    # 1 block/step: multi-block payloads take >1 step to land
    done = [e for e in dec.event_log if e.kind == "transfer_done"]
    assert any(e.info["steps"] >= e.info["blocks"] - 1 for e in done)


def test_retained_prefixes_skip_follower_prefill(setup):
    """With retention on, the prefill engine keeps exported prompts as
    donors: same-prefix followers skip their shared leading blocks."""
    cfg, params = setup
    cluster = DisaggCluster(cfg, params, _econf(), replicas=1)
    cluster.submit(_grouped_reqs(cfg, groups=2, per=4))
    cluster.run()
    pre = cluster.registry[0].prefill
    assert pre.stats.prefill_tokens_skipped > 0
    assert pre.stats.blocks_shared > 0
    # retention off: same workload shares nothing across handoffs
    cold = DisaggCluster(cfg, params, _econf(), replicas=1,
                         disagg=DisaggConfig(retain_prefixes=False))
    cold.submit(_grouped_reqs(cfg, groups=2, per=4))
    cold.run()
    assert cold.registry[0].prefill.retained_rids == []


# ======================================================================
# routing
# ======================================================================
def test_affinity_routing_concentrates_prefix_groups(setup):
    """Every member of a prefix family routes to ONE replica (sticky
    memo); followers count as affinity hits and skip shared prefill."""
    cfg, params = setup
    groups, per = 3, 4
    cluster = DisaggCluster(cfg, params, _econf(), replicas=2,
                            routing="affinity")
    reqs = cluster.submit(_grouped_reqs(cfg, groups=groups, per=per))
    cluster.run()
    for g in range(groups):
        fam = reqs[g * per:(g + 1) * per]
        homes = {cluster.replica_of(r.rid) for r in fam}
        assert len(homes) == 1, f"group {g} split across {homes}"
    s = cluster.summary()
    assert s["router_affinity_hits"] == groups * (per - 1)
    assert s["prefill_tokens_skipped"] > 0
    assert len(cluster.router.assignments) == groups


def test_router_prefers_least_loaded_for_short_prompts(setup):
    """A prompt with no full leading block has nothing to be affine
    about — it routes least-loaded and leaves no sticky assignment."""
    cfg, params = setup
    cluster = DisaggCluster(cfg, params, _econf(), replicas=2)
    short = Request(prompt=[1, 2, 3],          # < block_size=4
                    params=SamplingParams(max_new_tokens=2))
    assert prefix_route_key(short.prompt, 4, 2) is None
    cluster.submit(short)
    assert cluster.router.assignments == {}
    cluster.run()
    assert short.state == State.FINISHED


def test_unhealthy_replica_diverts_without_losing_affinity(setup):
    """A quarantined shard on the affinity target diverts new arrivals to
    the least-loaded healthy replica WITHOUT overwriting the sticky memo;
    the stream snaps back (and counts a hit) after the shard rejoins."""
    cfg, params = setup
    cluster = DisaggCluster(cfg, params, _econf(), replicas=2)
    prompt = list(np.random.default_rng(5).integers(
        0, cfg.vocab_size, size=12))
    r1 = cluster.submit(Request(prompt=prompt,
                                params=SamplingParams(max_new_tokens=2)))[0]
    home = cluster.replica_of(r1.rid)
    key = prefix_route_key(prompt, 4, 2)
    assert cluster.router.assignments[key] == home

    cluster.registry[home].decode.kv.quarantine_shard(0)
    assert not cluster.registry[home].healthy
    r2 = cluster.submit(Request(prompt=list(prompt),
                                params=SamplingParams(max_new_tokens=2)))[0]
    assert cluster.replica_of(r2.rid) != home
    assert cluster.router.assignments[key] == home   # memo untouched
    hits_before = cluster.registry[home].prefill.stats.router_affinity_hits

    cluster.registry[home].decode.kv.rejoin_shard(0)
    r3 = cluster.submit(Request(prompt=list(prompt),
                                params=SamplingParams(max_new_tokens=2)))[0]
    assert cluster.replica_of(r3.rid) == home        # snapped back
    assert cluster.registry[home].prefill.stats.router_affinity_hits == \
        hits_before + 1


def test_random_routing_is_seeded(setup):
    cfg, params = setup
    def routes(seed):
        c = DisaggCluster(cfg, params, _econf(), replicas=2,
                          routing="random", seed=seed)
        rs = c.submit(_grouped_reqs(cfg, groups=2, per=3, new=1))
        return [c.replica_of(r.rid) for r in rs]
    assert routes(3) == routes(3)           # deterministic per seed
    assert set(routes(3) + routes(4)) == {0, 1}


def test_cluster_validates_construction(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="replicas"):
        DisaggCluster(cfg, params, _econf(), replicas=0)
    with pytest.raises(ValueError, match="routing policy"):
        DisaggCluster(cfg, params, _econf(), routing="round_robin")


def test_fnv1a_is_stable_and_content_keyed():
    """The routing hash must be process-stable (unlike salted hash()) and
    keyed on token content."""
    toks = (17, 4096, -1, 0)
    assert fnv1a_tokens(toks) == fnv1a_tokens(list(toks))
    assert fnv1a_tokens(toks) != fnv1a_tokens(toks[:-1])
    assert fnv1a_tokens(()) == 0xcbf29ce484222325   # FNV-1a offset basis
    # key = leading FULL blocks only, capped at affinity_blocks
    assert prefix_route_key(list(range(10)), 4, 2) == tuple(range(8))
    assert prefix_route_key(list(range(10)), 4, 1) == tuple(range(4))
    assert prefix_route_key(list(range(5)), 4, 2) == tuple(range(4))


# ======================================================================
# standalone engines (no cluster): the poll-style transport
# ======================================================================
def test_standalone_engines_with_polled_outbox(setup):
    """Without an on_handoff sink the prefill engine parks exports in its
    outbox; a caller relays them — the RPC-less transport seam."""
    cfg, params = setup
    econf = _econf()
    prefill = PrefillEngine(cfg, params, econf)
    decode = DecodeEngine(cfg, params, econf)
    reqs = _grouped_reqs(cfg, groups=1, per=2)
    prefill.submit(reqs)
    while prefill.has_work():
        prefill.step()
        for h in prefill.collect_handoffs():
            decode.enqueue_handoff(h.request, h.payload)
    while decode.has_work():
        decode.step()
    assert all(r.state == State.FINISHED for r in reqs)
    assert all(len(r.output) == 6 for r in reqs)
    assert decode.stats.handoffs_completed == 2
    # byte accounting agrees across the seam
    assert decode.stats.kv_bytes_transferred == \
        prefill.stats.kv_bytes_transferred


def test_cluster_summary_shape(setup):
    cfg, params = setup
    cluster = DisaggCluster(cfg, params, _econf(), replicas=2)
    cluster.submit(_grouped_reqs(cfg, groups=2, per=2, new=3))
    cluster.run()
    s = cluster.summary()
    for key in ("replicas", "routing", "requests", "kv_bytes_transferred",
                "handoffs_completed", "handoff_retries",
                "router_affinity_hits", "prefill_tokens_skipped",
                "blocks_shared", "tokens_generated", "per_replica",
                "handoff_p50_s", "handoff_p90_s", "handoff_p99_s"):
        assert key in s, key
    assert s["replicas"] == 2 and s["routing"] == "affinity"
    assert s["handoffs_completed"] == 4
    # each request's FIRST token is sampled prefill-side at handoff time;
    # the decode tier generates the remaining new-1
    assert s["tokens_generated"] == 4 * (3 - 1)
    assert len(s["per_replica"]) == 2
    assert sum(p["handoffs_completed"] for p in s["per_replica"]) == 4
