"""Cost model vs the paper's own quantitative claims (§2, §3.1, §6)."""
import pytest

from repro.configs import registry
from repro.core import costmodel as cm


@pytest.fixture(scope="module")
def l70():
    return registry.get_config("llama3-70b")


def test_paper_table2_param_count(l70):
    assert 68e9 < cm.param_count(l70) < 73e9


def test_fig2_low_mfu_at_small_batch(l70):
    """§2.2.1: MFU below ~20% for small batches on H100, bandwidth-bound."""
    h100 = cm.HARDWARE["h100"]
    assert cm.mfu_nonattention(l70, 8, h100) < 0.05
    assert cm.mfu_nonattention(l70, 32, h100) < 0.20
    assert cm.mfu_nonattention(l70, 500, h100) > 0.8  # compute-bound regime


def test_fig3_attention_stays_bandwidth_bound(l70):
    """§2.2.2: MBU ≈ 1 regardless of batch — arithmetic intensity constant."""
    h20 = cm.HARDWARE["h20"]
    for B in (4, 20, 100, 400):
        assert cm.mbu_attention(l70, B, 8192, h20) > 0.95


def test_fig4_minimum_bandwidth_under_30gbs(l70):
    """§3.1: required interconnect ≤ ~30 GB/s up to B=300 at α=0.2 —
    reachable by 400 Gbps networking (paper Fig. 4)."""
    h100, h20 = cm.HARDWARE["h100"], cm.HARDWARE["h20"]
    for B in (32, 100, 300):
        bw = cm.minimum_bandwidth(l70, B, 4096, h100, h20, alpha=0.2,
                                  dop=(1, 1))
        assert bw < 30e9, (B, bw / 1e9)


def test_kv_capacity_claim(l70):
    """§2.2.2: one H100 holds KV for only ~30 requests at 8k context."""
    per_req = cm.kv_bytes_per_token(l70) * 8192
    h100 = cm.HARDWARE["h100"]
    n = h100.mem_bytes / per_req
    assert 25 < n < 40


def test_equal_cost_throughput_gain(l70):
    """§6.1: Lamina DOP=(2,4) vs vLLM 4×H100 — 16.1~90.1% more throughput at
    slightly LOWER cost, with ~2.4× batch."""
    h100, h20 = cm.HARDWARE["h100"], cm.HARDWARE["h20"]
    v = cm.estimate_vllm(l70, 4096, h100, 4)
    l = cm.estimate_lamina(l70, 4096, h100, h20, (2, 4))
    gain = l.throughput_tok_s / v.throughput_tok_s - 1
    assert 0.10 < gain < 1.0, gain
    assert l.cost_hr < v.cost_hr
    assert 1.5 < l.batch / v.batch < 3.5
    # latency grows but stays interactive (paper: within SLO)
    assert l.tbt_s < 0.25


def test_network_stack_fig13():
    """FHBN: 33.0 µs RTT (50.5% below NCCL's 66.6 µs); 45.7 GB/s ≈ 91% line
    rate vs NCCL 35.5."""
    fhbn = cm.NETWORK_STACKS["fhbn"]
    nccl = cm.NETWORK_STACKS["nccl"]
    assert cm.pingpong_rtt_us(fhbn, 1024) < 0.55 * cm.pingpong_rtt_us(
        nccl, 1024)
    assert fhbn.peak_gbs / 50.0 > 0.9
    big = 1 << 30
    assert cm.pingpong_rtt_us(fhbn, big) < cm.pingpong_rtt_us(nccl, big)


def test_overlap_reduces_network_time(l70):
    t0 = cm.network_time_per_iteration(l70, 128, cm.NETWORK_STACKS["fhbn"],
                                       overlap_fraction=0.0)
    t1 = cm.network_time_per_iteration(l70, 128, cm.NETWORK_STACKS["fhbn"],
                                       overlap_fraction=0.3)
    assert t1 == pytest.approx(0.7 * t0)


def test_dop_sweep_shape(l70):
    """Fig. 11: adding attention workers lifts throughput sharply (bigger
    feasible batch); adding model workers helps only mildly."""
    h100, h20 = cm.HARDWARE["h100"], cm.HARDWARE["h20"]
    base = cm.estimate_lamina(l70, 4096, h100, h20, (2, 2))
    more_attn = cm.estimate_lamina(l70, 4096, h100, h20, (2, 4))
    more_model = cm.estimate_lamina(l70, 4096, h100, h20, (3, 2))
    gain_attn = more_attn.throughput_tok_s / base.throughput_tok_s
    gain_model = more_model.throughput_tok_s / base.throughput_tok_s
    assert gain_attn > gain_model
    assert gain_attn > 1.3


def test_rwkv_attention_free_zero_atime():
    cfg = registry.get_config("rwkv6-7b")
    assert cm.kv_bytes_per_token(cfg) == 0.0
    assert cm.atime(cfg, 64, 4096, cm.HARDWARE["h20"]) == 0.0
