"""Optional-hypothesis shim: property tests skip cleanly (instead of the
whole module erroring at collection) when the container lacks `hypothesis`.

Usage in test modules:  ``from _hypothesis_compat import given, settings, st``
— identical to ``from hypothesis import ...`` when the package is present;
otherwise ``@given`` turns the test into a skip and strategy construction
becomes a no-op.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover — optional dependency
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for `strategies`: any strategy constructor returns None
        (|given| below never inspects them)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
