"""Cross-cutting property tests: cost-model monotonicity/limits, converter
cuts on randomized graphs, checkpoint dtype preservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import registry
from repro.core import converter, costmodel as cm

# hypothesis-heavy sweeps: CI's blocking matrix skips them (-m "not slow");
# the non-blocking slow job still runs the file on every PR
pytestmark = pytest.mark.slow


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=30)
@given(b1=st.integers(1, 512), b2=st.integers(1, 512))
def test_mtime_monotone_in_batch(b1, b2):
    cfg = registry.get_config("llama3-70b")
    hw = cm.HARDWARE["h100"]
    lo, hi = sorted((b1, b2))
    assert cm.mtime(cfg, lo, hw) <= cm.mtime(cfg, hi, hw) + 1e-12


@settings(deadline=None, max_examples=30)
@given(b=st.integers(1, 512), l=st.integers(128, 32768))
def test_atime_linear_in_batch_and_seq(b, l):
    """BGEMV: attention time scales with B·l (the paper's §2.2.2 point that
    batching does not improve attention's arithmetic intensity)."""
    cfg = registry.get_config("llama3-70b")
    hw = cm.HARDWARE["h20"]
    t1 = cm.atime(cfg, b, l, hw)
    t2 = cm.atime(cfg, 2 * b, l, hw)
    t3 = cm.atime(cfg, b, 2 * l, hw)
    assert t2 == pytest.approx(2 * t1, rel=1e-6)
    assert t3 == pytest.approx(2 * t1, rel=1e-6)


@settings(deadline=None, max_examples=30)
@given(b=st.integers(1, 300), l=st.sampled_from([1024, 4096, 8192]),
       alpha=st.floats(0.05, 0.5))
def test_min_bandwidth_decreases_with_alpha(b, l, alpha):
    cfg = registry.get_config("llama3-70b")
    h100, h20 = cm.HARDWARE["h100"], cm.HARDWARE["h20"]
    bw1 = cm.minimum_bandwidth(cfg, b, l, h100, h20, alpha=alpha)
    bw2 = cm.minimum_bandwidth(cfg, b, l, h100, h20, alpha=alpha * 2)
    assert bw2 == pytest.approx(bw1 / 2, rel=1e-6)


def test_lamina_estimate_internally_consistent():
    cfg = registry.get_config("llama3-70b")
    h100, h20 = cm.HARDWARE["h100"], cm.HARDWARE["h20"]
    est = cm.estimate_lamina(cfg, 4096, h100, h20, (2, 4))
    assert est.cost_hr == pytest.approx(2 * h100.price_hr + 4 * h20.price_hr)
    assert est.throughput_tok_s * est.tbt_s >= est.batch * 0.99  # pipelining
    assert est.tok_per_dollar == pytest.approx(
        est.throughput_tok_s * 3600 / est.cost_hr)


# ---------------------------------------------------------------------------
# converter on randomized block graphs
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=20)
@given(layers=st.integers(1, 4), batch=st.sampled_from([1, 4]),
       seed=st.integers(0, 1000))
def test_converter_random_multilayer_exec_parity(layers, batch, seed):
    """Random per-edge weights; sliced execution must equal direct execution
    and produce exactly n_attention + 1 slices with valid topo programs."""
    rng = np.random.default_rng(seed)
    g = converter.OpGraph()
    d = 8
    g.add("x", "input", [], int(rng.integers(1, 100)))
    prev = "x"
    mats = {}
    for i in range(layers):
        p = f"l{i}_"
        for name, kind, inputs in [
                ("norm", "norm", [prev]),
                ("q", "q_proj", [p + "norm"]),
                ("k", "kv_proj", [p + "norm"]),
                ("v", "kv_proj", [p + "norm"]),
        ]:
            mats[p + name] = rng.standard_normal((d, d)).astype(np.float32)
            g.add(p + name, kind, inputs, int(rng.integers(1, 100)),
                  fn=(lambda h, W=mats[p + name]: h @ W))
        g.add(p + "attention", "attention", [p + "q", p + "k", p + "v"],
              int(rng.integers(1, 100)))
        mats[p + "o"] = rng.standard_normal((d, d)).astype(np.float32)
        g.add(p + "o", "proj", [p + "attention"], int(rng.integers(1, 100)),
              fn=(lambda a, W=mats[p + "o"]: a @ W))
        g.add(p + "res", "add", [prev, p + "o"], int(rng.integers(1, 100)),
              fn=lambda x, o: x + o)
        prev = p + "res"

    sp = converter.split_at_attention(g)
    assert len(sp.slices) == layers + 1

    def attn_fn(name, env):
        lid = name.split("_")[0]
        return env[f"{lid}_q"] + env[f"{lid}_v"]  # arbitrary deterministic

    x = rng.standard_normal((batch, d)).astype(np.float32)
    env = sp.run({"x": x}, attn_fn)
    # direct execution
    env2 = {"x": x}
    for name in g.order:
        op = g.ops[name]
        if op.kind == "input":
            continue
        if op.kind == "attention":
            env2[name] = attn_fn(name, env2)
        else:
            env2[name] = op.fn(*[env2[i] for i in op.inputs])
    np.testing.assert_allclose(env[prev], env2[prev], atol=1e-5)
    # every slice's program respects dependencies
    for sl in sp.slices:
        seen = set(sl.context_in) | {"x"}
        if sl.recv_attn:
            seen.add(sl.recv_attn)
        for name in sl.program:
            for inp in g.ops[name].inputs:
                assert inp in seen or inp in sl.program[:sl.program.index(
                    name)], (name, inp)
            seen.add(name)


# ---------------------------------------------------------------------------
# checkpoint dtype preservation across the whole config space
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-1.2b",
                                  "seamless-m4t-medium"])
def test_checkpoint_preserves_structure(arch, tmp_path):
    from repro.models import transformer
    from repro.training import checkpoint as ckpt
    cfg = registry.get_smoke_config(arch).replace(dtype=jnp.bfloat16)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    ckpt.save(str(tmp_path), params, None, step=1)
    tree, _ = ckpt.restore(str(tmp_path), {"params": params})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(tree["params"])):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
