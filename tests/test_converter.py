"""Automated model converter (paper §4.2): min-cut slicing, Q-early
scheduling, executable parity."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core import converter
from repro.models import blocks


@pytest.fixture(scope="module")
def block_setup():
    cfg = registry.get_smoke_config("llama3-8b")
    w = blocks.init_dense_block(jax.random.PRNGKey(0), cfg)
    return cfg, w


def test_single_block_slices(block_setup):
    cfg, w = block_setup
    g = converter.build_block_graph(cfg, weights=w, batch=4)
    sp = converter.split_at_attention(g)
    # n attention ops -> n+1 slices
    assert len(sp.slices) == len(g.attention_ops()) + 1 == 2
    # the min cut across the boundary is exactly the residual stream
    assert sp.slices[0].context_out == ["x"]
    assert sp.cut_bytes[0] == 4 * cfg.d_model * 2
    # Q-proj scheduled before K/V (paper §4.2.2 hoisting)
    prog = sp.slices[0].program
    assert prog.index("q_proj") < prog.index("k_proj")
    assert prog.index("q_proj") < prog.index("v_proj")
    assert sp.slices[0].sends == {"q_proj": "q", "k_proj": "kv",
                                  "v_proj": "kv"}
    assert sp.slices[1].recv_attn == "attention"


def test_sliced_execution_matches_unsliced(block_setup):
    cfg, w = block_setup
    g = converter.build_block_graph(cfg, weights=w, batch=4)
    sp = converter.split_at_attention(g)
    x = np.random.default_rng(0).standard_normal(
        (4, cfg.d_model)).astype(np.float32)

    def attn_fn(name, env):
        v = env["v_proj"]
        return np.repeat(v, env["q_proj"].shape[1] // v.shape[1], axis=1)

    trace = []
    env = sp.run({"x": x}, attn_fn, trace=trace)
    # send-Q appears before send-KV in the executed schedule
    assert trace.index("send_q:q_proj") < trace.index("send_kv:k_proj")
    # unsliced reference
    g2 = converter.build_block_graph(cfg, weights=w, batch=4)
    env2 = {"x": x}
    for name in g2.order:
        op = g2.ops[name]
        if op.kind == "input":
            continue
        if op.kind == "attention":
            env2[name] = attn_fn(name, env2)
        else:
            env2[name] = op.fn(*[env2[i] for i in op.inputs])
    np.testing.assert_allclose(env["residual2"], env2["residual2"],
                               atol=1e-5)


def test_multi_layer_graph_slicing(block_setup):
    """Chain two blocks: 2 attention ops -> 3 slices, every boundary cut is
    one residual stream."""
    cfg, w = block_setup
    g = converter.OpGraph()
    e = 2
    B, d = 4, cfg.d_model
    g.add("x", "input", [], B * d * e)
    prev = "x"
    for layer in range(2):
        p = f"l{layer}_"
        g.add(p + "norm1", "norm", [prev], B * d * e)
        g.add(p + "q_proj", "q_proj", [p + "norm1"], B * cfg.q_dim * e)
        g.add(p + "k_proj", "kv_proj", [p + "norm1"], B * cfg.kv_dim * e)
        g.add(p + "v_proj", "kv_proj", [p + "norm1"], B * cfg.kv_dim * e)
        g.add(p + "attention", "attention",
              [p + "q_proj", p + "k_proj", p + "v_proj"], B * cfg.q_dim * e)
        g.add(p + "o_proj", "proj", [p + "attention"], B * d * e)
        g.add(p + "res1", "add", [prev, p + "o_proj"], B * d * e)
        g.add(p + "norm2", "norm", [p + "res1"], B * d * e)
        g.add(p + "ffn", "proj", [p + "norm2"], B * d * e)
        g.add(p + "res2", "add", [p + "res1", p + "ffn"], B * d * e)
        prev = p + "res2"
    sp = converter.split_at_attention(g)
    assert len(sp.slices) == 3
    assert sp.cut_bytes == [B * d * e, B * d * e]
    assert sp.slices[0].context_out == ["x"]
    # boundary 2 saves the residual stream entering layer 1 (= l0's output)
    assert sp.slices[1].context_out == ["l0_res2"]
    # slice 1 contains the first block's tail and second block's head
    assert "l0_o_proj" in sp.slices[1].program
    assert "l1_q_proj" in sp.slices[1].program


def test_cut_prefers_cheapest_edge():
    """If the residual is wider than an alternative bottleneck, the min cut
    must pick the cheaper one."""
    g = converter.OpGraph()
    g.add("x", "input", [], 100)
    g.add("narrow", "proj", ["x"], 10)      # cheap bottleneck
    g.add("q", "q_proj", ["narrow"], 50)
    g.add("k", "kv_proj", ["narrow"], 50)
    g.add("v", "kv_proj", ["narrow"], 50)
    g.add("attention", "attention", ["q", "k", "v"], 50)
    g.add("o", "proj", ["attention"], 50)
    g.add("merge", "add", ["narrow", "o"], 50)   # residual from `narrow`
    sp = converter.split_at_attention(g)
    assert sp.slices[0].context_out == ["narrow"]
    assert sp.cut_bytes[0] == 10
