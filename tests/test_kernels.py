"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.ssm_scan import ssm_scan


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,S,Hkv,G,hd,block_k", [
    (1, 64, 1, 1, 64, 64),
    (2, 128, 2, 4, 64, 64),
    (3, 300, 4, 8, 128, 128),     # ragged: S % block_k != 0
    (2, 96, 8, 2, 128, 32),
    (1, 513, 2, 16, 64, 256),     # big GQA group
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, S, Hkv, G, hd, block_k, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * S + hd), 4)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd), dtype)
    kc = jax.random.normal(ks[1], (B, Hkv, S, hd), dtype)
    vc = jax.random.normal(ks[2], (B, Hkv, S, hd), dtype)
    clen = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = decode_attention(q, kc, vc, clen, block_k=block_k, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, clen)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("sw,cap", [(32, 0.0), (0, 30.0), (17, 50.0)])
def test_decode_attention_window_softcap(sw, cap):
    B, S, Hkv, G, hd = 2, 200, 2, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd))
    kc = jax.random.normal(ks[1], (B, Hkv, S, hd))
    vc = jax.random.normal(ks[2], (B, Hkv, S, hd))
    clen = jnp.array([200, 63], jnp.int32)
    out = decode_attention(q, kc, vc, clen, block_k=64, sliding_window=sw,
                           logit_softcap=cap, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, clen, sliding_window=sw,
                                    logit_softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_decode_attention_partials_combine():
    """The kernel's (o, l, m) triple must merge per §4.2.2: attention over
    [0, n) == combine(kernel partial over cache [0, n-1), new token)."""
    from repro.core import combine as C
    B, S, Hkv, G, hd = 2, 128, 2, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd))
    kc = jax.random.normal(ks[1], (B, Hkv, S, hd))
    vc = jax.random.normal(ks[2], (B, Hkv, S, hd))
    full_len = jnp.array([100, 77], jnp.int32)
    want = ref.decode_attention_ref(q, kc, vc, full_len)
    o, l, m = decode_attention(q, kc, vc, full_len - 1, block_k=64,
                               interpret=True, return_partials=True)
    p_prev = C.Partial(a=o.astype(jnp.float32) * l[..., None], s=l, m=m)
    b = jnp.arange(B)
    # the "new" token = position full_len-1, broadcast over the GQA group
    p_new = C.partial_attention(q, kc[b, :, full_len - 1][:, :, None, None],
                                vc[b, :, full_len - 1][:, :, None, None])
    merged = C.finalize(C.combine(p_prev, p_new))
    np.testing.assert_allclose(np.asarray(merged), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,H,P,chunk", [
    (1, 32, 1, 32, 16), (2, 100, 4, 64, 32), (2, 64, 2, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_sweep(B, S, H, P, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + P), 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, P), dtype) * 0.5
               for i in range(3))
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, P))) * 0.5 + 0.5)
    u = jax.random.normal(ks[4], (H, P)) * 0.3
    out = rwkv6_scan(r, k, v, w.astype(dtype), u, chunk=chunk,
                     interpret=True)
    want = ref.rwkv6_scan_ref(r, k, v, w.astype(dtype), u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=_tol(dtype) * 4, rtol=_tol(dtype) * 4)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 32, 1, 32, 16, 16), (2, 100, 4, 64, 64, 32), (2, 64, 2, 32, 8, 64),
])
def test_ssm_scan_sweep(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(S + N), 4)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    Bi = jax.random.normal(ks[1], (B, S, N)) * 0.5
    Ci = jax.random.normal(ks[2], (B, S, N)) * 0.5
    a = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H))) * 0.5 + 0.4
    out = ssm_scan(x, Bi, Ci, a, chunk=chunk, interpret=True)
    want = ref.ssm_scan_ref(x, None, Bi, Ci, a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_model_kernel_path_parity():
    """Full-model forward through the Pallas kernels equals the scan path."""
    from repro.configs import registry
    from repro.models import transformer
    for arch in ("rwkv6-7b", "zamba2-1.2b"):
        cfg0 = registry.get_smoke_config(arch)
        cfg1 = cfg0.replace(use_pallas_kernels=True)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg0)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 24),
                                              0, cfg0.vocab_size)}
        l0, _ = transformer.forward(params, cfg0, batch)
        l1, _ = transformer.forward(params, cfg1, batch)
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-4)
