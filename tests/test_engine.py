"""Worker-pool and cross-placement serving invariants: homogeneous vs
attention-pool greedy parity, continuous batching under a tight pool,
transfer accounting vs the paper's §3.1 formula, head vs request load
balance. (The legacy oracle engines these tests once exercised are gone —
``LLMEngine`` cross-config checks are the parity surface now.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import traces
from repro.models import transformer
from repro.serving import EngineConfig, LLMEngine
from repro.serving.request import Request, SamplingParams
from repro.serving.worker_pool import (AttentionWorkerPool,
                                       expected_transfer_bytes)


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, lens=(5, 12, 9, 20), new=8):
    rng = np.random.default_rng(0)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=n).tolist(),
                    params=SamplingParams(max_new_tokens=new)) for n in lens]


def _run(cfg, params, **conf):
    reqs = _reqs(cfg)
    eng = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=64,
                                              **conf))
    eng.submit(reqs)
    eng.run()
    return [r.output for r in reqs], eng


def test_placements_identical_outputs(setup):
    cfg, params = setup
    ref, _ = _run(cfg, params, placement="homogeneous")
    head, _ = _run(cfg, params, placement="attention_pool",
                   partition="head", attention_workers=2)
    req, _ = _run(cfg, params, placement="attention_pool",
                  partition="request", attention_workers=4)
    assert ref == head == req
    assert all(len(o) == 8 for o in ref)


def test_transfer_bytes_match_paper_formula(setup):
    cfg, params = setup
    _, eng = _run(cfg, params, placement="attention_pool",
                  partition="head", attention_workers=2)
    per_token = eng.pool.log.total / eng.stats.tokens_generated
    assert per_token == pytest.approx(expected_transfer_bytes(cfg, 1))
    # and the formula itself is (2 + 2/G)·e·d·L for one token
    G = cfg.gqa_group
    assert expected_transfer_bytes(cfg, 1) == int(
        (2 + 2 / G) * 2 * cfg.q_dim * cfg.num_layers)


def test_continuous_batching_admits_as_memory_frees(setup):
    cfg, params = setup
    # pool sized so only ~3 requests fit at once
    reqs = _reqs(cfg, lens=(20, 20, 20, 20, 20, 20), new=4)
    eng = LLMEngine(cfg, params, EngineConfig(max_batch=8, num_blocks=12,
                                              block_size=8))
    eng.submit(reqs)
    eng.run()
    assert all(r.done() for r in reqs)
    assert max(eng.stats.batch_sizes) < 6  # memory-capped concurrency
    assert eng.kv.used_blocks == 0         # everything freed


def test_head_partition_balances_request_partition_does_not(setup):
    cfg, params = setup
    B, S, Hkv, hd = 4, 32, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jax.random.normal(jax.random.PRNGKey(1),
                          (B, cfg.num_heads, hd))
    kc = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, S, hd))
    vc = jax.random.normal(jax.random.PRNGKey(3), (B, Hkv, S, hd))
    kn = jax.random.normal(jax.random.PRNGKey(4), (B, Hkv, hd))
    vn = jax.random.normal(jax.random.PRNGKey(5), (B, Hkv, hd))
    clen = jnp.array([32, 2, 2, 2], jnp.int32)  # imbalanced lengths
    head = AttentionWorkerPool(cfg, 2, "head")
    req = AttentionWorkerPool(cfg, 2, "request")
    o1 = head.attend(q, kc, vc, clen, kn, vn)
    o2 = req.attend(q, kc, vc, clen, kn, vn)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    # head-level: equal bytes per worker; request-level also splits evenly
    # in *allocated* bytes here, but the paper's point is VALID work — with
    # per-request lengths [32,2,2,2], worker 0 holds 34 valid tokens of 36
    assert head.per_worker_kv_bytes[0] == head.per_worker_kv_bytes[1]
    valid = [int(clen[0] + clen[1]), int(clen[2] + clen[3])]
    assert valid[0] / sum(valid) > 0.8  # request-level imbalance exists


def test_head_partition_divisibility_guard(setup):
    cfg, _ = setup
    with pytest.raises(ValueError):
        AttentionWorkerPool(cfg, 3, "head")  # 4 kv heads % 3 != 0


def test_trace_generation_stats():
    reqs = traces.generate("azure-conv", 200, vocab_size=100, scale=0.05,
                           seed=1)
    lens = np.array([len(r.prompt) for r in reqs])
    gens = np.array([r.params.max_new_tokens for r in reqs])
    spec = traces.TRACES["azure-conv"]
    assert abs(lens.mean() - spec.mean_prompt * 0.05) / \
        (spec.mean_prompt * 0.05) < 0.35
    assert gens.mean() > 0
    assert set(traces.TRACES) == {"azure-conv", "azure-code", "kimi-conv",
                                  "kimi-ta"}


def test_block_partition_matches_homogeneous(setup):
    """partition="block" (pool block axis sharded over workers, §4.2.2
    partial merge) decodes bit-identically to the fused baseline."""
    cfg, params = setup
    ref, _ = _run(cfg, params, placement="homogeneous")
    blk, eng = _run(cfg, params, placement="attention_pool",
                    partition="block", attention_workers=4)
    assert eng.kv.n_shards == 4  # engine wired the pool shards automatically
    assert blk == ref
    # live-token accounting ran (data-dependent, host-side)
    assert sum(eng.pool.per_worker_kv_bytes) > 0


@pytest.mark.slow
def test_block_partition_long_request_spans_all_shards(setup):
    """The block partition's raison d'être: ONE long request's KV spans
    every attention worker, per-shard live tokens within one block of even
    (round-robin placement) — and per-worker byte accounting reflects it."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    req = Request(prompt=rng.integers(0, cfg.vocab_size, size=150).tolist(),
                  params=SamplingParams(max_new_tokens=4))
    eng = LLMEngine(cfg, params, EngineConfig(
        placement="attention_pool", partition="block", attention_workers=4,
        max_batch=4, num_blocks=64, block_size=8))
    eng.submit([req])
    eng.step()  # prefill + first decode iteration
    toks = eng.kv.shard_live_tokens([req.rid])
    assert (toks > 0).all()
    assert toks.max() - toks.min() <= eng.kv.block_size
    eng.run()
    bytes_per_worker = eng.pool.per_worker_kv_bytes
    assert all(b > 0 for b in bytes_per_worker)
    assert max(bytes_per_worker) / min(bytes_per_worker) < 1.5


def test_attend_overlapped_is_the_paged_path(setup):
    cfg, _ = setup
    pool = AttentionWorkerPool(cfg, 2, "head")
    assert pool.attend_overlapped.__func__ is \
        AttentionWorkerPool.attend_paged


def test_block_partition_pallas_backend_matches_jnp(setup):
    """attend_paged partition="block" honours decode_backend: the pallas
    kernel path (positions-aware, in place) matches the jnp gather
    reference."""
    cfg, _ = setup
    from repro.serving.kvcache import PagedKVCache
    Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    kv = PagedKVCache(cfg, num_blocks=32, block_size=4, n_shards=4)
    kv.allocate(0, 50)
    kv.allocate(1, 7)
    rng = np.random.default_rng(0)
    kv.k_pool = jnp.asarray(rng.standard_normal(kv.k_pool.shape), jnp.float32)
    kv.v_pool = jnp.asarray(rng.standard_normal(kv.v_pool.shape), jnp.float32)
    tables, lens = kv.block_table_batch([0, 1])
    bt, clen = jnp.asarray(tables), jnp.asarray(lens)
    B = 2
    q = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.num_heads, hd))
    kn = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, hd))
    vn = jax.random.normal(jax.random.PRNGKey(3), (B, Hkv, hd))
    outs = {}
    for backend in ("jnp", "pallas"):
        pool = AttentionWorkerPool(cfg, 4, "block", backend)
        outs[backend] = pool.attend_paged(q, kv.k_pool[0], kv.v_pool[0],
                                          bt, clen, kn, vn,
                                          sliding_window=9)
    np.testing.assert_allclose(np.asarray(outs["pallas"]),
                               np.asarray(outs["jnp"]), atol=2e-5, rtol=2e-5)


def test_block_partition_rejects_mismatched_kv_shards(setup):
    cfg, params = setup
    with pytest.raises(ValueError):
        LLMEngine(cfg, params, placement="attention_pool", partition="block",
                  attention_workers=4, kv_shards=2, num_blocks=64)
