"""Unified LLMEngine facade: greedy token-for-token parity across
placements (every disaggregated placement must match the fused
homogeneous baseline bit-for-bit), the streaming request lifecycle,
preemption under pool pressure with recompute re-admission, per-request
seeded sampling, and the scheduler/lifecycle edge cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer
from repro.serving import (EngineConfig, EngineStats, FCFSPolicy, LLMEngine,
                           PoolExhausted, PreemptingPolicy, Request,
                           RequestScheduler, SamplingParams,
                           SchedulingStalled, State, make_policy)
from repro.serving.kvcache import PagedKVCache
from repro.serving.worker_pool import (expected_transfer_bytes,
                                       transfer_bytes_moe)


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, lens=(5, 12, 9, 20), new=8, **sp):
    rng = np.random.default_rng(0)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=n).tolist(),
                    params=SamplingParams(max_new_tokens=new, **sp))
            for n in lens]


@pytest.fixture(scope="module")
def homogeneous_ref(setup):
    """Fused homogeneous baseline's greedy outputs — the parity oracle
    every disaggregated placement must reproduce bit-for-bit."""
    cfg, params = setup
    reqs = _reqs(cfg)
    eng = LLMEngine(cfg, params, EngineConfig(placement="homogeneous",
                                              max_batch=4, num_blocks=64))
    eng.submit(reqs)
    eng.run()
    return [r.output for r in reqs]


# ======================================================================
# tentpole: one engine, every placement — cross-config greedy parity
# ======================================================================

def test_homogeneous_outputs_deterministic(setup, homogeneous_ref):
    cfg, params = setup
    reqs = _reqs(cfg)
    eng = LLMEngine(cfg, params, EngineConfig(placement="homogeneous",
                                              max_batch=4, num_blocks=64))
    eng.submit(reqs)
    eng.run()
    assert [r.output for r in reqs] == homogeneous_ref
    assert all(len(r.output) == r.params.max_new_tokens for r in reqs)


def test_attention_pool_head_matches_homogeneous(setup, homogeneous_ref):
    cfg, params = setup
    reqs = _reqs(cfg)
    eng = LLMEngine(cfg, params, EngineConfig(
        placement="attention_pool", partition="head", attention_workers=2,
        max_batch=4, num_blocks=64))
    eng.submit(reqs)
    eng.run()
    assert [r.output for r in reqs] == homogeneous_ref
    # the pool's analytic per-token wire accounting matches the paper's
    # §3.1 formula exactly (the same invariant the legacy engine carried)
    per_token = eng.pool.log.total / eng.stats.tokens_generated
    assert per_token == pytest.approx(expected_transfer_bytes(cfg, 1))


@pytest.mark.parametrize("partition,workers", [("request", 4), ("block", 4)])
def test_attention_pool_partitions_match_homogeneous(setup, homogeneous_ref,
                                                     partition, workers):
    cfg, params = setup
    reqs = _reqs(cfg)
    eng = LLMEngine(cfg, params, EngineConfig(
        placement="attention_pool", partition=partition,
        attention_workers=workers, max_batch=4, num_blocks=64))
    eng.submit(reqs)
    eng.run()
    assert [r.output for r in reqs] == homogeneous_ref
    if partition == "block":
        assert eng.kv.n_shards == workers   # facade wired the pool shards
    # data-dependent per-worker KV accounting ran host-side
    assert sum(eng.pool.per_worker_kv_bytes) > 0


def test_moe_offload_matches_homogeneous(setup):
    cfg = registry.get_smoke_config("qwen3-moe-30b-a3b").replace(
        capacity_factor=64.0)  # no drops -> bit-stable across placements
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)

    def reqs():
        rng = np.random.default_rng(0)
        return [Request(prompt=rng.integers(0, cfg.vocab_size,
                                            size=n).tolist(),
                        params=SamplingParams(max_new_tokens=6))
                for n in (5, 9)]

    r_ref = reqs()
    ref = LLMEngine(cfg, params, EngineConfig(
        placement="homogeneous", max_batch=2, num_blocks=64))
    ref.submit(r_ref)
    ref.run()
    r_new = reqs()
    new = LLMEngine(cfg, params, EngineConfig(
        placement="moe_offload", attention_workers=2, expert_workers=2,
        max_batch=2, num_blocks=64))
    new.submit(r_new)
    new.run()
    assert [r.output for r in r_new] == [r.output for r in r_ref]
    # both pools accounted transfers through the placement strategy, and
    # the expert boundary's per-token bytes match the analytic formula
    assert new.pool.log.transfers > 0
    per_tok = new.expert_pool.log.total / new.stats.tokens_generated
    assert per_tok == pytest.approx(transfer_bytes_moe(cfg, 1))


def test_attention_pool_matches_homogeneous_on_windowed_softcap_model(setup):
    """gemma2 drives every exotic branch of the sliced decode step —
    alternating local/global sliding windows, attention sinks, logit
    softcap, sandwich post-norms, tied embeddings — through the placement
    strategy; parity with the fused baseline must survive them all."""
    cfg = registry.get_smoke_config("gemma2-27b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)

    def reqs():
        rng = np.random.default_rng(0)
        # first prompt is longer than the 64-token window: the window mask
        # actually bites during decode
        return [Request(prompt=rng.integers(0, cfg.vocab_size,
                                            size=n).tolist(),
                        params=SamplingParams(max_new_tokens=8))
                for n in (70, 9)]

    r_ref = reqs()
    ref = LLMEngine(cfg, params, EngineConfig(
        placement="homogeneous", max_batch=2, num_blocks=64))
    ref.submit(r_ref)
    ref.run()
    r_new = reqs()
    new = LLMEngine(cfg, params, EngineConfig(
        placement="attention_pool", max_batch=2, num_blocks=64))
    new.submit(r_new)
    new.run()
    assert [r.output for r in r_new] == [r.output for r in r_ref]


def test_moe_offload_rejects_dense_config(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="MoE"):
        LLMEngine(cfg, params, EngineConfig(placement="moe_offload"))


# ======================================================================
# streaming lifecycle
# ======================================================================

def test_streaming_tokens_arrive_before_batch_finishes(setup):
    cfg, params = setup
    reqs = _reqs(cfg, lens=(5, 9), new=6)
    eng = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=64))
    h0, h1 = eng.submit(reqs)
    observed = []
    for tok in h0:
        observed.append((tok, h1.finished))
    # tokens streamed incrementally: the sibling request was still decoding
    # when the first tokens arrived, and finished by the time h0 drained
    assert len(observed) == 6
    assert observed[0][1] is False
    assert h0.finished
    assert [t for t, _ in observed] == reqs[0].output
    h1.result()
    assert h1.finished and len(h1.output) == 6


def test_events_stream_drives_engine_and_orders_lifecycle(setup):
    cfg, params = setup
    reqs = _reqs(cfg, lens=(5, 9), new=4)
    eng = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=64))
    eng.submit(reqs)
    events = list(eng.events())      # pumps the engine until drained
    assert not eng.has_work()
    kinds = [(e.kind, e.rid) for e in events]
    for r in reqs:
        assert kinds.index(("submit", r.rid)) < \
            kinds.index(("admit", r.rid)) < kinds.index(("finish", r.rid))
        assert r.state == State.FINISHED


def test_generate_convenience_returns_handle(setup):
    cfg, params = setup
    eng = LLMEngine(cfg, params, EngineConfig(max_batch=2, num_blocks=64))
    handle = eng.generate([1, 2, 3], SamplingParams(max_new_tokens=3))
    assert handle.result() == handle.request.output
    assert len(handle.output) == 3


# ======================================================================
# preemption under pool pressure
# ======================================================================

def _contended(cfg, new=16):
    rng = np.random.default_rng(7)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=10).tolist(),
                    params=SamplingParams(max_new_tokens=new))
            for _ in range(3)]


def test_preemption_evicts_readmits_and_matches_uncontended(setup):
    """The acceptance scenario: under pool pressure a victim is evicted
    (blocks back to the pool), later re-admitted via recompute, and every
    request finishes with output identical to an uncontended run."""
    cfg, params = setup
    ref = _contended(cfg)
    e_ref = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=64))
    e_ref.submit(ref)
    e_ref.run()
    assert e_ref.stats.preemptions == 0     # uncontended

    tight = _contended(cfg)
    # 3 requests of 10-token prompts growing to 26 tokens each need ~12
    # blocks of 8; give the pool 8 so decode-time growth forces eviction
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=4, num_blocks=8, block_size=8, scheduler="preempt",
        decode_headroom=2))
    eng.submit(tight)
    eng.run(max_steps=2000)
    assert eng.stats.preemptions > 0
    kinds = [e.kind for e in eng.event_log]
    assert "preempt" in kinds and "readmit" in kinds
    # a preempt event carries its accounting payload
    ev = next(e for e in eng.event_log if e.kind == "preempt")
    assert ev.info["freed_blocks"] > 0
    assert [r.output for r in tight] == [r.output for r in ref]
    assert eng.kv.used_blocks == 0          # everything released


def test_fcfs_pool_exhaustion_raises_with_context(setup):
    cfg, params = setup
    reqs = _contended(cfg)
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=4, num_blocks=8, block_size=8, scheduler="fcfs",
        decode_headroom=0))
    eng.submit(reqs)
    with pytest.raises(PoolExhausted) as ei:
        eng.run(max_steps=2000)
    err = ei.value
    assert err.rid in {r.rid for r in reqs}
    assert err.live_tokens > 0
    assert err.free_blocks < 3
    assert "preempt" in str(err)            # tells the operator the fix


def test_preempting_policy_is_lifo_and_spares_singletons():
    pol = make_policy("preempt")
    assert isinstance(pol, PreemptingPolicy) and pol.preemptible
    a, b = Request(prompt=[1]), Request(prompt=[2])
    assert pol.select_victim([a, b]) is b    # last admitted
    assert pol.select_victim([a]) is None    # never the sole request
    assert make_policy("fcfs").select_victim([a, b]) is None
    with pytest.raises(ValueError):
        make_policy("edf")


def test_request_scheduler_preempt_bookkeeping(setup):
    cfg, _ = setup
    kv = PagedKVCache(cfg, num_blocks=16, block_size=8)
    sched = RequestScheduler(kv, max_batch=4, policy=PreemptingPolicy())
    reqs = [Request(prompt=list(range(8)),
                    params=SamplingParams(max_new_tokens=4))
            for _ in range(2)]
    sched.submit(reqs)
    assert sched.admit() == reqs
    victim = reqs[1]
    victim.output.append(3)                  # pretend prefill happened
    freed = sched.preempt(victim)
    assert freed == 1 and victim.state == State.PREEMPTED
    assert sched.waiting[0] is victim        # front of the queue
    assert victim.rid not in kv.tables       # blocks back in the pool
    assert sched.n_preemptions == 1
    # re-admission sizes for prompt + generated-but-unstored tokens
    assert sched.stored_tokens(victim) == 8
    assert sched.admit() == [victim] and victim.state == State.RUNNING


# ======================================================================
# per-request seeded sampling (SamplingParams.seed honoured)
# ======================================================================

def test_seeded_sampling_reproduces_across_batch_compositions(setup):
    cfg, params = setup
    prompt = np.random.default_rng(3).integers(
        0, cfg.vocab_size, size=8).tolist()

    def sp(seed):
        return SamplingParams(max_new_tokens=8, temperature=0.9, top_k=8,
                              seed=seed)

    solo = Request(prompt=list(prompt), params=sp(42))
    e1 = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=64))
    e1.submit(solo)
    e1.run()

    a = Request(prompt=list(prompt), params=sp(42))
    b = Request(prompt=list(prompt), params=sp(42))
    c = Request(prompt=list(prompt), params=sp(7))
    e2 = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=64))
    e2.submit([a, b, c])
    e2.run()
    # same seed -> same stream, regardless of batch composition
    assert a.output == solo.output
    assert a.output == b.output
    # a different seed diverges (overwhelmingly likely over 8 draws)
    assert c.output != a.output


# ======================================================================
# scheduler / lifecycle edge cases (satellite)
# ======================================================================

def test_eos_sampled_at_prefill_finishes_without_decode(setup):
    cfg, params = setup
    prompt = [3, 1, 4, 1, 5]
    probe = Request(prompt=list(prompt),
                    params=SamplingParams(max_new_tokens=1))
    e1 = LLMEngine(cfg, params, EngineConfig(max_batch=2, num_blocks=64))
    e1.submit(probe)
    e1.run()
    first = probe.output[0]                  # the greedy prefill token

    req = Request(prompt=list(prompt),
                  params=SamplingParams(max_new_tokens=8, eos_token=first))
    eng = LLMEngine(cfg, params, EngineConfig(max_batch=2, num_blocks=64))
    eng.submit(req)
    eng.run()
    assert req.output == [first]             # EOS at prefill: one token
    assert req.state == State.FINISHED
    assert eng.stats.steps == 0              # no decode iteration ran
    assert eng.kv.used_blocks == 0
    kinds = [e.kind for e in eng.event_log]
    assert kinds == ["submit", "admit", "finish"]


def test_zero_token_request_finishes_immediately(setup):
    cfg, params = setup
    eng = LLMEngine(cfg, params, EngineConfig(max_batch=2, num_blocks=64))
    handle = eng.generate([1, 2, 3], SamplingParams(max_new_tokens=0))
    assert handle.finished and handle.output == []
    assert list(handle) == []                # empty stream, no deadlock
    assert not eng.has_work()
    assert [e.kind for e in eng.event_log] == ["submit", "finish"]
    assert eng.kv.used_blocks == 0           # never touched the pool


def test_head_of_line_blocking_when_first_waiting_does_not_fit(setup):
    """FCFS admission is strict: a head-of-queue prompt that doesn't fit
    blocks smaller requests behind it (the documented trade-off the
    SchedulingPolicy hook exists to override)."""
    cfg, _ = setup
    kv = PagedKVCache(cfg, num_blocks=8, block_size=8)
    sched = RequestScheduler(kv, max_batch=4, policy=FCFSPolicy(),
                             decode_headroom=0)
    occupant = Request(prompt=list(range(32)),
                       params=SamplingParams(max_new_tokens=4))
    sched.submit([occupant])
    assert sched.admit() == [occupant]       # 4 of 8 blocks used
    big = Request(prompt=list(range(48)),    # needs 6 blocks; 4 free
                  params=SamplingParams(max_new_tokens=4))
    small = Request(prompt=list(range(8)),   # would fit easily
                    params=SamplingParams(max_new_tokens=4))
    sched.submit([big, small])
    assert sched.admit() == []               # head blocks the line
    assert small.state == State.WAITING
    # the occupant finishing unblocks the head (and then the tail)
    occupant.state = State.FINISHED
    sched.retire_finished()
    assert sched.admit() == [big, small]


def test_stall_raises_instead_of_spinning(setup):
    cfg, params = setup
    eng = LLMEngine(cfg, params, EngineConfig(max_batch=2, num_blocks=4,
                                              block_size=8))
    eng.submit(Request(prompt=list(range(200)),
                       params=SamplingParams(max_new_tokens=4)))
    with pytest.raises(SchedulingStalled, match="never be admitted"):
        eng.run()


def test_prefill_finish_frees_blocks_for_next_admission_same_step(setup):
    """Regression: a request that finishes at prefill returns its blocks
    immediately — a waiting request that NOW fits must be admitted in the
    same step, not spuriously reported as a scheduling stall."""
    cfg, params = setup
    eng = LLMEngine(cfg, params, EngineConfig(max_batch=2, num_blocks=8,
                                              block_size=16))
    first = Request(prompt=list(np.arange(96) % cfg.vocab_size),
                    params=SamplingParams(max_new_tokens=1))   # 6 blocks
    second = Request(prompt=list(np.arange(96) % cfg.vocab_size),
                     params=SamplingParams(max_new_tokens=1))  # needs 7 free
    eng.submit([first, second])
    eng.run()
    assert first.state == State.FINISHED
    assert second.state == State.FINISHED
    assert second.output == first.output     # greedy, identical prompt


def test_engine_seed_is_fallback_for_unseeded_requests(setup):
    cfg, params = setup
    prompt = [5, 3, 8, 2]

    def run(engine_seed):
        req = Request(prompt=list(prompt),
                      params=SamplingParams(max_new_tokens=6,
                                            temperature=0.9, top_k=8))
        eng = LLMEngine(cfg, params, EngineConfig(
            max_batch=1, num_blocks=64, seed=engine_seed))
        eng.submit(req)
        eng.run()
        return req.output

    assert run(0) == run(0)                  # deterministic fallback
    assert run(0) != run(123)                # the engine seed matters


def test_retire_then_readmit_same_rid(setup):
    cfg, params = setup
    eng = LLMEngine(cfg, params, EngineConfig(max_batch=2, num_blocks=64))
    first = Request(prompt=[2, 7, 1, 8], rid=990_001,
                    params=SamplingParams(max_new_tokens=4))
    eng.submit(first)
    eng.run()
    assert first.state == State.FINISHED and eng.kv.used_blocks == 0
    # a NEW request reusing the retired rid is admitted cleanly and decodes
    # identically (greedy) — the allocator fully recycled the id
    second = Request(prompt=[2, 7, 1, 8], rid=990_001,
                     params=SamplingParams(max_new_tokens=4))
    eng.submit(second)
    eng.run()
    assert second.output == first.output
    assert eng.kv.used_blocks == 0


# ======================================================================
# EngineConfig validation
# ======================================================================

def test_engine_config_validates_choices():
    with pytest.raises(ValueError, match="placement"):
        EngineConfig(placement="hybrid")
    with pytest.raises(ValueError, match="partition"):
        EngineConfig(partition="layer")
    with pytest.raises(ValueError, match="scheduler"):
        EngineConfig(scheduler="edf")
    with pytest.raises(ValueError, match="decode_backend"):
        EngineConfig(decode_backend="triton")
    with pytest.raises(ValueError, match="max_batch"):
        EngineConfig(max_batch=0)
    with pytest.raises(ValueError, match="kv_shards"):
        EngineConfig(kv_shards=0)
    with pytest.raises(ValueError, match="kv_shards"):
        EngineConfig(kv_shards=-1)


def test_engine_config_block_partition_shard_coupling():
    with pytest.raises(ValueError, match="kv_shards"):
        EngineConfig(placement="attention_pool", partition="block",
                     attention_workers=4, kv_shards=2)
    ec = EngineConfig(placement="attention_pool", partition="block",
                      attention_workers=4, num_blocks=64)
    assert ec.resolved_kv_shards == 4        # derived, not spelled out
    assert EngineConfig(placement="homogeneous").resolved_kv_shards == 1
    with pytest.raises(ValueError, match="divide"):
        EngineConfig(placement="attention_pool", partition="block",
                     attention_workers=3, num_blocks=64)


# ======================================================================
# EngineStats percentile surface (satellite)
# ======================================================================

def test_engine_stats_percentiles_and_summary():
    stats = EngineStats()
    for ttft, tbts in ((0.1, [0.01, 0.02]), (0.2, [0.02, 0.04]),
                       (0.4, [0.03, 0.03])):
        r = Request(prompt=[1], params=SamplingParams(max_new_tokens=2))
        r.arrival_s = 0.0
        r.first_token_s = ttft
        r.token_times = [ttft] + [ttft + t for t in tbts]
        stats.observe_request(r)
    p = stats.ttft_percentiles()
    assert p["p50"] == pytest.approx(0.2)
    assert p["p50"] <= p["p90"] <= p["p99"] <= 0.4
    s = stats.summary()
    assert {"throughput_tok_s", "mean_batch", "preemptions", "requests",
            "ttft_p50_s", "ttft_p90_s", "ttft_p99_s",
            "tbt_p50_s", "tbt_p90_s", "tbt_p99_s",
            "kv_bytes_transferred", "handoffs_completed", "handoff_retries",
            "router_affinity_hits", "handoff_p50_s", "handoff_p90_s",
            "handoff_p99_s"} <= set(s)
    assert s["requests"] == 3
    # the handoff/transfer surface (disaggregated cluster) aggregates
    stats.kv_bytes_transferred += 1024
    stats.handoff_latencies.extend([0.1, 0.3])
    stats.router_affinity_hits += 2
    s2 = stats.summary()
    assert s2["kv_bytes_transferred"] == 1024
    assert s2["handoffs_completed"] == 2
    assert s2["router_affinity_hits"] == 2
    assert s2["handoff_p50_s"] == pytest.approx(0.2)
    # empty stats stay well-defined (no NaNs in dashboards)
    empty = EngineStats().summary()
    assert empty["ttft_p99_s"] == 0.0 and empty["throughput_tok_s"] == 0.0
    assert empty["handoff_p99_s"] == 0.0


def test_llm_engine_populates_latency_percentiles(setup):
    cfg, params = setup
    reqs = _reqs(cfg, lens=(5, 9), new=4)
    eng = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=64))
    eng.submit(reqs)
    s = eng.run().summary()
    assert s["requests"] == 2
    assert s["ttft_p50_s"] > 0.0
    assert s["tbt_p99_s"] >= s["tbt_p50_s"] > 0.0


# ======================================================================
# pool-exhaustion signal (satellite): clear errors at the allocator edge
# ======================================================================

def test_append_token_pool_exhausted_names_request(setup):
    cfg, _ = setup
    kv = PagedKVCache(cfg, num_blocks=2, block_size=4)
    kv.allocate(0, 8)                        # both blocks owned by seq 0
    with pytest.raises(PoolExhausted) as ei:
        kv.append_token(0)                   # token 9 needs a third block
    err = ei.value
    assert err.rid == 0
    assert err.live_tokens == 8
    assert err.free_blocks == 0
    assert "request 0" in str(err) and "free" in str(err)


def test_write_prefill_capacity_error_names_request(setup):
    cfg, _ = setup
    kv = PagedKVCache(cfg, num_blocks=4, block_size=4)
    kv.allocate(7, 4)                        # one block: 4 tokens capacity
    hd = cfg.resolved_head_dim
    L, Hkv, S = cfg.num_layers, cfg.num_kv_heads, 9
    k = jnp.zeros((L, Hkv, S, hd))
    with pytest.raises(PoolExhausted, match="request 7"):
        kv.write_prefill(7, k, k)
    assert kv.k_pool.shape[2] == 4           # pool untouched by the failure
