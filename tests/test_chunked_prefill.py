"""Chunked paged prefill end to end: bit-identical greedy streams with
chunking on vs off for every placement/partition (plus gemma2 windows/sinks/
softcap, MoE fallback, prefix-sharing and preemption interplay), the
paged-context chunk attention kernel vs its jnp reference, incremental
block allocation accounting, the write_prefill token-count validation, the
memoised gather indices, and chunked admission of a prompt larger than the
currently-free pool."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import registry
from repro.kernels import ops
from repro.kernels.paged_prefill_attention import (
    paged_prefill_chunk_attention_jnp)
from repro.models import transformer
from repro.serving import (ChunkedPrefillPolicy, EngineConfig, LLMEngine,
                           PoolExhausted, Request, RequestScheduler,
                           SamplingParams, SchedulingStalled, State,
                           make_policy)
from repro.serving.kvcache import PagedKVCache

_PARAMS = {}


def _setup(arch):
    if arch not in _PARAMS:
        cfg = registry.get_smoke_config(arch)
        _PARAMS[arch] = (cfg, transformer.init_params(
            jax.random.PRNGKey(0), cfg))
    return _PARAMS[arch]


@pytest.fixture(scope="module")
def setup():
    return _setup("llama3-8b")


def _reqs(cfg, lens, new=6, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=n).tolist(),
                    params=SamplingParams(max_new_tokens=new)) for n in lens]


def _chunked_oneshot_pair(cfg, params, lens, econf_kw, chunk, new=6, seed=3):
    res = {}
    for c in (None, chunk):
        reqs = _reqs(cfg, lens, new=new, seed=seed)
        eng = LLMEngine(cfg, params, EngineConfig(
            prefill_chunk_tokens=c, **econf_kw))
        eng.submit(reqs)
        eng.run(max_steps=3000)
        res[c] = ([r.output for r in reqs], eng)
    return res[chunk], res[None]


# ======================================================================
# model layer: chunked prefill is bit-identical to the one-shot prefill
# ======================================================================

def _run_chunked(cfg, params, toks, chunk, block_size=8, num_blocks=64):
    """Drive prefill_chunk + the pool exactly like the engine does,
    asserting the incremental-allocation invariant after every chunk."""
    kv = PagedKVCache(cfg, num_blocks=num_blocks, block_size=block_size)
    S = toks.shape[1]
    cursor, logits = 0, None
    while cursor < S:
        target = min(cursor + chunk, S)
        idx = kv.gather_prefix_indices(0, cursor) if cursor else \
            jnp.zeros((0,), jnp.int32)
        logits, cache = transformer.prefill_chunk(
            params, cfg, {"tokens": jnp.asarray(toks[:, cursor:target],
                                                jnp.int32)},
            kv.k_pool, kv.v_pool, idx)
        kv.write_prefill_chunk(0, cache["k"][:, 0], cache["v"][:, 0],
                               start_token=cursor)
        # pool-accounting invariant: blocks allocated by chunk k cover
        # exactly the tokens written so far — nothing pre-allocated
        assert len(kv.tables[0]) == kv.blocks_needed(target)
        assert kv.lengths[0] == target
        assert int(cache["len"][0]) == target
        cursor = target
    return logits, kv


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma2-27b"])
@pytest.mark.parametrize("chunk", [8, 16, 24, 37, 64])
def test_prefill_chunk_bit_parity(arch, chunk):
    """Chunked prefill — every chunk size, including a non-block-aligned
    final chunk and a single chunk covering the whole prompt — reproduces
    the one-shot prefill EXACTLY: last-position logits and the pool KV,
    including gemma2's local windows, attention sinks, and softcap."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    S = 37
    toks = rng.integers(0, cfg.vocab_size, size=(1, S))
    logits_full, cache = transformer.prefill(
        params, cfg, {"tokens": jnp.asarray(toks, jnp.int32)}, max_seq=S)
    logits_chunked, kv = _run_chunked(cfg, params, toks, chunk)
    np.testing.assert_array_equal(np.asarray(logits_full),
                                  np.asarray(logits_chunked))
    # pool contents == the one-shot cache, bit for bit (gather is the
    # dense test oracle; it returns seq-major (L, B, S, Hkv, hd))
    k, v = kv.gather([0], S)[:2]
    np.testing.assert_array_equal(np.asarray(cache["k"][:, 0]),
                                  np.asarray(jnp.swapaxes(k, 2, 3)[:, 0]))
    np.testing.assert_array_equal(np.asarray(cache["v"][:, 0]),
                                  np.asarray(jnp.swapaxes(v, 2, 3)[:, 0]))


def test_prefill_chunk_guards():
    cfg, params = _setup("llama3-8b")
    rcfg = registry.get_smoke_config("rwkv6-7b")
    with pytest.raises(ValueError, match="family"):
        transformer.prefill_chunk(None, rcfg, {}, None, None, None)
    kv = PagedKVCache(cfg, num_blocks=8, block_size=8)
    with pytest.raises(ValueError, match="B == 1"):
        transformer.prefill_chunk(
            params, cfg, {"tokens": jnp.zeros((2, 4), jnp.int32)},
            kv.k_pool, kv.v_pool, jnp.zeros((0,), jnp.int32))


@settings(max_examples=8, deadline=None)
@given(chunk=st.integers(1, 8), n_extra=st.integers(0, 15),
       arch=st.sampled_from(["llama3-8b", "gemma2-27b"]))
def test_chunked_prefill_property(chunk, n_extra, arch):
    """Hypothesis property: for ANY chunk size (in blocks) and prompt
    length, chunked prefill is bit-identical to one-shot and every chunk
    allocates exactly blocks_needed(tokens so far) (the invariant is
    asserted inside _run_chunked after each chunk)."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(chunk * 31 + n_extra)
    S = 17 + n_extra
    toks = rng.integers(0, cfg.vocab_size, size=(1, S))
    logits_full, _ = transformer.prefill(
        params, cfg, {"tokens": jnp.asarray(toks, jnp.int32)}, max_seq=S)
    logits_chunked, _ = _run_chunked(cfg, params, toks, chunk * 8)
    np.testing.assert_array_equal(np.asarray(logits_full),
                                  np.asarray(logits_chunked))


# ======================================================================
# kernel: pallas paged-context chunk attention vs jnp reference
# ======================================================================

@pytest.mark.parametrize("C,nb", [(5, 4), (8, 0), (13, 2), (1, 3)])
@pytest.mark.parametrize("sw,sinks,cap", [(0, 0, 0.0), (12, 0, 0.0),
                                          (12, 2, 0.0), (0, 0, 30.0)])
def test_paged_chunk_kernel_matches_jnp(C, nb, sw, sinks, cap):
    """The pallas chunk kernel (prefix streamed from the pool in place)
    matches the jnp gather reference across windows, sinks, softcap, an
    EMPTY prefix (first chunk), and a non-block-aligned chunk."""
    rng = np.random.default_rng(C * 17 + nb)
    Hkv, G, hd, bs = 2, 3, 16, 8
    kp = jnp.asarray(rng.standard_normal((Hkv, 16, bs, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((Hkv, 16, bs, hd)), jnp.float32)
    table = jnp.asarray(rng.permutation(16)[:nb], jnp.int32)
    q = jnp.asarray(rng.standard_normal((C, Hkv * G, hd)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((C, Hkv, hd)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((C, Hkv, hd)), jnp.float32)
    kw = dict(sliding_window=sw, attention_sinks=sinks, logit_softcap=cap)
    ref = paged_prefill_chunk_attention_jnp(q, kp, vp, table, kc, vc, **kw)
    out = ops.paged_prefill_chunk_attention(q, kp, vp, table, kc, vc,
                                            backend="pallas", **kw)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=2e-5, atol=2e-5)


def test_chunk_jnp_reference_bit_matches_oneshot_rows():
    """The jnp reference's output rows are BIT-equal to the corresponding
    rows of one flat blockwise pass over the whole sequence — the scan
    boundaries (512-key blocks from position 0) are identical, so masked
    future blocks are exact no-ops."""
    from repro.models.attention import blockwise_attention

    rng = np.random.default_rng(7)
    Hkv, G, hd, bs = 2, 2, 16, 8
    P, C = 24, 13
    H = Hkv * G
    k_all = jnp.asarray(rng.standard_normal((P + C, Hkv, hd)), jnp.float32)
    v_all = jnp.asarray(rng.standard_normal((P + C, Hkv, hd)), jnp.float32)
    q_all = jnp.asarray(rng.standard_normal((P + C, H, hd)), jnp.float32)
    full = blockwise_attention(q_all[None], k_all[None], v_all[None],
                               causal=True)[0]
    # scatter the prefix into a shuffled pool through a table
    table = rng.permutation(8)[:P // bs]
    kp = jnp.zeros((Hkv, 8, bs, hd), jnp.float32)
    vp = jnp.zeros((Hkv, 8, bs, hd), jnp.float32)
    pre_k = jnp.swapaxes(k_all[:P], 0, 1).reshape(Hkv, P // bs, bs, hd)
    pre_v = jnp.swapaxes(v_all[:P], 0, 1).reshape(Hkv, P // bs, bs, hd)
    kp = kp.at[:, table].set(pre_k)
    vp = vp.at[:, table].set(pre_v)
    out = ops.paged_prefill_chunk_attention(
        q_all[P:], kp, vp, jnp.asarray(table, jnp.int32),
        k_all[P:], v_all[P:], backend="jnp")
    np.testing.assert_array_equal(np.asarray(full[P:]), np.asarray(out))


# ======================================================================
# kvcache satellites: write validation, incremental chunk writes, memo
# ======================================================================

def test_write_prefill_rejects_token_count_mismatch(setup):
    """A k/v whose token count disagrees with the allocated length raises
    a contextual ValueError instead of silently zero-padding the tail
    block (which decode would then read as real context)."""
    cfg, _ = setup
    kv = PagedKVCache(cfg, num_blocks=8, block_size=4)
    kv.allocate(1, 7)
    hd = cfg.resolved_head_dim
    mk = lambda s: jnp.zeros((cfg.num_layers, cfg.num_kv_heads, s, hd))  # noqa: E731
    with pytest.raises(ValueError, match="expected exactly 7"):
        kv.write_prefill(1, mk(5), mk(5))       # short: silent corruption
    with pytest.raises(ValueError, match="expected exactly 7"):
        kv.write_prefill(1, mk(8), mk(8))       # long but within capacity
    with pytest.raises(ValueError, match="expected exactly 3"):
        kv.write_prefill(1, mk(4), mk(4), start_token=4)
    kv.write_prefill(1, mk(7), mk(7))           # exact: fine


def test_write_prefill_chunk_allocates_incrementally(setup):
    cfg, _ = setup
    kv = PagedKVCache(cfg, num_blocks=4, block_size=4)
    hd = cfg.resolved_head_dim
    mk = lambda s: jnp.ones((cfg.num_layers, cfg.num_kv_heads, s, hd))  # noqa: E731
    kv.allocate(1, 4)
    kv.write_prefill_chunk(1, mk(4), mk(4), start_token=0)
    assert len(kv.tables[1]) == 1
    kv.write_prefill_chunk(1, mk(4), mk(4), start_token=4)
    assert len(kv.tables[1]) == 2 and kv.lengths[1] == 8
    kv.write_prefill_chunk(1, mk(3), mk(3), start_token=8)  # partial final
    assert len(kv.tables[1]) == 3 and kv.lengths[1] == 11
    kv.allocate(2, 4)                       # take the last free block
    with pytest.raises(PoolExhausted, match="chunked"):
        kv.write_prefill_chunk(1, mk(4), mk(4), start_token=11)


def test_gather_prefix_indices_memoised(setup):
    """The gather-index array is memoised by block-id CONTENT: a sharing
    wave's recipients (same physical blocks) hit one entry, and a CoW fork
    (different ids) misses instead of aliasing."""
    cfg, _ = setup
    kv = PagedKVCache(cfg, num_blocks=16, block_size=4)
    kv.allocate(1, 8)
    a = kv.gather_prefix_indices(1, 8)
    assert kv.gather_prefix_indices(1, 8) is a          # memo hit
    kv.share_blocks(1, 2, 8)
    assert kv.gather_prefix_indices(2, 8) is a          # same physical ids
    kv._cow_block(2, 1)                                 # fork slot 1
    b = kv.gather_prefix_indices(2, 8)
    assert b is not a
    assert list(np.asarray(b)) == kv.tables[2][:2]
    with pytest.raises(ValueError, match="block-aligned"):
        kv.gather_prefix_indices(1, 3)


# ======================================================================
# engine: greedy parity for every placement x partition (+ exotic configs)
# ======================================================================

@pytest.mark.parametrize("placement,partition,workers", [
    ("homogeneous", "head", 2),
    ("attention_pool", "head", 2),
    ("attention_pool", "request", 4),
    ("attention_pool", "block", 4),
])
def test_chunked_parity_across_placements(setup, placement, partition,
                                          workers):
    cfg, params = setup
    (on, eng_on), (off, eng_off) = _chunked_oneshot_pair(
        cfg, params, lens=(70, 9, 33, 18), chunk=16,
        econf_kw=dict(placement=placement, partition=partition,
                      attention_workers=workers, max_batch=4, num_blocks=64,
                      block_size=16))
    assert on == off                    # bit-identical greedy streams
    assert eng_on.stats.prefill_chunks_run >= 9   # ceil(70/16)+1+3+2
    assert eng_on.stats.max_prefill_slab_tokens == 16
    assert eng_off.stats.prefill_chunks_run == 0
    assert eng_off.stats.max_prefill_slab_tokens == 70
    assert eng_on.kv.used_blocks == 0   # everything released


def test_chunked_pallas_backend_end_to_end(setup):
    """decode_backend='pallas' reaches the chunk KERNEL (prefix streamed
    from the pool in place — no dense gather): the engine completes and
    its greedy stream stays close to the jnp reference engine's (kernel
    numerics, like every pallas backend; bit-parity is the jnp contract)."""
    cfg, params = setup
    outs = {}
    for backend in ("jnp", "pallas"):
        reqs = _reqs(cfg, (40, 18), new=4, seed=12)
        eng = LLMEngine(cfg, params, EngineConfig(
            max_batch=2, num_blocks=64, block_size=16,
            prefill_chunk_tokens=16, decode_backend=backend))
        eng.submit(reqs)
        eng.run()
        assert eng.stats.prefill_chunks_run == 5    # ceil(40/16)+ceil(18/16)
        assert all(r.state == State.FINISHED for r in reqs)
        outs[backend] = [r.output for r in reqs]
    assert outs["pallas"] == outs["jnp"]   # tiny smoke logits: argmax agrees


def test_chunked_gemma2_parity():
    """Windows + sinks + softcap + post-norms through the chunk path, with
    a prompt longer than the sliding window."""
    cfg, params = _setup("gemma2-27b")
    (on, _), (off, _) = _chunked_oneshot_pair(
        cfg, params, lens=(81, 40), chunk=16, new=8,
        econf_kw=dict(placement="attention_pool", max_batch=2,
                      num_blocks=64, block_size=16))
    assert on == off


def test_chunked_moe_falls_back_to_oneshot():
    """A chunk boundary changes MoE capacity-dispatch groups, so the
    engine runs MoE prompts one-shot: outputs identical, zero chunks."""
    cfg = registry.get_smoke_config("qwen3-moe-30b-a3b").replace(
        capacity_factor=64.0)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    (on, eng_on), (off, _) = _chunked_oneshot_pair(
        cfg, params, lens=(20, 23), chunk=8, new=5,
        econf_kw=dict(placement="moe_offload", attention_workers=2,
                      expert_workers=2, max_batch=2, num_blocks=64,
                      block_size=8))
    assert on == off
    assert eng_on.stats.prefill_chunks_run == 0
    assert eng_on._chunk_tokens is None


def test_chunked_with_prefix_sharing_parity(setup):
    cfg, params = setup
    common = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=40).tolist()
    res = {}
    for chunk in (None, 16):
        r = np.random.default_rng(42)
        reqs = [Request(prompt=list(common) +
                        r.integers(0, cfg.vocab_size, size=t).tolist(),
                        params=SamplingParams(max_new_tokens=8))
                for t in (5, 6, 7, 8)]
        eng = LLMEngine(cfg, params, EngineConfig(
            max_batch=4, num_blocks=64, block_size=16, prefix_sharing=True,
            prefill_chunk_tokens=chunk))
        eng.submit(reqs)
        eng.run()
        res[chunk] = ([q.output for q in reqs], eng)
    assert res[None][0] == res[16][0]
    # same-wave sharing under chunking is capped at the donor's progress
    # (its first chunk) — still nonzero, and the pool still drains clean
    assert res[16][1].stats.blocks_shared > 0
    assert res[16][1].kv.used_blocks == 0
    assert res[16][1].kv.refcounts == {}


def test_late_sharer_of_mid_prefill_donor_is_bit_safe(setup):
    """A recipient arriving while its donor is MID-PREFILL may only share
    blocks the donor has written (the match is capped at the donor's
    allocated progress) — its stream is bit-identical to a solo run."""
    cfg, params = setup
    common = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=40).tolist()
    r = np.random.default_rng(9)
    donor = Request(prompt=list(common) +
                    r.integers(0, cfg.vocab_size, size=56).tolist(),
                    params=SamplingParams(max_new_tokens=4))
    prompt = list(common[:32]) + r.integers(0, cfg.vocab_size,
                                            size=8).tolist()
    solo = Request(prompt=list(prompt),
                   params=SamplingParams(max_new_tokens=6))
    e0 = LLMEngine(cfg, params, EngineConfig(max_batch=2, num_blocks=64,
                                             block_size=16))
    e0.submit(solo)
    e0.run()
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=4, num_blocks=64, block_size=16, prefix_sharing=True,
        prefill_chunk_tokens=16))
    eng.submit(donor)
    eng.step()
    assert eng.sched.prefill_cursor(donor.rid) == 16   # donor mid-prefill
    late = Request(prompt=list(prompt),
                   params=SamplingParams(max_new_tokens=6))
    eng.submit(late)
    eng.run()
    assert late.output == solo.output
    assert donor.state == State.FINISHED
    assert eng.kv.used_blocks == 0 and eng.kv.refcounts == {}


def test_chunked_preemption_parity(setup):
    """Pool pressure forces evictions while prompts prefill chunked; every
    stream still finishes bit-identical to an uncontended run."""
    cfg, params = setup

    def mk():
        r = np.random.default_rng(7)
        return [Request(prompt=r.integers(0, cfg.vocab_size,
                                          size=18).tolist(),
                        params=SamplingParams(max_new_tokens=24))
                for _ in range(3)]

    ref = mk()
    e0 = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=64,
                                             block_size=8))
    e0.submit(ref)
    e0.run()
    tight = mk()
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=4, num_blocks=12, block_size=8, scheduler="preempt",
        decode_headroom=2, prefill_chunk_tokens=8))
    eng.submit(tight)
    eng.run(max_steps=3000)
    assert eng.stats.preemptions > 0
    assert [r.output for r in tight] == [r.output for r in ref]
    assert eng.kv.used_blocks == 0


# ======================================================================
# tentpole acceptance: admission beyond the currently-free pool + mixed
# iterations keep the decode batch moving
# ======================================================================

def test_long_prompt_admitted_into_mostly_held_pool(setup):
    """A prompt whose whole allocation exceeds the FREE pool at arrival is
    admitted on its first chunk and completes (blocks arrive as decoders
    retire) — one-shot admission must wait head-of-line for the full
    allocation."""
    cfg, params = setup
    r = np.random.default_rng(5)
    prompt = r.integers(0, cfg.vocab_size, size=176).tolist()
    solo = Request(prompt=list(prompt), params=SamplingParams(max_new_tokens=4))
    e0 = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=32,
                                             block_size=8))
    e0.submit(solo)
    e0.run()
    waits = {}
    for chunk in (None, 16):
        eng = LLMEngine(cfg, params, EngineConfig(
            max_batch=4, num_blocks=24, block_size=8,
            prefill_chunk_tokens=chunk))
        shorts = _reqs(cfg, (17, 17), new=6, seed=8)
        eng.submit(shorts)
        eng.step()
        long_req = Request(prompt=list(prompt),
                           params=SamplingParams(max_new_tokens=4))
        free = len(eng.kv.free)
        assert free < eng.kv.blocks_needed(len(prompt))   # cannot one-shot
        eng.submit(long_req)
        eng.run(max_steps=1000)
        steps = {e.kind: e.step for e in eng.event_log
                 if e.rid == long_req.rid}
        waits[chunk] = steps["admit"] - steps["submit"]
        assert long_req.output == solo.output
    assert waits[16] < waits[None]


def test_decode_batch_advances_during_chunked_prefill(setup):
    """Mixed iterations: while the long prompt's chunks run, every running
    decoder still produces exactly one token per engine step."""
    cfg, params = setup
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=4, num_blocks=256, block_size=16,
        prefill_chunk_tokens=16))
    shorts = _reqs(cfg, (16, 16), new=30, seed=2)
    eng.submit(shorts)
    eng.step(); eng.step()
    long_req = _reqs(cfg, (128,), new=2, seed=4)[0]
    eng.submit(long_req)
    for _ in range(50):
        before = [len(r.output) for r in shorts]
        eng.step()
        after = [len(r.output) for r in shorts]
        assert all(b - a == 1 for a, b in zip(before, after)
                   if a < 30)          # decoders advanced THIS step
        if long_req.state == State.RUNNING and \
                eng.sched.prefill_done(long_req.rid):
            break
    chunks = [e for e in eng.event_log
              if e.kind == "chunk" and e.rid == long_req.rid]
    assert len(chunks) == 8            # ceil(128 / 16), one per step
    assert [c.step for c in chunks] == \
        list(range(chunks[0].step, chunks[0].step + 8))
    eng.run()
    assert long_req.state == State.FINISHED


@pytest.mark.parametrize("scheduler", ["fcfs", "preempt"])
def test_concurrent_partial_prompts_never_deadlock(setup, scheduler):
    """Aggregate over-commitment guard: several long prompts whose first
    chunks all fit must NOT be co-admitted into a pool that cannot
    complete them (younger partial prompts' holdings are stuck until the
    oldest finishes) — the workload completes exactly like one-shot
    admission does, just with earlier overlap."""
    cfg, params = setup

    def mk():
        r = np.random.default_rng(21)
        return [Request(prompt=r.integers(0, cfg.vocab_size,
                                          size=100).tolist(),
                        params=SamplingParams(max_new_tokens=4))
                for _ in range(3)]

    ref = mk()
    e0 = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=8,
                                             block_size=16,
                                             scheduler=scheduler))
    e0.submit(ref)
    e0.run(max_steps=2000)
    reqs = mk()
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=4, num_blocks=8, block_size=16, scheduler=scheduler,
        prefill_chunk_tokens=16))
    eng.submit(reqs)
    eng.run(max_steps=2000)
    assert all(r.state == State.FINISHED for r in reqs)
    assert [r.output for r in reqs] == [r.output for r in ref]
    assert eng.kv.used_blocks == 0


def test_commitment_guard_counts_shared_blocks_once(setup):
    """The over-commitment guard counts a prefix-shared physical block
    ONCE across co-admitted partial prompts — a common-prefix family is
    admitted together (double-counting would serialise it and erase the
    sharing capacity win)."""
    cfg, _ = setup
    common = list(np.random.default_rng(2).integers(
        0, cfg.vocab_size, size=32))
    r = np.random.default_rng(5)
    reqs = [Request(prompt=common + r.integers(0, cfg.vocab_size,
                                               size=8).tolist(),
                    params=SamplingParams(max_new_tokens=2))
            for _ in range(3)]
    kv = PagedKVCache(cfg, num_blocks=6, block_size=16)
    sched = RequestScheduler(
        kv, max_batch=4, policy=make_policy("fcfs",
                                            prefill_chunk_tokens=16),
        decode_headroom=0, prefix_sharing=True)
    sched.submit(reqs)
    assert len(sched.admit()) == 3      # whole family co-admitted
    # each sharer borrowed the donor's first block — counted once
    assert kv.tables[reqs[1].rid][0] == kv.tables[reqs[0].rid][0]
    assert kv.tables[reqs[2].rid][0] == kv.tables[reqs[0].rid][0]


def test_scheduler_rejects_misaligned_chunk_tokens(setup):
    cfg, _ = setup
    kv = PagedKVCache(cfg, num_blocks=8, block_size=16)
    with pytest.raises(ValueError, match="multiple of the KV block size"):
        RequestScheduler(kv, max_batch=2,
                         policy=make_policy("fcfs",
                                            prefill_chunk_tokens=24))


def test_never_fitting_prompt_stalls_cleanly(setup):
    """A prompt the TOTAL pool can never hold is not admitted chunked (it
    could never finish): the engine surfaces SchedulingStalled."""
    cfg, params = setup
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=2, num_blocks=8, block_size=8, prefill_chunk_tokens=8))
    eng.submit(_reqs(cfg, (100,), new=2))
    with pytest.raises(SchedulingStalled):
        eng.run()


# ======================================================================
# surface: events, stats, config, policy
# ======================================================================

def test_chunk_events_and_stats(setup):
    cfg, params = setup
    eng = LLMEngine(cfg, params, EngineConfig(
        max_batch=2, num_blocks=64, block_size=16,
        prefill_chunk_tokens=32))
    req = _reqs(cfg, (70,), new=2, seed=6)[0]
    eng.submit(req)
    eng.run()
    chunks = [e for e in eng.event_log if e.kind == "chunk"]
    assert [c.info["tokens"] for c in chunks] == [32, 32, 6]
    assert [c.info["start"] for c in chunks] == [0, 32, 64]
    assert chunks[-1].info["remaining"] == 0
    s = eng.stats.summary()
    assert s["prefill_chunks_run"] == 3
    assert s["max_prefill_slab_tokens"] == 32
    admit = [e for e in eng.event_log if e.kind == "admit"][0]
    assert admit.info.get("chunked") is True


def test_config_validates_chunk_tokens():
    with pytest.raises(ValueError, match="multiple of block_size"):
        EngineConfig(block_size=16, prefill_chunk_tokens=24)
    with pytest.raises(ValueError, match=">= 1"):
        EngineConfig(prefill_chunk_tokens=0)
    assert EngineConfig().prefill_chunk_tokens is None   # default off
    assert EngineConfig(block_size=16, prefill_chunk_tokens=32) \
        .prefill_chunk_tokens == 32


def test_chunked_policy_wraps_inner():
    p = make_policy("preempt", prefill_chunk_tokens=32)
    assert isinstance(p, ChunkedPrefillPolicy)
    assert p.preemptible and p.chunk_tokens == 32
    assert "preempt" in p.name
    assert make_policy("fcfs").__class__.__name__ == "FCFSPolicy"
    with pytest.raises(ValueError, match=">= 1"):
        ChunkedPrefillPolicy(make_policy("fcfs"), 0)


def test_chunked_admission_charges_only_first_chunk(setup):
    """Scheduler-level: chunked admission pops exactly the first chunk's
    blocks; the cursor starts at the shared prefix."""
    cfg, _ = setup
    kv = PagedKVCache(cfg, num_blocks=64, block_size=8)
    sched = RequestScheduler(kv, max_batch=4,
                             policy=make_policy("fcfs",
                                                prefill_chunk_tokens=16),
                             decode_headroom=0)
    req = _reqs(cfg, (100,), new=2)[0]
    sched.submit([req])
    assert sched.admit() == [req]
    assert kv.lengths[req.rid] == 16          # first chunk only
    assert len(kv.tables[req.rid]) == 2
    assert sched.prefill_cursor(req.rid) == 0
    assert not sched.prefill_done(req.rid)
    assert sched.next_prefill() is req
    sched.advance_prefill(req, 16)
    assert sched.prefill_cursor(req.rid) == 16
    sched.advance_prefill(req, 100)
    assert sched.prefill_done(req.rid)
    assert sched.next_prefill() is None
