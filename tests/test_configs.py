"""Config/registry coverage: input_specs builds for every applicable
(arch x shape); long_500k applicability matrix matches DESIGN.md §4."""
import pytest

from repro.configs import registry
from repro.configs.base import INPUT_SHAPES, input_specs


def test_applicability_matrix():
    runs_500k = {a for a in registry.ASSIGNED
                 if "long_500k" in registry.applicable_shapes(a)}
    assert runs_500k == {"rwkv6-7b", "zamba2-1.2b", "gemma2-27b",
                         "llama3-8b", "glm4-9b"}
    # every arch runs the other three shapes
    for a in registry.ASSIGNED:
        shapes = registry.applicable_shapes(a)
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


@pytest.mark.parametrize("arch", registry.ASSIGNED)
def test_input_specs_build(arch):
    for shape in registry.applicable_shapes(arch):
        cfg = registry.config_for_shape(arch, shape)
        specs = input_specs(cfg, shape)
        shp = INPUT_SHAPES[shape]
        if shp.kind == "decode":
            assert specs["tokens"].shape == (shp.global_batch,)
            assert "cache" in specs
            # one-token decode: head-major cache (L, B, Hkv, S, hd) covers
            # seq_len positions
            if cfg.family in ("dense", "vlm", "moe"):
                assert specs["cache"]["k"].shape[3] == shp.seq_len
                assert specs["cache"]["k"].shape[2] == cfg.num_kv_heads
        else:
            toks = specs["batch"]["tokens"]
            assert toks.shape[0] == shp.global_batch
            if cfg.family == "audio":
                assert specs["batch"]["frames"].shape[1] == shp.seq_len
            elif cfg.modality == "vision":
                F = specs["batch"]["frontend"].shape[1]
                assert F + toks.shape[1] == shp.seq_len
            else:
                assert toks.shape[1] == shp.seq_len


def test_long_500k_uses_sliding_window_variant_for_llama():
    cfg = registry.config_for_shape("llama3-8b", "long_500k")
    assert cfg.sliding_window == 8192
    cfg2 = registry.config_for_shape("llama3-8b", "decode_32k")
    assert cfg2.sliding_window == 0
    # glm4 long context rides the StreamingLLM sinks variant (paper §7)
    cfg3 = registry.config_for_shape("glm4-9b", "long_500k")
    assert cfg3.attention_sinks == 4 and cfg3.sliding_window == 8192


def test_smoke_configs_are_reduced():
    for arch in registry.ASSIGNED:
        cfg = registry.get_smoke_config(arch)
        assert cfg.num_layers <= 5
        assert cfg.d_model <= 512
        assert cfg.vocab_size <= 512
        if cfg.num_experts:
            assert cfg.num_experts <= 4
        assert cfg.family == registry.get_config(arch).family
