"""Sharding-level tests on 8 fake host devices (subprocess-isolated so the
main pytest process keeps its single real device), plus spec-building
checks that run in-process on full-size configs via eval_shape."""
import os
import subprocess
import sys
import textwrap

import jax

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_specs_build_for_all_archs_and_shapes():
    from repro.configs import registry
    from repro.core import disagg
    from repro.models import transformer

    # AbstractMesh: production shape without needing 256 devices
    try:  # jax >= 0.5 signature
        mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    except TypeError:  # 0.4.x takes (name, size) pairs
        mesh = jax.sharding.AbstractMesh((("data", 16), ("model", 16)))
    for arch in registry.ASSIGNED:
        cfg = registry.get_config(arch)
        pshape = jax.eval_shape(
            lambda c=cfg: transformer.init_params(jax.random.PRNGKey(0), c))
        specs = disagg.specs_for_params(cfg, pshape, mesh,
                                        fsdp=arch == "kimi-k2-1t-a32b")
        # every leaf got a spec of matching rank
        flat_p = jax.tree.leaves(pshape)
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        assert len(flat_p) == len(flat_s)
        for p, s in zip(flat_p, flat_s):
            assert len(s) <= len(p.shape), (arch, p.shape, s)
            # divisibility of every sharded dim
            for i, ax in enumerate(s):
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                assert p.shape[i] % n == 0, (arch, p.shape, s)


def test_seq_and_head_parallel_attention_match_oracle():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.core import attention_parallel
        from repro.models.attention import decode_attention_jnp
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((2, 4), ("data", "model"))
        B, S, H, Hkv, hd = 4, 64, 8, 4, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, H, hd))
        kc = jax.random.normal(ks[1], (B, S, Hkv, hd))
        vc = jax.random.normal(ks[2], (B, S, Hkv, hd))
        clen = jnp.array([64, 17, 33, 50], jnp.int32)
        ref = decode_attention_jnp(q, kc, vc, clen)
        for fn, name in [
            (attention_parallel.seq_parallel_decode_attention, "seq"),
            (attention_parallel.head_parallel_decode_attention, "head")]:
            out = fn(mesh, "model", q, kc, vc, clen, batch_axis="data")
            err = float(jnp.max(jnp.abs(out - ref)))
            assert err < 1e-4, (name, err)
        print("PARALLEL_OK")
    """)
    assert "PARALLEL_OK" in out


def test_paged_head_and_request_parallel_attention_match_oracle():
    """Pool-native shard_map backends: head-sharded pool and batch-sharded
    block tables must both reproduce the paged jnp oracle."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.core import attention_parallel
        from repro.kernels import ref
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((2, 4), ("data", "model"))
        B, Hkv, G, hd, bs, nb = 4, 4, 2, 32, 8, 4
        NB = B * nb + 3
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, Hkv * G, hd))
        kp = jax.random.normal(ks[1], (Hkv, NB, bs, hd))
        vp = jax.random.normal(ks[2], (Hkv, NB, bs, hd))
        bt = jax.random.permutation(ks[3], NB)[:B * nb]
        bt = bt.reshape(B, nb).astype(jnp.int32)
        clen = jnp.array([32, 7, 20, 15], jnp.int32)
        want = ref.paged_decode_attention_ref(
            q.reshape(B, Hkv, G, hd), kp, vp, bt, clen
            ).reshape(B, Hkv * G, hd)
        o1 = attention_parallel.head_parallel_paged_decode_attention(
            mesh, "model", q, kp, vp, bt, clen)
        o2 = attention_parallel.request_parallel_paged_decode_attention(
            mesh, "data", q, kp, vp, bt, clen)
        for name, out in (("head", o1), ("request", o2)):
            err = float(jnp.max(jnp.abs(out - want)))
            assert err < 1e-4, (name, err)
        print("PAGED_PARALLEL_OK")
    """)
    assert "PAGED_PARALLEL_OK" in out


def test_sharded_train_step_runs_on_fake_mesh():
    """Actually EXECUTE a sharded train step of a reduced llama on a (2,4)
    mesh — values, not just lowering."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro.core import disagg
        from repro.launch.mesh import make_test_mesh
        from repro.models import transformer
        from repro.training import optimizer as opt
        from repro.training.train_loop import make_train_step
        mesh = make_test_mesh((2, 4), ("data", "model"))
        cfg = registry.get_smoke_config("llama3-8b", num_heads=8,
                                        num_kv_heads=4, d_model=256)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        state = opt.init_opt_state(params)
        pshape = jax.eval_shape(lambda: params)
        pspecs = disagg.specs_for_params(cfg, pshape, mesh)
        named = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                             is_leaf=lambda x: isinstance(x, P))
        params = jax.tree.map(jax.device_put, params, named)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                 (4, 32), 0, cfg.vocab_size)}
        step = jax.jit(make_train_step(cfg, opt.AdamWConfig(lr=1e-3)))
        p2, s2, m = step(params, state, batch)  # shardings ride the args
        loss = float(m["loss"])
        assert np.isfinite(loss), loss
        # compare against single-device execution
        params_local = jax.device_get(params)
        p3, s3, m3 = make_train_step(cfg, opt.AdamWConfig(lr=1e-3))(
            jax.tree.map(jnp.asarray, params_local), state, batch)
        assert abs(loss - float(m3["loss"])) < 1e-3
        print("TRAIN_SHARDED_OK", loss)
    """)
    assert "TRAIN_SHARDED_OK" in out


def test_dryrun_entry_small_mesh():
    """The real dryrun.run_one machinery on a layer-reduced config."""
    out = _run_subprocess("""
        import os
        # 8 devices already set via XLA_FLAGS by the harness
        import jax
        from repro.launch import dryrun
        import repro.launch.mesh as mesh_mod
        mesh_mod.make_production_mesh = \
            lambda multi_pod=False: mesh_mod.make_test_mesh(
                (2, 2, 2) if multi_pod else (2, 4),
                ("pod", "data", "model") if multi_pod else ("data", "model"))
        # reload the symbol inside dryrun
        dryrun.run_one.__globals__  # no-op
        import tempfile
        with tempfile.TemporaryDirectory() as d:
            rec = dryrun.run_one("tinyllama-1.1b", "decode_32k",
                                 multi_pod=False, mode="both", out_dir=d,
                                 overrides={"num_layers": 2,
                                            "vocab_size": 2048})
            assert rec["ok"]
            assert rec["roofline"]["dominant"] in ("compute", "memory",
                                                   "collective")
        print("DRYRUN_OK")
    """)
    assert "DRYRUN_OK" in out


def test_block_parallel_paged_attention_matches_oracle():
    """Block-level split: one sequence's KV spans all pool devices
    (PagedKVCache round-robin shards), per-device partials psum-combined.
    Must reproduce the full-table paged oracle for both the jnp reference
    and the Pallas kernel (interpret) in-shard, ragged lengths and
    window+sinks included."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry
        from repro.core import attention_parallel
        from repro.kernels import ref
        from repro.launch.mesh import make_test_attn_pool_mesh
        from repro.serving.kvcache import PagedKVCache
        mesh = make_test_attn_pool_mesh(n_pool=4, model=2)
        cfg = registry.get_smoke_config("llama3-8b")
        Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        G = cfg.num_heads // Hkv
        kv = PagedKVCache(cfg, num_blocks=64, block_size=8, n_shards=4)
        kv.allocate(0, 200)   # long: spans every shard
        kv.allocate(1, 13)    # short: some shards hold nothing -> empty
        rng = np.random.default_rng(0)
        kv.k_pool = jnp.asarray(rng.standard_normal(kv.k_pool.shape),
                                jnp.float32)
        kv.v_pool = jnp.asarray(rng.standard_normal(kv.v_pool.shape),
                                jnp.float32)
        bt, lens = kv.block_table_batch([0, 1])
        lt, lp, st = kv.block_table_shards([0, 1])
        assert (st.sum(1) > 0).all()  # the batch's KV spans all 4 shards
        B = 2
        q = jax.random.normal(jax.random.PRNGKey(1), (B, Hkv * G, hd))
        clen = jnp.asarray(lens)
        for kw in ({}, {"sliding_window": 23, "attention_sinks": 3},
                   {"logit_softcap": 30.0}):
            want = ref.paged_decode_attention_ref(
                q.reshape(B, Hkv, G, hd), kv.k_pool[0], kv.v_pool[0],
                jnp.asarray(bt), clen, **kw).reshape(B, Hkv * G, hd)
            for backend in ("jnp", "pallas"):
                got = attention_parallel.block_parallel_paged_decode_attention(
                    mesh, "attn", q, kv.k_pool[0], kv.v_pool[0],
                    jnp.asarray(lt), jnp.asarray(lp), clen,
                    backend=backend, interpret=True, **kw)
                err = float(jnp.max(jnp.abs(got - want)))
                assert err < 1e-4, (backend, kw, err)
        print("BLOCK_PARALLEL_OK")
    """)
    assert "BLOCK_PARALLEL_OK" in out


def test_paged_parallel_backends_propagate_sinks():
    """head-/request-level paged backends now carry attention_sinks through
    to the in-shard kernel/reference."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.core import attention_parallel
        from repro.kernels import ref
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((2, 4), ("data", "model"))
        B, Hkv, G, hd, bs, nb = 4, 4, 2, 32, 8, 4
        NB = B * nb + 3
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, Hkv * G, hd))
        kp = jax.random.normal(ks[1], (Hkv, NB, bs, hd))
        vp = jax.random.normal(ks[2], (Hkv, NB, bs, hd))
        bt = jax.random.permutation(ks[3], NB)[:B * nb]
        bt = bt.reshape(B, nb).astype(jnp.int32)
        clen = jnp.array([32, 7, 20, 15], jnp.int32)
        kw = dict(sliding_window=9, attention_sinks=2)
        want = ref.paged_decode_attention_ref(
            q.reshape(B, Hkv, G, hd), kp, vp, bt, clen, **kw
            ).reshape(B, Hkv * G, hd)
        o1 = attention_parallel.head_parallel_paged_decode_attention(
            mesh, "model", q, kp, vp, bt, clen, **kw)
        o2 = attention_parallel.request_parallel_paged_decode_attention(
            mesh, "data", q, kp, vp, bt, clen, **kw)
        for name, out in (("head", o1), ("request", o2)):
            err = float(jnp.max(jnp.abs(out - want)))
            assert err < 1e-4, (name, err)
        print("PAGED_SINKS_OK")
    """)
    assert "PAGED_SINKS_OK" in out


def test_psum_combine_matches_combine_many_incl_empty_shard():
    """psum_combine over a mesh axis == host-side combine_many over the same
    disjoint partials — including a shard whose subset is EMPTY (m = -inf,
    s = 0), the case block sharding hits routinely (a device holding none of
    a short sequence's blocks)."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        try:
            shard_map = jax.shard_map
        except AttributeError:
            from jax.experimental.shard_map import shard_map
        from repro.core import combine as C
        from repro.launch.mesh import make_test_mesh
        n = 4
        mesh = make_test_mesh((n,), ("pool",))
        B, H, hd, S = 3, 4, 16, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, hd))
        k = jax.random.normal(ks[1], (B, H, S, hd))
        v = jax.random.normal(ks[2], (B, H, S, hd))
        Ss = S // n
        # shard 3's subset is fully masked -> empty partial (m=-inf, s=0)
        mask = jnp.arange(S) < (S - Ss)
        parts = [C.partial_attention(q, k[:, :, i*Ss:(i+1)*Ss],
                                     v[:, :, i*Ss:(i+1)*Ss],
                                     mask=mask[i*Ss:(i+1)*Ss])
                 for i in range(n)]
        want = C.finalize(C.combine_many(parts))
        # same partials stacked on the mesh axis, merged by psum_combine
        stacked = C.Partial(*[jnp.stack(a) for a in zip(*parts)])
        def shard_fn(p):
            local = C.Partial(p.a[0], p.s[0], p.m[0])
            return C.finalize(C.psum_combine(local, "pool"))
        got = shard_map(shard_fn, mesh=mesh,
                        in_specs=(C.Partial(P("pool"), P("pool"), P("pool")),),
                        out_specs=P())(stacked)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        # all-empty merge stays finite (no NaN from the -inf rebase)
        empty = C.partial_attention(q, k, v, mask=jnp.zeros((S,), bool))
        st_e = C.Partial(*[jnp.stack([a]*n) for a in empty])
        out_e = shard_map(shard_fn, mesh=mesh,
                          in_specs=(C.Partial(P("pool"), P("pool"),
                                              P("pool")),),
                          out_specs=P())(st_e)
        assert np.all(np.isfinite(np.asarray(out_e)))
        print("PSUM_COMBINE_OK")
    """)
    assert "PSUM_COMBINE_OK" in out
