"""Rotational staggered pipelining (paper §4.3): schedule properties proven
for swept (n, steps) and the executable rotation demo."""
import jax
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import registry
from repro.core import converter, pipeline
from repro.models import blocks


@settings(deadline=None, max_examples=40)
@given(n=st.integers(2, 10), steps=st.integers(1, 50))
def test_schedule_properties(n, steps):
    s = pipeline.rotational_schedule(n, steps)
    v = pipeline.validate(s)
    assert v["conflict_free"], (n, steps)
    assert v["sequential"], (n, steps)
    assert v["attn_bubble_free"], (n, steps)


def test_rotation_law():
    s = pipeline.rotational_schedule(5, 8)
    for e in s.events:
        if e.device.startswith("model:"):
            assert e.device == f"model:{(e.batch + e.step) % 4}"


def test_steady_state_utilisation_approaches_one():
    u = pipeline.utilisation(pipeline.rotational_schedule(4, 200))
    assert u["attn"] > 0.98
    for r in range(3):
        assert u[f"model:{r}"] > 0.98


def test_throughput_speedup_monotone():
    # n/(n-1): biggest win at n=2, approaching 1 from above
    prev = float("inf")
    for n in range(2, 10):
        s = pipeline.throughput_speedup(n)
        assert 1.0 < s <= 2.0
        assert s < prev
        prev = s


def test_run_rotational_executes_correctly():
    """n batches through real converter slices under the rotation order:
    results match direct execution, and the replica log obeys the law."""
    cfg = registry.get_smoke_config("llama3-8b")
    w = blocks.init_dense_block(jax.random.PRNGKey(0), cfg)
    n = 4
    progs, inputs, direct = [], [], []

    def attn_fn(j, name, env):
        v = env["v_proj"]
        return np.repeat(v, env["q_proj"].shape[1] // v.shape[1], axis=1)

    for j in range(n):
        g = converter.build_block_graph(cfg, weights=w, batch=2)
        sp = converter.split_at_attention(g)
        progs.append(sp)
        x = np.random.default_rng(j).standard_normal(
            (2, cfg.d_model)).astype(np.float32)
        inputs.append({"x": x})
        direct.append(sp.run({"x": x}, lambda nm, env: attn_fn(j, nm, env)))

    envs, log = pipeline.run_rotational(progs, inputs, attn_fn)
    for j in range(n):
        np.testing.assert_allclose(envs[j]["residual2"],
                                   direct[j]["residual2"], atol=1e-6)
    for j, k, replica in log:
        assert replica == (j + k) % (n - 1)
    # every (batch, slice) executed exactly once
    assert sorted({(j, k) for j, k, _ in log}) == \
        [(j, k) for j in range(n) for k in range(len(progs[0].slices))]
