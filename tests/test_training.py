"""Training substrate: learning actually happens, checkpoint roundtrip,
lr schedule, data pipeline packing."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data.synthetic import SyntheticCorpus, packed_batches
from repro.models import transformer
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.train_loop import train


def test_loss_decreases():
    cfg = registry.get_smoke_config("tinyllama-1.1b")
    data = packed_batches(cfg.vocab_size, batch=4, seq_len=64, seed=0)
    _, _, hist = train(
        cfg, opt.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60),
        data, 60, log_every=10)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.15


def test_checkpoint_roundtrip():
    cfg = registry.get_smoke_config("qwen3-moe-30b-a3b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init_opt_state(params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, params, state, step=3)
        ckpt.save(d, params, state, step=9)
        assert ckpt.latest_step(d) == 9
        tree, step = ckpt.restore(d, {"params": params, "opt": state})
        assert step == 9
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(tree["params"])):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lr_schedule_warmup_and_cosine():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_ratio=0.1)
    assert float(opt.lr_schedule(cfg, jnp.asarray(5))) < 0.6
    assert float(opt.lr_schedule(cfg, jnp.asarray(10))) == 1.0
    end = float(opt.lr_schedule(cfg, jnp.asarray(110)))
    assert abs(end - 0.1) < 1e-5


def test_grad_clip_bounds_update():
    cfg = registry.get_smoke_config("tinyllama-1.1b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init_opt_state(params)
    huge = jax.tree.map(lambda p: jnp.full(p.shape, 100.0, jnp.float32),
                        params)
    _, _, m = opt.apply_updates(params, huge, state,
                                opt.AdamWConfig(grad_clip=1.0))
    assert float(m["grad_norm"]) > 1.0  # reported pre-clip


def test_synthetic_corpus_has_structure():
    c = SyntheticCorpus(vocab_size=64, seed=0)
    rng = np.random.default_rng(0)
    doc = c.document(rng, 2000)
    # successor entropy must be far below uniform (learnable structure)
    pair_counts = {}
    for a, b in zip(doc[:-1], doc[1:]):
        pair_counts.setdefault(int(a), []).append(int(b))
    uniq = np.mean([len(set(v)) for v in pair_counts.values()
                    if len(v) >= 10])
    assert uniq < 32  # far fewer than 64 distinct successors


def test_packed_batches_shapes():
    it = packed_batches(100, batch=3, seq_len=32, seed=0)
    b = next(it)
    assert b["tokens"].shape == (3, 32)
    assert b["labels"].shape == (3, 32)
    assert b["mask"].shape == (3, 32)
    assert float(b["mask"][0, -1]) == 0.0
