"""Int8 quantized KV pool: the scale sidecars follow every block-level
allocator invariant (copy-on-write forks the scale tile with its block,
shared blocks' scale bytes count once, quarantine never shrinks a scale
pool), handoff payloads round-trip scales bit-exactly across shard
geometries, byte accounting reflects the ~2× reduction, and the engine's
greedy outputs agree with bf16 on the smoke configs while resident /
per-step-read KV bytes drop by at least ~2×."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import registry
from repro.models import transformer
from repro.serving import EngineConfig, LLMEngine, Request, SamplingParams
from repro.serving.kvcache import PagedKVCache


def _cache(num_blocks=32, block_size=4, n_shards=1, kv_dtype="int8"):
    cfg = registry.get_smoke_config("llama3-8b")
    return PagedKVCache(cfg, num_blocks, block_size, n_shards=n_shards,
                        kv_dtype=kv_dtype)


def _prefill(kv, sid, n, seed=0):
    """Allocate + write `n` random tokens; returns the (k, v) written."""
    L, Hkv, hd = kv.k_pool.shape[0], kv.k_pool.shape[1], kv.k_pool.shape[4]
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.standard_normal((L, Hkv, n, hd)), kv.cfg.dtype)
    v = jnp.asarray(rng.standard_normal((L, Hkv, n, hd)), kv.cfg.dtype)
    kv.allocate(sid, n)
    kv.write_prefill(sid, k, v)
    return k, v


def _check_ref_invariants(kv):
    refs = {}
    for table in kv.tables.values():
        for b in table:
            refs[b] = refs.get(b, 0) + 1
    assert refs == kv.refcounts, "refcount != live table references"
    free = kv.free + [b for s in kv.quarantined_shards
                      for b in kv._free_shard[s]]
    assert set(refs).isdisjoint(free), "free block still referenced"
    assert len(refs) + len(free) == kv.num_blocks, "blocks leaked"


# ======================================================================
# scales follow blocks: CoW / sharing / quarantine
# ======================================================================

def test_cow_fork_copies_scale_tile_and_spares_donor():
    kv = _cache(num_blocks=16, block_size=4)
    _prefill(kv, 1, 6)                     # 2 blocks, partial tail
    kv.share_blocks(1, 2, 6)
    donor_tail = kv.tables[1][1]
    dk_pool = np.asarray(kv.k_pool[:, :, donor_tail])
    dk_s = np.asarray(kv.k_scale[:, :, donor_tail])
    dv_s = np.asarray(kv.v_scale[:, :, donor_tail])

    kv.append_token(2)                     # grows into the shared tail
    forked = kv.tables[2][1]
    assert forked != donor_tail and kv.cow_forks == 1
    # the fork carried the scale tile with the value tile
    np.testing.assert_array_equal(np.asarray(kv.k_scale[:, :, forked]), dk_s)
    np.testing.assert_array_equal(np.asarray(kv.v_scale[:, :, forked]), dv_s)

    # the divergent write lands in the fork; the donor tile AND its
    # scales stay bit-identical
    L, Hkv, hd = kv.k_pool.shape[0], kv.k_pool.shape[1], kv.k_pool.shape[4]
    rng = np.random.default_rng(7)
    tok = jnp.asarray(rng.standard_normal((L, Hkv, hd)), kv.cfg.dtype)
    kv.write_token(2, tok, tok, position=6)
    np.testing.assert_array_equal(
        np.asarray(kv.k_pool[:, :, donor_tail]), dk_pool)
    np.testing.assert_array_equal(
        np.asarray(kv.k_scale[:, :, donor_tail]), dk_s)
    assert float(kv.k_scale[0, 0, forked, 2]) > 0.0   # fork got its scale
    _check_ref_invariants(kv)


@settings(deadline=None, max_examples=10)
@given(n_tok=st.integers(1, 24), share=st.integers(1, 24),
       appends=st.integers(1, 6), seed=st.integers(0, 5))
def test_donor_scales_survive_any_fork_depth(n_tok, share, appends, seed):
    """Property: whatever the share depth and however many tokens a
    borrower appends (partial-tail CoW, block-boundary growth, repeated
    appends), the donor's value AND scale tiles never change."""
    share = min(share, n_tok)
    kv = _cache(num_blocks=32, block_size=4)
    _prefill(kv, 1, n_tok, seed=seed)
    donor = jnp.asarray(kv.tables[1], jnp.int32)
    dk = np.asarray(kv.k_pool[:, :, donor])
    dv = np.asarray(kv.v_pool[:, :, donor])
    dks = np.asarray(kv.k_scale[:, :, donor])
    dvs = np.asarray(kv.v_scale[:, :, donor])
    kv.share_blocks(1, 2, share)
    L, Hkv, hd = kv.k_pool.shape[0], kv.k_pool.shape[1], kv.k_pool.shape[4]
    rng = np.random.default_rng(seed + 100)
    for i in range(appends):
        kv.append_token(2)
        tok_k = jnp.asarray(rng.standard_normal((L, Hkv, hd)), kv.cfg.dtype)
        tok_v = jnp.asarray(rng.standard_normal((L, Hkv, hd)), kv.cfg.dtype)
        kv.write_token(2, tok_k, tok_v, position=share + i)
        _check_ref_invariants(kv)
    np.testing.assert_array_equal(np.asarray(kv.k_pool[:, :, donor]), dk)
    np.testing.assert_array_equal(np.asarray(kv.v_pool[:, :, donor]), dv)
    np.testing.assert_array_equal(np.asarray(kv.k_scale[:, :, donor]), dks)
    np.testing.assert_array_equal(np.asarray(kv.v_scale[:, :, donor]), dvs)


def test_quarantine_never_shrinks_scale_pools():
    kv = _cache(num_blocks=16, block_size=4, n_shards=4)
    _prefill(kv, 1, 12)                    # round-robin spans shards
    shape = kv.k_scale.shape
    npb = kv.blocks_per_shard
    dead_tiles = np.asarray(kv.k_scale[:, :, npb:2 * npb])

    kv.quarantine_shard(1)
    assert kv.k_scale.shape == shape and kv.v_scale.shape == shape
    # allocations avoid the dead shard; scale writes still land
    _prefill(kv, 2, 8, seed=1)
    assert all(kv.shard_of(b) != 1 for b in kv.tables[2])
    # victims draining back leave the scale pool geometry (and the dead
    # shard's tiles) untouched
    kv.free_seq(1)
    assert kv.k_scale.shape == shape
    np.testing.assert_array_equal(
        np.asarray(kv.k_scale[:, :, npb:2 * npb]), dead_tiles)
    kv.rejoin_shard(1)
    assert kv.k_scale.shape == shape
    _check_ref_invariants(kv)


# ======================================================================
# byte accounting: resident, per-token, shared-once
# ======================================================================

def test_byte_accounting_counts_scales_and_shared_blocks_once():
    kv = _cache(num_blocks=16, block_size=4)
    bf = _cache(num_blocks=16, block_size=4, kv_dtype="bf16")
    L, Hkv, hd = kv.k_pool.shape[0], kv.k_pool.shape[1], kv.k_pool.shape[4]
    slots = 16 * 4                          # num_blocks * block_size
    e = jnp.dtype(bf.cfg.dtype).itemsize
    # int8: 1 value byte + 4 fp32 scale bytes per token-head, K and V
    assert kv.pool_bytes_resident == 2 * L * Hkv * slots * (hd + 4)
    assert bf.pool_bytes_resident == 2 * L * Hkv * slots * hd * e
    assert kv.pool_bytes_resident < 0.6 * bf.pool_bytes_resident
    assert kv.bytes_per_live_token() == 2 * L * Hkv * (hd + 4)
    assert bf.bytes_per_live_token() == 2 * L * Hkv * hd * e
    # a prefix-shared block reads/resides once, not once per sharer
    _prefill(kv, 1, 8)
    kv.share_blocks(1, 2, 8)
    assert kv.unique_live_tokens([1, 2]) == 8
    assert sum(kv.lengths.values()) == 16   # logical tokens double-count


# ======================================================================
# handoff: scales ride the wire, bit-exactly, across geometries
# ======================================================================

@pytest.mark.parametrize("src_shards,dst_shards",
                         [(1, 1), (1, 2), (2, 4), (4, 1)])
def test_handoff_roundtrip_scales_exact(src_shards, dst_shards):
    src = _cache(num_blocks=16, block_size=4, n_shards=src_shards)
    _prefill(src, 1, 10, seed=0)
    src.share_blocks(1, 2, 8)              # shared prefix rides once
    src.allocate(2, 11)
    L, Hkv, hd = src.k_pool.shape[0], src.k_pool.shape[1], src.k_pool.shape[4]
    rng = np.random.default_rng(1)
    suf_k = jnp.asarray(rng.standard_normal((L, Hkv, 3, hd)), src.cfg.dtype)
    suf_v = jnp.asarray(rng.standard_normal((L, Hkv, 3, hd)), src.cfg.dtype)
    src.write_prefill(2, suf_k, suf_v, start_token=8)

    payload = src.export_seqs([1, 2])
    assert payload.k_scales is not None and payload.v_scales is not None
    assert len(payload.block_ids) == len(set(payload.block_ids))

    dst = _cache(num_blocks=16, block_size=4, n_shards=dst_shards)
    mapping = dst.import_seqs(payload)
    # every unique block's int8 values AND fp32 scales land bit-exactly
    for b in payload.block_ids:
        d = mapping[b]
        np.testing.assert_array_equal(np.asarray(dst.k_pool[:, :, d]),
                                      np.asarray(src.k_pool[:, :, b]))
        np.testing.assert_array_equal(np.asarray(dst.v_pool[:, :, d]),
                                      np.asarray(src.v_pool[:, :, b]))
        np.testing.assert_array_equal(np.asarray(dst.k_scale[:, :, d]),
                                      np.asarray(src.k_scale[:, :, b]))
        np.testing.assert_array_equal(np.asarray(dst.v_scale[:, :, d]),
                                      np.asarray(src.v_scale[:, :, b]))
    # sharing survives the wire: the prefix blocks stay refcount-2
    for b in src.tables[1][:2]:
        assert dst.refcounts[mapping[b]] == 2
    # dequantized prefix readback is identical on both sides
    for sid in (1, 2):
        ks, vs = src.gather_prefix(sid, 8)
        kd, vd = dst.gather_prefix(sid, 8)
        np.testing.assert_array_equal(np.asarray(kd), np.asarray(ks))
        np.testing.assert_array_equal(np.asarray(vd), np.asarray(vs))
    _check_ref_invariants(dst)


def test_handoff_payload_bytes_halved_vs_bf16():
    i8 = _cache(num_blocks=16, block_size=4)
    bf = _cache(num_blocks=16, block_size=4, kv_dtype="bf16")
    for kv in (i8, bf):
        _prefill(kv, 1, 10, seed=0)
    p8, pbf = i8.export_seqs([1]), bf.export_seqs([1])
    hd = i8.k_pool.shape[4]
    e = jnp.dtype(bf.cfg.dtype).itemsize
    assert p8.nbytes / pbf.nbytes == pytest.approx((hd + 4) / (hd * e))
    assert p8.nbytes < 0.6 * pbf.nbytes
    # the per-block transfer accounting includes the scale tiles
    assert p8.bytes_of_blocks(1) * p8.n_blocks == p8.nbytes


def test_handoff_kv_dtype_mismatch_raises_both_directions():
    i8 = _cache(num_blocks=16, block_size=4)
    bf = _cache(num_blocks=16, block_size=4, kv_dtype="bf16")
    _prefill(i8, 1, 6, seed=0)
    _prefill(bf, 1, 6, seed=0)
    bf_dst = _cache(num_blocks=16, block_size=4, kv_dtype="bf16")
    with pytest.raises(ValueError, match="kv_dtype"):
        bf_dst.import_seqs(i8.export_seqs([1]))  # scales into bf16 pool
    i8_dst = _cache(num_blocks=16, block_size=4)
    with pytest.raises(ValueError, match="kv_dtype"):
        i8_dst.import_seqs(bf.export_seqs([1]))  # scaleless into int8 pool


# ======================================================================
# engine-level: greedy agreement with bf16 + the ~2× byte reduction
# ======================================================================

@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, lens=(5, 12, 9, 20), new=8):
    rng = np.random.default_rng(0)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=n).tolist(),
                    params=SamplingParams(max_new_tokens=new))
            for n in lens]


def _run(cfg, params, **ekw):
    reqs = _reqs(cfg)
    eng = LLMEngine(cfg, params, EngineConfig(max_batch=4, num_blocks=64,
                                              **ekw))
    eng.submit(reqs)
    eng.run()
    return [r.output for r in reqs], eng.stats.summary()


@pytest.fixture(scope="module")
def bf16_ref(setup):
    cfg, params = setup
    return _run(cfg, params)


@pytest.mark.parametrize("pkw", [
    {"placement": "homogeneous"},
    {"placement": "attention_pool", "partition": "head"},
    {"placement": "attention_pool", "partition": "block"},
    {"placement": "attention_pool", "partition": "request"},
], ids=["homogeneous", "pool_head", "pool_block", "pool_request"])
def test_engine_int8_matches_bf16_greedy_and_halves_kv_bytes(
        setup, bf16_ref, pkw):
    cfg, params = setup
    ref_out, ref_stats = bf16_ref
    out, stats = _run(cfg, params, kv_dtype="int8", **pkw)
    assert out == ref_out
    # resident AND per-step read bytes drop by at least ~2× (more on
    # fp32-pool smoke configs: (hd+4)/(4·hd))
    assert stats["kv_pool_bytes_resident"] <= \
        0.55 * ref_stats["kv_pool_bytes_resident"]
    assert stats["kv_bytes_read_per_step"] <= \
        0.55 * ref_stats["kv_bytes_read_per_step"]
    assert stats["kv_bytes_read_per_step"] > 0


def test_engine_int8_chunked_prefill_with_sharing_matches_bf16(setup):
    """Chunked prefill reads the quantized prefix through the fused-dequant
    chunk kernel; prefix sharing adds CoW forks of quantized blocks. Both
    must (a) agree with the int8 one-shot path (same pool bytes, same
    greedy tokens) and (b) agree with bf16 greedy on these prompts — the
    cross-dtype agreement is empirical (quantized readback is not
    bit-identical), so the prompts are fixed to a seed where greedy is not
    within quantization noise of a tie."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, size=32).tolist()
    prompts = [prefix + rng.integers(0, cfg.vocab_size, size=s).tolist()
               for s in (3, 7)]
    outs = {}
    for key, ekw in (
            ("bf16", dict(kv_dtype="bf16", prefix_sharing=True,
                          prefill_chunk_tokens=16)),
            ("int8_chunk", dict(kv_dtype="int8", prefix_sharing=True,
                                prefill_chunk_tokens=16)),
            ("int8_oneshot", dict(kv_dtype="int8"))):
        reqs = [Request(prompt=list(p),
                        params=SamplingParams(max_new_tokens=6))
                for p in prompts]
        eng = LLMEngine(cfg, params, EngineConfig(
            max_batch=4, num_blocks=64, **ekw))
        eng.submit(reqs)
        eng.run()
        if ekw.get("prefix_sharing"):
            assert eng.kv.blocks_shared_total > 0   # sharing engaged
        outs[key] = [r.output for r in reqs]
    assert outs["int8_chunk"] == outs["int8_oneshot"]
    assert outs["int8_chunk"] == outs["bf16"]
