"""Paper-§7 extensions: attention sinks (StreamingLLM), MoE expert
offloading, int8-free long-context variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer
from repro.serving import EngineConfig, LLMEngine
from repro.serving.request import Request, SamplingParams
from repro.serving.worker_pool import min_bandwidth_moe, transfer_bytes_moe


# ---------------------------------------------------------------------------
# attention sinks
# ---------------------------------------------------------------------------
def test_sinks_decode_matches_forward():
    """sink+window decode == sink+window full forward, and both differ from
    pure-window (the sinks matter)."""
    base = registry.get_smoke_config("llama3-8b")
    cfg = base.replace(sliding_window=6, attention_sinks=2)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0,
                              cfg.vocab_size)
    full_logits, _ = transformer.forward(params, cfg, {"tokens": toks})
    _, cache = transformer.prefill(params, cfg, {"tokens": toks[:, :-1]},
                                   max_seq=32)
    lg, _ = transformer.decode_step(params, cfg, toks[:, -1], cache)
    np.testing.assert_allclose(np.asarray(full_logits[:, -1]),
                               np.asarray(lg), atol=1e-4, rtol=1e-4)
    # pure window (no sinks) produces different logits at long range
    cfg2 = base.replace(sliding_window=6, attention_sinks=0)
    other, _ = transformer.forward(params, cfg2, {"tokens": toks})
    assert not np.allclose(np.asarray(full_logits[:, -1]),
                           np.asarray(other[:, -1]), atol=1e-4)


def test_sinks_mask_semantics():
    """Positions attendable at decode = sinks ∪ window ∪ new token."""
    from repro.kernels import ref
    B, S, Hkv, G, hd = 1, 30, 1, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd))
    kc = jax.random.normal(ks[1], (B, Hkv, S, hd))
    vc = jax.random.normal(ks[2], (B, Hkv, S, hd))
    clen = jnp.array([25], jnp.int32)
    got = ref.decode_attention_ref(q, kc, vc, clen, sliding_window=8,
                                   attention_sinks=3)
    # manual oracle
    s = np.einsum("k,sk->s", np.asarray(q[0, 0, 0]) / np.sqrt(hd),
                  np.asarray(kc[0, 0], np.float32))
    valid = np.zeros(S, bool)
    valid[:3] = True                      # sinks
    valid[25 - 8:25] = True               # window
    s = np.where(valid, s, -np.inf)
    p = np.exp(s - s.max())
    p /= p.sum()
    want = p @ np.asarray(vc[0, 0], np.float32)
    np.testing.assert_allclose(np.asarray(got[0, 0, 0]), want, atol=2e-5)


def test_sinks_pallas_kernel_parity():
    from repro.kernels import ref
    from repro.kernels.decode_attention import decode_attention
    B, S, Hkv, G, hd = 2, 100, 2, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd))
    kc = jax.random.normal(ks[1], (B, Hkv, S, hd))
    vc = jax.random.normal(ks[2], (B, Hkv, S, hd))
    clen = jnp.array([100, 41], jnp.int32)
    out = decode_attention(q, kc, vc, clen, block_k=32, sliding_window=16,
                           attention_sinks=4, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, clen, sliding_window=16,
                                    attention_sinks=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# MoE expert offloading (paper §7)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def moe_setup():
    cfg = registry.get_smoke_config("qwen3-moe-30b-a3b").replace(
        capacity_factor=64.0)  # no drops -> bit-stable across engines
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, lens=(5, 9), new=6):
    rng = np.random.default_rng(0)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=n).tolist(),
                    params=SamplingParams(max_new_tokens=new)) for n in lens]


def test_moe_offload_engine_matches_baseline(moe_setup):
    cfg, params = moe_setup
    r1 = _reqs(cfg)
    e1 = LLMEngine(cfg, params, EngineConfig(placement="homogeneous",
                                             max_batch=2, num_blocks=64))
    e1.submit(r1)
    e1.run()
    r2 = _reqs(cfg)
    e2 = LLMEngine(cfg, params, EngineConfig(
        placement="moe_offload", attention_workers=2, expert_workers=2,
        max_batch=2, num_blocks=64))
    e2.submit(r2)
    e2.run()
    for a, b in zip(r1, r2):
        assert a.output == b.output
    # both pools accounted transfers
    assert e2.pool.log.transfers > 0
    assert e2.expert_pool.log.transfers > 0
    per_tok = e2.expert_pool.log.total / e2.stats.tokens_generated
    assert per_tok == pytest.approx(transfer_bytes_moe(cfg, 1))


def test_moe_offload_bandwidth_is_modest():
    """Paper §7 claim: operator-level offloads need an optimised stack but
    stay within DCN rates — the MoE boundary needs far less than attention
    (no KV growth)."""
    from repro.core import costmodel as cm
    cfg = registry.get_config("qwen3-moe-30b-a3b")
    bw = min_bandwidth_moe(cfg, 128, 8192, cm.HARDWARE["h100"],
                           cm.HARDWARE["h20"])
    assert bw < 50e9  # under 400 GbE
    assert transfer_bytes_moe(cfg, 1) == 2 * 2 * cfg.d_model * cfg.num_layers


def test_expert_pool_divisibility_guard(moe_setup):
    cfg, _ = moe_setup
    from repro.serving.worker_pool import ExpertWorkerPool
    with pytest.raises(ValueError):
        ExpertWorkerPool(cfg, 3)  # 4 experts % 3 != 0


# ---------------------------------------------------------------------------
# int8 KV cache (paper §7: reduced-precision KV storage)
# ---------------------------------------------------------------------------
def test_int8_kv_quantization_roundtrip():
    from repro.models import kv_quant
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 16, 32)) * 3.0
    q, scale = kv_quant.quantize_kv(x)
    assert q.dtype == jnp.int8 and scale.shape == (2, 4, 16)
    back = kv_quant.dequantize_kv(q, scale, jnp.float32)
    err = np.max(np.abs(np.asarray(back) - np.asarray(x)))
    amax = float(np.max(np.abs(np.asarray(x))))
    assert err <= amax / 127.0 + 1e-6  # one quantization step


def test_int8_kv_decode_close_to_fp():
    cfg16 = registry.get_smoke_config("llama3-8b")
    cfg8 = cfg16.replace(kv_cache_bits=8)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg16)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0,
                              cfg16.vocab_size)
    full, _ = transformer.forward(params, cfg16, {"tokens": toks})
    _, c8 = transformer.prefill(params, cfg8, {"tokens": toks[:, :-2]},
                                max_seq=32)
    assert c8["k"].dtype == jnp.int8 and "k_scale" in c8
    lg1, upd = transformer.decode_step(params, cfg8, toks[:, -2], c8)
    c8 = transformer.apply_decode_updates(c8, upd)
    lg2, _ = transformer.decode_step(params, cfg8, toks[:, -1], c8)

    def cos(a, b):
        a = np.asarray(a, np.float64).ravel()
        b = np.asarray(b, np.float64).ravel()
        return a @ b / (np.linalg.norm(a) * np.linalg.norm(b))

    assert cos(full[:, -2], lg1) > 0.999
    assert cos(full[:, -1], lg2) > 0.999
    assert bool((jnp.argmax(full[:, -1], -1) == jnp.argmax(lg2, -1)).all())


def test_int8_kv_memory_accounting():
    """paper §3.1 sizing: int8 halves KV bytes per token (plus scales)."""
    from repro.core import costmodel as cm
    cfg = registry.get_config("gemma2-27b")
    per_tok_bf16 = cm.kv_bytes_per_token(cfg)
    per_tok_int8 = per_tok_bf16 / 2 + 2 * 4 * cfg.num_layers * \
        cfg.num_kv_heads  # + fp32 scales
    assert per_tok_int8 < 0.6 * per_tok_bf16


# ---------------------------------------------------------------------------
# speculative decoding (paper §8 related work) — greedy-exact variant
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_speculative_equals_greedy():
    from repro.serving.speculative import (greedy_generate,
                                           speculative_generate)
    target_cfg = registry.get_smoke_config("tinyllama-1.1b")
    draft_cfg = registry.get_smoke_config("tinyllama-1.1b", num_layers=1,
                                          d_model=128, d_ff=256)
    tp = transformer.init_params(jax.random.PRNGKey(0), target_cfg)
    dp = transformer.init_params(jax.random.PRNGKey(7), draft_cfg)
    prompt = [3, 1, 4, 1, 5]
    want = greedy_generate(tp, target_cfg, prompt, 12)
    for k in (1, 3, 5):
        got, stats = speculative_generate(tp, target_cfg, dp, draft_cfg,
                                          prompt, 12, k=k)
        assert got == want, (k, got, want)
        assert stats.target_calls <= 12  # never worse than plain greedy
        assert 0.0 <= stats.acceptance_rate <= 1.0


def test_speculative_perfect_draft_maximises_acceptance():
    """Draft == target: every proposal accepted, target calls ≈ N/(k+1)."""
    from repro.serving.speculative import speculative_generate
    cfg = registry.get_smoke_config("tinyllama-1.1b")
    p = transformer.init_params(jax.random.PRNGKey(0), cfg)
    got, stats = speculative_generate(p, cfg, p, cfg, [1, 2, 3], 12, k=3)
    assert stats.acceptance_rate == 1.0
    assert stats.target_calls == 3  # 12 tokens / (3 accepted + 1 bonus)
