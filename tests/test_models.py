"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
variant of the same family, one forward + one train step on CPU, asserting
output shapes and finite values; plus prefill/decode == full-forward parity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer
from repro.training import optimizer as opt
from repro.training.train_loop import make_train_step

ARCHS = registry.ASSIGNED


def _batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, 24, cfg.d_model),
                                            cfg.dtype)
    if cfg.modality == "vision":
        batch["frontend"] = jax.random.normal(key, (B, 8, cfg.d_model),
                                              cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = registry.get_smoke_config(arch)
    assert cfg.num_layers <= 5 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    batch = _batch(cfg, key)
    B, S = batch["tokens"].shape
    logits, aux = transformer.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # one full train step (fwd + bwd + AdamW update)
    state = opt.init_opt_state(params)
    step = make_train_step(cfg, opt.AdamWConfig(lr=1e-3, warmup_steps=1,
                                                total_steps=10))
    new_params, new_state, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state.step) == 1
    # parameters actually moved
    moved = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = registry.get_smoke_config(arch)
    if cfg.num_experts:
        # capacity-dropping MoE is only bit-stable across prefill/decode
        # splits when nothing drops: give the router unlimited capacity
        cfg = cfg.replace(capacity_factor=64.0)
    key = jax.random.PRNGKey(1)
    params = transformer.init_params(key, cfg)
    batch = _batch(cfg, key, B=2, S=12)
    toks = batch["tokens"]
    full_logits, _ = transformer.forward(params, cfg, batch)
    pre = dict(batch, tokens=toks[:, :-2])
    last_logits, cache = transformer.prefill(params, cfg, pre, max_seq=32)
    lg1, upd = transformer.decode_step(params, cfg, toks[:, -2], cache)
    cache = transformer.apply_decode_updates(cache, upd)
    lg2, _ = transformer.decode_step(params, cfg, toks[:, -1], cache)
    atol = 1e-4
    np.testing.assert_allclose(np.asarray(full_logits[:, -3]),
                               np.asarray(last_logits), atol=atol, rtol=atol)
    np.testing.assert_allclose(np.asarray(full_logits[:, -2]),
                               np.asarray(lg1), atol=atol, rtol=atol)
    np.testing.assert_allclose(np.asarray(full_logits[:, -1]),
                               np.asarray(lg2), atol=atol, rtol=atol)


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned dimensions."""
    spec = {
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "rwkv6-7b": (32, 4096, None, None, 14336, 65536),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }
    for arch, (L, d, H, Hkv, dff, V) in spec.items():
        cfg = registry.get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        if H is not None:
            assert cfg.num_heads == H, arch
            assert cfg.num_kv_heads == Hkv, arch
        assert cfg.d_ff == dff, arch
        assert cfg.vocab_size == V, arch
        assert cfg.source, arch
    assert registry.get_config("qwen3-moe-30b-a3b").num_experts == 128
    assert registry.get_config("qwen3-moe-30b-a3b").experts_per_token == 8
    assert registry.get_config("kimi-k2-1t-a32b").num_experts == 384
    assert registry.get_config("zamba2-1.2b").ssm_state == 64
    assert registry.get_config("gemma2-27b").local_global
    assert registry.get_config("gemma2-27b").attn_logit_softcap == 50.0


def test_param_counts_match_model_cards():
    """Analytic parameter counts land near the advertised sizes."""
    from repro.core import costmodel as cm
    expect = {"llama3-8b": 8.0e9, "tinyllama-1.1b": 1.1e9,
              "glm4-9b": 9.4e9, "rwkv6-7b": 7.6e9,
              "kimi-k2-1t-a32b": 1.0e12, "qwen3-moe-30b-a3b": 30.5e9,
              "gemma2-27b": 27.2e9, "pixtral-12b": 12.0e9}
    for arch, n in expect.items():
        got = cm.param_count(registry.get_config(arch))
        assert 0.75 * n <= got <= 1.30 * n, (arch, got / 1e9)
    # MoE active params: kimi ~32B active, qwen3 ~3B active
    assert 20e9 < cm.active_param_count(
        registry.get_config("kimi-k2-1t-a32b")) < 45e9
    assert 2e9 < cm.active_param_count(
        registry.get_config("qwen3-moe-30b-a3b")) < 5e9


def test_gemma2_local_global_masking_differs():
    """Local layers must actually window-mask: long-range token influence
    only via global layers."""
    cfg = registry.get_smoke_config("gemma2-27b").replace(sliding_window=4)
    key = jax.random.PRNGKey(2)
    params = transformer.init_params(key, cfg)
    toks = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)
    base, _ = transformer.forward(params, cfg, {"tokens": toks})
    # perturb an early token: with window=4 the local layer can't see it at
    # the last position directly, but the global layer can -> logits differ
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    pert, _ = transformer.forward(params, cfg, {"tokens": toks2})
    assert not np.allclose(np.asarray(base[0, -1]), np.asarray(pert[0, -1]))


def test_grad_accumulation_equivalence():
    cfg = registry.get_smoke_config("tinyllama-1.1b")
    key = jax.random.PRNGKey(3)
    params = transformer.init_params(key, cfg)
    state = opt.init_opt_state(params)
    batch = _batch(cfg, key, B=4, S=16)
    acfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    p1, _, m1 = make_train_step(cfg, acfg, grad_accum=1)(params, state, batch)
    p2, _, m2 = make_train_step(cfg, acfg, grad_accum=2)(params, state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               atol=1e-3, rtol=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)
