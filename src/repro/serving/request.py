"""Request lifecycle for the serving engines."""
from __future__ import annotations

import dataclasses
import enum
import itertools
import time
from typing import List, Optional

_ids = itertools.count()


class State(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    # evicted under pool pressure; blocks returned to the pool, generated
    # tokens kept — re-admission recomputes the KV by re-prefilling
    PREEMPTED = "preempted"
    # disaggregated cluster: prefill finished on the prefill engine, KV
    # blocks in flight to (or queued on) a decode replica — the request
    # belongs to no scheduler until transfer-complete admission
    TRANSFERRING = "transferring"
    FINISHED = "finished"


@dataclasses.dataclass
class SamplingParams:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 => greedy
    top_k: int = 0                 # 0 => full softmax
    eos_token: Optional[int] = None
    # per-request PRNG stream seed; None falls back to the engine's
    # EngineConfig.seed (LLMEngine derives token i's draw from
    # fold_in(PRNGKey(seed), i) — batch-composition independent)
    seed: Optional[int] = None


@dataclasses.dataclass
class Request:
    prompt: List[int]
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    rid: int = dataclasses.field(default_factory=lambda: next(_ids))
    state: State = State.WAITING
    output: List[int] = dataclasses.field(default_factory=list)
    arrival_s: float = dataclasses.field(default_factory=time.time)
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def total_len(self) -> int:
        return len(self.prompt) + len(self.output)

    def done(self) -> bool:
        p = self.params
        if p.eos_token is not None and self.output and \
                self.output[-1] == p.eos_token:
            return True
        return len(self.output) >= p.max_new_tokens

    def record_token(self, tok: int) -> None:
        now = time.time()
        if self.first_token_s is None:
            self.first_token_s = now
        self.output.append(int(tok))
        self.token_times.append(now)
        if self.done():
            self.state = State.FINISHED
            self.finish_s = now

    def tbt_s(self) -> float:
        """Mean time between tokens."""
        if len(self.token_times) < 2:
            return 0.0
        diffs = [b - a for a, b in zip(self.token_times, self.token_times[1:])]
        return sum(diffs) / len(diffs)
