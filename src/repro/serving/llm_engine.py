"""LLMEngine — the unified streaming serving facade.

One engine serves every placement the paper studies. Placement is a
declarative :class:`~repro.serving.config.EngineConfig` decision
(``homogeneous`` | ``attention_pool`` | ``moe_offload`` × ``head`` |
``request`` | ``block``), realised by a composable
:class:`~repro.serving.placement.PlacementStrategy` instead of the deleted
legacy ``Engine`` → ``DisaggEngine`` → ``MoEOffloadEngine`` tower; and
scheduling is a pluggable :class:`~repro.serving.scheduler.SchedulingPolicy`
(FCFS, or preemption under pool pressure with recompute re-admission).

The request lifecycle is streaming, not batch:

  * :meth:`LLMEngine.submit` returns a :class:`RequestHandle` per request;
    iterating a handle drives the engine and yields token ids *as they are
    generated* — a handle's consumer sees tokens while the rest of the
    continuous batch is still decoding;
  * :meth:`LLMEngine.events` streams iteration-level lifecycle events
    (``submit`` / ``admit`` / ``readmit`` / ``chunk`` / ``preempt`` /
    ``finish``);
  * :meth:`LLMEngine.run` keeps the legacy drain-everything loop.

Chunked paged prefill (``EngineConfig(prefill_chunk_tokens=...)``) makes
every iteration MIXED: at most one prompt advances by one block-aligned
chunk — its queries attending over the pool blocks already written, its KV
scattered into incrementally-allocated blocks as the chunk completes —
while the full decode batch decodes in the same step. Peak prefill memory
is O(chunk) instead of O(prompt), admission charges only the first chunk
(a prompt larger than the currently-free pool is admitted and completes as
earlier requests retire), and decode TBT no longer stalls behind long
prefills. Greedy outputs are bit-identical with chunking on or off (MoE
models fall back to one-shot prefill: a chunk boundary changes
capacity-dispatch groups — the same coupling that makes prefix sharing
recompute them).

Preemption fixes the legacy engines' latent OOM: a request that outlives
its ``decode_headroom`` margin used to exhaust the pool with no recourse
(``OutOfBlocks`` deep in the allocator, pool stranded mid-decode). Now the
engine checks pool pressure *before* each decode iteration; under the
``preempt`` policy it evicts a victim's blocks back to the pool (generated
tokens kept) and later re-admits it via recompute — greedy decoding resumes
bit-identically (same mechanism as the paper-§5 fault-tolerance path).
Under ``fcfs`` the same condition surfaces a clear
:class:`~repro.serving.kvcache.PoolExhausted` naming the offending request,
live tokens, and free blocks.

Sampling honours ``SamplingParams.seed``: each request draws token `i` from
``fold_in(PRNGKey(its seed), i)`` — its stochastic stream is independent of
batch composition, admission order, and preemption, so identical requests
reproduce identically wherever and whenever they run.

Fault tolerance (``serving/faults.py``): cheap, numerous pool devices
straggle, corrupt results, and die — the engine survives all three with
greedy bit-parity intact. A :class:`~repro.serving.faults.FaultInjector`
(deterministic, seeded scenarios) exercises the machinery at the host-side
pool boundary; detection is a per-shard ``healthy → suspect → dead`` state
machine fed by heartbeat probes and NaN/inf validation of the merged decode
output, with bounded retry-with-backoff before a shard is declared dead.
Recovery is the §5 preempt-and-recompute path: the dead shard is
QUARANTINED (the allocator masks it out and every capacity/headroom guard
drops to the surviving shards), every request holding blocks on it is
evicted through the normal preemption path and re-admitted via recompute
onto survivors — since KV is recomputable from prompt + generated tokens,
outputs through a mid-decode shard death are bit-identical to a fault-free
run (shared blocks recover once per physical block via the refcounts). A
transient fault that clears within the retry budget recovers with no
eviction at all, and a validated retry is bit-identical because the decode
step is deterministic and nothing was committed before validation. NaN/inf
that is NOT attributable to an injected fault raises
:class:`CorruptedLogitsError` naming the requests and step — garbage is
never silently sampled.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.common import ModelConfig
from repro.serving.config import EngineConfig
from repro.serving.stats import EngineStats
from repro.serving.faults import DEAD, FaultInjector, ShardHealthTracker
from repro.serving.kvcache import PagedKVCache, PoolExhausted
from repro.serving.placement import PlacementStrategy, make_placement
from repro.serving.request import Request, SamplingParams, State
from repro.serving.sampler import request_key, sample_per_request
from repro.serving.scheduler import RequestScheduler, make_policy


class SchedulingStalled(RuntimeError):
    """Nothing is running and the head of the waiting queue can never be
    admitted — the engine would spin forever. Raised instead."""


class CorruptedLogitsError(RuntimeError):
    """Decode/prefill produced non-finite logits that no injected fault
    accounts for — sampling from them would silently emit garbage tokens.
    Carries the affected request ids and the engine step for triage."""

    def __init__(self, message: str, *, rids: Sequence[int] = (),
                 step: int = 0):
        super().__init__(message)
        self.rids = tuple(rids)
        self.step = step


@dataclasses.dataclass(frozen=True)
class EngineEvent:
    """One iteration-level lifecycle event (the ``events()`` stream)."""

    # submit | admit | readmit | chunk | preempt | finish, plus the fault
    # lifecycle: shard_suspect | retry | recover | shard_down | shard_up
    # (shard-level events carry rid=-1 and name the shard in info["shard"])
    kind: str
    rid: int
    step: int          # engine step counter when the event fired
    info: Dict = dataclasses.field(default_factory=dict)


class RequestHandle:
    """Streaming view of one submitted request.

    Iterating yields token ids incrementally, driving the engine only as
    far as needed — tokens arrive while the rest of the batch is still
    decoding. The handle never rewinds: preemption keeps generated tokens
    (re-admission recomputes KV, not text), so every yielded token is
    final.
    """

    __slots__ = ("request", "_engine")

    def __init__(self, engine: "LLMEngine", request: Request):
        self._engine = engine
        self.request = request

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def finished(self) -> bool:
        return self.request.state == State.FINISHED

    @property
    def output(self) -> List[int]:
        return self.request.output

    def __iter__(self) -> Iterator[int]:
        sent = 0
        while True:
            out = self.request.output
            while sent < len(out):
                yield out[sent]
                sent += 1
            if self.request.state == State.FINISHED:
                return
            self._engine.step()

    def result(self) -> List[int]:
        """Drain the stream; returns the complete output token list."""
        for _ in self:
            pass
        return self.request.output

    def __repr__(self):
        return (f"RequestHandle(rid={self.rid}, "
                f"state={self.request.state.value}, "
                f"tokens={len(self.request.output)})")


class LLMEngine:
    """The unified serving facade: one engine, every placement."""

    def __init__(self, cfg: ModelConfig, params,
                 engine_config: Optional[EngineConfig] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 **overrides):
        """``overrides`` are EngineConfig fields for call-site convenience:
        ``LLMEngine(cfg, params, placement="attention_pool", partition=
        "block")`` ≡ passing the equivalent validated EngineConfig.

        ``fault_injector`` attaches a deterministic fault scenario
        (``serving/faults.py``) at the pool boundary; the health machine
        and recovery paths are always live — the injector only supplies
        the faults."""
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError("engine serves KV-cache architectures; "
                             f"got family={cfg.family}")
        econf = engine_config or EngineConfig()
        if overrides:
            econf = econf.replace(**overrides)
        self.cfg = cfg
        self.config = econf
        self.params = params
        self.kv = PagedKVCache(cfg, econf.num_blocks, econf.block_size,
                               n_shards=econf.resolved_kv_shards,
                               kv_dtype=econf.kv_dtype)
        self.placement: PlacementStrategy = make_placement(cfg, econf)
        # Chunked prefill is a COMPUTE decision like the prefix-sharing
        # skip: a chunk boundary changes MoE capacity-dispatch groups, so
        # chunked MoE prefill would not be bit-stable against the one-shot
        # — MoE models fall back to one-shot prefill (the config knob is
        # accepted and simply has no effect).
        self._chunk_tokens = (econf.prefill_chunk_tokens
                              if cfg.family != "moe" else None)
        self.policy = make_policy(econf.scheduler,
                                  prefill_chunk_tokens=self._chunk_tokens)
        self.sched = RequestScheduler(self.kv, econf.max_batch, self.policy,
                                      econf.decode_headroom,
                                      prefix_sharing=econf.prefix_sharing)
        self.stats = EngineStats()
        self.stats.kv_pool_bytes_resident = self.kv.pool_bytes_resident
        self._decode_jit = jax.jit(self.placement.decode_fn())
        self._prefill_jit = jax.jit(
            lambda p, b: transformer.prefill(p, cfg, b,
                                             max_seq=b["tokens"].shape[1]))
        def _suffix_prefill(p, b, k_pool, v_pool, idx,
                            k_scale=None, v_scale=None):
            # fused prefix gather: the shared blocks' KV is sliced out of
            # the pool INSIDE the jitted program (one compiled gather, no
            # eager dispatch / host round-trip per admission). Int8 pools
            # dequantize the gathered prefix here — admission-time, once
            # per shared prefix, explicitly off the per-step hot path.
            L, Hkv, _, bs, hd = k_pool.shape
            n_tok = idx.shape[0] * bs
            kp = k_pool[:, :, idx].reshape(L, Hkv, n_tok, hd)
            vp = v_pool[:, :, idx].reshape(L, Hkv, n_tok, hd)
            if k_scale is not None:
                ks = k_scale[:, :, idx].reshape(L, Hkv, n_tok)
                vs = v_scale[:, :, idx].reshape(L, Hkv, n_tok)
                kp = (kp.astype(jnp.float32) * ks[..., None]).astype(cfg.dtype)
                vp = (vp.astype(jnp.float32) * vs[..., None]).astype(cfg.dtype)
            return transformer.prefill_suffix(p, cfg, b, kp[:, None],
                                              vp[:, None])
        self._prefill_suffix_jit = jax.jit(_suffix_prefill)
        # chunked paged prefill: one chunk's queries over the already-
        # written pool blocks. The context path follows decode_backend:
        # 'jnp' gathers one layer's prefix at a time inside the scan and is
        # BIT-IDENTICAL to the one-shot prefill; 'pallas' streams the pool
        # in place through the chunk kernel (no densify — kernel numerics,
        # allclose to the reference like every other pallas backend).
        # Chunk shapes amortise across prompts: for a fixed chunk size the
        # prefix-index operand only takes shapes (0,), (cb,), (2·cb,), …,
        # so a second long prompt reuses the first one's compiled programs
        # (one-shot prefill, by contrast, compiles per distinct prompt
        # length); only the final partial chunk adds a per-length shape.
        self._prefill_chunk_jit = jax.jit(
            lambda p, b, kp, vp, idx, ks=None, vs=None:
                transformer.prefill_chunk(
                    p, cfg, b, kp, vp, idx, backend=econf.decode_backend,
                    k_scale_pool=ks, v_scale_pool=vs))
        # Prefill COMPUTE can only be skipped when suffix-only prefill is
        # bit-identical to the full one. MoE capacity dispatch couples the
        # tokens of a routing group (expert capacity and reduction shapes
        # depend on the whole group), so MoE models share pool MEMORY but
        # recompute the full prompt, writing only the unshared suffix.
        self._skip_prefill_compute = cfg.family != "moe"
        # fault tolerance: per-shard health machine (always live) plus the
        # optional injector; _recovering maps a shard-death victim's rid to
        # the wall-clock instant its shard was declared dead, closed out
        # (into stats.recovery_latencies) when the request is decodable
        # again on the surviving shards
        self._fault = fault_injector
        self.health = ShardHealthTracker(self.kv.n_shards,
                                         econf.fault_retry_limit)
        self._backoff_s = econf.fault_retry_backoff_s
        self._recovering: Dict[int, float] = {}
        self._events: List[EngineEvent] = []
        self._step_no = 0

    # ------------------------------------------------------------------
    # submission / streaming surface
    # ------------------------------------------------------------------
    def submit(self, reqs: Union[Request, Sequence[Request]]
               ) -> Union[RequestHandle, List[RequestHandle]]:
        """Enqueue request(s); returns one streaming handle per request
        (a single handle for a single request)."""
        single = isinstance(reqs, Request)
        batch = [reqs] if single else list(reqs)
        handles = []
        for req in batch:
            self._emit("submit", req.rid)
            if not req.output and req.done():      # max_new_tokens == 0
                req.state = State.FINISHED
                req.finish_s = time.time()
                self._emit("finish", req.rid, tokens=0)
            else:
                self.sched.submit([req])
            handles.append(RequestHandle(self, req))
        return handles[0] if single else handles

    def generate(self, prompt: Sequence[int],
                 params: Optional[SamplingParams] = None) -> RequestHandle:
        """Convenience: wrap a raw prompt in a Request and submit it."""
        return self.submit(Request(prompt=list(prompt),
                                   params=params or SamplingParams()))

    def events(self) -> Iterator[EngineEvent]:
        """Stream lifecycle events, driving the engine while work remains.
        Yields everything recorded so far, then steps the engine for more;
        ends when the engine drains. (``event_log`` is the passive view.)"""
        i = 0
        while True:
            while i < len(self._events):
                yield self._events[i]
                i += 1
            if not self.sched.has_work():
                return
            self.step()

    @property
    def event_log(self) -> List[EngineEvent]:
        return list(self._events)

    def _emit(self, kind: str, rid: int, **info) -> None:
        self._events.append(EngineEvent(kind, rid, self._step_no, info))

    # ------------------------------------------------------------------
    # the iteration
    # ------------------------------------------------------------------
    def step(self) -> None:
        """One MIXED engine iteration: admit (one-shot prefill / recompute,
        or chunked admission that only seeds a prefill cursor), resolve
        pool pressure (possibly preempting), advance at most one prefill
        chunk, decode one token for every running request whose prefill is
        complete, retire the finished. Fault bookkeeping (rejoins,
        heartbeat probes, straggler observation) runs first, so a shard
        death detected at the step boundary is recovered before this very
        step's admission wave — the evicted victims re-admit immediately
        onto the surviving shards."""
        self._step_no += 1
        self._fault_tick()
        self._pre_admit_tick()
        while True:
            admitted = self.sched.admit()
            for req in admitted:
                if self.sched.prefill_cursor(req.rid) is not None:
                    # chunked admission: only the first chunk's blocks were
                    # charged; the model runs via _prefill_chunk_iteration,
                    # one chunk per engine step, alongside the decode batch
                    shared = self.sched.shared_prefix_tokens(req.rid)
                    self.stats.blocks_shared += shared // self.kv.block_size
                    self.stats.prefill_tokens_skipped += shared
                    kind = "readmit" if req.output else "admit"
                    self._emit(kind, req.rid, prompt_len=len(req.prompt),
                               chunked=True)
                elif req.output:               # preempted earlier: recompute
                    self._recompute(req)
                    self._emit("readmit", req.rid,
                               recomputed_tokens=self.kv.lengths[req.rid])
                else:
                    self._emit("admit", req.rid, prompt_len=len(req.prompt))
                    self._prefill(req)
            self._retire()                     # EOS-at-prefill frees early
            # an admission wave that finished entirely at prefill just
            # returned its blocks — the next waiting request may fit NOW
            if self.sched.running or not admitted:
                break
        if not self.sched.running and self.sched.waiting:
            head = self.sched.waiting[0]
            need = self.sched.stored_tokens(head) + self.sched.decode_headroom
            blocks = self.kv.blocks_needed(need)
            # degraded pool with a rejoin on the schedule: the head may fit
            # once the quarantined shard returns — idle this step instead
            # of declaring a permanent stall
            waitable = (self.kv.quarantined_shards
                        and self._fault is not None
                        and self._fault.pending_rejoins(self._step_no)
                        and blocks <= self.kv.num_blocks)
            if not waitable and not self._stall_waiver():
                raise SchedulingStalled(
                    f"request {head.rid} needs {blocks} "
                    f"blocks ({need} tokens incl. headroom) but the pool "
                    f"only has {self.kv.capacity_blocks} blocks "
                    f"({self.kv.num_free} free) and nothing is running — "
                    f"it can never be admitted; shrink the prompt or grow "
                    f"num_blocks" + self.kv._degraded_note())
        self._prefill_chunk_iteration()
        self._note_recoveries()
        self._decode_iteration()
        self._retire()

    def run(self, max_steps: int = 10_000) -> EngineStats:
        steps = 0
        while self.has_work() and steps < max_steps:
            self.step()
            steps += 1
        return self.stats

    def has_work(self) -> bool:
        return self.sched.has_work()

    # ------------------------------------------------------------------
    # disaggregation hooks (serving/cluster/ overrides these)
    # ------------------------------------------------------------------
    def _pre_admit_tick(self) -> None:
        """Hook between fault bookkeeping and this step's admission wave.
        The disaggregated cluster engines live here: a DecodeEngine drains
        its Prealloc→Transfer→Waiting handoff queues (so a transfer that
        completes this step joins this step's decode batch), a
        PrefillEngine evicts retained prefix donors under pool pressure
        (so retained blocks never block the admission the stall check is
        about to judge). Runs AFTER ``_fault_tick`` so a shard death this
        step is visible to mid-transfer recovery."""

    def _stall_waiver(self) -> bool:
        """Hook: return True to suppress this step's SchedulingStalled
        check. A DecodeEngine with handoffs in flight waives it — the
        queued imports hold pool blocks while nothing is running yet, a
        state the single-engine stall logic would misread as permanent."""
        return False

    def _retire(self) -> None:
        for req in self.sched.retire_finished():
            self.stats.observe_request(req)
            self._emit("finish", req.rid, tokens=len(req.output))

    def cancel_all(self) -> int:
        """Graceful shutdown: cancel every in-flight request (running AND
        waiting), freeing their pool blocks and marking each FINISHED so
        handle iterators terminate cleanly. Partial outputs are kept —
        every already-yielded token stays final. Returns the number of
        requests cancelled."""
        cancelled = self.sched.cancel_all()
        now = time.time()
        for req in cancelled:
            req.state = State.FINISHED
            req.finish_s = now
            self.stats.observe_request(req)
            self._emit("finish", req.rid, tokens=len(req.output),
                       cancelled=True)
        self._recovering.clear()
        return len(cancelled)

    # ------------------------------------------------------------------
    # fault detection / recovery
    # ------------------------------------------------------------------
    def _fault_tick(self) -> None:
        """Per-step fault bookkeeping at the pool boundary: scheduled
        rejoins restore quarantined capacity, stragglers are observed
        (slow is suspect, not wrong — no eviction), then every live shard
        is heartbeat-probed with bounded retry-with-backoff. A shard that
        answers within the retry budget recovers (transient blip, no
        eviction); one that doesn't is declared dead and its requests are
        recovered via :meth:`_handle_shard_death`."""
        if self._fault is None:
            return
        self._fault.begin_step(self._step_no)
        for s in self._fault.rejoins(self._step_no):
            if self.health.is_dead(s):
                self.kv.rejoin_shard(s)
                self.health.mark_up(s)
                self.stats.shard_rejoins += 1
                self._emit("shard_up", -1, shard=s,
                           capacity_blocks=self.kv.capacity_blocks)
        for s, delay in self._fault.straggles(self._step_no):
            if self.health.is_dead(s):
                continue
            self.stats.straggle_steps += 1
            if delay > 0:
                time.sleep(delay)
            self._emit("shard_suspect", -1, shard=s, cause="straggler",
                       delay_s=delay)
            self._emit("recover", -1, shard=s, cause="straggler")
        for s in range(self.kv.n_shards):
            if self.health.is_dead(s):
                continue
            attempt = 0
            suspected = False
            while not self._fault.probe(s, self._step_no):
                self.stats.fault_retries += 1
                if not suspected:
                    suspected = True
                    self._emit("shard_suspect", -1, shard=s,
                               cause="heartbeat")
                if self.health.strike(s) == DEAD:
                    self._handle_shard_death(s, cause="heartbeat")
                    break
                self._emit("retry", -1, shard=s, attempt=attempt + 1)
                self._backoff(attempt)
                attempt += 1
            else:
                if suspected:
                    self.health.clear(s)
                    self.stats.transient_faults_recovered += 1
                    self._emit("recover", -1, shard=s, cause="heartbeat",
                               retries=attempt)

    def _handle_shard_death(self, shard: int, cause: str) -> None:
        """Quarantine a dead shard and recover its requests: the allocator
        masks the shard out (capacity drops to the survivors — every
        admission/headroom guard sees the degraded pool), every request
        holding blocks there is evicted through the normal preemption path
        (generated tokens kept), and re-admission recomputes its KV onto
        the surviving shards — the §5 path, so greedy outputs are
        bit-identical to a fault-free run. Shared/CoW blocks need no
        special casing: eviction drops refcounts, survivors keep their
        physical blocks, and each physical block recovers at most once.

        Eviction bypasses ``policy.select_victim`` deliberately: shard
        death names its victims by block placement, not by scheduling
        policy, so recovery works under ``fcfs`` too — and MID-PREFILL
        victims are allowed here (their prefill cursor resets with the
        eviction), the one place that invariant yields."""
        t0 = time.time()
        victims = set(self.kv.seqs_on_shard(shard))
        # quarantine BEFORE freeing: the dead shard's blocks must not be
        # handed back out to the re-admission wave
        self.kv.quarantine_shard(shard)
        self.stats.shard_failures += 1
        self._emit("shard_down", -1, shard=shard, cause=cause,
                   victims=sorted(victims),
                   live_shards=list(self.kv.live_shards),
                   capacity_blocks=self.kv.capacity_blocks)
        for r in list(self.sched.running):
            if r.rid in victims:
                freed = self.sched.preempt(r)
                self.stats.preemptions = self.sched.n_preemptions
                self._emit("preempt", r.rid, freed_blocks=freed,
                           generated_tokens=len(r.output),
                           cause="shard_down")
                self._recovering[r.rid] = t0

    def _note_recoveries(self) -> None:
        """Close out recovery-latency timers: a shard-death victim counts
        as recovered the moment it is decodable again (running, prefill
        complete) on the surviving shards."""
        if not self._recovering:
            return
        for r in self.sched.running:
            t0 = self._recovering.get(r.rid)
            if t0 is not None and self.sched.prefill_done(r.rid):
                lat = time.time() - t0
                del self._recovering[r.rid]
                self.stats.recovery_latencies.append(lat)
                self.stats.requests_recovered += 1
                self._emit("recover", r.rid, latency_s=lat,
                           cause="readmitted")

    def _backoff(self, attempt: int) -> None:
        if self._backoff_s > 0:
            time.sleep(self._backoff_s * (2 ** attempt))

    def _guard_finite(self, reqs: List[Request], logits: jax.Array) -> None:
        """Refuse to sample from non-finite logits (satellite guard — live
        with or without an injector): name the offending requests and the
        engine step instead of silently emitting garbage tokens."""
        finite = np.asarray(jnp.isfinite(logits).all(axis=-1))
        if bool(finite.all()):
            return
        bad = [r.rid for r, ok in zip(reqs, finite) if not ok]
        raise CorruptedLogitsError(
            f"non-finite logits at engine step {self._step_no} for "
            f"request(s) {bad} — refusing to sample; no injected fault "
            f"accounts for this (check model numerics / KV integrity)",
            rids=bad, step=self._step_no)

    # ------------------------------------------------------------------
    # prefill / recompute
    # ------------------------------------------------------------------
    def _scale_kwargs(self, k_name: str, v_name: str) -> Dict:
        """The int8 pool's scale operands for a jitted call, keyed by the
        callee's kwarg names; empty for bf16 pools (scales-follow-blocks:
        every compute path that reads the pool also receives its scales)."""
        if self.kv.k_scale is None:
            return {}
        return {k_name: self.kv.k_scale, v_name: self.kv.v_scale}

    def _prefill(self, req: Request) -> None:
        logits = self._prefill_known(req.rid, req.prompt)
        tok = self._sample([req], logits)
        req.record_token(int(tok[0]))
        # the sampled token's K/V gets stored by the next decode pass (it is
        # that step's input token); kv.lengths stays = stored tokens

    def _recompute(self, req: Request) -> None:
        """Re-admission of a preempted request: rebuild its pool KV by
        re-prefilling prompt + generated tokens minus the still-unstored
        last one (the next decode input) — the §5 recovery path. No token
        is sampled: the stream continues from ``req.output[-1]``. Prefix
        sharing applies here too: a readmitted request whose prompt prefix
        matched a live donor at re-admission skips those blocks."""
        known = req.prompt + req.output[:-1]
        self._prefill_known(req.rid, known)

    def _prefill_known(self, rid: int, known: Sequence[int]) -> jax.Array:
        """Compute and store pool KV for `known` tokens, honouring the
        prefix the scheduler mapped onto a donor's blocks at admission.
        Returns the last position's logits.

        With a shared prefix: the matched blocks' KV is already resident
        (bit-identical — the donor stored the same tokens at the same
        positions), so only the suffix runs through the model
        (``transformer.prefill_suffix`` attends suffix queries over the
        gathered prefix context) and only the suffix is written. MoE
        recomputes the full prompt (see ``_skip_prefill_compute``) but
        still writes only the suffix — the donor's blocks are never
        rewritten, so no copy-on-write fires and the memory stays shared.
        """
        shared = self.sched.shared_prefix_tokens(rid)
        # increment-based (like prefill_tokens_skipped below) so a stats
        # reset mid-engine-lifetime stays consistent; the allocator's
        # kv.blocks_shared_total keeps the engine-lifetime cumulative view
        self.stats.blocks_shared += shared // self.kv.block_size
        if shared and self._skip_prefill_compute:
            # memoised gather indices: a prefix-sharing admission wave's K
            # recipients all resolve to the donor's physical blocks, so the
            # whole wave reuses one converted index array
            idx = self.kv.gather_prefix_indices(rid, shared)
            toks = jnp.asarray([list(known[shared:])], jnp.int32)
            logits, cache = self._prefill_suffix_jit(
                self.params, {"tokens": toks}, self.kv.k_pool,
                self.kv.v_pool, idx, **self._scale_kwargs("k_scale",
                                                          "v_scale"))
            # suffix cache k/v are head-major (L, 1, Hkv, S-shared, hd)
            self.kv.write_prefill(rid, cache["k"][:, 0], cache["v"][:, 0],
                                  start_token=shared)
            self.stats.prefill_tokens_skipped += shared
            self.stats.max_prefill_slab_tokens = max(
                self.stats.max_prefill_slab_tokens, len(known) - shared)
            return logits
        toks = jnp.asarray([list(known)], jnp.int32)
        self.stats.max_prefill_slab_tokens = max(
            self.stats.max_prefill_slab_tokens, len(known))
        logits, cache = self._prefill_jit(self.params, {"tokens": toks})
        # cache k/v are head-major (L, 1, Hkv, S, hd) — the pool's layout
        self.kv.write_prefill(rid, cache["k"][:, 0, :, shared:],
                              cache["v"][:, 0, :, shared:],
                              start_token=shared)
        return logits

    # ------------------------------------------------------------------
    # chunked prefill (mixed iterations)
    # ------------------------------------------------------------------
    def _prefill_chunk_iteration(self) -> None:
        """Advance the OLDEST incomplete prefill by one chunk (the
        per-iteration prefill token budget, ``prefill_chunk_tokens``) while
        the decode batch keeps decoding — the paper-§4 overlap on the
        prefill axis. The chunk's queries attend over the already-written
        pool blocks (plus the in-chunk causal mask), its KV is written as
        it completes (blocks allocated incrementally), and only the FINAL
        chunk samples the request's first token."""
        req = self.sched.next_prefill()
        if req is None:
            return
        rid = req.rid
        # re-admission after preemption recomputes prompt + generated
        # tokens minus the still-unstored last one (the §5 recovery path)
        known = list(req.prompt) + req.output[:-1] if req.output \
            else req.prompt
        total = len(known)
        cursor = self.sched.prefill_cursor(rid)
        target = min(cursor + self._chunk_tokens, total)
        grow = self.kv.blocks_needed(target) - len(self.kv.tables[rid])
        # the FINAL chunk re-establishes the decode headroom one-shot
        # admission reserves up front: completing a prefill with zero slack
        # would strand the request at its first decode-growth block
        headroom = 0
        if target >= total:
            headroom = (self.kv.blocks_needed(total +
                                              self.sched.decode_headroom) -
                        self.kv.blocks_needed(total))
        if grow + headroom > 0:
            # the chunk may not starve the decode batch either: reserve the
            # blocks this iteration's decodes are about to append before
            # taking any for the chunk (the decoders are what retires and
            # frees the rest of this prompt's allocation)
            reserve = sum(self.kv.blocks_to_append(r.rid)
                          for r in self.sched.running
                          if r.state == State.RUNNING
                          and self.sched.prefill_done(r.rid))
            if not self._free_blocks_for_chunk(req,
                                               grow + headroom + reserve):
                return  # stall this iteration: admission charged only the
                # first chunk, so the rest of the allocation arrives as
                # running requests retire — decode continues meanwhile
        toks = jnp.asarray([list(known[cursor:target])], jnp.int32)
        idx = self.kv.gather_prefix_indices(rid, cursor)
        logits, cache = self._prefill_chunk_jit(
            self.params, {"tokens": toks}, self.kv.k_pool, self.kv.v_pool,
            idx, **self._scale_kwargs("ks", "vs"))
        # chunk cache k/v are head-major (L, 1, Hkv, C, hd) — the pool's
        # layout; write_prefill_chunk extends the allocation then scatters
        self.kv.write_prefill_chunk(rid, cache["k"][:, 0], cache["v"][:, 0],
                                    start_token=cursor)
        self.stats.prefill_chunks_run += 1
        self.stats.max_prefill_slab_tokens = max(
            self.stats.max_prefill_slab_tokens, target - cursor)
        self.placement.log_prefill_chunk(target - cursor)
        self._emit("chunk", rid, start=cursor, tokens=target - cursor,
                   remaining=total - target)
        self.sched.advance_prefill(req, target)
        if target >= total and not req.output:
            # last chunk's last position seeds sampling — same contract as
            # the one-shot prefill (TTFT lands here)
            tok = self._sample([req], logits)
            req.record_token(int(tok[0]))

    def _free_blocks_for_chunk(self, req: Request, need: int) -> bool:
        """Check `need` blocks are free before a chunk allocation. Chunk
        growth NEVER preempts: while any decoder is still running the
        chunk simply STALLS this iteration (returns False) — decoders
        retire (or are themselves evicted by the decode-side pool-pressure
        path) and the freed blocks arrive over the next iterations, which
        is chunked admission's whole point. This also makes the sharing
        safety invariant enforced rather than emergent: a MID-PREFILL
        request is never a preemption victim anywhere (the decode path
        only selects among prefill-complete requests), so blocks a donor
        has allocated are always eventually written — a recipient mapped
        onto them can never gather garbage. Raises contextual
        :class:`PoolExhausted` only when no running decoder is left to
        ever free a block."""
        if self.kv.num_free >= need:
            return True
        if any(r.state == State.RUNNING and r is not req
               and self.sched.prefill_done(r.rid)
               for r in self.sched.running):
            return False             # decoders still running: wait them out
        free = self.kv.num_free
        fix = ("raise num_blocks" if self.policy.preemptible
               else "use scheduler='preempt' or raise num_blocks")
        raise PoolExhausted(
            f"KV pool exhausted mid chunked prefill: request "
            f"{req.rid} needs {need} blocks for its next chunk and "
            f"{free} of {self.kv.capacity_blocks} are free "
            f"({sum(self.kv.lengths.values())} live tokens across "
            f"{len(self.kv.tables)} sequences) with no running "
            f"decoder left to retire: {fix}" + self.kv._degraded_note(),
            rid=req.rid,
            live_tokens=sum(self.kv.lengths.values()),
            free_blocks=free,
            **self.kv._degraded_kw())

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _decode_iteration(self) -> None:
        running = [r for r in self.sched.running
                   if r.state == State.RUNNING
                   and self.sched.prefill_done(r.rid)]
        if not running:
            return
        running = self._resolve_pool_pressure(running)
        if not running:
            return
        ids = [r.rid for r in running]
        # placement-specific per-iteration operands + per-worker accounting
        extra = self.placement.decode_extra_args(self.kv, ids)
        tables, lens = self.kv.block_table_batch(ids)
        tokens = jnp.asarray([r.output[-1] for r in running], jnp.int32)
        t0 = time.time()
        out = self._decode_validated(running, tokens, tables, lens, extra)
        if out is None:
            # a shard died mid-decode: this iteration is aborted with
            # NOTHING committed (no append, no pool write, no sample) —
            # its victims were evicted, survivors decode next step with
            # outputs unchanged, so greedy bit-parity holds
            return
        logits, updates = out
        dt = time.time() - t0
        # placement is the memory pool's job: append the input token's K/V
        # (allocator bookkeeping per sequence, then ONE batched scatter)
        positions = [int(n) for n in lens]
        for r in running:
            self.kv.append_token(r.rid)
        self.kv.write_tokens(ids, updates["k_new"], updates["v_new"],
                             positions)
        toks = self._sample(running, logits)
        for i, r in enumerate(running):
            r.record_token(int(toks[i]))
        self.placement.log_step(len(running))
        self.stats.steps += 1
        self.stats.kv_pool_bytes_resident = self.kv.pool_bytes_resident
        self.stats.kv_bytes_read += (self.kv.unique_live_tokens(ids) *
                                     self.kv.bytes_per_live_token())
        self.stats.tokens_generated += len(running)
        self.stats.batch_sizes.append(len(running))
        self.stats.step_times.append(dt)

    def _decode_validated(self, running: List[Request], tokens, tables,
                          lens, extra):
        """Run the jitted decode step and VALIDATE the merged output
        before anything is committed (no token append, no pool write, no
        sampling has happened yet). Injected corruption — NaN partials
        from a pool shard, the stand-in for a per-shard checksum / sender
        identity a real RPC fabric attaches — strikes the shard and
        retries; the decode step is deterministic, so a retry that
        succeeds is bit-identical to an unfaulted step. Strikes past the
        retry budget declare the shard dead (returns ``None`` — the
        caller aborts the iteration; victims were already evicted).
        Non-finite logits NO fault accounts for raise
        :class:`CorruptedLogitsError`."""
        attempt = 0
        suspect = None
        while True:
            logits, updates = self._decode_jit(
                self.params, tokens, self.kv.k_pool, self.kv.v_pool,
                jnp.asarray(tables), jnp.asarray(lens), *extra,
                **self._scale_kwargs("k_scale_pool", "v_scale_pool"))
            logits.block_until_ready()
            shard = None
            if self._fault is not None:
                logits, shard = self._fault.filter_decode(self._step_no,
                                                          logits)
            if bool(jnp.isfinite(logits).all()):
                if suspect is not None:
                    self.health.clear(suspect)
                    self.stats.transient_faults_recovered += 1
                    self._emit("recover", -1, shard=suspect,
                               cause="corrupt_partial", retries=attempt)
                return logits, updates
            if shard is None:
                # non-finite output with no injected fault to blame: the
                # always-on guard refuses to sample garbage
                self._guard_finite(running, logits)
            if suspect is None:
                suspect = shard
                self._emit("shard_suspect", -1, shard=shard,
                           cause="corrupt_partial")
            self.stats.fault_retries += 1
            if self.health.strike(shard) == DEAD:
                self._handle_shard_death(shard, cause="corrupt_partial")
                return None
            self._emit("retry", -1, shard=shard, attempt=attempt + 1)
            self._backoff(attempt)
            attempt += 1

    def _resolve_pool_pressure(self, running: List[Request]
                               ) -> List[Request]:
        """Ensure every running sequence can store one more token. Each
        grower needs exactly one fresh block — because its table must grow
        OR because its tail block is shared and the divergent append will
        copy-on-write (``blocks_to_append`` counts both); when the pool
        can't cover them, the policy evicts victims (blocks freed back to
        the pool, re-admission via recompute) or — non-preemptible — the
        engine surfaces the allocator's PoolExhausted signal up front
        instead of stranding the pool mid-iteration."""
        def needs_block(r: Request) -> bool:
            return self.kv.blocks_to_append(r.rid) > 0

        while True:
            growers = [r for r in running if needs_block(r)]
            free = self.kv.num_free
            if len(growers) <= free:
                return running
            victim = self.policy.select_victim(running)
            if victim is None:
                g = growers[0]
                fix = ("a sole running request has no viable victim — "
                       "raise num_blocks" if self.policy.preemptible
                       else "use scheduler='preempt' or raise num_blocks")
                raise PoolExhausted(
                    f"KV pool exhausted: request {g.rid} "
                    f"({self.kv.lengths[g.rid]} stored tokens) needs a "
                    f"block and {free} of {self.kv.capacity_blocks} are "
                    f"free ({sum(self.kv.lengths.values())} live tokens "
                    f"across {len(self.kv.tables)} sequences); the "
                    f"{self.policy.name!r} policy found no victim: "
                    f"{fix}" + self.kv._degraded_note(),
                    rid=g.rid,
                    live_tokens=sum(self.kv.lengths.values()),
                    free_blocks=free,
                    **self.kv._degraded_kw())
            freed = self.sched.preempt(victim)
            # the scheduler's counter is the source of truth; stats mirrors
            # it (assignment, not increment — the two can never diverge)
            self.stats.preemptions = self.sched.n_preemptions
            self._emit("preempt", victim.rid, freed_blocks=freed,
                       generated_tokens=len(victim.output))
            running = [r for r in running if r is not victim]

    # ------------------------------------------------------------------
    # sampling (per-request PRNG streams — SamplingParams.seed honoured)
    # ------------------------------------------------------------------
    def _sample(self, reqs: List[Request], logits: jax.Array) -> jax.Array:
        self._guard_finite(reqs, logits)
        keys = jnp.stack([self._request_key(r) for r in reqs])
        temps = np.asarray([r.params.temperature for r in reqs], np.float32)
        topks = np.asarray([r.params.top_k for r in reqs], np.int32)
        return sample_per_request(logits, keys, temps, topks)

    def _request_key(self, req: Request) -> jax.Array:
        # token i of this request always draws from stream index i, via the
        # one canonical seed→stream mapping (sampler.request_key); a request
        # without its own seed falls back to the engine's
        seed = req.params.seed
        return request_key(self.config.seed if seed is None else seed,
                           len(req.output))

    # ------------------------------------------------------------------
    # introspection (CLI / benchmarks)
    # ------------------------------------------------------------------
    @property
    def pool(self):
        """The attention worker pool (None for homogeneous placement)."""
        return self.placement.pool

    @property
    def expert_pool(self):
        """The expert worker pool (moe_offload placement only)."""
        return self.placement.expert_pool

    @property
    def transfer_log(self):
        return self.placement.transfer_log

