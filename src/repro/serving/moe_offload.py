"""MoE expert offloading (paper §7 "Generality of our techniques").

The paper observes that its operator-level disaggregation generalises beyond
attention: MoE expert FFNs are *also* low-arithmetic-intensity (each expert's
weights serve only its routed tokens) and can live on cheap memory-optimized
workers, with the same per-layer DCN transfer pattern the FHBN stack makes
affordable. This module realises that proposal:

  * ExpertWorkerPool holds the expert weights (the "memory devices"),
    receives routed token activations, runs the expert FFNs, and returns
    combined outputs — with the same byte accounting contract as the
    attention pool;
  * transfer_bytes_moe gives the analytic per-iteration wire cost
    (2·e·d·B·L_moe both ways — token activations out, expert outputs back;
    unlike attention there is no KV growth, so the ratio to compute is even
    more favourable);
  * MoEOffloadEngine plugs the pool into the disaggregated decode step, so a
    qwen3/kimi-style model runs with BOTH attention and experts offloaded.

DEPRECATED (MoEOffloadEngine only): new code should use
:class:`repro.serving.llm_engine.LLMEngine` with
``EngineConfig(placement="moe_offload")``. The engine subclass is kept
verbatim as the greedy-parity oracle for the facade's tests;
``ExpertWorkerPool`` and the analytic bounds remain canonical.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.models import transformer
from repro.models.attention import qkv_project, out_project
from repro.models.common import ModelConfig, rms_norm
from repro.models.moe import moe_forward
from repro.serving.disagg_engine import BYTES, DisaggEngine, TransferLog


def transfer_bytes_moe(cfg: ModelConfig, batch: int) -> int:
    """Per-iteration wire bytes for expert offloading: token activations to
    the pool and expert outputs back, per MoE layer."""
    return int(2 * BYTES * cfg.d_model * batch * cfg.num_layers)


def min_bandwidth_moe(cfg: ModelConfig, batch: int, seq_len: float,
                      hw_model: cm.HardwareSpec, hw_exp: cm.HardwareSpec,
                      alpha: float = 0.2) -> float:
    """Paper-§3.1 style minimum-bandwidth bound for the MoE boundary."""
    t = cm.mtime(cfg, batch, hw_model) + cm.atime(cfg, batch, seq_len,
                                                  hw_model)
    return transfer_bytes_moe(cfg, batch) / (alpha * t)


class ExpertWorkerPool:
    """Memory-device pool owning the expert weights + FFN compute."""

    def __init__(self, cfg: ModelConfig, n_workers: int = 2):
        if cfg.num_experts % max(n_workers, 1):
            raise ValueError(
                f"expert partition needs num_experts ({cfg.num_experts}) "
                f"divisible by workers ({n_workers})")
        self.cfg = cfg
        self.n = n_workers
        self.log = TransferLog()
        self.per_worker_tokens = [0] * n_workers

    def run_experts(self, moe_params: Dict, x: jax.Array,
                    account: bool = False) -> jax.Array:
        """x: (B, S, d) routed-token activations arriving over the wire.
        Expert-partitioned across workers: each worker computes the routed
        contribution of its expert shard; outputs sum (experts are disjoint
        per token choice, so partial outputs add exactly)."""
        cfg = self.cfg
        y, _ = moe_forward(moe_params, cfg, x)
        if account:
            self.log.q_bytes += x.size * BYTES       # activations out
            self.log.out_bytes += y.size * BYTES     # expert outputs back
            self.log.transfers += 2
        return y

    def log_iteration(self, batch: int) -> None:
        d, L = self.cfg.d_model, self.cfg.num_layers
        self.log.q_bytes += batch * d * BYTES * L
        self.log.out_bytes += batch * d * BYTES * L
        self.log.transfers += 2 * L


class MoEOffloadEngine(DisaggEngine):
    """Lamina extended per paper §7: attention AND experts disaggregated."""

    def __init__(self, cfg: ModelConfig, params, *, n_expert_workers=2, **kw):
        if cfg.family != "moe":
            raise ValueError("MoEOffloadEngine needs a MoE config")
        super().__init__(cfg, params, **kw)
        self.expert_pool = ExpertWorkerPool(cfg, n_expert_workers)
        self._decode_jit = jax.jit(self._disagg_decode_moe)

    def _disagg_decode_moe(self, params, tokens, k_pool, v_pool,
                           block_tables, lens, shard_tables=None,
                           shard_positions=None):
        cfg = self.cfg
        cur_len = lens
        x = jnp.take(params["embed"], tokens[:, None], axis=0)
        positions = cur_len[:, None]
        ks, vs = [], []
        for layer in range(cfg.num_layers):
            p = jax.tree.map(lambda a: a[layer], params["layers"])
            # model slice 0: norm + QKV
            h = rms_norm(x, p["norm1"], cfg.norm_eps)
            q, k, v = qkv_project(p["attn"], cfg, h, positions)
            ks.append(k[:, 0])
            vs.append(v[:, 0])
            # attention pool (paged: workers read the block pool in place)
            attn = self.pool.attend_paged(
                q[:, 0], k_pool[layer], v_pool[layer], block_tables, cur_len,
                k[:, 0], v[:, 0], logit_softcap=cfg.attn_logit_softcap,
                shard_tables=shard_tables, shard_positions=shard_positions)
            x = x + out_project(p["attn"], attn[:, None])
            # expert pool (paper §7): router runs on the model worker, the
            # routed FFN on the expert workers
            h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
            f = self.expert_pool.run_experts(p["moe"], h2)
            x = x + f
        updates = {"k_new": jnp.stack(ks), "v_new": jnp.stack(vs),
                   "len": cur_len + 1}
        logits = transformer._head(params, cfg, x[:, 0])
        return logits, updates

    def _decode_iteration(self) -> None:
        from repro.serving.request import State
        n = len([r for r in self.sched.running if r.state == State.RUNNING])
        super(DisaggEngine, self)._decode_iteration()
        if n:
            self.pool.log_iteration(n)
            self.expert_pool.log_iteration(n)
