"""Shard fault injection + health tracking for the attention-pool path.

The paper's economics depend on attending over a fleet of *cheap*,
memory-optimized devices — and cheap, numerous devices straggle, corrupt
results, and die. This module is the engine's fault machinery:

  * :class:`FaultEvent` / :class:`FaultScenario` — a deterministic, seeded
    schedule of injected faults (shard death at step N with optional
    rejoin, transient probe failures, corrupted/NaN attention partials,
    straggler slow-steps), parseable from a compact CLI spec or a JSON
    file (``repro-serve --fault-scenario``);
  * :class:`FaultInjector` — the runtime hook :class:`LLMEngine` consults
    at the host-side pool boundary. Injection NEVER touches jitted code:
    shard death and transient unavailability surface as failed *probes*
    (the stand-in for a heartbeat/RPC timeout), and partial corruption is
    applied to the merged decode output AFTER the jitted step returns
    (the stand-in for a worker shipping garbage over the wire);
  * :class:`ShardHealthTracker` — the per-shard health state machine
    (``healthy → suspect → dead``): each failed probe/validation is a
    strike; a shard recovers to healthy when a retry succeeds before
    ``retry_limit`` strikes, and is declared DEAD (quarantine + request
    recovery, see ``llm_engine._handle_shard_death``) when it doesn't.

Recovery itself is NOT here — it is the §5 preempt-and-recompute path the
scheduler already owns: KV is recomputable from prompt + generated tokens,
so a dead shard's requests are evicted and re-prefilled onto surviving
shards with greedy outputs bit-identical to a fault-free run.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# health states
# ---------------------------------------------------------------------------
HEALTHY = "healthy"
SUSPECT = "suspect"
DEAD = "dead"

FAULT_KINDS = ("shard_death", "transient", "corrupt", "straggle")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``kind``:
      * ``shard_death`` — the shard stops answering probes from ``step``
        on (until ``rejoin_step``, if set). Detection exhausts the retry
        budget and declares the shard dead; its requests are recovered.
      * ``transient``   — the shard fails ``failures`` consecutive probes
        at ``step`` then answers again (a blip, not a death — recovers via
        retry when ``failures`` is below the engine's retry limit).
      * ``corrupt``     — the merged decode output contains NaN for
        ``failures`` consecutive attempts at ``step`` (a worker shipped a
        garbage partial); clean on the next retry.
      * ``straggle``    — the shard answers ``delay_s`` late at ``step``
        (observability only: slow is not wrong, health returns to healthy).
    """

    kind: str
    shard: int
    step: int
    failures: int = 1                  # transient / corrupt
    rejoin_step: Optional[int] = None  # shard_death
    delay_s: float = 0.0               # straggle

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"fault kind must be one of {FAULT_KINDS}; "
                             f"got {self.kind!r}")
        if self.shard < 0:
            raise ValueError(f"fault shard must be >= 0; got {self.shard}")
        if self.step < 1:
            raise ValueError(f"fault step must be >= 1 (engine steps are "
                             f"1-based); got {self.step}")
        if self.failures < 1:
            raise ValueError(f"fault failures must be >= 1; "
                             f"got {self.failures}")
        if self.rejoin_step is not None and self.rejoin_step <= self.step:
            raise ValueError(
                f"rejoin_step ({self.rejoin_step}) must be after the death "
                f"step ({self.step})")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0; got {self.delay_s}")


class FaultScenario:
    """An ordered, validated schedule of :class:`FaultEvent`\\ s."""

    def __init__(self, events: Sequence[FaultEvent]):
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.step, e.shard)))

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)

    def __repr__(self):
        return f"FaultScenario({list(self.events)!r})"

    # ------------------------------------------------------------------
    @classmethod
    def parse(cls, spec: str) -> "FaultScenario":
        """Build a scenario from the CLI spec.

        Two forms:
          * a path to a JSON file (a list of event objects:
            ``[{"kind": "shard_death", "shard": 1, "step": 6,
            "rejoin_step": 20}, ...]``);
          * an inline spec: ``;``-separated events, each
            ``kind:key=value,key=value`` — e.g.
            ``shard_death:shard=1,step=6,rejoin=20;``
            ``corrupt:shard=0,step=9,failures=2;``
            ``straggle:shard=1,step=3,delay_ms=5``.
        """
        spec = spec.strip()
        if os.path.isfile(spec):
            with open(spec) as f:
                raw = json.load(f)
            if not isinstance(raw, list):
                raise ValueError(
                    f"fault scenario file {spec!r} must hold a JSON list "
                    f"of event objects; got {type(raw).__name__}")
            return cls([FaultEvent(**ev) for ev in raw])
        events = []
        for part in spec.split(";"):
            part = part.strip()
            if not part:
                continue
            kind, _, kvs = part.partition(":")
            kind = kind.strip()
            kw: Dict = {}
            for item in kvs.split(","):
                item = item.strip()
                if not item:
                    continue
                key, _, val = item.partition("=")
                key = key.strip()
                if not val:
                    raise ValueError(
                        f"fault spec item {item!r} needs key=value "
                        f"(in {part!r})")
                if key == "rejoin":
                    kw["rejoin_step"] = int(val)
                elif key == "delay_ms":
                    kw["delay_s"] = float(val) / 1e3
                elif key == "delay_s":
                    kw["delay_s"] = float(val)
                elif key in ("shard", "step", "failures"):
                    kw[key] = int(val)
                else:
                    raise ValueError(
                        f"unknown fault spec key {key!r} (in {part!r}); "
                        f"known: shard, step, failures, rejoin, delay_ms, "
                        f"delay_s")
            events.append(FaultEvent(kind=kind, **kw))
        if not events:
            raise ValueError(f"fault scenario spec {spec!r} holds no events")
        return cls(events)

    @classmethod
    def random(cls, seed: int, n_shards: int, horizon: int,
               n_events: int = 3) -> "FaultScenario":
        """A deterministic pseudo-random schedule: same seed, same faults —
        reproducible chaos testing without hand-writing scenarios."""
        import numpy as np
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_events):
            kind = FAULT_KINDS[rng.integers(0, len(FAULT_KINDS))]
            shard = int(rng.integers(0, n_shards))
            step = int(rng.integers(1, max(2, horizon)))
            if kind == "shard_death":
                rejoin = None
                if rng.random() < 0.5:
                    rejoin = step + int(rng.integers(2, 10))
                events.append(FaultEvent(kind, shard, step,
                                         rejoin_step=rejoin))
            elif kind in ("transient", "corrupt"):
                events.append(FaultEvent(kind, shard, step,
                                         failures=int(rng.integers(1, 3))))
            else:
                events.append(FaultEvent(kind, shard, step,
                                         delay_s=float(rng.uniform(0, 2e-3))))
        return cls(events)


# ---------------------------------------------------------------------------
# the injector (host-side pool boundary — never inside jit)
# ---------------------------------------------------------------------------
class FaultInjector:
    """Runtime fault source the engine consults once per step.

    Stateful and deterministic: each transient/corrupt event carries a
    remaining-failure budget that is consumed attempt by attempt, so a
    retry sequence plays out identically run after run. The injector
    stands in for the health channel a real RPC fabric would provide —
    ``probe`` is the heartbeat, ``filter_decode`` is the response
    validator that knows WHICH worker shipped the garbage partial (a real
    fabric gets this from per-shard checksums / sender identity).
    """

    def __init__(self, scenario: FaultScenario):
        if isinstance(scenario, (list, tuple)):
            scenario = FaultScenario(scenario)
        self.scenario = scenario
        self._deaths: Dict[int, FaultEvent] = {}
        for ev in scenario:
            if ev.kind == "shard_death":
                if ev.shard in self._deaths:
                    raise ValueError(
                        f"shard {ev.shard} has two shard_death events — "
                        f"one life per shard per scenario")
                self._deaths[ev.shard] = ev
        # per-event remaining failure budgets (transient / corrupt)
        self._budget: Dict[int, int] = {
            i: ev.failures for i, ev in enumerate(scenario)
            if ev.kind in ("transient", "corrupt")}
        self._step = 0

    # ------------------------------------------------------------------
    def begin_step(self, step: int) -> None:
        """Advance the injector's clock to engine step `step`."""
        self._step = step

    def rejoins(self, step: int) -> List[int]:
        """Shards whose scheduled rejoin lands at `step`."""
        return sorted(ev.shard for ev in self._deaths.values()
                      if ev.rejoin_step == step)

    def pending_rejoins(self, step: int) -> bool:
        """True when some dead shard is still scheduled to rejoin after
        `step` — the engine waits instead of declaring a permanent stall."""
        return any(ev.rejoin_step is not None and ev.rejoin_step > step
                   for ev in self._deaths.values())

    def straggles(self, step: int) -> List[Tuple[int, float]]:
        """(shard, delay_s) straggler events firing at `step`."""
        return [(ev.shard, ev.delay_s) for ev in self.scenario
                if ev.kind == "straggle" and ev.step == step]

    def probe(self, shard: int, step: int) -> bool:
        """One health probe of `shard` (the heartbeat / RPC liveness
        check). False = no answer. A dead shard never answers between its
        death step and its rejoin; a transient event consumes one failure
        per probe and answers again once its budget is spent."""
        death = self._deaths.get(shard)
        if death is not None and death.step <= step and \
                (death.rejoin_step is None or step < death.rejoin_step):
            return False
        for i, ev in enumerate(self.scenario):
            if ev.kind == "transient" and ev.shard == shard \
                    and ev.step == step and self._budget.get(i, 0) > 0:
                self._budget[i] -= 1
                return False
        return True

    def filter_decode(self, step: int, logits: jax.Array
                      ) -> Tuple[jax.Array, Optional[int]]:
        """Apply any active corruption fault to the merged decode output
        (host-side, AFTER the jitted step — jitted code paths are never
        touched). Returns (possibly corrupted logits, faulty shard or
        None). Each call consumes one failure from the event's budget, so
        the engine's bounded retry deterministically rides it out."""
        for i, ev in enumerate(self.scenario):
            if ev.kind == "corrupt" and ev.step == step \
                    and self._budget.get(i, 0) > 0:
                self._budget[i] -= 1
                return jnp.full_like(logits, jnp.nan), ev.shard
        return logits, None


# ---------------------------------------------------------------------------
# per-shard health state machine
# ---------------------------------------------------------------------------
class ShardHealthTracker:
    """``healthy → suspect → dead`` per pool shard.

    Every failed probe / corrupted-output validation is a STRIKE: the
    first strike moves a healthy shard to ``suspect``; reaching
    ``retry_limit`` strikes without a success in between declares it
    ``dead`` (the engine quarantines it and recovers its requests). A
    success while suspect clears the strikes — transient blips recover.
    A rejoined shard is marked up and starts clean.
    """

    def __init__(self, n_shards: int, retry_limit: int = 3):
        if retry_limit < 1:
            raise ValueError(f"retry_limit must be >= 1; got {retry_limit}")
        self.n_shards = n_shards
        self.retry_limit = retry_limit
        self._state = [HEALTHY] * n_shards
        self._strikes = [0] * n_shards

    def state(self, shard: int) -> str:
        return self._state[shard]

    def strikes(self, shard: int) -> int:
        return self._strikes[shard]

    def is_dead(self, shard: int) -> bool:
        return self._state[shard] == DEAD

    @property
    def dead_shards(self) -> List[int]:
        return [s for s, st in enumerate(self._state) if st == DEAD]

    def strike(self, shard: int) -> str:
        """Record one failure; returns the shard's new state."""
        if self._state[shard] == DEAD:
            return DEAD
        self._strikes[shard] += 1
        self._state[shard] = (DEAD if self._strikes[shard] >=
                              self.retry_limit else SUSPECT)
        return self._state[shard]

    def clear(self, shard: int) -> None:
        """A retry succeeded: the suspect shard is healthy again."""
        if self._state[shard] != DEAD:
            self._state[shard] = HEALTHY
            self._strikes[shard] = 0

    def mark_up(self, shard: int) -> None:
        """A dead shard rejoined (fresh hardware / restarted worker)."""
        self._state[shard] = HEALTHY
        self._strikes[shard] = 0

    def __repr__(self):
        return (f"ShardHealthTracker({dict(enumerate(self._state))}, "
                f"retry_limit={self.retry_limit})")
