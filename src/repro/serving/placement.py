"""Placement strategies — the composable objects that make model-attention
disaggregation a *declarative* decision (paper thesis).

Each strategy owns everything placement-specific that the legacy engines
encoded as subclass overrides (``DisaggEngine._disagg_decode``,
``_decode_extra_args``, per-partition accounting in ``_decode_iteration``):

  * :meth:`PlacementStrategy.decode_fn` builds the jittable one-iteration
    decode step ``(params, tokens, k_pool, v_pool, block_tables, lens,
    *extra) -> (logits, updates)`` over the paged block pool;
  * :meth:`PlacementStrategy.decode_extra_args` supplies the per-iteration
    host-side operands the step needs (the block partition rides its
    compacted per-shard tables through here) and performs the
    data-dependent per-worker KV-read accounting;
  * :meth:`PlacementStrategy.log_step` does the analytic per-iteration
    transfer accounting (paper §3.1 — jit-safe, shape-derived).

``LLMEngine`` composes one strategy with the scheduler and the KV pool; no
placement ever subclasses the engine. The numerical contract is exact:
every placement decodes greedy token-for-token identically to the fused
baseline (the §4.2.2 combine identity), which the parity tests in
``tests/test_llm_engine.py`` pin against the pre-refactor engines.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.attention import out_project, qkv_project
from repro.models.common import ModelConfig, rms_norm
from repro.models.ffn import ffn_forward
from repro.models.moe import moe_forward
from repro.serving.config import EngineConfig
from repro.serving.kvcache import PagedKVCache
from repro.serving.worker_pool import (BYTES, AttentionWorkerPool,
                                       ExpertWorkerPool, TransferLog)


def _tree_index(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def sliced_decode_step(cfg: ModelConfig, pool: AttentionWorkerPool,
                       params, tokens, k_pool, v_pool, block_tables, lens,
                       shard_tables=None, shard_positions=None,
                       expert_pool: Optional[ExpertWorkerPool] = None,
                       k_scale_pool=None, v_scale_pool=None):
    """One disaggregated decode iteration — the converter's slices, executed.

    Model slice 0 (norm1 + QKV) runs on the model worker, attention on the
    worker pool (which reads the paged block pool in place), model slice 1
    (o-proj + FFN) back on the model worker; when ``expert_pool`` is given
    (paper §7) the routed expert FFNs run on the expert workers instead.

    Int8 pools: k_scale_pool/v_scale_pool are the (L, Hkv, num_blocks,
    block_size) scale pools; each layer's slice rides to the worker pool
    alongside its value pools and dequant fuses inside the workers'
    attention backends (no dense dequantized slab on this hot path).
    """
    cur_len = lens  # stored tokens
    x = jnp.take(params["embed"], tokens[:, None], axis=0)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(jnp.sqrt(float(cfg.d_model)), x.dtype)
    positions = cur_len[:, None]
    ks, vs = [], []
    for layer in range(cfg.num_layers):
        p = _tree_index(params["layers"], layer)
        is_local = cfg.local_global and layer % 2 == 0
        window = cfg.sliding_window if (is_local or not cfg.local_global) \
            else 0
        # ---- model slice 0: norm1 + QKV (send q early — §4.2.2) ----
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        q, k, v = qkv_project(p["attn"], cfg, h, positions)
        ks.append(k[:, 0])
        vs.append(v[:, 0])
        # ---- attention pool: workers read the paged pool in place ----
        attn = pool.attend_paged(
            q[:, 0], k_pool[layer], v_pool[layer], block_tables, cur_len,
            k[:, 0], v[:, 0], sliding_window=int(window),
            attention_sinks=cfg.attention_sinks if window else 0,
            logit_softcap=cfg.attn_logit_softcap,
            shard_tables=shard_tables, shard_positions=shard_positions,
            k_scale=None if k_scale_pool is None else k_scale_pool[layer],
            v_scale=None if v_scale_pool is None else v_scale_pool[layer])
        # ---- model slice 1: o-proj + residual + FFN ----
        attn_out = out_project(p["attn"], attn[:, None])
        if cfg.post_norms:
            attn_out = rms_norm(attn_out, p["norm_post_attn"], cfg.norm_eps)
        x = x + attn_out
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if "moe" in p:
            if expert_pool is not None:
                # router on the model worker, routed FFNs on the experts
                f = expert_pool.run_experts(p["moe"], h2)
            else:
                f, _ = moe_forward(p["moe"], cfg, h2)
        else:
            f = ffn_forward(p["ffn"], h2)
        if cfg.post_norms:
            f = rms_norm(f, p["norm_post_ffn"], cfg.norm_eps)
        x = x + f
    updates = {"k_new": jnp.stack(ks), "v_new": jnp.stack(vs),
               "len": cur_len + 1}
    logits = transformer._head(params, cfg, x[:, 0])
    return logits, updates


class PlacementStrategy:
    """Base placement: where each operator of the decode step executes."""

    name = "base"

    def __init__(self, cfg: ModelConfig, econf: EngineConfig):
        self.cfg = cfg
        self.econf = econf

    # ---- jittable decode step ----
    def decode_fn(self):
        raise NotImplementedError

    # ---- per-iteration host-side operands + data-dependent accounting ----
    def decode_extra_args(self, kv: PagedKVCache,
                          ids: Sequence[int]) -> Tuple:
        return ()

    # ---- analytic per-iteration transfer accounting ----
    def log_step(self, batch: int) -> None:
        pass

    def log_prefill_chunk(self, tokens: int) -> None:
        """Account one prefill chunk's KV landing in the pool (chunked
        prefill ships each chunk's (L, Hkv, C, hd) K/V model->pool as it
        completes; homogeneous placement moves nothing off-worker)."""
        pass

    # ---- introspection (CLI / benchmarks) ----
    @property
    def pool(self) -> Optional[AttentionWorkerPool]:
        return None

    @property
    def expert_pool(self) -> Optional[ExpertWorkerPool]:
        return None

    @property
    def transfer_log(self) -> Optional[TransferLog]:
        return self.pool.log if self.pool is not None else None


class HomogeneousPlacement(PlacementStrategy):
    """vLLM-style baseline: every operator fused on the model workers."""

    name = "homogeneous"

    def decode_fn(self):
        cfg, backend = self.cfg, self.econf.decode_backend

        def step(params, tokens, k_pool, v_pool, block_tables, lens,
                 k_scale_pool=None, v_scale_pool=None):
            return transformer.decode_step_paged(
                params, cfg, tokens, k_pool, v_pool, block_tables, lens,
                backend=backend, k_scale_pool=k_scale_pool,
                v_scale_pool=v_scale_pool)
        return step


class AttentionPoolPlacement(PlacementStrategy):
    """Lamina (paper §4): attention on a memory-optimized worker pool,
    partitioned ``head`` / ``request`` / ``block``."""

    name = "attention_pool"

    def __init__(self, cfg: ModelConfig, econf: EngineConfig):
        super().__init__(cfg, econf)
        self._pool = AttentionWorkerPool(
            cfg, econf.attention_workers, econf.partition,
            econf.decode_backend, kv_dtype=econf.kv_dtype)

    @property
    def pool(self) -> AttentionWorkerPool:
        return self._pool

    def decode_fn(self):
        cfg, pool = self.cfg, self._pool

        def step(params, tokens, k_pool, v_pool, block_tables, lens,
                 shard_tables=None, shard_positions=None,
                 k_scale_pool=None, v_scale_pool=None):
            return sliced_decode_step(
                cfg, pool, params, tokens, k_pool, v_pool, block_tables,
                lens, shard_tables, shard_positions,
                expert_pool=self.expert_pool,
                k_scale_pool=k_scale_pool, v_scale_pool=v_scale_pool)
        return step

    def decode_extra_args(self, kv: PagedKVCache,
                          ids: Sequence[int]) -> Tuple:
        """Per-worker live-token KV-read accounting (data-dependent, so
        host-side — the jitted step's python body fires at trace time only)
        plus, for the block partition, the compacted per-shard local tables
        that let each worker walk only its ~1/n of the live blocks."""
        pool, L = self._pool, self.cfg.num_layers
        if pool.partition == "block":
            # one table walk serves both the jitted step's compacted shard
            # tables and the live-token accounting
            lt, lp, shard_tokens = kv.block_table_shards(ids)
            pool.log_paged_kv(shard_tokens.sum(axis=1), L)
            return (jnp.asarray(lt), jnp.asarray(lp))
        # byte accounting counts a prefix-SHARED physical block once (its
        # bytes are resident, and streamable, once per chip — not once per
        # sharer): unique_live_tokens dedupes; without sharing it equals
        # the plain per-sequence length sum
        if pool.partition == "head":
            total = kv.unique_live_tokens(ids)
            pool.log_paged_kv([total] * pool.n, L,
                              kv_head_fraction=1.0 / pool.n)
        else:  # request: each worker walks only its requests' tables
            toks = [kv.unique_live_tokens([ids[i] for i in idx])
                    for idx in np.array_split(np.arange(len(ids)), pool.n)]
            pool.log_paged_kv(toks, L)
        return ()

    def log_step(self, batch: int) -> None:
        self._pool.log_iteration(batch)

    def log_prefill_chunk(self, tokens: int) -> None:
        """One chunk's KV crosses the wire model->pool once per layer (the
        prefill-axis counterpart of the per-step k_new/v_new transfer).
        Int8 pools ship quantized values + fp32 scales (hd + 4 bytes per
        token-head instead of hd·2) — the wire follows the pool dtype."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        per_head = hd + 4 if self.econf.kv_dtype == "int8" else hd * BYTES
        self._pool.log.kv_bytes += (2 * tokens * cfg.num_kv_heads *
                                    per_head * cfg.num_layers)
        self._pool.log.transfers += cfg.num_layers


class MoEOffloadPlacement(AttentionPoolPlacement):
    """Paper §7: attention AND the routed expert FFNs on worker pools."""

    name = "moe_offload"

    def __init__(self, cfg: ModelConfig, econf: EngineConfig):
        if cfg.family != "moe":
            raise ValueError("moe_offload placement needs a MoE config; "
                             f"got family={cfg.family}")
        super().__init__(cfg, econf)
        self._expert_pool = ExpertWorkerPool(cfg, econf.expert_workers)

    @property
    def expert_pool(self) -> ExpertWorkerPool:
        return self._expert_pool

    def log_step(self, batch: int) -> None:
        super().log_step(batch)
        self._expert_pool.log_iteration(batch)


_PLACEMENTS = {
    "homogeneous": HomogeneousPlacement,
    "attention_pool": AttentionPoolPlacement,
    "moe_offload": MoEOffloadPlacement,
}


def make_placement(cfg: ModelConfig, econf: EngineConfig
                   ) -> PlacementStrategy:
    return _PLACEMENTS[econf.placement](cfg, econf)
