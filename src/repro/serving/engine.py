"""Homogeneous serving engine — the vLLM-style baseline the paper compares
against: continuous batching (Orca) + paged KV (PagedAttention), all
operators on one device pool.

CPU-scale correctness engine: drives the real model (`transformer.prefill` /
`transformer.decode_step`) against the paged pool, gathering dense KV views
per iteration and scattering the new token's K/V back. Designed for reduced
configs in tests/examples; the dry-run path exercises the full-size shapes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.common import ModelConfig
from repro.serving.kvcache import PagedKVCache
from repro.serving.request import Request, SamplingParams, State
from repro.serving.sampler import sample
from repro.serving.scheduler import Scheduler


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_generated: int = 0
    batch_sizes: List[int] = dataclasses.field(default_factory=list)
    step_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def mean_batch(self) -> float:
        return float(np.mean(self.batch_sizes)) if self.batch_sizes else 0.0

    @property
    def throughput(self) -> float:
        t = sum(self.step_times)
        return self.tokens_generated / t if t > 0 else 0.0

    @property
    def mean_tbt(self) -> float:
        return float(np.mean(self.step_times)) if self.step_times else 0.0


class Engine:
    """Baseline homogeneous engine."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 num_blocks: int = 256, block_size: int = 16,
                 decode_backend: str = "jnp", seed: int = 0):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError("engine serves KV-cache architectures; "
                             f"got family={cfg.family}")
        self.cfg = cfg
        self.params = params
        self.kv = PagedKVCache(cfg, num_blocks, block_size)
        self.sched = Scheduler(self.kv, max_batch)
        self.backend = decode_backend
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        self._decode_jit = jax.jit(
            lambda p, t, c: transformer.decode_step(
                p, cfg, t, c, backend=decode_backend))
        self._prefill_jit = jax.jit(
            lambda p, b: transformer.prefill(p, cfg, b,
                                             max_seq=b["tokens"].shape[1]))

    # ------------------------------------------------------------------
    def submit(self, reqs: List[Request]) -> None:
        self.sched.submit(reqs)

    def _prefill(self, req: Request) -> None:
        toks = jnp.asarray([req.prompt], jnp.int32)
        logits, cache = self._prefill_jit(self.params, {"tokens": toks})
        # cache k/v are head-major (L, 1, Hkv, S, hd); pool stores seq-major
        self.kv.write_prefill(req.rid,
                              jnp.swapaxes(cache["k"][:, 0], 1, 2),
                              jnp.swapaxes(cache["v"][:, 0], 1, 2))
        self.key, sub = jax.random.split(self.key)
        tok = sample(logits, sub, req.params.temperature, req.params.top_k)
        req.record_token(int(tok[0]))
        # the sampled token's K/V gets stored by the next decode pass (it is
        # that step's input token); kv.lengths stays = stored tokens

    def _decode_iteration(self) -> None:
        running = [r for r in self.sched.running if r.state == State.RUNNING]
        if not running:
            return
        ids = [r.rid for r in running]
        lens = [self.kv.lengths[r.rid] for r in running]  # stored tokens
        pad = -(-max(lens) // self.kv.block_size) * self.kv.block_size
        k, v, _ = self.kv.gather(ids, pad)
        # engine pool is seq-major; the model wants head-major (§Perf #3)
        cache = {"k": jnp.swapaxes(k, 2, 3), "v": jnp.swapaxes(v, 2, 3),
                 "len": jnp.asarray(lens, jnp.int32)}
        tokens = jnp.asarray([r.output[-1] for r in running], jnp.int32)
        t0 = time.time()
        logits, updates = self._decode_jit(self.params, tokens, cache)
        logits.block_until_ready()
        dt = time.time() - t0
        # placement is the memory pool's job: append the input token's K/V
        for i, r in enumerate(running):
            self.kv.append_token(r.rid)
            self.kv.write_token(r.rid, updates["k_new"][:, i],
                                updates["v_new"][:, i], lens[i])
        self.key, sub = jax.random.split(self.key)
        toks = sample(logits, sub,
                      running[0].params.temperature, running[0].params.top_k)
        for i, r in enumerate(running):
            r.record_token(int(toks[i]))
        self.stats.steps += 1
        self.stats.tokens_generated += len(running)
        self.stats.batch_sizes.append(len(running))
        self.stats.step_times.append(dt)

    def step(self) -> None:
        for req in self.sched.admit():
            self._prefill(req)
        self._decode_iteration()
        self.sched.retire_finished()

    def run(self, max_steps: int = 10_000) -> EngineStats:
        steps = 0
        while self.sched.has_work() and steps < max_steps:
            self.step()
            steps += 1
        return self.stats
