"""Homogeneous serving engine — the vLLM-style baseline the paper compares
against: continuous batching (Orca) + paged KV (PagedAttention), all
operators on one device pool.

CPU-scale correctness engine: drives the real model (`transformer.prefill` /
`transformer.decode_step_paged`) straight over the paged block pool. The
decode hot path is fully paged: attention consumes the head-major pools in
place through a per-iteration block table (no dense gather, no transposes —
per-step KV traffic is exactly one read of the live KV), and the new token's
K/V lands with one batched `write_tokens` scatter. Sampling is per-request
(each Request's own SamplingParams). Designed for reduced configs in
tests/examples; the dry-run path exercises the full-size shapes.

DEPRECATED: new code should use :class:`repro.serving.llm_engine.LLMEngine`
with ``EngineConfig(placement="homogeneous")`` — one facade serves every
placement with a streaming request lifecycle. This class is kept verbatim
as the greedy-parity oracle for the facade's tests and will be deleted once
downstream callers have migrated. (``EngineStats`` stays canonical here —
both generations share it.)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.common import ModelConfig
from repro.serving.kvcache import PagedKVCache
from repro.serving.request import Request, State
from repro.serving.sampler import sample, sample_batch
from repro.serving.scheduler import Scheduler


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_generated: int = 0
    batch_sizes: List[int] = dataclasses.field(default_factory=list)
    step_times: List[float] = dataclasses.field(default_factory=list)
    # per-request latency samples (seconds) — populated by observe_request
    # on retirement; the percentile surface bench_serving reports
    request_ttfts: List[float] = dataclasses.field(default_factory=list)
    request_tbts: List[float] = dataclasses.field(default_factory=list)
    preemptions: int = 0
    # prefix sharing (LLMEngine with EngineConfig.prefix_sharing):
    # physical blocks mapped onto a donor's at admission, and prompt tokens
    # whose prefill COMPUTE was skipped (MoE shares memory but recomputes,
    # so its blocks_shared can grow while prefill_tokens_skipped stays 0)
    blocks_shared: int = 0
    prefill_tokens_skipped: int = 0
    # chunked paged prefill (LLMEngine with EngineConfig.prefill_chunk_
    # tokens): chunk model calls run, and the largest dense KV slab one
    # prefill call materialised before scattering it into the pool (tokens)
    # — bounded by the chunk size when chunking is on, by the longest
    # prompt when off (the admission-capping transient the tentpole kills)
    prefill_chunks_run: int = 0
    max_prefill_slab_tokens: int = 0
    # fault tolerance (LLMEngine with a FaultInjector / shard health
    # machine, serving/faults.py): shard lifecycle counts, retry volume,
    # and per-request recovery latency samples (seconds from the shard
    # being declared dead to the victim request decodable again on the
    # surviving shards — detection + eviction + recompute re-admission)
    shard_failures: int = 0
    shard_rejoins: int = 0
    transient_faults_recovered: int = 0
    fault_retries: int = 0
    straggle_steps: int = 0
    requests_recovered: int = 0
    recovery_latencies: List[float] = dataclasses.field(default_factory=list)

    @property
    def mean_batch(self) -> float:
        return float(np.mean(self.batch_sizes)) if self.batch_sizes else 0.0

    @property
    def throughput(self) -> float:
        t = sum(self.step_times)
        return self.tokens_generated / t if t > 0 else 0.0

    @property
    def mean_tbt(self) -> float:
        return float(np.mean(self.step_times)) if self.step_times else 0.0

    # ---------------- per-request latency surface ----------------
    def observe_request(self, req) -> None:
        """Fold one retired request's latencies in: TTFT (arrival to first
        token) and its mean time-between-tokens."""
        if req.first_token_s is not None:
            self.request_ttfts.append(req.first_token_s - req.arrival_s)
        if len(req.token_times) >= 2:
            self.request_tbts.append(req.tbt_s())

    @staticmethod
    def _pcts(samples: List[float]) -> Dict[str, float]:
        if not samples:
            return {"p50": 0.0, "p90": 0.0, "p99": 0.0}
        arr = np.asarray(samples, np.float64)
        return {p: float(np.percentile(arr, q))
                for p, q in (("p50", 50), ("p90", 90), ("p99", 99))}

    def ttft_percentiles(self) -> Dict[str, float]:
        """p50/p90/p99 time-to-first-token over retired requests (s)."""
        return self._pcts(self.request_ttfts)

    def tbt_percentiles(self) -> Dict[str, float]:
        """p50/p90/p99 of per-request mean time-between-tokens (s)."""
        return self._pcts(self.request_tbts)

    def recovery_percentiles(self) -> Dict[str, float]:
        """p50/p90/p99 request-recovery latency (s): shard declared dead →
        victim request decodable again on the surviving shards."""
        return self._pcts(self.recovery_latencies)

    def summary(self) -> Dict[str, float]:
        """Flat scalar summary (the dict bench_serving reports)."""
        out = {
            "steps": self.steps,
            "tokens_generated": self.tokens_generated,
            "requests": len(self.request_ttfts),
            "mean_batch": self.mean_batch,
            "throughput_tok_s": self.throughput,
            "mean_tbt_s": self.mean_tbt,
            "preemptions": self.preemptions,
            "blocks_shared": self.blocks_shared,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            "prefill_chunks_run": self.prefill_chunks_run,
            "max_prefill_slab_tokens": self.max_prefill_slab_tokens,
            "shard_failures": self.shard_failures,
            "shard_rejoins": self.shard_rejoins,
            "transient_faults_recovered": self.transient_faults_recovered,
            "fault_retries": self.fault_retries,
            "straggle_steps": self.straggle_steps,
            "requests_recovered": self.requests_recovered,
        }
        for name, pcts in (("ttft", self.ttft_percentiles()),
                           ("tbt", self.tbt_percentiles()),
                           ("recovery", self.recovery_percentiles())):
            for p, v in pcts.items():
                out[f"{name}_{p}_s"] = v
        return out


class Engine:
    """Baseline homogeneous engine."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 num_blocks: int = 256, block_size: int = 16,
                 kv_shards: int = 1, decode_backend: str = "jnp",
                 seed: int = 0):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError("engine serves KV-cache architectures; "
                             f"got family={cfg.family}")
        self.cfg = cfg
        self.params = params
        # kv_shards > 1 places blocks round-robin over that many pool shards
        # (the DisaggEngine block partition / cross-chip block sharding)
        self.kv = PagedKVCache(cfg, num_blocks, block_size,
                               n_shards=kv_shards)
        self.sched = Scheduler(self.kv, max_batch)
        self.backend = decode_backend
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        self._decode_jit = jax.jit(
            lambda p, t, kp, vp, bt, ln: transformer.decode_step_paged(
                p, cfg, t, kp, vp, bt, ln, backend=decode_backend))
        self._prefill_jit = jax.jit(
            lambda p, b: transformer.prefill(p, cfg, b,
                                             max_seq=b["tokens"].shape[1]))

    # ------------------------------------------------------------------
    def submit(self, reqs: List[Request]) -> None:
        self.sched.submit(reqs)

    def _prefill(self, req: Request) -> None:
        toks = jnp.asarray([req.prompt], jnp.int32)
        logits, cache = self._prefill_jit(self.params, {"tokens": toks})
        # cache k/v are head-major (L, 1, Hkv, S, hd) — the pool's layout
        self.kv.write_prefill(req.rid, cache["k"][:, 0], cache["v"][:, 0])
        self.key, sub = jax.random.split(self.key)
        tok = sample(logits, sub, req.params.temperature, req.params.top_k)
        req.record_token(int(tok[0]))
        # the sampled token's K/V gets stored by the next decode pass (it is
        # that step's input token); kv.lengths stays = stored tokens

    def _decode_iteration(self) -> None:
        running = [r for r in self.sched.running if r.state == State.RUNNING]
        if not running:
            return
        ids = [r.rid for r in running]
        # paged hot path: the model attends over the pool in place through
        # the block table — no dense gather, no transposes
        tables, lens = self.kv.block_table_batch(ids)
        tokens = jnp.asarray([r.output[-1] for r in running], jnp.int32)
        t0 = time.time()
        logits, updates = self._decode_jit(
            self.params, tokens, self.kv.k_pool, self.kv.v_pool,
            jnp.asarray(tables), jnp.asarray(lens),
            *self._decode_extra_args(ids))
        logits.block_until_ready()
        dt = time.time() - t0
        # placement is the memory pool's job: append the input token's K/V
        # (allocator bookkeeping per sequence, then ONE batched scatter)
        positions = [int(n) for n in lens]
        for r in running:
            self.kv.append_token(r.rid)
        self.kv.write_tokens(ids, updates["k_new"], updates["v_new"],
                             positions)
        self.key, sub = jax.random.split(self.key)
        toks = sample_batch(
            logits, sub,
            np.asarray([r.params.temperature for r in running], np.float32),
            np.asarray([r.params.top_k for r in running], np.int32))
        for i, r in enumerate(running):
            r.record_token(int(toks[i]))
        self.stats.steps += 1
        self.stats.tokens_generated += len(running)
        self.stats.batch_sizes.append(len(running))
        self.stats.step_times.append(dt)

    def _decode_extra_args(self, ids) -> tuple:
        """Hook: extra per-iteration operands for the jitted decode step
        (the DisaggEngine block partition rides its per-shard local tables
        through here)."""
        return ()

    def step(self) -> None:
        for req in self.sched.admit():
            self._prefill(req)
        self._decode_iteration()
        self.sched.retire_finished()

    def run(self, max_steps: int = 10_000) -> EngineStats:
        steps = 0
        while self.sched.has_work() and steps < max_steps:
            self.step()
            steps += 1
        return self.stats
