"""Speculative decoding (paper §8 related work; [31, 36, 38]).

The paper positions speculative decoding as the *other* lever on decode
arithmetic intensity: instead of moving attention to memory-optimized
devices, guess k tokens with a cheap draft model and verify them with ONE
target-model pass (a k-token BGEMM instead of k BGEMVs). The two compose:
in a Lamina deployment the verify pass batches the attention reads the
memory pool serves.

This implementation is the greedy-exact variant: acceptance keeps the
longest prefix where the target's greedy choice equals the draft's proposal
and then takes the target's own next token — provably IDENTICAL output to
plain greedy decoding of the target model (asserted by tests), with
`target_calls ≈ tokens / (mean_accepted + 1)`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.common import ModelConfig


@dataclasses.dataclass
class SpecStats:
    target_calls: int = 0
    draft_calls: int = 0
    proposed: int = 0
    accepted: int = 0

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0

    @property
    def tokens_per_target_call(self) -> float:
        return (self.accepted + self.target_calls) / max(self.target_calls, 1)


def _greedy_next(params, cfg, tokens) -> jax.Array:
    """Greedy logits over the full prefix (smoke-scale verify; production
    uses a chunked cache-extend step — see module docstring)."""
    logits, _ = transformer.forward(params, cfg, {"tokens": tokens})
    return logits


def speculative_generate(target_params, target_cfg: ModelConfig,
                         draft_params, draft_cfg: ModelConfig,
                         prompt: List[int], max_new_tokens: int,
                         k: int = 4) -> Tuple[List[int], SpecStats]:
    """Greedy speculative decoding. Returns (generated tokens, stats)."""
    stats = SpecStats()
    seq = list(prompt)
    out: List[int] = []
    while len(out) < max_new_tokens:
        # --- draft proposes up to k tokens autoregressively ---
        draft_seq = list(seq)
        proposal: List[int] = []
        for _ in range(min(k, max_new_tokens - len(out))):
            logits = _greedy_next(draft_params, draft_cfg,
                                  jnp.asarray([draft_seq], jnp.int32))
            stats.draft_calls += 1
            tok = int(jnp.argmax(logits[0, -1]))
            proposal.append(tok)
            draft_seq.append(tok)
        stats.proposed += len(proposal)

        # --- target verifies the whole proposal in one pass ---
        verify_seq = jnp.asarray([seq + proposal], jnp.int32)
        logits = _greedy_next(target_params, target_cfg, verify_seq)
        stats.target_calls += 1
        base = len(seq) - 1  # logits[base + i] predicts proposal[i]
        n_accept = 0
        for i, tok in enumerate(proposal):
            if int(jnp.argmax(logits[0, base + i])) == tok:
                n_accept += 1
            else:
                break
        stats.accepted += n_accept
        accepted = proposal[:n_accept]
        # the target's own next token (correction, or bonus when all match)
        next_tok = int(jnp.argmax(logits[0, base + n_accept]))
        new_tokens = accepted + [next_tok]
        out.extend(new_tokens)
        seq.extend(new_tokens)
    return out[:max_new_tokens], stats


def greedy_generate(params, cfg: ModelConfig, prompt: List[int],
                    max_new_tokens: int) -> List[int]:
    """Plain greedy reference."""
    seq = list(prompt)
    out: List[int] = []
    for _ in range(max_new_tokens):
        logits = _greedy_next(params, cfg, jnp.asarray([seq], jnp.int32))
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
        seq.append(tok)
    return out
