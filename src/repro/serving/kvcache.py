"""Paged KV-cache manager (PagedAttention-style, paper baseline [28]).

Fixed-size blocks of `block_size` tokens from a global pool; per-sequence
block tables; allocation is O(1) off a free list. The pool arrays are the
single source of truth for KV bytes and are HEAD-MAJOR
``(L, Hkv, num_blocks, block_size, hd)`` so one (layer, head, block) tile is
a contiguous ``(block_size, hd)`` DMA — the layout the paged flash-decode
kernel (``kernels/paged_decode_attention.py``) streams in place through
``block_table_batch()``. The engines never gather a dense per-step view on
the hot path: attention reads the pool through the table, and the new
token's K/V lands with one batched ``write_tokens`` scatter. ``gather()``
survives only as the dense test oracle.

Invariants (hypothesis-tested in tests/test_kvcache.py):
  * a block is owned by at most one sequence,
  * free + owned == total,
  * a sequence's capacity always covers its token count,
  * freeing returns exactly the blocks that were owned.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig


class OutOfBlocks(RuntimeError):
    pass


@dataclasses.dataclass
class PagedKVCache:
    cfg: ModelConfig
    num_blocks: int
    block_size: int = 16

    def __post_init__(self):
        hd = self.cfg.resolved_head_dim
        L = self._n_kv_layers()
        self.k_pool = jnp.zeros((L, self.cfg.num_kv_heads, self.num_blocks,
                                 self.block_size, hd), self.cfg.dtype)
        self.v_pool = jnp.zeros_like(self.k_pool)
        self.free: List[int] = list(range(self.num_blocks))
        self.tables: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}

    def _n_kv_layers(self) -> int:
        if self.cfg.family == "hybrid":
            return self.cfg.num_layers // self.cfg.shared_attn_period
        return self.cfg.num_layers

    # ---------------- allocation ----------------
    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n_tokens: int) -> bool:
        return len(self.free) >= self.blocks_needed(n_tokens)

    def allocate(self, seq_id: int, n_tokens: int) -> None:
        assert seq_id not in self.tables, f"seq {seq_id} already allocated"
        need = self.blocks_needed(n_tokens)
        if need > len(self.free):
            raise OutOfBlocks(f"need {need}, have {len(self.free)}")
        self.tables[seq_id] = [self.free.pop() for _ in range(need)]
        self.lengths[seq_id] = n_tokens

    def append_token(self, seq_id: int) -> None:
        n = self.lengths[seq_id] + 1
        if self.blocks_needed(n) > len(self.tables[seq_id]):
            if not self.free:
                raise OutOfBlocks("pool exhausted on append")
            self.tables[seq_id].append(self.free.pop())
        self.lengths[seq_id] = n

    def free_seq(self, seq_id: int) -> None:
        self.free.extend(self.tables.pop(seq_id))
        del self.lengths[seq_id]

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self.free)

    def utilisation(self) -> float:
        toks = sum(self.lengths.values())
        return toks / (self.num_blocks * self.block_size)

    # ---------------- hot-path views ----------------
    def block_table_batch(self, seq_ids: Sequence[int]
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched (B, nb) block table + (B,) lengths for the paged decode
        step. nb covers the longest live sequence; pad slots are block 0
        (their positions are ≥ cache_len, so the kernel masks them)."""
        lens = np.array([self.lengths[sid] for sid in seq_ids], np.int32)
        nb = max(1, self.blocks_needed(int(lens.max()))) if len(lens) else 1
        tables = np.zeros((len(seq_ids), nb), np.int32)
        for i, sid in enumerate(seq_ids):
            t = self.tables[sid][:nb]
            tables[i, :len(t)] = t
        return tables, lens

    # ---------------- data movement ----------------
    def write_prefill(self, seq_id: int, k: jax.Array, v: jax.Array) -> None:
        """k/v: HEAD-MAJOR (L, Hkv, S, hd) for this sequence's prompt — the
        prefill cache layout, stored without any transpose."""
        S = k.shape[2]
        table = self.tables[seq_id]
        pad = len(table) * self.block_size - S
        if pad:
            k = jnp.pad(k, [(0, 0), (0, 0), (0, pad), (0, 0)])
            v = jnp.pad(v, [(0, 0), (0, 0), (0, pad), (0, 0)])
        kb = k.reshape(k.shape[0], k.shape[1], len(table), self.block_size,
                       k.shape[3])
        vb = v.reshape(*kb.shape)
        idx = jnp.asarray(table)
        self.k_pool = self.k_pool.at[:, :, idx].set(kb)
        self.v_pool = self.v_pool.at[:, :, idx].set(vb)

    def write_token(self, seq_id: int, k: jax.Array, v: jax.Array,
                    position: int) -> None:
        """k/v: (L, Hkv, hd) for one token at `position` (0-based)."""
        blk = self.tables[seq_id][position // self.block_size]
        off = position % self.block_size
        self.k_pool = self.k_pool.at[:, :, blk, off].set(k)
        self.v_pool = self.v_pool.at[:, :, blk, off].set(v)

    def write_tokens(self, seq_ids: Sequence[int], k_new: jax.Array,
                     v_new: jax.Array, positions: Sequence[int]) -> None:
        """Batched scatter of one token per sequence — the decode step's
        single pool write. k_new/v_new: (L, B, Hkv, hd) as produced by the
        model's decode updates; positions: per-sequence 0-based slots
        (the pre-append lengths). Replaces the per-sequence host loop."""
        blk = jnp.asarray([self.tables[sid][p // self.block_size]
                           for sid, p in zip(seq_ids, positions)], jnp.int32)
        off = jnp.asarray([p % self.block_size for p in positions], jnp.int32)
        kn = jnp.swapaxes(k_new, 1, 2)  # (L, Hkv, B, hd)
        vn = jnp.swapaxes(v_new, 1, 2)
        self.k_pool = self.k_pool.at[:, :, blk, off].set(kn)
        self.v_pool = self.v_pool.at[:, :, blk, off].set(vn)

    def gather(self, seq_ids: List[int], pad_len: int
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Dense (L, B, pad_len, Hkv, hd) views + lengths for the batch.

        TEST ORACLE ONLY: the serving engines attend over the pool in place
        (block_table_batch + the paged kernel); this materialised copy is
        exactly the per-step traffic the paged path eliminates."""
        nb = -(-pad_len // self.block_size)
        tables = np.zeros((len(seq_ids), nb), np.int32)
        lens = np.zeros((len(seq_ids),), np.int32)
        for i, sid in enumerate(seq_ids):
            t = self.tables[sid][:nb]
            tables[i, :len(t)] = t
            lens[i] = self.lengths[sid]
        idx = jnp.asarray(tables)      # (B, nb)
        k = self.k_pool[:, :, idx]     # (L, Hkv, B, nb, bs, hd)
        v = self.v_pool[:, :, idx]
        L, Hkv = k.shape[0], k.shape[1]
        B = len(seq_ids)
        k = jnp.transpose(k, (0, 2, 3, 4, 1, 5)).reshape(
            L, B, nb * self.block_size, Hkv, -1)[:, :, :pad_len]
        v = jnp.transpose(v, (0, 2, 3, 4, 1, 5)).reshape(
            L, B, nb * self.block_size, Hkv, -1)[:, :, :pad_len]
        return k, v, jnp.asarray(lens)
