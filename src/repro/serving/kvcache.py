"""Paged KV-cache manager (PagedAttention-style, paper baseline [28]).

Fixed-size blocks of `block_size` tokens from a global pool; per-sequence
block tables; allocation is O(1) off a free list. The pool arrays are the
single source of truth for KV bytes and are HEAD-MAJOR
``(L, Hkv, num_blocks, block_size, hd)`` so one (layer, head, block) tile is
a contiguous ``(block_size, hd)`` DMA — the layout the paged flash-decode
kernel (``kernels/paged_decode_attention.py``) streams in place through
``block_table_batch()``. The engines never gather a dense per-step view on
the hot path: attention reads the pool through the table, and the new
token's K/V lands with one batched ``write_tokens`` scatter. ``gather()``
survives only as the dense test oracle.

Cross-chip block sharding (``n_shards > 1``): the pool's block axis is cut
into `n_shards` contiguous ranges of ``num_blocks // n_shards`` blocks —
shard s owns global ids [s·npb, (s+1)·npb), exactly the slice shard_map's
block-axis partition hands each attention-pool device. Allocation places a
sequence's i-th block ROUND-ROBIN on shard i mod n_shards, so a single
`long_500k` request's KV spans every chip with per-shard live-token counts
within one block of even. ``block_table_shards()`` exposes the per-shard
LOCAL tables plus each slot's global base position (the §4.2.2
partial-combine backends need true positions because a shard's walk is
non-contiguous in the sequence).

Prefix sharing / copy-on-write (refcounted blocks): identical prompt
prefixes map multiple sequences' block tables onto the SAME physical blocks
(``share_blocks``), so the pool admits strictly more concurrent requests
for the same memory — the paper's scarce resource (§3, §4.2). Every block
carries a reference count; a shared block is freed only when the last
referencing sequence releases it, and the first divergent write into a
shared block (``append_token`` growing into a shared partial tail, or a
re-prefill over shared slots) triggers copy-on-write: the writer gets a
private copy of just that block (placed by the SAME round-robin slot rule,
so the shard-balance invariant survives forking), the donor keeps the
original untouched.

Quantized pool (``kv_dtype="int8"``): the pool arrays store int8 values
with per-token, per-kv-head fp32 scales in sidecar pools
``(L, Hkv, num_blocks, block_size)`` that mirror the value pools' block
axis exactly — *scales follow blocks*. Every write path quantizes at write
time (symmetric max-abs, ``models/kv_quant.py``); every block-level
operation (copy-on-write fork, free, quarantine, round-robin placement,
handoff export/import) moves the scale tile with its value tile, so the
refcount/CoW/quarantine invariants hold for the scale arrays by
construction. The decode/prefill-chunk hot paths hand the int8 pools plus
the scale pools to the attention kernels, which fuse dequantization into
the score/PV products as a broadcast multiply per tile — no dense
dequantized K/V slab is ever materialised (the no-densify invariant
extends to *no-dense-dequant*). Only the admission-time prefix gathers
(``gather_prefix``, one per admission) and the dense test oracle
dequantize to a materialised array.

Shard quarantine (fault recovery): a shard the engine declares dead is
masked out of the allocator (``quarantine_shard``) — the round-robin slot
rule walks the LIVE shards only, and every capacity view (``num_free``,
``capacity_blocks``, ``can_allocate``) drops to the survivors, so the
admission/headroom guards honour degraded capacity. The dead shard's free
list is retained: victim sequences release their refs through the normal
refcount path (a block shared by K sharers returns once, when the last ref
drops) and the blocks drain back in place, unallocatable until
``rejoin_shard`` restores the shard.

Invariants (hypothesis-tested in tests/test_kvcache.py and
tests/test_fault_tolerance.py):
  * a block's refcount == the number of live tables referencing it,
  * free + referenced == total (a block is free iff its refcount is zero),
  * an UNSHARED block is owned by at most one sequence,
  * a sequence's capacity always covers its token count,
  * freeing decrements refcounts and returns exactly the blocks that hit
    zero, each to the shard that owns it,
  * a writer never mutates a block another live sequence references
    (copy-on-write forks first),
  * no allocation ever lands on a quarantined shard, and rejoin restores
    exactly the blocks that drained back to the shard's free list.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import kv_quant
from repro.models.common import ModelConfig

# Base-position sentinel for table slots a shard does not own — the single
# definition lives with the kernel; its numeric value is load-bearing for
# mask correctness across the kernel, jnp partials, and the engines.
from repro.kernels.paged_decode_attention import POS_PAD  # noqa: F401,E402


class OutOfBlocks(RuntimeError):
    pass


class PoolExhausted(OutOfBlocks):
    """Pool-exhaustion with full context: which request hit the wall, how
    many tokens are live in the pool, and how many blocks remain free —
    the signal the preemption-capable scheduling policy consumes (and the
    clear error FCFS surfaces instead of failing deep in the allocator).

    ``quarantined_shards`` / ``live_shards`` carry the DEGRADED-capacity
    context when shard faults have quarantined part of the pool: an
    operator reading the error can distinguish "pool too small" (no
    quarantined shards) from "pool degraded" (exhaustion against the
    surviving shards only — e.g. during post-fault re-admission).

    Subclasses :class:`OutOfBlocks` so pre-existing handlers keep working.
    """

    def __init__(self, message: str, *, rid: Optional[int] = None,
                 live_tokens: int = 0, free_blocks: int = 0,
                 quarantined_shards: Tuple[int, ...] = (),
                 live_shards: Tuple[int, ...] = ()):
        super().__init__(message)
        self.rid = rid
        self.live_tokens = live_tokens
        self.free_blocks = free_blocks
        self.quarantined_shards = tuple(quarantined_shards)
        self.live_shards = tuple(live_shards)

    @property
    def degraded(self) -> bool:
        """True when the exhaustion happened against a fault-degraded pool
        (some shards quarantined) rather than a simply-too-small one."""
        return bool(self.quarantined_shards)


@dataclasses.dataclass
class PagedKVCache:
    cfg: ModelConfig
    num_blocks: int
    block_size: int = 16
    n_shards: int = 1
    kv_dtype: str = "bf16"             # "bf16" (cfg.dtype) | "int8"

    def __post_init__(self):
        if self.num_blocks % self.n_shards:
            raise ValueError(
                f"num_blocks ({self.num_blocks}) must divide evenly over "
                f"n_shards ({self.n_shards}) — the pool's block axis is "
                f"sharded contiguously over the attention-pool mesh axis")
        if self.kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"kv_dtype must be 'bf16' or 'int8'; "
                             f"got {self.kv_dtype!r}")
        hd = self.cfg.resolved_head_dim
        L = self._n_kv_layers()
        pool_dtype = jnp.int8 if self.kv_dtype == "int8" else self.cfg.dtype
        self.k_pool = jnp.zeros((L, self.cfg.num_kv_heads, self.num_blocks,
                                 self.block_size, hd), pool_dtype)
        self.v_pool = jnp.zeros_like(self.k_pool)
        # int8: per-token, per-kv-head fp32 scale pools mirroring the value
        # pools' block axis — block-level ops move scale tiles with their
        # value tiles ("scales follow blocks"). None on the bf16 path.
        if self.kv_dtype == "int8":
            self.k_scale = jnp.zeros((L, self.cfg.num_kv_heads,
                                      self.num_blocks, self.block_size),
                                     jnp.float32)
            self.v_scale = jnp.zeros_like(self.k_scale)
        else:
            self.k_scale = None
            self.v_scale = None
        npb = self.blocks_per_shard
        # per-shard free lists: shard s owns global ids [s·npb, (s+1)·npb)
        self._free_shard: List[List[int]] = [
            list(range(s * npb, (s + 1) * npb)) for s in range(self.n_shards)]
        # shards quarantined by the fault-recovery path: their free lists
        # are retained (blocks drain back in as victims release refs) but
        # masked out of every allocation / capacity view until rejoin
        self._quarantined: set = set()
        self.tables: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}
        # block id -> number of live tables referencing it (only blocks that
        # are currently referenced have an entry; free blocks have none)
        self.refcounts: Dict[int, int] = {}
        # seq -> block ids it BORROWED via share_blocks (vs allocated
        # itself). A borrower's prefill-write into a still-shared borrowed
        # block copy-on-writes; the original allocator's write is the
        # canonical fill the borrowers are waiting for (within one admission
        # wave a recipient maps the donor's blocks BEFORE the donor's
        # prefill has stored them) and goes through in place.
        self._borrowed: Dict[int, set] = {}
        # cumulative counters (benchmarks / EngineStats surface them)
        self.blocks_shared_total = 0   # refcount bumps via share_blocks
        self.cow_forks = 0             # copy-on-write block copies
        # memoised gather indices, keyed by the CONTENT of the gathered
        # table slice (the physical block-id tuple): a prefix-sharing
        # admission wave's K sharers map onto the same donor blocks, so
        # they hit one entry instead of K host->device conversions, and a
        # chunked prefill reuses its growing prefix without rebuilding the
        # array each chunk. Content keys can never go stale — the value is
        # a pure function of the ids (CoW/free/realloc just miss or alias
        # harmlessly); the dict is cleared when it outgrows its cap.
        self._gather_idx_cache: Dict[Tuple[int, ...], jax.Array] = {}

    @property
    def blocks_per_shard(self) -> int:
        return self.num_blocks // self.n_shards

    @property
    def free(self) -> List[int]:
        """All ALLOCATABLE free block ids (flattened across live shards;
        a quarantined shard's drained blocks are excluded) — read-only."""
        return [b for s, shard in enumerate(self._free_shard)
                for b in shard if s not in self._quarantined]

    @property
    def num_free(self) -> int:
        """Count of allocatable free blocks — O(shards), unlike
        ``len(self.free)`` which materialises every id (the per-iteration
        pressure checks run this on the serving hot loop). Quarantined
        shards contribute nothing."""
        return sum(len(s) for i, s in enumerate(self._free_shard)
                   if i not in self._quarantined)

    # ---------------- shard health (fault-recovery surface) ----------------
    @property
    def live_shards(self) -> List[int]:
        """Shards currently accepting allocations (not quarantined)."""
        return [s for s in range(self.n_shards) if s not in self._quarantined]

    @property
    def quarantined_shards(self) -> Tuple[int, ...]:
        return tuple(sorted(self._quarantined))

    @property
    def capacity_blocks(self) -> int:
        """Total blocks the pool can currently hold — ``num_blocks`` when
        healthy, the surviving shards' share when degraded. Every
        "can this request EVER fit" check must use this, not
        ``num_blocks``: admission guards and stall detection otherwise
        promise capacity a dead shard no longer provides."""
        return self.blocks_per_shard * (self.n_shards -
                                        len(self._quarantined))

    def seqs_on_shard(self, shard: int) -> List[int]:
        """Live sequences holding at least one block on `shard` — the
        victim set a shard death forces through recovery (a sequence that
        merely BORROWS a donor's block there is a victim too: its context
        includes the lost bytes)."""
        lo, hi = shard * self.blocks_per_shard, \
            (shard + 1) * self.blocks_per_shard
        return sorted(sid for sid, table in self.tables.items()
                      if any(lo <= b < hi for b in table))

    def quarantine_shard(self, shard: int) -> None:
        """Mask `shard` out of the allocator: no new block lands on it and
        every capacity view (``num_free`` / ``capacity_blocks`` /
        ``can_allocate``) drops to the surviving shards. Its free list is
        kept — blocks drain back as the recovery path releases victim
        refs — but stays unallocatable until :meth:`rejoin_shard`."""
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} outside [0, {self.n_shards})")
        self._quarantined.add(shard)

    def rejoin_shard(self, shard: int) -> None:
        """Restore a quarantined shard's capacity (replacement hardware /
        restarted worker). Only blocks that drained back to its free list
        return — any block a live sequence somehow still references stays
        referenced (refcounts are the single source of truth)."""
        self._quarantined.discard(shard)

    def shard_of(self, block_id: int) -> int:
        return block_id // self.blocks_per_shard

    def _pop_block(self, seq_slot: int) -> int:
        """Pop a free block for a sequence's `seq_slot`-th table entry:
        round-robin over the LIVE shards (quarantined shards are masked
        out — the shard-masked round-robin keeps the balance invariant
        over survivors), falling back to the least-loaded (most-free)
        live shard when the target is exhausted."""
        live = self.live_shards
        if not live:
            raise OutOfBlocks("every pool shard is quarantined")
        target = live[seq_slot % len(live)]
        if not self._free_shard[target]:
            target = max(live, key=lambda s: len(self._free_shard[s]))
            if not self._free_shard[target]:
                raise OutOfBlocks("pool exhausted")
        return self._free_shard[target].pop()

    def _n_kv_layers(self) -> int:
        if self.cfg.family == "hybrid":
            return self.cfg.num_layers // self.cfg.shared_attn_period
        return self.cfg.num_layers

    # ---------------- allocation ----------------
    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.num_free >= self.blocks_needed(n_tokens)

    def _degraded_kw(self) -> Dict:
        """PoolExhausted kwargs carrying the shard-health context — every
        raise site attaches these so operators can tell "pool too small"
        from "pool degraded by a shard fault"."""
        return {"quarantined_shards": self.quarantined_shards,
                "live_shards": tuple(self.live_shards)}

    def _degraded_note(self) -> str:
        if not self._quarantined:
            return ""
        q = sorted(self._quarantined)
        return (f" [pool DEGRADED: shard(s) {q} quarantined after a fault; "
                f"{len(self.live_shards)} of {self.n_shards} shards live, "
                f"capacity {self.capacity_blocks} of {self.num_blocks} "
                f"blocks]")

    def allocate(self, seq_id: int, n_tokens: int) -> None:
        """Give `seq_id` capacity for `n_tokens`. A fresh sequence gets a new
        round-robin table; an EXISTING sequence is EXTENDED — fresh private
        blocks are appended until capacity covers `n_tokens`. Extension
        serves both admission flavours: a table seeded by
        :meth:`share_blocks` grows past its shared prefix (admission charges
        only the unshared suffix against the free list), and a CHUNKED
        prefill grows its table incrementally, one chunk's blocks per engine
        iteration, so peak up-front allocation is O(chunk) not O(prompt)."""
        if seq_id in self.tables:       # extend (share-seeded or chunked)
            table = self.tables[seq_id]
            assert n_tokens >= self.lengths[seq_id], \
                f"seq {seq_id}: cannot shrink allocation"
            need = self.blocks_needed(n_tokens) - len(table)
            have = self.num_free
            if need > have:
                raise PoolExhausted(
                    f"extending seq {seq_id}: need {need}, have {have}"
                    f"{self._degraded_note()}",
                    rid=seq_id, live_tokens=sum(self.lengths.values()),
                    free_blocks=have, **self._degraded_kw())
            for i in range(len(table), len(table) + need):
                b = self._pop_block(i)
                self.refcounts[b] = 1
                table.append(b)
            self.lengths[seq_id] = n_tokens
            return
        need = self.blocks_needed(n_tokens)
        have = self.num_free
        if need > have:
            raise PoolExhausted(
                f"allocating seq {seq_id}: need {need}, have {have}"
                f"{self._degraded_note()}",
                rid=seq_id, live_tokens=sum(self.lengths.values()),
                free_blocks=have, **self._degraded_kw())
        # round-robin over shards: the sequence's i-th block lands on shard
        # i mod n_shards, so its KV spans every pool chip near-evenly
        table = [self._pop_block(i) for i in range(need)]
        for b in table:
            self.refcounts[b] = 1
        self.tables[seq_id] = table
        self.lengths[seq_id] = n_tokens

    def share_blocks(self, src_rid: int, dst_rid: int, n_tokens: int) -> int:
        """Map a NEW sequence `dst_rid`'s table onto `src_rid`'s existing
        physical blocks covering its first `n_tokens` — the prefix-sharing
        entry point. No pool memory is consumed: the shared blocks'
        refcounts are bumped instead. `n_tokens` need not be block-aligned:
        a trailing partial block is shared too (the fork case — the first
        divergent write into it copy-on-writes). Returns the number of
        blocks shared. Extend the table afterwards with :meth:`allocate`."""
        assert dst_rid not in self.tables, \
            f"seq {dst_rid} already allocated — share_blocks seeds new tables"
        if n_tokens < 1 or n_tokens > self.lengths[src_rid]:
            raise ValueError(
                f"share_blocks: n_tokens={n_tokens} outside donor {src_rid}'s"
                f" stored range [1, {self.lengths[src_rid]}]")
        shared = self.tables[src_rid][:self.blocks_needed(n_tokens)]
        for b in shared:
            self.refcounts[b] += 1
        self.tables[dst_rid] = list(shared)
        self.lengths[dst_rid] = n_tokens
        self._borrowed[dst_rid] = set(shared)
        self.blocks_shared_total += len(shared)
        return len(shared)

    def _cow_block(self, seq_id: int, slot: int) -> None:
        """Copy-on-write fork of `seq_id`'s table slot: pop a private block
        (same round-robin slot rule, so shard balance survives), copy the
        physical tile, decrement the donor refcount. The donor's data is
        never touched. Raises OutOfBlocks when no block is free."""
        old = self.tables[seq_id][slot]
        new = self._pop_block(slot)
        self.refcounts[old] -= 1
        self.refcounts[new] = 1
        self.tables[seq_id][slot] = new
        self._borrowed.get(seq_id, set()).discard(old)
        self.k_pool = self.k_pool.at[:, :, new].set(self.k_pool[:, :, old])
        self.v_pool = self.v_pool.at[:, :, new].set(self.v_pool[:, :, old])
        if self.k_scale is not None:   # the scale tile forks with its block
            self.k_scale = self.k_scale.at[:, :, new].set(
                self.k_scale[:, :, old])
            self.v_scale = self.v_scale.at[:, :, new].set(
                self.v_scale[:, :, old])
        self.cow_forks += 1

    def blocks_to_append(self, seq_id: int) -> int:
        """Fresh blocks the next :meth:`append_token` will consume: 1 when
        the sequence must grow its table OR copy-on-write a shared tail
        block, else 0 — the engine's pool-pressure check must count both."""
        n = self.lengths[seq_id]
        table = self.tables[seq_id]
        if self.blocks_needed(n + 1) > len(table):
            return 1
        if self.refcounts[table[n // self.block_size]] > 1:
            return 1
        return 0

    def append_token(self, seq_id: int) -> None:
        n = self.lengths[seq_id] + 1
        table = self.tables[seq_id]
        try:
            if self.blocks_needed(n) > len(table):
                b = self._pop_block(len(table))
                self.refcounts[b] = 1
                table.append(b)
            else:
                # the new token lands in an existing block: fork it first if
                # another live sequence still references it (shared tail)
                slot = (n - 1) // self.block_size
                if self.refcounts[table[slot]] > 1:
                    self._cow_block(seq_id, slot)
        except OutOfBlocks:
            free = self.num_free
            live = sum(self.lengths.values())
            raise PoolExhausted(
                f"KV pool exhausted growing request {seq_id} to token "
                f"{n}: {live} live tokens across {len(self.tables)} "
                f"sequences occupy all {self.capacity_blocks} usable "
                f"blocks ({free} free){self._degraded_note()} — preempt "
                f"a victim or raise num_blocks",
                rid=seq_id, live_tokens=live, free_blocks=free,
                **self._degraded_kw()) from None
        self.lengths[seq_id] = n

    def free_seq(self, seq_id: int) -> None:
        for b in self.tables.pop(seq_id):
            self.refcounts[b] -= 1
            if self.refcounts[b] == 0:
                del self.refcounts[b]
                self._free_shard[self.shard_of(b)].append(b)
        self._borrowed.pop(seq_id, None)
        del self.lengths[seq_id]

    @property
    def used_blocks(self) -> int:
        """PHYSICAL blocks in use — a block shared by K sequences counts
        once (the memory actually occupied; what sharing saves)."""
        return self.num_blocks - sum(len(s) for s in self._free_shard)

    @property
    def pool_bytes_resident(self) -> int:
        """Resident bytes of the whole pool allocation: value pools plus
        (int8) the fp32 scale sidecars — the §3.1 capacity quantity
        ``EngineStats.kv_pool_bytes_resident`` surfaces. int8 ≈ 0.5× bf16
        for hd ≫ 4 (hd + 4 scale bytes vs 2·hd per token-head)."""
        total = int(self.k_pool.nbytes + self.v_pool.nbytes)
        if self.k_scale is not None:
            total += int(self.k_scale.nbytes + self.v_scale.nbytes)
        return total

    def bytes_per_live_token(self) -> int:
        """Pool bytes one token of context occupies (K + V across the KV
        layers, scale sidecars included) — the per-step KV read accounting
        unit (`kv_bytes_read_per_step ≈ live_tokens · this`)."""
        L, Hkv, _, _, hd = self.k_pool.shape
        e = self.k_pool.dtype.itemsize
        per = 2 * L * Hkv * hd * e
        if self.k_scale is not None:
            per += 2 * L * Hkv * 4
        return per

    def utilisation(self) -> float:
        toks = sum(self.lengths.values())
        return toks / (self.num_blocks * self.block_size)

    def unique_live_tokens(self, seq_ids: Optional[Sequence[int]] = None
                           ) -> int:
        """Live tokens over UNIQUE physical blocks — a block shared by K
        sequences counts once, at the deepest fill any sharer reaches (the
        residency/ideal-DMA accounting; ``sum(lengths)`` double-counts
        shared prefixes)."""
        if seq_ids is None:
            seq_ids = list(self.tables)
        per_block: Dict[int, int] = {}
        bs = self.block_size
        for sid in seq_ids:
            length = self.lengths[sid]
            for j, g in enumerate(self.tables[sid]):
                t = min(bs, max(0, length - j * bs))
                if t > per_block.get(g, 0):
                    per_block[g] = t
        return sum(per_block.values())

    # ---------------- hot-path views ----------------
    def block_table_batch(self, seq_ids: Sequence[int]
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched (B, nb) block table + (B,) lengths for the paged decode
        step. nb covers the longest live sequence; pad slots are block 0
        (their positions are ≥ cache_len, so the kernel masks them)."""
        lens = np.array([self.lengths[sid] for sid in seq_ids], np.int32)
        nb = max(1, self.blocks_needed(int(lens.max()))) if len(lens) else 1
        tables = np.zeros((len(seq_ids), nb), np.int32)
        for i, sid in enumerate(seq_ids):
            t = self.tables[sid][:nb]
            tables[i, :len(t)] = t
        return tables, lens

    def block_table_shards(self, seq_ids: Sequence[int]
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-shard LOCAL block tables for the block-parallel decode step.

        Returns (local_tables, local_positions, shard_tokens):
          * local_tables (n_shards, B, nbl) int32 — pool-block ids LOCAL to
            each shard's contiguous slice (global − shard·blocks_per_shard),
            i.e. direct indices into the (npb, block_size, hd) pool slice
            shard_map hands that device. Pad slots are 0.
          * local_positions (n_shards, B, nbl) int32 — each slot's global
            base position in the sequence (slot index in the global table ×
            block_size); POS_PAD on pad slots so every mask kills them. A
            shard's walk is non-contiguous in the sequence, so these — not
            slot·block_size — anchor the causal/window/sink masks.
          * shard_tokens (n_shards, B) int32 — live tokens per (shard, seq):
            the per-chip KV-read accounting (round-robin placement keeps
            max−min ≤ block_size for any single sequence). A PHYSICAL block
            shared by several sequences in the batch is counted ONCE, for
            the first sequence that references it — a prefix-shared block
            lives on whatever shard the donor placed it and its bytes are
            resident (and streamable) once per chip, not once per sharer.
        """
        B = len(seq_ids)
        n, npb, bs = self.n_shards, self.blocks_per_shard, self.block_size
        per = [[[] for _ in range(B)] for _ in range(n)]  # (local id, base)
        shard_tokens = np.zeros((n, B), np.int32)
        # deepest fill across sharers, same rule as shard_live_tokens /
        # unique_live_tokens (a partial tail shared at different depths is
        # resident at the donor's deeper fill regardless of batch order)
        fill: Dict[int, int] = {}
        for sid in seq_ids:
            length = self.lengths[sid]
            for j, g in enumerate(self.tables[sid]):
                t = min(bs, max(0, length - j * bs))
                if t > fill.get(g, 0):
                    fill[g] = t
        counted: set = set()
        for i, sid in enumerate(seq_ids):
            for j, g in enumerate(self.tables[sid]):
                s = self.shard_of(g)
                per[s][i].append((g - s * npb, j * bs))
                if g not in counted:
                    counted.add(g)
                    shard_tokens[s, i] += fill[g]
        nbl = max([1] + [len(per[s][i]) for s in range(n) for i in range(B)])
        local_tables = np.zeros((n, B, nbl), np.int32)
        local_positions = np.full((n, B, nbl), POS_PAD, np.int32)
        for s in range(n):
            for i in range(B):
                for j, (lb, base) in enumerate(per[s][i]):
                    local_tables[s, i, j] = lb
                    local_positions[s, i, j] = base
        return local_tables, local_positions, shard_tokens

    def shard_live_tokens(self, seq_ids: Optional[Sequence[int]] = None
                          ) -> np.ndarray:
        """(n_shards,) live tokens held per pool shard (all sequences by
        default) — the per-chip KV balance the block benchmark reports.
        A shared physical block counts once, at the deepest fill any sharer
        reaches (residency, not per-sequence reads)."""
        if seq_ids is None:
            seq_ids = list(self.tables)
        totals = np.zeros((self.n_shards,), np.int64)
        bs = self.block_size
        per_block: Dict[int, int] = {}
        for sid in seq_ids:
            length = self.lengths[sid]
            for j, g in enumerate(self.tables[sid]):
                t = min(bs, max(0, length - j * bs))
                if t > per_block.get(g, 0):
                    per_block[g] = t
        for g, t in per_block.items():
            totals[self.shard_of(g)] += t
        return totals

    # ---------------- data movement ----------------
    def write_prefill(self, seq_id: int, k: jax.Array, v: jax.Array,
                      start_token: int = 0) -> None:
        """k/v: HEAD-MAJOR (L, Hkv, S, hd) for this sequence's prompt — the
        prefill cache layout, stored without any transpose.

        ``start_token`` (block-aligned) writes the slice starting at that
        position — the prefix-sharing path prefills only the unshared
        suffix, leaving the shared prefix blocks untouched. A re-prefill
        into a still-shared BORROWED block copy-on-write-forks it first (a
        divergent write must never corrupt the donor); a write by the
        block's original allocator goes through in place — it is the
        canonical fill recipients that shared within the same admission
        wave are waiting on."""
        if start_token % self.block_size:
            raise ValueError(
                f"write_prefill start_token ({start_token}) must be "
                f"block-aligned (block_size={self.block_size})")
        S = k.shape[2]
        table = self.tables[seq_id]
        if start_token + S > len(table) * self.block_size:
            free = self.num_free
            live = sum(self.lengths.values())
            raise PoolExhausted(
                f"request {seq_id}: write_prefill of {S} tokens at "
                f"{start_token} exceeds its allocated {len(table)} blocks × "
                f"{self.block_size} (= {len(table) * self.block_size} "
                f"tokens); pool holds {live} live tokens with {free} of "
                f"{self.num_blocks} blocks free{self._degraded_note()} — "
                f"allocate() must cover the prompt first", rid=seq_id,
                live_tokens=live, free_blocks=free, **self._degraded_kw())
        # within capacity, the token count must agree EXACTLY with the
        # sequence's allocated length — a short write used to zero-pad the
        # tail block silently while `lengths` claimed those tokens stored,
        # so decode read zeros as real context (and a long one overwrote
        # slack slots `lengths` never covered)
        expected = self.lengths[seq_id] - start_token
        if S != expected or k.shape != v.shape:
            raise ValueError(
                f"request {seq_id}: write_prefill got k/v of {S} tokens "
                f"(k {tuple(k.shape)}, v {tuple(v.shape)}) at start_token "
                f"{start_token}, but the sequence's allocated length is "
                f"{self.lengths[seq_id]} — expected exactly {expected} "
                f"tokens; allocate() the true token count first (chunked "
                f"prefill extends the allocation before each chunk write)")
        b0 = start_token // self.block_size
        nb = self.blocks_needed(S)
        borrowed = self._borrowed.get(seq_id, ())
        for slot in range(b0, b0 + nb):
            if table[slot] in borrowed and self.refcounts[table[slot]] > 1:
                self._cow_block(seq_id, slot)
        ks = vs = None
        if self.kv_dtype == "int8":    # quantize at write time, pre-pad
            k, ks = kv_quant.quantize_kv(k)
            v, vs = kv_quant.quantize_kv(v)
        pad = nb * self.block_size - S
        if pad:
            k = jnp.pad(k, [(0, 0), (0, 0), (0, pad), (0, 0)])
            v = jnp.pad(v, [(0, 0), (0, 0), (0, pad), (0, 0)])
        kb = k.reshape(k.shape[0], k.shape[1], nb, self.block_size,
                       k.shape[3])
        vb = v.reshape(*kb.shape)
        idx = jnp.asarray(table[b0:b0 + nb])
        self.k_pool = self.k_pool.at[:, :, idx].set(kb)
        self.v_pool = self.v_pool.at[:, :, idx].set(vb)
        if ks is not None:
            if pad:
                ks = jnp.pad(ks, [(0, 0), (0, 0), (0, pad)])
                vs = jnp.pad(vs, [(0, 0), (0, 0), (0, pad)])
            shp = (ks.shape[0], ks.shape[1], nb, self.block_size)
            self.k_scale = self.k_scale.at[:, :, idx].set(ks.reshape(shp))
            self.v_scale = self.v_scale.at[:, :, idx].set(vs.reshape(shp))

    def write_prefill_chunk(self, seq_id: int, k: jax.Array, v: jax.Array,
                            start_token: int) -> None:
        """Incremental chunk write — the chunked-prefill data path: extend
        the sequence's allocation to cover exactly this chunk (fresh blocks
        are popped as the chunk completes, so peak up-front allocation is
        one chunk, not the prompt), then scatter the chunk's head-major
        (L, Hkv, C, hd) K/V at `start_token` (block-aligned; only the FINAL
        chunk may be a partial block). Raises the same contextual
        :class:`PoolExhausted` as the decode path when the pool cannot
        cover the chunk's new blocks."""
        target = start_token + k.shape[2]
        if target > self.lengths.get(seq_id, 0):
            try:
                self.allocate(seq_id, target)
            except OutOfBlocks:
                free = self.num_free
                live = sum(self.lengths.values())
                raise PoolExhausted(
                    f"KV pool exhausted growing request {seq_id}'s chunked "
                    f"prefill to token {target}: {live} live tokens across "
                    f"{len(self.tables)} sequences occupy all "
                    f"{self.capacity_blocks} usable blocks ({free} free)"
                    f"{self._degraded_note()} — preempt a victim or raise "
                    f"num_blocks", rid=seq_id, live_tokens=live,
                    free_blocks=free, **self._degraded_kw()) from None
        self.write_prefill(seq_id, k, v, start_token=start_token)

    def write_token(self, seq_id: int, k: jax.Array, v: jax.Array,
                    position: int) -> None:
        """k/v: (L, Hkv, hd) for one token at `position` (0-based)."""
        slot = position // self.block_size
        if self.refcounts[self.tables[seq_id][slot]] > 1:
            self._cow_block(seq_id, slot)      # never write a donor's block
        blk = self.tables[seq_id][slot]
        off = position % self.block_size
        if self.kv_dtype == "int8":
            k, ks = kv_quant.quantize_token(k)
            v, vs = kv_quant.quantize_token(v)
            self.k_scale = self.k_scale.at[:, :, blk, off].set(ks)
            self.v_scale = self.v_scale.at[:, :, blk, off].set(vs)
        self.k_pool = self.k_pool.at[:, :, blk, off].set(k)
        self.v_pool = self.v_pool.at[:, :, blk, off].set(v)

    def write_tokens(self, seq_ids: Sequence[int], k_new: jax.Array,
                     v_new: jax.Array, positions: Sequence[int]) -> None:
        """Batched scatter of one token per sequence — the decode step's
        single pool write. k_new/v_new: (L, B, Hkv, hd) as produced by the
        model's decode updates; positions: per-sequence 0-based slots
        (the pre-append lengths). Replaces the per-sequence host loop.
        Shared targets copy-on-write first (``append_token`` normally forked
        already — this is the allocator-level guarantee)."""
        for sid, p in zip(seq_ids, positions):
            slot = p // self.block_size
            if self.refcounts[self.tables[sid][slot]] > 1:
                self._cow_block(sid, slot)
        blk = jnp.asarray([self.tables[sid][p // self.block_size]
                           for sid, p in zip(seq_ids, positions)], jnp.int32)
        off = jnp.asarray([p % self.block_size for p in positions], jnp.int32)
        kn = jnp.swapaxes(k_new, 1, 2)  # (L, Hkv, B, hd)
        vn = jnp.swapaxes(v_new, 1, 2)
        if self.kv_dtype == "int8":
            kn, kns = kv_quant.quantize_token(kn)   # scales (L, Hkv, B)
            vn, vns = kv_quant.quantize_token(vn)
            self.k_scale = self.k_scale.at[:, :, blk, off].set(kns)
            self.v_scale = self.v_scale.at[:, :, blk, off].set(vns)
        self.k_pool = self.k_pool.at[:, :, blk, off].set(kn)
        self.v_pool = self.v_pool.at[:, :, blk, off].set(vn)

    def gather_prefix_indices(self, seq_id: int, n_tokens: int) -> jax.Array:
        """(nb,) int32 device array of the pool-block ids covering this
        sequence's first `n_tokens` (block-aligned) — the index operand of
        every prefix gather (suffix prefill, chunked prefill, recompute).

        MEMOISED by block-id content: a prefix-sharing admission wave's K
        recipients all map onto the donor's physical blocks, so the whole
        wave (and every later chunk / recompute over the same prefix) reuses
        ONE converted array instead of re-building it per call. Keys are the
        ids themselves, so copy-on-write forks or free/re-allocate cycles
        can never serve a wrong value — at worst they miss."""
        if n_tokens % self.block_size:
            raise ValueError(
                f"gather_prefix n_tokens ({n_tokens}) must be block-aligned "
                f"(block_size={self.block_size})")
        key = tuple(self.tables[seq_id][:n_tokens // self.block_size])
        idx = self._gather_idx_cache.get(key)
        if idx is None:
            if len(self._gather_idx_cache) > 4096:   # bound the memo
                self._gather_idx_cache.clear()
            idx = jnp.asarray(key, jnp.int32)
            self._gather_idx_cache[key] = idx
        return idx

    def gather_prefix(self, seq_id: int, n_tokens: int
                      ) -> Tuple[jax.Array, jax.Array]:
        """HEAD-MAJOR (L, Hkv, n_tokens, hd) K/V of this sequence's first
        `n_tokens` (block-aligned) — the context operand of the prefix-
        cached suffix prefill. One gather per ADMISSION (not per decode
        step), so the no-densify invariant on the decode hot path holds."""
        idx = self.gather_prefix_indices(seq_id, n_tokens)
        L, Hkv = self.k_pool.shape[0], self.k_pool.shape[1]
        hd = self.k_pool.shape[4]
        k = self.k_pool[:, :, idx].reshape(L, Hkv, n_tokens, hd)
        v = self.v_pool[:, :, idx].reshape(L, Hkv, n_tokens, hd)
        if self.kv_dtype == "int8":   # admission-time dequant (off hot path)
            ks = self.k_scale[:, :, idx].reshape(L, Hkv, n_tokens)
            vs = self.v_scale[:, :, idx].reshape(L, Hkv, n_tokens)
            k = kv_quant.dequantize_kv(k, ks, self.cfg.dtype)
            v = kv_quant.dequantize_kv(v, vs, self.cfg.dtype)
        return k, v

    def gather(self, seq_ids: List[int], pad_len: int
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Dense (L, B, pad_len, Hkv, hd) views + lengths for the batch.

        TEST ORACLE ONLY: the serving engines attend over the pool in place
        (block_table_batch + the paged kernel); this materialised copy is
        exactly the per-step traffic the paged path eliminates."""
        nb = -(-pad_len // self.block_size)
        tables = np.zeros((len(seq_ids), nb), np.int32)
        lens = np.zeros((len(seq_ids),), np.int32)
        for i, sid in enumerate(seq_ids):
            t = self.tables[sid][:nb]
            tables[i, :len(t)] = t
            lens[i] = self.lengths[sid]
        idx = jnp.asarray(tables)      # (B, nb)
        k = self.k_pool[:, :, idx]     # (L, Hkv, B, nb, bs, hd)
        v = self.v_pool[:, :, idx]
        if self.kv_dtype == "int8":    # oracle only — dense dequant is fine
            k = kv_quant.dequantize_kv(k, self.k_scale[:, :, idx],
                                       self.cfg.dtype)
            v = kv_quant.dequantize_kv(v, self.v_scale[:, :, idx],
                                       self.cfg.dtype)
        L, Hkv = k.shape[0], k.shape[1]
        B = len(seq_ids)
        k = jnp.transpose(k, (0, 2, 3, 4, 1, 5)).reshape(
            L, B, nb * self.block_size, Hkv, -1)[:, :, :pad_len]
        v = jnp.transpose(v, (0, 2, 3, 4, 1, 5)).reshape(
            L, B, nb * self.block_size, Hkv, -1)[:, :, :pad_len]
        return k, v, jnp.asarray(lens)

    # ---------------- block-granular KV handoff (disaggregated cluster) ----
    def export_seqs(self, seq_ids: Sequence[int]) -> "KVHandoffPayload":
        """Serialize the given sequences' KV state into a block-granular
        :class:`KVHandoffPayload` — the prefill→decode wire unit of the
        disaggregated cluster (serving/cluster/).

        The payload carries each sequence's LOGICAL table (its source block
        ids, in slot order) plus every referenced PHYSICAL block exactly
        once: a block shared by several exported sequences (refcounted
        prefix sharing) appears once in ``block_ids`` / the stacked tiles,
        so sharing survives the wire without re-transferring bytes. Tiles
        stay in the pool's head-major ``(L, Hkv, n, bs, hd)`` layout — the
        importer scatters them block-by-block into its own pool (the
        no-densify invariant holds across the wire: no dense seq-major view
        is ever built on either side).

        The source sequences are NOT freed — the prefill engine decides
        whether to retain them as prefix donors or release them."""
        missing = [sid for sid in seq_ids if sid not in self.tables]
        if missing:
            raise ValueError(
                f"export_seqs: sequence(s) {missing} have no table in this "
                f"pool — only admitted, prefilled sequences can be exported")
        ids: List[int] = []
        seen: set = set()
        for sid in seq_ids:
            for b in self.tables[sid]:
                if b not in seen:
                    seen.add(b)
                    ids.append(b)
        idx = jnp.asarray(ids, jnp.int32)
        # one device gather per payload, then host-side tiles (the "wire")
        k = np.asarray(self.k_pool[:, :, idx])
        v = np.asarray(self.v_pool[:, :, idx])
        ks = vs = None
        if self.k_scale is not None:   # scales ship with their blocks
            ks = np.asarray(self.k_scale[:, :, idx])
            vs = np.asarray(self.v_scale[:, :, idx])
        return KVHandoffPayload(
            tables={sid: tuple(self.tables[sid]) for sid in seq_ids},
            lengths={sid: self.lengths[sid] for sid in seq_ids},
            block_ids=tuple(ids), k_blocks=k, v_blocks=v,
            block_size=self.block_size, k_scales=ks, v_scales=vs)

    def prealloc_handoff(self, payload: "KVHandoffPayload"
                         ) -> Dict[int, int]:
        """Phase 1 of a handoff import: reserve destination blocks for every
        sequence in `payload` and rebuild its table/refcount/length state —
        no bytes move yet (that is :meth:`write_handoff_blocks`, the
        incremental phase 2 a decode replica's TransferQueue drives).

        Each UNIQUE source physical block gets exactly ONE destination
        block, popped by the same round-robin slot rule as a local
        allocation (using the slot of its first referencing table entry, so
        the shard-balance invariant survives the wire); per-sequence tables
        are then rebuilt through the src→dst mapping and refcounts are set
        to the number of referencing tables — shared prefixes stay shared
        on the destination pool. Returns the src→dst block-id mapping the
        transfer phase scatters through.

        Raises contextual :class:`PoolExhausted` (degraded-shard context
        included) when the destination pool cannot cover the payload; on
        failure nothing is allocated (all-or-nothing)."""
        if payload.block_size != self.block_size:
            raise ValueError(
                f"prealloc_handoff: payload block_size "
                f"({payload.block_size}) != destination pool block_size "
                f"({self.block_size}) — handoff is block-granular and "
                f"never re-chunks tiles")
        for rid in payload.tables:
            if rid in self.tables:
                raise ValueError(
                    f"prealloc_handoff: seq {rid} already has a table on "
                    f"the destination pool — a handoff import must land on "
                    f"a fresh rid")
        need = len(payload.block_ids)
        have = self.num_free
        if need > have:
            live = sum(self.lengths.values())
            raise PoolExhausted(
                f"handoff prealloc of {len(payload.tables)} seq(s) needs "
                f"{need} blocks, have {have}{self._degraded_note()}",
                rid=next(iter(payload.tables)), live_tokens=live,
                free_blocks=have, **self._degraded_kw())
        # slot of each unique block's FIRST reference drives placement
        first_slot: Dict[int, int] = {}
        for table in payload.tables.values():
            for slot, b in enumerate(table):
                first_slot.setdefault(b, slot)
        mapping: Dict[int, int] = {}
        try:
            for b in payload.block_ids:
                mapping[b] = self._pop_block(first_slot[b])
        except OutOfBlocks:
            for dst in mapping.values():   # all-or-nothing: roll back
                self._free_shard[self.shard_of(dst)].append(dst)
            live = sum(self.lengths.values())
            raise PoolExhausted(
                f"handoff prealloc exhausted the pool after "
                f"{len(mapping)} of {need} blocks{self._degraded_note()}",
                rid=next(iter(payload.tables)), live_tokens=live,
                free_blocks=self.num_free, **self._degraded_kw()) from None
        owners: Dict[int, int] = {}     # dst block -> first referencing rid
        for rid, src_table in payload.tables.items():
            dst_table = [mapping[b] for b in src_table]
            self.tables[rid] = dst_table
            self.lengths[rid] = payload.lengths[rid]
            for d in dst_table:
                self.refcounts[d] = self.refcounts.get(d, 0) + 1
                owners.setdefault(d, rid)
        for rid, src_table in payload.tables.items():
            borrowed = {mapping[b] for b in src_table
                        if owners[mapping[b]] != rid}
            if borrowed:
                self._borrowed[rid] = borrowed
        return mapping

    def write_handoff_blocks(self, payload: "KVHandoffPayload",
                             mapping: Dict[int, int],
                             start: int, stop: int) -> int:
        """Phase 2 of a handoff import: land payload blocks [start, stop)
        (indices into ``payload.block_ids``) at their mapped destination
        ids — one batched block-granular scatter, never a dense view. The
        sub-range IS the simulated wire budget: a decode replica's
        TransferQueue calls this with ``transfer_blocks_per_step`` blocks
        per engine step. Returns the bytes written."""
        # validate dtype compatibility BEFORE any scatter: a mismatched
        # payload must fail cleanly, not corrupt the pool and then raise
        if payload.k_scales is not None and self.k_scale is None:
            raise ValueError(
                "write_handoff_blocks: payload carries int8 scales but "
                "the destination pool is not kv_dtype='int8' — source "
                "and destination tiers must agree on kv_dtype")
        if payload.k_scales is None and self.k_scale is not None:
            raise ValueError(
                "write_handoff_blocks: destination pool is kv_dtype='int8' "
                "but the payload carries no scales — source and destination "
                "tiers must agree on kv_dtype")
        ids = payload.block_ids[start:stop]
        if not ids:
            return 0
        dst = jnp.asarray([mapping[b] for b in ids], jnp.int32)
        k = jnp.asarray(payload.k_blocks[:, :, start:stop])
        v = jnp.asarray(payload.v_blocks[:, :, start:stop])
        self.k_pool = self.k_pool.at[:, :, dst].set(k)
        self.v_pool = self.v_pool.at[:, :, dst].set(v)
        if payload.k_scales is not None:
            self.k_scale = self.k_scale.at[:, :, dst].set(
                jnp.asarray(payload.k_scales[:, :, start:stop]))
            self.v_scale = self.v_scale.at[:, :, dst].set(
                jnp.asarray(payload.v_scales[:, :, start:stop]))
        return payload.bytes_of_blocks(stop - start)

    def import_seqs(self, payload: "KVHandoffPayload") -> Dict[int, int]:
        """One-shot import: prealloc + write every payload block. The
        decode replicas drive the two phases separately (incremental
        transfer); this convenience wrapper serves tests and single-step
        callers. Returns the src→dst mapping."""
        mapping = self.prealloc_handoff(payload)
        self.write_handoff_blocks(payload, mapping, 0, payload.n_blocks)
        return mapping


@dataclasses.dataclass(frozen=True)
class KVHandoffPayload:
    """Block-granular KV handoff unit (prefill engine → decode replica).

    ``tables`` keeps each sequence's logical block chain in SOURCE ids;
    ``block_ids`` lists every referenced physical block exactly once (a
    refcount-shared block transfers once per physical block, not once per
    sharer), in the order the stacked head-major tiles ``k_blocks`` /
    ``v_blocks`` ``(L, Hkv, n_unique, bs, hd)`` are packed. The importer
    never sees source pool geometry beyond the ids — `prealloc_handoff`
    remaps them onto its own shards (source and destination pools may have
    different ``n_shards``).

    int8 pools additionally ship ``k_scales`` / ``v_scales``
    ``(L, Hkv, n_unique, bs)`` fp32 tiles packed in the same block order —
    scales follow their blocks across the wire, and the int8 + scale bytes
    together ≈ halve ``nbytes`` vs a bf16 payload of the same blocks."""
    tables: Dict[int, Tuple[int, ...]]
    lengths: Dict[int, int]
    block_ids: Tuple[int, ...]
    k_blocks: np.ndarray
    v_blocks: np.ndarray
    block_size: int
    k_scales: Optional[np.ndarray] = None
    v_scales: Optional[np.ndarray] = None

    @property
    def n_blocks(self) -> int:
        return len(self.block_ids)

    @property
    def nbytes(self) -> int:
        """Total wire bytes (K + V tiles, plus scale tiles when int8)."""
        total = int(self.k_blocks.nbytes + self.v_blocks.nbytes)
        if self.k_scales is not None:
            total += int(self.k_scales.nbytes + self.v_scales.nbytes)
        return total

    def bytes_of_blocks(self, n: int) -> int:
        """Wire bytes of `n` payload blocks (K + V)."""
        if not self.n_blocks:
            return 0
        return int(self.nbytes * n // self.n_blocks)
