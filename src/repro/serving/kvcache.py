"""Paged KV-cache manager (PagedAttention-style, paper baseline [28]).

Fixed-size blocks of `block_size` tokens from a global pool; per-sequence
block tables; allocation is O(1) off a free list. The pool arrays are the
single source of truth for KV bytes — the engines gather per-step dense
views for the batched decode and scatter the new token's K/V back.

Invariants (hypothesis-tested in tests/test_kvcache.py):
  * a block is owned by at most one sequence,
  * free + owned == total,
  * a sequence's capacity always covers its token count,
  * freeing returns exactly the blocks that were owned.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig


class OutOfBlocks(RuntimeError):
    pass


@dataclasses.dataclass
class PagedKVCache:
    cfg: ModelConfig
    num_blocks: int
    block_size: int = 16

    def __post_init__(self):
        hd = self.cfg.resolved_head_dim
        L = self._n_kv_layers()
        self.k_pool = jnp.zeros((L, self.num_blocks, self.block_size,
                                 self.cfg.num_kv_heads, hd), self.cfg.dtype)
        self.v_pool = jnp.zeros_like(self.k_pool)
        self.free: List[int] = list(range(self.num_blocks))
        self.tables: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}

    def _n_kv_layers(self) -> int:
        if self.cfg.family == "hybrid":
            return self.cfg.num_layers // self.cfg.shared_attn_period
        return self.cfg.num_layers

    # ---------------- allocation ----------------
    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n_tokens: int) -> bool:
        return len(self.free) >= self.blocks_needed(n_tokens)

    def allocate(self, seq_id: int, n_tokens: int) -> None:
        assert seq_id not in self.tables, f"seq {seq_id} already allocated"
        need = self.blocks_needed(n_tokens)
        if need > len(self.free):
            raise OutOfBlocks(f"need {need}, have {len(self.free)}")
        self.tables[seq_id] = [self.free.pop() for _ in range(need)]
        self.lengths[seq_id] = n_tokens

    def append_token(self, seq_id: int) -> None:
        n = self.lengths[seq_id] + 1
        if self.blocks_needed(n) > len(self.tables[seq_id]):
            if not self.free:
                raise OutOfBlocks("pool exhausted on append")
            self.tables[seq_id].append(self.free.pop())
        self.lengths[seq_id] = n

    def free_seq(self, seq_id: int) -> None:
        self.free.extend(self.tables.pop(seq_id))
        del self.lengths[seq_id]

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self.free)

    def utilisation(self) -> float:
        toks = sum(self.lengths.values())
        return toks / (self.num_blocks * self.block_size)

    # ---------------- data movement ----------------
    def write_prefill(self, seq_id: int, k: jax.Array, v: jax.Array) -> None:
        """k/v: (L, S, Hkv, hd) for this sequence's prompt."""
        S = k.shape[1]
        table = self.tables[seq_id]
        pad = len(table) * self.block_size - S
        if pad:
            k = jnp.pad(k, [(0, 0), (0, pad), (0, 0), (0, 0)])
            v = jnp.pad(v, [(0, 0), (0, pad), (0, 0), (0, 0)])
        kb = k.reshape(k.shape[0], len(table), self.block_size, *k.shape[2:])
        vb = v.reshape(*kb.shape)
        idx = jnp.asarray(table)
        self.k_pool = self.k_pool.at[:, idx].set(kb)
        self.v_pool = self.v_pool.at[:, idx].set(vb)

    def write_token(self, seq_id: int, k: jax.Array, v: jax.Array,
                    position: int) -> None:
        """k/v: (L, Hkv, hd) for one token at `position` (0-based)."""
        blk = self.tables[seq_id][position // self.block_size]
        off = position % self.block_size
        self.k_pool = self.k_pool.at[:, blk, off].set(k)
        self.v_pool = self.v_pool.at[:, blk, off].set(v)

    def gather(self, seq_ids: List[int], pad_len: int
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Dense (L, B, pad_len, Hkv, hd) views + lengths for the batch."""
        nb = -(-pad_len // self.block_size)
        tables = np.zeros((len(seq_ids), nb), np.int32)
        lens = np.zeros((len(seq_ids),), np.int32)
        for i, sid in enumerate(seq_ids):
            t = self.tables[sid][:nb]
            tables[i, :len(t)] = t
            lens[i] = self.lengths[sid]
        idx = jnp.asarray(tables)  # (B, nb)
        k = self.k_pool[:, idx]    # (L, B, nb, bs, Hkv, hd)
        v = self.v_pool[:, idx]
        L = k.shape[0]
        B = len(seq_ids)
        k = k.reshape(L, B, nb * self.block_size, *k.shape[4:])[:, :, :pad_len]
        v = v.reshape(L, B, nb * self.block_size, *v.shape[4:])[:, :, :pad_len]
        return k, v, jnp.asarray(lens)
