"""Disaggregated serving cluster — prefill/decode split + routing.

Public surface::

    from repro.serving.cluster import (DisaggCluster, PrefillEngine,
                                       DecodeEngine, ClusterRouter,
                                       HandoffError)

``DisaggCluster`` is the one-call deployment: K prefill/decode replica
pairs, block-granular KV handoff between them (PreallocQueue →
TransferQueue → WaitingQueue on the decode side), and a prefix-affinity
router fronting the fleet. The engines are also usable standalone —
``PrefillEngine.on_handoff`` / ``DecodeEngine.enqueue_handoff`` is the
transport seam a real RPC fabric would replace.
"""
from repro.serving.cluster.cluster import DisaggCluster
from repro.serving.cluster.engines import DecodeEngine, PrefillEngine
from repro.serving.cluster.queues import (Handoff, HandoffError,
                                          PreallocQueue, TransferQueue,
                                          WaitingQueue)
from repro.serving.cluster.registry import Replica, ReplicaRegistry
from repro.serving.cluster.router import (ClusterRouter, fnv1a_tokens,
                                          prefix_route_key)

__all__ = [
    "DisaggCluster", "PrefillEngine", "DecodeEngine",
    "Handoff", "HandoffError",
    "PreallocQueue", "TransferQueue", "WaitingQueue",
    "Replica", "ReplicaRegistry",
    "ClusterRouter", "fnv1a_tokens", "prefix_route_key",
]
