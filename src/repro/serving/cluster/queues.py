"""Decode-side handoff lifecycle: Prealloc → Transfer → Waiting.

A handoff (one request's exported KV payload, serving/kvcache.py) arriving
at a decode replica walks three queues — the sglang-style disaggregated
decode lifecycle:

  * :class:`PreallocQueue` — payloads waiting for destination blocks.
    FCFS: the head preallocates (``PagedKVCache.prealloc_handoff``) as
    soon as the pool can cover it; a head that doesn't fit blocks the
    tail, exactly like the scheduler's FCFS admission.
  * :class:`TransferQueue` — preallocated handoffs landing their blocks
    incrementally (``write_handoff_blocks``), a bounded number of blocks
    per engine step (``DisaggConfig.transfer_blocks_per_step`` — the
    simulated wire budget).
  * :class:`WaitingQueue` — fully transferred handoffs waiting for a
    decode batch slot (``RequestScheduler.admit_prefilled``): the request
    joins the PREBUILT batch, skipping the prefill forward entirely.

Every failure path raises a contextual :class:`HandoffError` carrying the
request id, replica id, and blocks in flight — the PR 6 ``PoolExhausted``
degraded-context convention, never a bare assert.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, List, Optional

from repro.serving.kvcache import KVHandoffPayload
from repro.serving.request import Request


class HandoffError(RuntimeError):
    """A KV handoff failed terminally (payload can never fit, transfer
    retry budget exhausted). Carries full context — rid, replica, blocks
    in flight, lifecycle stage — mirroring ``PoolExhausted``'s
    degraded-context convention."""

    def __init__(self, message: str, *, rid: int, replica: int,
                 blocks_in_flight: int, stage: str):
        super().__init__(message)
        self.rid = rid
        self.replica = replica
        self.blocks_in_flight = blocks_in_flight
        self.stage = stage      # "enqueue" | "prealloc" | "transfer"


@dataclasses.dataclass
class Handoff:
    """One in-flight prefill→decode handoff."""

    request: Request
    payload: KVHandoffPayload
    replica: int
    enqueued_step: int                  # decode engine step at arrival
    enqueue_s: float = dataclasses.field(default_factory=time.time)
    # set by prealloc (src→dst block mapping); reset on transfer abort
    mapping: Optional[Dict[int, int]] = None
    cursor: int = 0                     # payload blocks written so far
    attempts: int = 0                   # transfer (re)starts consumed

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def blocks_in_flight(self) -> int:
        """Blocks this handoff still has to land (0 once transferred)."""
        return self.payload.n_blocks - self.cursor

    @property
    def transferred(self) -> bool:
        return self.mapping is not None and \
            self.cursor >= self.payload.n_blocks


class _FIFOQueue:
    """Minimal FIFO with stable iteration + mid-queue removal (shard-death
    recovery plucks faulted handoffs out of the middle)."""

    def __init__(self):
        self._items: List[Handoff] = []

    def push(self, h: Handoff) -> None:
        self._items.append(h)

    def push_front(self, h: Handoff) -> None:
        self._items.insert(0, h)

    def peek(self) -> Optional[Handoff]:
        return self._items[0] if self._items else None

    def pop(self) -> Handoff:
        return self._items.pop(0)

    def remove(self, h: Handoff) -> None:
        self._items.remove(h)

    def __iter__(self) -> Iterator[Handoff]:
        return iter(list(self._items))

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)


class PreallocQueue(_FIFOQueue):
    """Handoffs awaiting destination-block preallocation (FCFS)."""


class TransferQueue(_FIFOQueue):
    """Preallocated handoffs landing blocks under the per-step budget."""


class WaitingQueue(_FIFOQueue):
    """Fully transferred handoffs awaiting a decode batch slot."""
