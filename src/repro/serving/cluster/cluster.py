"""DisaggCluster — the full disaggregated deployment in one object.

K replicas (each a prefill engine paired with a decode engine, wired
prefill → decode through the handoff queues) fronted by a
:class:`~repro.serving.cluster.router.ClusterRouter`. The paired topology
makes prefix affinity productive: the router concentrates same-prefix
streams on one replica, whose prefill engine's retained donors serve the
shared blocks from residency — ``prefill_tokens_skipped`` and warm TTFT
are the benchmark's observables.

This is the single-process simulation of the paper's heterogeneous
deployment (the same stance as the worker pools): every engine is real,
every handoff payload carries real pool bytes, and the cluster ``step``
interleaves the engines the way independent hosts would free-run.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

from repro.models.common import ModelConfig
from repro.serving.config import DisaggConfig, EngineConfig
from repro.serving.cluster.engines import DecodeEngine, PrefillEngine
from repro.serving.cluster.registry import Replica, ReplicaRegistry
from repro.serving.cluster.router import ClusterRouter
from repro.serving.faults import FaultInjector
from repro.serving.request import Request, SamplingParams, State
from repro.serving.stats import EngineStats


class DisaggCluster:
    """K paired prefill/decode replicas behind a prefix-affinity router."""

    def __init__(self, cfg: ModelConfig, params,
                 engine_config: Optional[EngineConfig] = None,
                 replicas: int = 2,
                 disagg: Optional[DisaggConfig] = None,
                 routing: str = "affinity",
                 affinity_blocks: int = 2,
                 prefill_faults: Optional[Dict[int, FaultInjector]] = None,
                 decode_faults: Optional[Dict[int, FaultInjector]] = None,
                 seed: int = 0):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1; got {replicas}")
        econf = engine_config or EngineConfig()
        self.cfg = cfg
        self.config = econf
        self.disagg = disagg or DisaggConfig()
        self.registry = ReplicaRegistry()
        for i in range(replicas):
            prefill = PrefillEngine(
                cfg, params, econf,
                disagg=self.disagg.replace(role="prefill"),
                fault_injector=(prefill_faults or {}).get(i), replica=i)
            decode = DecodeEngine(
                cfg, params, econf,
                disagg=self.disagg.replace(role="decode"),
                fault_injector=(decode_faults or {}).get(i), replica=i)
            prefill.on_handoff = decode.enqueue_handoff
            self.registry.add(Replica(idx=i, prefill=prefill,
                                      decode=decode))
        self.router = ClusterRouter(self.registry, econf.block_size,
                                    policy=routing,
                                    affinity_blocks=affinity_blocks,
                                    seed=seed)
        self.requests: List[Request] = []
        self._route_of: Dict[int, int] = {}

    # ------------------------------------------------------------------
    def submit(self, reqs: Union[Request, Sequence[Request]]
               ) -> List[Request]:
        """Route and enqueue request(s); returns them as a list (outputs
        accumulate in place as the cluster runs)."""
        batch = [reqs] if isinstance(reqs, Request) else list(reqs)
        for req in batch:
            replica = self.router.route(req)
            self._route_of[req.rid] = replica.idx
            replica.prefill.submit(req)
            self.requests.append(req)
        return batch

    def generate(self, prompt: Sequence[int],
                 params: Optional[SamplingParams] = None) -> Request:
        return self.submit(Request(prompt=list(prompt),
                                   params=params or SamplingParams()))[0]

    def replica_of(self, rid: int) -> Optional[int]:
        return self._route_of.get(rid)

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One cluster tick: every engine with work advances one step —
        the single-process stand-in for independently free-running hosts
        (handoff callbacks deliver synchronously, so a payload exported
        this tick is in its decode replica's prealloc queue this tick)."""
        for r in self.registry:
            if r.prefill.has_work():
                r.prefill.step()
            if r.decode.has_work():
                r.decode.step()

    def has_work(self) -> bool:
        return any(r.has_work() for r in self.registry)

    def run(self, max_steps: int = 10_000) -> "DisaggCluster":
        steps = 0
        while self.has_work() and steps < max_steps:
            self.step()
            steps += 1
        return self

    def drain(self, max_steps: int = 10_000) -> List[List[int]]:
        """Run to completion; returns outputs in submission order."""
        self.run(max_steps)
        return [list(r.output) for r in self.requests]

    @property
    def finished(self) -> bool:
        return all(r.state == State.FINISHED for r in self.requests)

    # ------------------------------------------------------------------
    def summary(self) -> Dict:
        """Cluster-level stats: decode-side transfer/handoff aggregates
        (counting each payload's bytes ONCE — the prefill side's export
        counter would double them), prefill-side affinity/sharing wins,
        and the per-replica breakdown."""
        agg = EngineStats()
        per_replica = []
        for r in self.registry:
            ps, ds = r.prefill.stats, r.decode.stats
            agg.kv_bytes_transferred += ds.kv_bytes_transferred
            agg.handoff_latencies.extend(ds.handoff_latencies)
            agg.handoff_retries += ds.handoff_retries
            agg.router_affinity_hits += ps.router_affinity_hits
            agg.prefill_tokens_skipped += ps.prefill_tokens_skipped
            agg.blocks_shared += ps.blocks_shared
            agg.tokens_generated += ds.tokens_generated
            per_replica.append({
                "replica": r.idx,
                "healthy": r.healthy,
                "router_affinity_hits": ps.router_affinity_hits,
                "prefill_tokens_skipped": ps.prefill_tokens_skipped,
                "kv_bytes_transferred": ds.kv_bytes_transferred,
                "handoffs_completed": ds.handoffs_completed,
                "handoff_retries": ds.handoff_retries,
            })
        out = {
            "replicas": len(self.registry),
            "routing": self.router.policy,
            "requests": len(self.requests),
            "kv_bytes_transferred": agg.kv_bytes_transferred,
            "handoffs_completed": agg.handoffs_completed,
            "handoff_retries": agg.handoff_retries,
            "router_affinity_hits": agg.router_affinity_hits,
            "prefill_tokens_skipped": agg.prefill_tokens_skipped,
            "blocks_shared": agg.blocks_shared,
            "tokens_generated": agg.tokens_generated,
            "per_replica": per_replica,
        }
        out.update({f"handoff_{k}_s": v
                    for k, v in agg.handoff_percentiles().items()})
        return out
