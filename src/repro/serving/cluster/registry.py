"""Replica registry — the router's view of the decode fleet.

A :class:`Replica` pairs one prefill engine with one decode engine (the
paired topology keeps prefix affinity meaningful: routing same-prefix
streams to the same replica concentrates them on ONE prefill engine's
retained donors). Health is drawn from the PR 6 fault machinery — a
replica whose prefill or decode pool has a quarantined shard is DEGRADED
and the router routes around it until the shard rejoins.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.serving.cluster.engines import DecodeEngine, PrefillEngine


@dataclasses.dataclass
class Replica:
    """One prefill/decode engine pair behind the router."""

    idx: int
    prefill: PrefillEngine
    decode: DecodeEngine

    @property
    def healthy(self) -> bool:
        """Healthy = neither pool is running degraded. Quarantine state is
        the same signal the engines' own admission guards consult, so the
        router's view can never disagree with the replica's."""
        return not (self.prefill.kv.quarantined_shards
                    or self.decode.kv.quarantined_shards)

    @property
    def load(self) -> int:
        """Outstanding work units: queued + running requests on both
        engines plus decode-side handoffs still in flight."""
        return (len(self.prefill.sched.waiting)
                + len(self.prefill.sched.running)
                + len(self.decode.sched.waiting)
                + len(self.decode.sched.running)
                + len(self.decode.prealloc_q)
                + len(self.decode.transfer_q)
                + len(self.decode.waiting_q))

    def has_work(self) -> bool:
        return self.prefill.has_work() or self.decode.has_work()


class ReplicaRegistry:
    """Indexable fleet with health filtering."""

    def __init__(self, replicas: Optional[List[Replica]] = None):
        self._replicas: List[Replica] = list(replicas or [])

    def add(self, replica: Replica) -> None:
        self._replicas.append(replica)

    def __len__(self) -> int:
        return len(self._replicas)

    def __getitem__(self, idx: int) -> Replica:
        return self._replicas[idx]

    def __iter__(self):
        return iter(self._replicas)

    @property
    def healthy(self) -> List[Replica]:
        return [r for r in self._replicas if r.healthy]

    def least_loaded(self, healthy_only: bool = True) -> Replica:
        pool = self.healthy if healthy_only else self._replicas
        if not pool:
            pool = self._replicas     # whole fleet degraded: pick anyway
            # (an engine on a degraded pool still serves at reduced
            # capacity — refusing every request would be strictly worse)
        return min(pool, key=lambda r: (r.load, r.idx))
