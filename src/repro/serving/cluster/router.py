"""ClusterRouter — prefix-affinity request routing over the replica fleet.

The routing key is the token-content chain of a prompt's LEADING FULL
BLOCKS — the same ``key_i = (key_{i-1}, block_tokens)`` chain the
:class:`~repro.serving.scheduler.PrefixIndex` uses — hashed with FNV-1a
(NOT Python's ``hash()``, which is salted per process: routing must be
stable across processes so a restarted router lands the same streams on
the same replicas). Two prompts sharing their leading blocks hash to the
same replica, whose prefill engine's retained donors then serve the
shared prefix from residency: the affinity win IS the prefix-sharing win,
concentrated.

Assignments are memoized (sticky): once a prefix key lands on a replica,
followers go there too and count as ``router_affinity_hits`` on that
replica's prefill engine. An unhealthy target (quarantined shard — PR 6
fault events) diverts to the least-loaded healthy replica WITHOUT
overwriting the memo — the stream snaps back when the shard rejoins.
"""
from __future__ import annotations

import random
from typing import Dict, Optional, Sequence, Tuple

from repro.serving.cluster.registry import Replica, ReplicaRegistry
from repro.serving.request import Request

ROUTING_POLICIES = ("affinity", "random", "least_loaded")

_FNV_OFFSET = 0xcbf29ce484222325
_FNV_PRIME = 0x100000001b3


def fnv1a_tokens(tokens: Sequence[int]) -> int:
    """64-bit FNV-1a over a token-id sequence. Deterministic across
    processes/runs (unlike the interpreter's salted ``hash``)."""
    h = _FNV_OFFSET
    for t in tokens:
        for b in int(t).to_bytes(8, "little", signed=True):
            h ^= b
            h = (h * _FNV_PRIME) & 0xFFFFFFFFFFFFFFFF
    return h


def prefix_route_key(prompt: Sequence[int], block_size: int,
                     affinity_blocks: int) -> Optional[Tuple[int, ...]]:
    """The routing key: tokens of the first ``affinity_blocks`` FULL
    blocks (fewer if the prompt is shorter). ``None`` when the prompt has
    no full leading block — nothing shareable to be affine about."""
    full = min(len(prompt) // block_size, affinity_blocks)
    if full <= 0:
        return None
    return tuple(prompt[:full * block_size])


class ClusterRouter:
    """Routes requests to replicas; policies: affinity (default — prefix
    hash with sticky memo + least-loaded fallback), random (seeded — the
    benchmark's baseline), least_loaded."""

    def __init__(self, registry: ReplicaRegistry, block_size: int,
                 policy: str = "affinity", affinity_blocks: int = 2,
                 seed: int = 0):
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"routing policy must be one of "
                             f"{ROUTING_POLICIES}; got {policy!r}")
        if affinity_blocks < 1:
            raise ValueError(f"affinity_blocks must be >= 1; "
                             f"got {affinity_blocks}")
        if not len(registry):
            raise ValueError("router needs at least one replica")
        self.registry = registry
        self.block_size = block_size
        self.policy = policy
        self.affinity_blocks = affinity_blocks
        self._rng = random.Random(seed)
        # sticky prefix-key -> replica idx assignments (affinity policy)
        self._assignments: Dict[Tuple[int, ...], int] = {}

    def route(self, request: Request) -> Replica:
        if self.policy == "random":
            return self.registry[
                self._rng.randrange(len(self.registry))]
        if self.policy == "least_loaded":
            return self.registry.least_loaded()
        return self._route_affinity(request)

    def _route_affinity(self, request: Request) -> Replica:
        key = prefix_route_key(request.prompt, self.block_size,
                               self.affinity_blocks)
        if key is None:
            return self.registry.least_loaded()
        idx = self._assignments.get(key)
        if idx is None:
            # first sight of this prefix: deterministic hash placement
            # (stable across routers), recorded sticky
            idx = fnv1a_tokens(key) % len(self.registry)
            self._assignments[key] = idx
            return self._fallback_if_unhealthy(self.registry[idx])
        target = self.registry[idx]
        if target.healthy:
            # an affinity HIT: the stream lands where its prefix lives
            target.prefill.stats.router_affinity_hits += 1
            return target
        return self.registry.least_loaded()

    def _fallback_if_unhealthy(self, target: Replica) -> Replica:
        if target.healthy:
            return target
        return self.registry.least_loaded()

    @property
    def assignments(self) -> Dict[Tuple[int, ...], int]:
        return dict(self._assignments)
