"""PrefillEngine / DecodeEngine — the disaggregated split of ``LLMEngine``.

Both are thin role overlays on the unified engine (same placement
strategies, same scheduler, same fault machinery); ``DisaggConfig`` names
the role and the handoff knobs. The split is the sglang-style prefill/
decode disaggregation:

  * a :class:`PrefillEngine` runs admission + prefill only. The moment a
    request's prefill completes (its first token is sampled), its KV
    blocks are EXPORTED (``PagedKVCache.export_seqs`` — block-granular,
    no densify) and the request is detached: the engine never decodes.
    With ``retain_prefixes`` the exported prompt's blocks stay resident
    as prefix-sharing donors (LRU-evicted under pool pressure), so
    same-prefix followers routed here skip their shared prefill.
  * a :class:`DecodeEngine` receives handoffs and walks them through the
    Prealloc → Transfer → Waiting lifecycle (``cluster/queues.py``); a
    fully transferred request joins the PREBUILT decode batch via
    ``RequestScheduler.admit_prefilled`` — no prefill forward ever runs
    for it. Preemption/fault recovery still recomputes locally (a decode
    replica CAN prefill — recovery is the one path that does).

Greedy outputs through the split are bit-identical to a single engine:
the exported pool bytes are the prefill engine's verbatim, positions are
preserved block-granularly across the wire, and sampling streams are
per-request (seeded), independent of which engine draws them.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

from repro.serving.config import DisaggConfig
from repro.serving.kvcache import KVHandoffPayload, PoolExhausted
from repro.serving.llm_engine import LLMEngine
from repro.serving.request import Request, State
from repro.serving.cluster.queues import (Handoff, HandoffError,
                                          PreallocQueue, TransferQueue,
                                          WaitingQueue)

# callback a PrefillEngine fires per completed prefill: (request, payload)
HandoffSink = Callable[[Request, KVHandoffPayload], None]


class PrefillEngine(LLMEngine):
    """Prefill-only role: admit, prefill, export, detach — never decode."""

    def __init__(self, cfg, params, engine_config=None,
                 disagg: Optional[DisaggConfig] = None,
                 fault_injector=None, replica: int = 0, **overrides):
        super().__init__(cfg, params, engine_config,
                         fault_injector=fault_injector, **overrides)
        disagg = disagg or DisaggConfig(role="prefill")
        if disagg.role != "prefill":
            disagg = disagg.replace(role="prefill")
        self.disagg = disagg
        self.replica = replica
        # rid -> detached Request whose prompt blocks stay resident as
        # prefix donors (insertion order = LRU order; re-export refreshes)
        self._retained: Dict[int, Request] = {}
        # where exported handoffs go (DisaggCluster wires this to the
        # paired DecodeEngine's enqueue_handoff); None = caller collects
        # via the handoff_out events / collect_handoffs()
        self.on_handoff: Optional[HandoffSink] = None
        self._outbox: List[Handoff] = []

    # ---- the role: harvest instead of decode ----
    def _decode_iteration(self) -> None:
        """A prefill engine never decodes. Every running request whose
        prefill just completed (first token sampled) is exported and
        detached — the handoff payload carries its pool blocks verbatim."""
        ready = [r for r in self.sched.running
                 if r.state == State.RUNNING
                 and self.sched.prefill_done(r.rid) and r.output]
        for req in ready:
            payload = self.kv.export_seqs([req.rid])
            self.stats.kv_bytes_transferred += payload.nbytes
            self._emit("handoff_out", req.rid, blocks=payload.n_blocks,
                       nbytes=payload.nbytes, replica=self.replica)
            self._detach(req)
            h = Handoff(request=req, payload=payload, replica=self.replica,
                        enqueued_step=self._step_no)
            if self.on_handoff is not None:
                self.on_handoff(req, payload)
            else:
                self._outbox.append(h)

    def collect_handoffs(self) -> List[Handoff]:
        """Drain exported handoffs (only populated when no ``on_handoff``
        sink is wired — the poll-style transport)."""
        out, self._outbox = self._outbox, []
        return out

    def _detach(self, req: Request) -> None:
        """Remove an exported request from the batch. With prefix
        retention its blocks stay resident (table + PrefixIndex entry
        kept) so followers can share them; otherwise they free now."""
        rid = req.rid
        self.sched.running.remove(req)
        req.state = State.TRANSFERRING
        if (self.disagg.retain_prefixes and self.disagg.max_retained_seqs
                and self.sched.prefix_index is not None):
            self.sched._shared.pop(rid, None)
            self._retained[rid] = req
        else:
            self.sched._release(rid)

    @property
    def retained_rids(self) -> List[int]:
        return list(self._retained)

    def _evict_retained(self, rid: int, cause: str) -> None:
        self._retained.pop(rid, None)
        self.sched._release(rid)
        self._emit("retain_evict", rid, cause=cause, replica=self.replica)

    # ---- pool-pressure integration for retained donors ----
    def _pre_admit_tick(self) -> None:
        """Retained donors yield to live work: enforce the retention cap,
        then evict LRU donors until the waiting head's admission fits —
        preferring to spare the head's own matched donor (evicting it
        would forfeit the prefix skip the retention exists for)."""
        while len(self._retained) > self.disagg.max_retained_seqs:
            self._evict_retained(next(iter(self._retained)), cause="cap")
        while self.sched.waiting and self._retained \
                and not self._head_fits():
            head = self.sched.waiting[0]
            donor, _ = self.sched._match_prefix(
                head, self.sched.stored_tokens(head))
            victim = next((r for r in self._retained if r != donor), None)
            if victim is None:
                victim = next(iter(self._retained))  # the donor itself:
                # correctness (admission) beats affinity (the skip)
            self._evict_retained(victim, cause="pressure")

    def _head_fits(self) -> bool:
        """Would ``sched.admit`` take the waiting head right now? Mirrors
        the admission arithmetic (shared-prefix discount, chunked first-
        chunk charge) without mutating anything."""
        sched, head = self.sched, self.sched.waiting[0]
        if len(sched.running) >= sched.max_batch:
            return True          # blocked on batch slots, not on blocks —
            # evicting retained donors cannot help
        stored = sched.stored_tokens(head)
        donor, shared = sched._match_prefix(head, stored)
        chunk = sched.chunk_tokens
        if chunk:
            if self.kv.blocks_needed(stored + sched.decode_headroom) > \
                    self.kv.capacity_blocks:
                return True      # can NEVER fit — eviction cannot help;
                # let the stall check surface it
            first = min(chunk, stored - shared)
            if not sched._chunked_commitment_ok(donor, shared, first):
                return False
        else:
            first = stored - shared
        return self.kv.can_allocate(first + sched.decode_headroom)

    def _free_blocks_for_chunk(self, req: Request, need: int) -> bool:
        """Chunk growth evicts retained donors before stalling: a prefill
        engine has no running decoders to wait out, so retained blocks are
        the only ones that will ever free."""
        while self.kv.num_free < need and self._retained:
            self._evict_retained(next(iter(self._retained)),
                                 cause="chunk_pressure")
        return super()._free_blocks_for_chunk(req, need)

    def _handle_shard_death(self, shard: int, cause: str) -> None:
        """Retained donors holding blocks on the dead shard are dropped
        (their bytes are lost — a follower must not map onto them); live
        requests recover through the base preempt-and-recompute path."""
        victims = set(self.kv.seqs_on_shard(shard))
        super()._handle_shard_death(shard, cause)
        for rid in [r for r in self._retained if r in victims]:
            self._evict_retained(rid, cause="shard_down")


class DecodeEngine(LLMEngine):
    """Decode role: imports handoffs, decodes prebuilt batches."""

    def __init__(self, cfg, params, engine_config=None,
                 disagg: Optional[DisaggConfig] = None,
                 fault_injector=None, replica: int = 0, **overrides):
        super().__init__(cfg, params, engine_config,
                         fault_injector=fault_injector, **overrides)
        disagg = disagg or DisaggConfig(role="decode")
        if disagg.role != "decode":
            disagg = disagg.replace(role="decode")
        self.disagg = disagg
        self.replica = replica
        self.prealloc_q = PreallocQueue()
        self.transfer_q = TransferQueue()
        self.waiting_q = WaitingQueue()

    # ---- ingress ----
    def enqueue_handoff(self, request: Request,
                        payload: KVHandoffPayload) -> Handoff:
        """Accept a prefill engine's export. Terminally oversized payloads
        (cannot fit even an EMPTY healthy pool) fail fast with full
        context; everything else queues for prealloc."""
        if payload.block_size != self.kv.block_size:
            raise HandoffError(
                f"handoff for request {request.rid}: payload block_size "
                f"{payload.block_size} != pool block_size "
                f"{self.kv.block_size} on replica {self.replica}",
                rid=request.rid, replica=self.replica,
                blocks_in_flight=payload.n_blocks, stage="enqueue")
        if payload.n_blocks + self._headroom_blocks() > self.kv.num_blocks:
            raise HandoffError(
                f"handoff for request {request.rid} can never fit: "
                f"{payload.n_blocks} payload blocks + "
                f"{self._headroom_blocks()} headroom exceed the pool's "
                f"{self.kv.num_blocks} blocks on replica {self.replica}",
                rid=request.rid, replica=self.replica,
                blocks_in_flight=payload.n_blocks, stage="enqueue")
        request.state = State.TRANSFERRING
        h = Handoff(request=request, payload=payload, replica=self.replica,
                    enqueued_step=self._step_no)
        self.prealloc_q.push(h)
        self._emit("handoff_recv", request.rid, blocks=payload.n_blocks,
                   nbytes=payload.nbytes, replica=self.replica)
        return h

    def _headroom_blocks(self) -> int:
        return self.kv.blocks_needed(self.sched.decode_headroom)

    # ---- the per-step queue walk ----
    def _pre_admit_tick(self) -> None:
        """Drain the handoff lifecycle BEFORE this step's admission wave:
        faulted mid-transfer imports reset first (``_fault_tick`` already
        ran, so this step's shard deaths are visible), then prealloc →
        transfer → admit. A transfer that completes this step joins this
        very step's decode batch."""
        self._reset_faulted_transfers()
        self._advance_prealloc()
        self._advance_transfer()
        self._advance_waiting()

    def _stall_waiver(self) -> bool:
        """Handoffs in flight hold pool blocks while nothing runs yet — a
        state the single-engine stall check would misread as permanent."""
        return bool(self.prealloc_q or self.transfer_q or self.waiting_q)

    def has_work(self) -> bool:
        return (super().has_work() or bool(self.prealloc_q)
                or bool(self.transfer_q) or bool(self.waiting_q))

    def _reset_faulted_transfers(self) -> None:
        """A shard death mid-transfer invalidates every handoff whose
        preallocated destination blocks live on the dead shard (its bytes
        are lost / partially landed): free the import, reset the cursor,
        and requeue at the FRONT of the prealloc queue — the retry
        preallocates fresh blocks on the survivors. Each reset burns one
        attempt; past ``max_transfer_attempts`` the handoff fails with
        full context instead of looping forever on a shrinking pool."""
        if not self.kv.quarantined_shards:
            return
        bad = set(self.kv.quarantined_shards)
        for q in (self.transfer_q, self.waiting_q):
            for h in q:
                table = self.kv.tables.get(h.rid)
                if table is None or \
                        not any(self.kv.shard_of(b) in bad for b in table):
                    continue
                q.remove(h)
                self.kv.free_seq(h.rid)
                in_flight = h.blocks_in_flight
                h.mapping = None
                h.cursor = 0
                h.attempts += 1
                self.stats.handoff_retries += 1
                if h.attempts >= self.disagg.max_transfer_attempts:
                    raise HandoffError(
                        f"handoff for request {h.rid} interrupted by shard "
                        f"death {h.attempts} time(s) on replica "
                        f"{self.replica} ({in_flight} blocks were in "
                        f"flight) — transfer attempt budget "
                        f"({self.disagg.max_transfer_attempts}) exhausted",
                        rid=h.rid, replica=self.replica,
                        blocks_in_flight=in_flight, stage="transfer")
                self.prealloc_q.push_front(h)
                self._emit("handoff_retry", h.rid, attempt=h.attempts,
                           blocks_lost=in_flight, replica=self.replica)

    def _advance_prealloc(self) -> None:
        """FCFS prealloc: the head reserves destination blocks as soon as
        the pool covers payload + decode headroom; a head that does not
        fit blocks the tail (same head-of-line contract as admission)."""
        while self.prealloc_q:
            h = self.prealloc_q.peek()
            if self.kv.num_free < h.payload.n_blocks + \
                    self._headroom_blocks():
                break
            try:
                h.mapping = self.kv.prealloc_handoff(h.payload)
            except PoolExhausted:
                break       # raced the headroom margin (borrowed blocks /
                # CoW forks); retry next step — capacity-wise it fits
            self.prealloc_q.pop()
            self.transfer_q.push(h)
            self._emit("prealloc", h.rid, blocks=h.payload.n_blocks,
                       replica=self.replica)

    def _advance_transfer(self) -> None:
        """Land blocks under the per-step wire budget
        (``transfer_blocks_per_step``; 0 = unbounded). The budget is
        shared across the queue in FIFO order, so a large import cannot
        starve a small one forever — the head finishes first."""
        budget = self.disagg.transfer_blocks_per_step or None
        for h in self.transfer_q:
            if budget is not None and budget <= 0:
                break
            step = h.blocks_in_flight if budget is None \
                else min(budget, h.blocks_in_flight)
            stop = h.cursor + step
            self.stats.kv_bytes_transferred += self.kv.write_handoff_blocks(
                h.payload, h.mapping, h.cursor, stop)
            h.cursor = stop
            if budget is not None:
                budget -= step
            if h.transferred:
                self.transfer_q.remove(h)
                self.waiting_q.push(h)
                self.stats.handoff_latencies.append(
                    time.time() - h.enqueue_s)
                self._emit("transfer_done", h.rid,
                           blocks=h.payload.n_blocks,
                           steps=self._step_no - h.enqueued_step,
                           replica=self.replica)

    def _advance_waiting(self) -> None:
        """Admit fully transferred requests into the PREBUILT decode
        batch — ``admit_prefilled`` skips allocation and prefill entirely;
        a full batch holds the queue (blocks stay resident) until slots
        retire."""
        while self.waiting_q:
            h = self.waiting_q.peek()
            if not self.sched.admit_prefilled(h.request):
                break
            self.waiting_q.pop()
            self._emit("handoff_admit", h.rid,
                       stored_tokens=self.kv.lengths[h.rid],
                       replica=self.replica)
