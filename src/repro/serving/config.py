"""Declarative serving configuration — the single knob surface for
:class:`repro.serving.llm_engine.LLMEngine`.

The paper's thesis is that model-attention disaggregation is a *placement*
decision, not a different engine: the same continuous-batching loop runs
whether attention (and optionally the MoE experts) execute fused on the
model workers or on a memory-optimized pool. ``EngineConfig`` makes that
decision declarative — one validated dataclass replaced the constructor
kwarg sprawl of the deleted legacy ``Engine`` → ``DisaggEngine`` →
``MoEOffloadEngine`` inheritance tower:

  * ``placement``:  ``homogeneous`` (vLLM-style baseline — every operator on
    the model workers), ``attention_pool`` (Lamina §4 — attention on a
    memory-device pool), or ``moe_offload`` (§7 — attention AND expert FFNs
    on pools);
  * ``partition``:  how the attention pool splits its work — ``head``
    (Lamina's choice), ``request`` (batch-sharded baseline), or ``block``
    (pool block axis sharded; one sequence's KV spans every worker);
  * ``scheduler``:  ``fcfs`` (strict arrival order, no eviction — a request
    that outgrows the pool surfaces ``PoolExhausted``) or ``preempt``
    (LIFO victim eviction under pool pressure with recompute re-admission).

Validation happens at construction: impossible combinations (block
partition with mismatched ``kv_shards``, unknown enum values, non-positive
sizes) fail loudly *before* any arrays are allocated. Model-dependent
divisibility checks (kv-head / expert counts vs worker counts) live with
the placement strategies, which see the ``ModelConfig``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

PLACEMENTS = ("homogeneous", "attention_pool", "moe_offload")
PARTITIONS = ("head", "request", "block")
SCHEDULERS = ("fcfs", "preempt")
BACKENDS = ("jnp", "pallas")
KV_DTYPES = ("bf16", "int8")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Validated, declarative serving-engine configuration.

    Frozen so a config can be shared between engines / logged verbatim;
    derive variants with :meth:`replace`.
    """

    # ---- placement (the paper's core decision) ----
    placement: str = "homogeneous"
    partition: str = "head"            # attention-pool work split
    attention_workers: int = 2         # pool DOP `b` (paper §5)
    expert_workers: int = 2            # moe_offload only
    # (no `overlap` knob: the §4.2.2 overlapped schedule IS the paged path
    #  — `AttentionWorkerPool.attend_overlapped` aliases `attend_paged`;
    #  the schedule's latency win is priced analytically in bench_overlap)

    # ---- KV pool ----
    num_blocks: int = 256
    block_size: int = 16
    kv_shards: Optional[int] = None    # None => derived (block partition
    #                                    shards the pool over the workers)
    # Pool element dtype. "int8" stores the block pool quantized (per-token,
    # per-kv-head symmetric max-abs scales in fp32 sidecar pools that follow
    # every block invariant — CoW fork, refcount, quarantine, handoff) and
    # fuses dequant into the attention kernels as a broadcast multiply per
    # tile, halving resident pool bytes AND per-step KV read bytes (paper
    # §3.1 / §7). Valid for every placement × partition; greedy outputs are
    # NOT bit-identical to bf16 (quantized readback), but attention-output
    # cosine ≥ 0.999 is test-asserted.
    kv_dtype: str = "bf16"

    # ---- batching / scheduling ----
    max_batch: int = 8
    scheduler: str = "fcfs"
    decode_headroom: int = 8           # tokens reserved per admitted request
    # Refcounted prompt-prefix sharing: at admission, full blocks whose
    # token content matches a live request's prompt prefix are MAPPED onto
    # that donor's physical blocks (copy-on-write on divergence) and only
    # the unshared suffix is charged against the pool / prefilled. Greedy
    # outputs are bit-identical with this on or off; it strictly increases
    # the concurrency a fixed pool admits for common-prefix workloads.
    prefix_sharing: bool = False
    # Chunked paged prefill: split every prompt into block-aligned chunks of
    # at most this many tokens; each engine iteration runs AT MOST ONE chunk
    # alongside the full decode batch (this is the per-iteration prefill
    # token budget), the chunk's KV is written into the pool as it
    # completes (blocks allocated incrementally), and admission charges
    # only the first chunk — so peak prefill memory is O(chunk) instead of
    # O(prompt), long prompts stop head-of-line-blocking running decodes,
    # and a prompt larger than the currently-free pool is admitted and
    # completes as earlier requests retire. Greedy outputs are
    # bit-identical with chunking on or off. None = one-shot prefill.
    # (MoE models run one-shot regardless: a chunk boundary changes
    # capacity-dispatch groups, the same reason prefix sharing recomputes.)
    prefill_chunk_tokens: Optional[int] = None

    # ---- fault tolerance (shard health machine; serving/faults.py) ----
    # Failed probes / corrupted-output validations a shard may accumulate
    # before being declared DEAD (quarantine + request recovery). A
    # transient fault that clears within fault_retry_limit - 1 strikes
    # recovers via retry with no eviction at all.
    fault_retry_limit: int = 3
    # Host-side backoff between retries (seconds; attempt i sleeps
    # backoff·2^i). 0 keeps tests/CI instant — real deployments set it to
    # their RPC timeout scale.
    fault_retry_backoff_s: float = 0.0

    # ---- decode backend / RNG ----
    decode_backend: str = "jnp"
    # fallback sampling seed for requests whose SamplingParams.seed is None
    # (each request's stream is fold_in(PRNGKey(seed), token_index))
    seed: int = 0

    def __post_init__(self):
        if self.placement not in PLACEMENTS:
            raise ValueError(f"placement must be one of {PLACEMENTS}; "
                             f"got {self.placement!r}")
        if self.partition not in PARTITIONS:
            raise ValueError(f"partition must be one of {PARTITIONS}; "
                             f"got {self.partition!r}")
        if self.scheduler not in SCHEDULERS:
            raise ValueError(f"scheduler must be one of {SCHEDULERS}; "
                             f"got {self.scheduler!r}")
        if self.decode_backend not in BACKENDS:
            raise ValueError(f"decode_backend must be one of {BACKENDS}; "
                             f"got {self.decode_backend!r}")
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {KV_DTYPES}; got "
                f"{self.kv_dtype!r} (placement={self.placement!r}, "
                f"partition={self.partition!r})")
        for field in ("attention_workers", "expert_workers", "num_blocks",
                      "block_size", "max_batch"):
            if getattr(self, field) < 1:
                raise ValueError(f"{field} must be >= 1; "
                                 f"got {getattr(self, field)}")
        if self.decode_headroom < 0:
            raise ValueError("decode_headroom must be >= 0")
        if self.fault_retry_limit < 1:
            raise ValueError(f"fault_retry_limit must be >= 1; "
                             f"got {self.fault_retry_limit}")
        if self.fault_retry_backoff_s < 0:
            raise ValueError(f"fault_retry_backoff_s must be >= 0; "
                             f"got {self.fault_retry_backoff_s}")
        if self.prefill_chunk_tokens is not None:
            if self.prefill_chunk_tokens < 1:
                raise ValueError(
                    f"prefill_chunk_tokens must be >= 1 (or None for "
                    f"one-shot prefill); got {self.prefill_chunk_tokens}")
            if self.prefill_chunk_tokens % self.block_size:
                raise ValueError(
                    f"prefill_chunk_tokens ({self.prefill_chunk_tokens}) "
                    f"must be a multiple of block_size ({self.block_size}) "
                    f"— every chunk boundary except the prompt's final "
                    f"partial block must be block-aligned so chunk KV "
                    f"scatters into whole pool blocks")
        if self.kv_shards is not None and self.kv_shards < 1:
            raise ValueError(f"kv_shards must be >= 1 (or None to derive); "
                             f"got {self.kv_shards}")
        if self.placement != "homogeneous" and self.partition == "block":
            shards = self.kv_shards
            if shards is not None and shards != self.attention_workers:
                raise ValueError(
                    "block partition shards the pool over the workers: "
                    f"kv_shards ({shards}) must equal attention_workers "
                    f"({self.attention_workers})")
        if self.num_blocks % self.resolved_kv_shards:
            raise ValueError(
                f"num_blocks ({self.num_blocks}) must divide evenly over "
                f"kv_shards ({self.resolved_kv_shards})")

    # ------------------------------------------------------------------
    @property
    def resolved_kv_shards(self) -> int:
        """kv_shards with the block-partition default applied: the pool's
        block axis is sharded over exactly the attention workers."""
        if self.kv_shards is not None:
            return self.kv_shards
        if self.placement != "homogeneous" and self.partition == "block":
            return self.attention_workers
        return 1

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


DISAGG_ROLES = ("prefill", "decode")


@dataclasses.dataclass(frozen=True)
class DisaggConfig:
    """Prefill/decode disaggregation knobs — drives the
    :class:`~repro.serving.cluster.PrefillEngine` /
    :class:`~repro.serving.cluster.DecodeEngine` split of ``LLMEngine``
    (serving/cluster/). One instance is shared by a replica pair; ``role``
    names which side an engine plays.
    """

    role: str = "prefill"
    # simulated wire budget: physical KV blocks a decode replica lands per
    # engine step while draining its TransferQueue. 0 = unbounded (a whole
    # payload imports in one step). Small values stretch transfers over
    # several steps — the window the interrupted-by-shard-death tests hit.
    transfer_blocks_per_step: int = 8
    # prefill-side prefix retention: an exported request's prompt blocks
    # stay resident (and registered in the PrefixIndex) as donor prefixes,
    # LRU-evicted under pool pressure — same-prefix followers routed to
    # this prefill engine skip their shared prefill. Only effective with
    # EngineConfig.prefix_sharing.
    retain_prefixes: bool = True
    max_retained_seqs: int = 32
    # transfer attempts per handoff before the decode replica gives up and
    # raises a contextual HandoffError (each mid-transfer shard death
    # resets + requeues the handoff and burns one attempt)
    max_transfer_attempts: int = 3

    def __post_init__(self):
        if self.role not in DISAGG_ROLES:
            raise ValueError(f"role must be one of {DISAGG_ROLES}; "
                             f"got {self.role!r}")
        if self.transfer_blocks_per_step < 0:
            raise ValueError(
                f"transfer_blocks_per_step must be >= 0 (0 = unbounded); "
                f"got {self.transfer_blocks_per_step}")
        if self.max_retained_seqs < 0:
            raise ValueError(f"max_retained_seqs must be >= 0; "
                             f"got {self.max_retained_seqs}")
        if self.max_transfer_attempts < 1:
            raise ValueError(f"max_transfer_attempts must be >= 1; "
                             f"got {self.max_transfer_attempts}")

    def replace(self, **kw) -> "DisaggConfig":
        return dataclasses.replace(self, **kw)
