"""EngineStats — the one serving-metrics surface.

Every engine generation has shared this dataclass; it now lives in its own
module (the legacy ``serving/engine.py`` that used to host it is gone).
``LLMEngine`` populates the core counters; the disaggregated-cluster
engines (``serving/cluster/``) add the handoff/transfer surface:

  * ``kv_bytes_transferred`` — physical KV bytes landed on a decode
    replica's pool through block-granular handoff imports;
  * ``handoff_latencies`` — seconds from a handoff payload arriving at a
    decode replica (PreallocQueue) to its last block written (TransferQueue
    drained); :meth:`handoff_percentiles` is the p50/p90/p99 view;
  * ``router_affinity_hits`` — requests the :class:`ClusterRouter` routed
    to this replica because its prefix was already resident there (the
    prefix-affinity win ``bench_disagg_cluster`` measures).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_generated: int = 0
    batch_sizes: List[int] = dataclasses.field(default_factory=list)
    step_times: List[float] = dataclasses.field(default_factory=list)
    # per-request latency samples (seconds) — populated by observe_request
    # on retirement; the percentile surface bench_serving reports
    request_ttfts: List[float] = dataclasses.field(default_factory=list)
    request_tbts: List[float] = dataclasses.field(default_factory=list)
    preemptions: int = 0
    # prefix sharing (LLMEngine with EngineConfig.prefix_sharing):
    # physical blocks mapped onto a donor's at admission, and prompt tokens
    # whose prefill COMPUTE was skipped (MoE shares memory but recomputes,
    # so its blocks_shared can grow while prefill_tokens_skipped stays 0)
    blocks_shared: int = 0
    prefill_tokens_skipped: int = 0
    # chunked paged prefill (LLMEngine with EngineConfig.prefill_chunk_
    # tokens): chunk model calls run, and the largest dense KV slab one
    # prefill call materialised before scattering it into the pool (tokens)
    # — bounded by the chunk size when chunking is on, by the longest
    # prompt when off (the admission-capping transient the tentpole kills)
    prefill_chunks_run: int = 0
    max_prefill_slab_tokens: int = 0
    # fault tolerance (LLMEngine with a FaultInjector / shard health
    # machine, serving/faults.py): shard lifecycle counts, retry volume,
    # and per-request recovery latency samples (seconds from the shard
    # being declared dead to the victim request decodable again on the
    # surviving shards — detection + eviction + recompute re-admission)
    shard_failures: int = 0
    shard_rejoins: int = 0
    transient_faults_recovered: int = 0
    fault_retries: int = 0
    straggle_steps: int = 0
    requests_recovered: int = 0
    recovery_latencies: List[float] = dataclasses.field(default_factory=list)
    # disaggregated cluster (serving/cluster/): block-granular KV handoff
    # between a prefill engine and a decode replica, and the router's
    # prefix-affinity accounting. Decode replicas own the transfer view
    # (bytes landed, end-to-end handoff latency); handoff_retries counts
    # transfers reset by a mid-transfer shard death and restarted.
    kv_bytes_transferred: int = 0
    handoff_latencies: List[float] = dataclasses.field(default_factory=list)
    handoff_retries: int = 0
    router_affinity_hits: int = 0
    # quantized KV pool (EngineConfig.kv_dtype): resident bytes of the
    # whole pool allocation (value pools + int8 scale sidecars) and the
    # cumulative bytes the decode hot path streamed over live tokens —
    # int8 lands both at ≈ 0.5× their bf16 values for hd ≫ 4 (hd + 4
    # bytes per token-head vs 2·hd), the reduction bench_serving asserts
    kv_pool_bytes_resident: int = 0
    kv_bytes_read: int = 0

    @property
    def mean_batch(self) -> float:
        return float(np.mean(self.batch_sizes)) if self.batch_sizes else 0.0

    @property
    def throughput(self) -> float:
        t = sum(self.step_times)
        return self.tokens_generated / t if t > 0 else 0.0

    @property
    def mean_tbt(self) -> float:
        return float(np.mean(self.step_times)) if self.step_times else 0.0

    @property
    def handoffs_completed(self) -> int:
        """Handoff payloads fully landed on this replica's pool."""
        return len(self.handoff_latencies)

    @property
    def kv_bytes_read_per_step(self) -> float:
        """Mean KV bytes one decode iteration streams from the pool
        (live-token bytes over unique physical blocks, scales included)."""
        return self.kv_bytes_read / self.steps if self.steps else 0.0

    # ---------------- per-request latency surface ----------------
    def observe_request(self, req) -> None:
        """Fold one retired request's latencies in: TTFT (arrival to first
        token) and its mean time-between-tokens."""
        if req.first_token_s is not None:
            self.request_ttfts.append(req.first_token_s - req.arrival_s)
        if len(req.token_times) >= 2:
            self.request_tbts.append(req.tbt_s())

    @staticmethod
    def _pcts(samples: List[float]) -> Dict[str, float]:
        if not samples:
            return {"p50": 0.0, "p90": 0.0, "p99": 0.0}
        arr = np.asarray(samples, np.float64)
        return {p: float(np.percentile(arr, q))
                for p, q in (("p50", 50), ("p90", 90), ("p99", 99))}

    def ttft_percentiles(self) -> Dict[str, float]:
        """p50/p90/p99 time-to-first-token over retired requests (s)."""
        return self._pcts(self.request_ttfts)

    def tbt_percentiles(self) -> Dict[str, float]:
        """p50/p90/p99 of per-request mean time-between-tokens (s)."""
        return self._pcts(self.request_tbts)

    def recovery_percentiles(self) -> Dict[str, float]:
        """p50/p90/p99 request-recovery latency (s): shard declared dead →
        victim request decodable again on the surviving shards."""
        return self._pcts(self.recovery_latencies)

    def handoff_percentiles(self) -> Dict[str, float]:
        """p50/p90/p99 handoff latency (s): payload enqueued on the decode
        replica → last physical block written into its pool."""
        return self._pcts(self.handoff_latencies)

    def summary(self) -> Dict[str, float]:
        """Flat scalar summary (the dict bench_serving reports)."""
        out = {
            "steps": self.steps,
            "tokens_generated": self.tokens_generated,
            "requests": len(self.request_ttfts),
            "mean_batch": self.mean_batch,
            "throughput_tok_s": self.throughput,
            "mean_tbt_s": self.mean_tbt,
            "preemptions": self.preemptions,
            "blocks_shared": self.blocks_shared,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            "prefill_chunks_run": self.prefill_chunks_run,
            "max_prefill_slab_tokens": self.max_prefill_slab_tokens,
            "shard_failures": self.shard_failures,
            "shard_rejoins": self.shard_rejoins,
            "transient_faults_recovered": self.transient_faults_recovered,
            "fault_retries": self.fault_retries,
            "straggle_steps": self.straggle_steps,
            "requests_recovered": self.requests_recovered,
            "kv_bytes_transferred": self.kv_bytes_transferred,
            "kv_pool_bytes_resident": self.kv_pool_bytes_resident,
            "kv_bytes_read_per_step": self.kv_bytes_read_per_step,
            "handoffs_completed": self.handoffs_completed,
            "handoff_retries": self.handoff_retries,
            "router_affinity_hits": self.router_affinity_hits,
        }
        for name, pcts in (("ttft", self.ttft_percentiles()),
                           ("tbt", self.tbt_percentiles()),
                           ("recovery", self.recovery_percentiles()),
                           ("handoff", self.handoff_percentiles())):
            for p, v in pcts.items():
                out[f"{name}_{p}_s"] = v
        return out
