"""Iteration-level scheduling for the serving engines.

Two generations live here:

  * :class:`Scheduler` — the original Orca-style FCFS admitter used by the
    legacy ``Engine``/``DisaggEngine`` classes (kept verbatim as the parity
    oracle; slated for deletion with them).
  * :class:`SchedulingPolicy` + :class:`RequestScheduler` — the pluggable
    scheduler behind :class:`repro.serving.llm_engine.LLMEngine`. The
    policy decides *who* gets admitted and *who* gets evicted under pool
    pressure; the scheduler owns the queues and the KV-pool bookkeeping
    (allocate on admit, free on retire/preempt). This is the hook surface
    the ROADMAP's prefix-sharing and chunked-prefill items plug into.

Preemption model (``PreemptingPolicy``): when a decode iteration needs more
blocks than the pool has free (requests outliving their ``decode_headroom``
margin), the policy picks a victim — LIFO over admission order, vLLM's
choice: the most recently admitted request has the least sunk work — whose
blocks are freed back to the pool. The victim's generated tokens are kept;
on re-admission its KV is *recomputed* by re-prefilling prompt + generated
tokens (minus the still-unstored last token — exactly the fault-tolerance
recovery path, paper §5), so greedy decoding resumes bit-identically.
Preempted requests re-enter at the FRONT of the waiting queue.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Protocol, Sequence, runtime_checkable

from repro.serving.kvcache import PagedKVCache
from repro.serving.request import Request, State


@dataclasses.dataclass
class Scheduler:
    """Legacy FCFS admitter (pre-``LLMEngine``; parity oracle only)."""

    kv: PagedKVCache
    max_batch: int
    decode_headroom: int = 8     # extra tokens reserved per admitted request

    def __post_init__(self):
        self.waiting: List[Request] = []
        self.running: List[Request] = []

    def submit(self, reqs: List[Request]) -> None:
        self.waiting.extend(reqs)

    def admit(self) -> List[Request]:
        """Move as many waiting requests to running as memory allows.
        Returns the newly admitted requests (they need prefill)."""
        admitted = []
        while self.waiting and len(self.running) < self.max_batch:
            req = self.waiting[0]
            need = len(req.prompt) + self.decode_headroom
            if not self.kv.can_allocate(need):
                break
            self.waiting.pop(0)
            self.kv.allocate(req.rid, len(req.prompt))
            req.state = State.RUNNING
            self.running.append(req)
            admitted.append(req)
        return admitted

    def retire_finished(self) -> List[Request]:
        done = [r for r in self.running if r.state == State.FINISHED]
        for r in done:
            self.kv.free_seq(r.rid)
        self.running = [r for r in self.running if r.state != State.FINISHED]
        return done

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)


# ======================================================================
# Pluggable scheduling (LLMEngine)
# ======================================================================

@runtime_checkable
class SchedulingPolicy(Protocol):
    """Decides admission order and preemption victims.

    ``select_victim`` returns the running request to evict under pool
    pressure, or ``None`` when the policy does not preempt (the engine then
    surfaces :class:`repro.serving.kvcache.PoolExhausted`). ``running`` is
    in admission order; the victim must come from it.
    """

    name: str
    preemptible: bool

    def select_victim(self, running: Sequence[Request]) -> Optional[Request]:
        ...


class FCFSPolicy:
    """Strict arrival order, no eviction — the legacy behaviour, now
    explicit: under pool pressure the engine raises ``PoolExhausted``
    instead of stranding the pool mid-decode."""

    name = "fcfs"
    preemptible = False

    def select_victim(self, running: Sequence[Request]) -> Optional[Request]:
        return None

    def __repr__(self):
        return "FCFSPolicy()"


class PreemptingPolicy(FCFSPolicy):
    """FCFS admission + LIFO victim eviction under pool pressure."""

    name = "preempt"
    preemptible = True

    def select_victim(self, running: Sequence[Request]) -> Optional[Request]:
        # last admitted = least sunk prefill/decode work (vLLM's recompute
        # preemption picks the same victim); never the head of the batch —
        # evicting the oldest request could livelock admission against it.
        if len(running) < 2:
            return None
        return running[-1]

    def __repr__(self):
        return "PreemptingPolicy()"


POLICIES = {"fcfs": FCFSPolicy, "preempt": PreemptingPolicy}


def make_policy(name: str) -> SchedulingPolicy:
    try:
        return POLICIES[name]()
    except KeyError:
        raise ValueError(f"unknown scheduler policy {name!r}; "
                         f"choose from {sorted(POLICIES)}") from None


@dataclasses.dataclass
class RequestScheduler:
    """Queue + KV-pool bookkeeping behind ``LLMEngine``.

    Differences from the legacy :class:`Scheduler`:
      * the admission/eviction *decisions* are delegated to a
        :class:`SchedulingPolicy`;
      * preempted requests are supported end to end: :meth:`preempt` frees
        the victim's blocks back to the pool and requeues it at the front;
        :meth:`admit` re-admits it sized for prompt + already-generated
        tokens (the recompute re-prefill needs them all stored again).
    """

    kv: PagedKVCache
    max_batch: int
    policy: SchedulingPolicy = dataclasses.field(default_factory=FCFSPolicy)
    decode_headroom: int = 8

    def __post_init__(self):
        self.waiting: List[Request] = []
        self.running: List[Request] = []   # admission order (LIFO eviction)
        self.n_preemptions = 0

    # ---- queue management ----
    def submit(self, reqs: Sequence[Request]) -> None:
        self.waiting.extend(reqs)

    def stored_tokens(self, req: Request) -> int:
        """Tokens that must be in the pool for `req` to decode: the prompt
        plus every generated token except the still-unstored last one."""
        return len(req.prompt) + max(len(req.output) - 1, 0)

    def admit(self) -> List[Request]:
        """FCFS-prefix admission: move waiting requests to running while the
        pool can hold their stored tokens + decode headroom. The head of the
        queue blocks the tail (head-of-line blocking is the documented FCFS
        trade-off — a size-aware policy can override this hook)."""
        admitted = []
        while self.waiting and len(self.running) < self.max_batch:
            req = self.waiting[0]
            need = self.stored_tokens(req) + self.decode_headroom
            if not self.kv.can_allocate(need):
                break
            self.waiting.pop(0)
            self.kv.allocate(req.rid, self.stored_tokens(req))
            req.state = State.RUNNING
            self.running.append(req)
            admitted.append(req)
        return admitted

    def preempt(self, req: Request) -> int:
        """Evict `req`: free its blocks back to the pool and requeue it at
        the FRONT of the waiting queue (preempted requests have priority).
        Returns the number of blocks freed."""
        freed = len(self.kv.tables[req.rid])
        self.kv.free_seq(req.rid)
        self.running.remove(req)
        req.state = State.PREEMPTED
        self.waiting.insert(0, req)
        self.n_preemptions += 1
        return freed

    def retire_finished(self) -> List[Request]:
        done = [r for r in self.running if r.state == State.FINISHED]
        for r in done:
            self.kv.free_seq(r.rid)
        self.running = [r for r in self.running if r.state != State.FINISHED]
        return done

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
