"""Iteration-level scheduling for the serving engines.

:class:`SchedulingPolicy` + :class:`RequestScheduler` — the pluggable
scheduler behind :class:`repro.serving.llm_engine.LLMEngine`. The
policy decides *who* gets admitted and *who* gets evicted under pool
pressure; the scheduler owns the queues and the KV-pool bookkeeping
(allocate on admit, free on retire/preempt). This is the hook surface
the prefix-sharing, chunked-prefill, and disaggregated-cluster layers
plug into (transfer-complete admission enters through
:meth:`RequestScheduler.admit_prefilled`). The legacy Orca-style
``Scheduler`` that served the deleted oracle engines is gone.

Preemption model (``PreemptingPolicy``): when a decode iteration needs more
blocks than the pool has free (requests outliving their ``decode_headroom``
margin), the policy picks a victim — LIFO over admission order, vLLM's
choice: the most recently admitted request has the least sunk work — whose
blocks are freed back to the pool. The victim's generated tokens are kept;
on re-admission its KV is *recomputed* by re-prefilling prompt + generated
tokens (minus the still-unstored last token — exactly the fault-tolerance
recovery path, paper §5), so greedy decoding resumes bit-identically.
Preempted requests re-enter at the FRONT of the waiting queue.
"""
from __future__ import annotations

import dataclasses
from typing import (Dict, List, Optional, Protocol, Sequence, Set, Tuple,
                    runtime_checkable)

from repro.serving.kvcache import PagedKVCache
from repro.serving.request import Request, State


# ======================================================================
# Pluggable scheduling (LLMEngine)
# ======================================================================

@runtime_checkable
class SchedulingPolicy(Protocol):
    """Decides admission order and preemption victims.

    ``select_victim`` returns the running request to evict under pool
    pressure, or ``None`` when the policy does not preempt (the engine then
    surfaces :class:`repro.serving.kvcache.PoolExhausted`). ``running`` is
    in admission order; the victim must come from it.
    """

    name: str
    preemptible: bool

    def select_victim(self, running: Sequence[Request]) -> Optional[Request]:
        ...


class FCFSPolicy:
    """Strict arrival order, no eviction — the legacy behaviour, now
    explicit: under pool pressure the engine raises ``PoolExhausted``
    instead of stranding the pool mid-decode."""

    name = "fcfs"
    preemptible = False

    def select_victim(self, running: Sequence[Request]) -> Optional[Request]:
        return None

    def __repr__(self):
        return "FCFSPolicy()"


class PreemptingPolicy(FCFSPolicy):
    """FCFS admission + LIFO victim eviction under pool pressure."""

    name = "preempt"
    preemptible = True

    def select_victim(self, running: Sequence[Request]) -> Optional[Request]:
        # last admitted = least sunk prefill/decode work (vLLM's recompute
        # preemption picks the same victim); never the head of the batch —
        # evicting the oldest request could livelock admission against it.
        if len(running) < 2:
            return None
        return running[-1]

    def __repr__(self):
        return "PreemptingPolicy()"


class ChunkedPrefillPolicy:
    """Chunked admission: wraps an inner admission/eviction policy and
    admits PARTIAL prompts — the ROADMAP's reserved scheduler hook.

    Admission charges only the request's FIRST prefill chunk (plus decode
    headroom) against the free list instead of the whole prompt, so a long
    prompt is admitted while most of the pool is still held by running
    requests; its remaining blocks are allocated incrementally, one chunk
    per engine iteration, as earlier requests retire and free them. The
    scheduler carries a per-request prefill CURSOR (tokens computed so
    far); the engine runs at most one chunk per iteration alongside the
    full decode batch (``prefill_chunk_tokens`` is the per-iteration
    prefill token budget), so decode TBT never stalls behind a long
    prefill. Victim selection under pool pressure delegates to the inner
    policy unchanged."""

    def __init__(self, inner: SchedulingPolicy, chunk_tokens: int):
        if chunk_tokens < 1:
            raise ValueError(f"chunk_tokens must be >= 1; got {chunk_tokens}")
        self.inner = inner
        self.chunk_tokens = chunk_tokens
        self.name = f"chunked[{inner.name}]"

    @property
    def preemptible(self) -> bool:
        return self.inner.preemptible

    def select_victim(self, running: Sequence[Request]) -> Optional[Request]:
        return self.inner.select_victim(running)

    def __repr__(self):
        return (f"ChunkedPrefillPolicy({self.inner!r}, "
                f"chunk_tokens={self.chunk_tokens})")


POLICIES = {"fcfs": FCFSPolicy, "preempt": PreemptingPolicy}


def make_policy(name: str,
                prefill_chunk_tokens: Optional[int] = None
                ) -> SchedulingPolicy:
    """Build a policy by name, optionally wrapped for chunked prefill
    (``prefill_chunk_tokens`` is the per-iteration prefill token budget)."""
    try:
        policy = POLICIES[name]()
    except KeyError:
        raise ValueError(f"unknown scheduler policy {name!r}; "
                         f"choose from {sorted(POLICIES)}") from None
    if prefill_chunk_tokens is not None:
        policy = ChunkedPrefillPolicy(policy, prefill_chunk_tokens)
    return policy


# ======================================================================
# Prefix sharing (block-granular prompt-prefix index)
# ======================================================================

class PrefixIndex:
    """Block-granular prompt-prefix trie consulted at admission.

    Nodes are keyed by the token-content CHAIN of the first i full blocks —
    ``key_i = (key_{i-1}, tuple(prompt[i·bs:(i+1)·bs]))`` — so lookup is
    exact (dict equality on the token tuples; hashes only route buckets, a
    collision can never alias two different prefixes). A node records which
    LIVE requests hold a physical block with that content at that table
    slot; any of them can donate (``PagedKVCache.share_blocks`` maps the
    new request's table onto the donor's blocks and bumps refcounts).

    Only FULL blocks are indexed: a partial tail block is never shared at
    admission (the allocator's copy-on-write handles partial-tail sharing
    for explicit forks). Registrants are removed on retire AND on preempt —
    an evicted request's table is gone, so it can no longer donate (its
    blocks survive through the refcounts of any sharer that remains).
    """

    def __init__(self, block_size: int):
        self.block_size = block_size
        self._nodes: Dict[Tuple, Set[int]] = {}
        self._keys_of: Dict[int, List[Tuple]] = {}

    def _chain(self, prompt: Sequence[int]):
        key: Tuple = ()
        bs = self.block_size
        for i in range(len(prompt) // bs):
            key = (key, tuple(prompt[i * bs:(i + 1) * bs]))
            yield key

    def register(self, rid: int, prompt: Sequence[int]) -> None:
        """Index every full prompt block of `prompt` for `rid`. Idempotent
        and INCREMENTAL: re-registering (or registering a longer prefix of
        the same prompt) only adds blocks deeper than those already
        indexed, so callers need not track what is registered."""
        keys = self._keys_of.get(rid, [])
        for depth, key in enumerate(self._chain(prompt)):
            if depth < len(keys):
                continue                 # already indexed (shallower call)
            self._nodes.setdefault(key, set()).add(rid)
            keys.append(key)
        if keys:
            self._keys_of[rid] = keys

    def unregister(self, rid: int) -> None:
        for key in self._keys_of.pop(rid, ()):
            rids = self._nodes.get(key)
            if rids is not None:
                rids.discard(rid)
                if not rids:
                    del self._nodes[key]

    def match(self, prompt: Sequence[int]) -> Tuple[Optional[int], int]:
        """Deepest indexed block-aligned prefix of `prompt`: returns
        (donor rid, matched tokens) — (None, 0) when nothing matches.
        The donor is the smallest rid at the deepest node (deterministic);
        its table covers every shallower block too."""
        donor, matched = None, 0
        for i, key in enumerate(self._chain(prompt)):
            rids = self._nodes.get(key)
            if not rids:
                break
            donor = min(rids)
            matched = (i + 1) * self.block_size
        return donor, matched

    def __len__(self) -> int:
        return len(self._nodes)


@dataclasses.dataclass
class RequestScheduler:
    """Queue + KV-pool bookkeeping behind ``LLMEngine``.

    Design points:
      * the admission/eviction *decisions* are delegated to a
        :class:`SchedulingPolicy`;
      * preempted requests are supported end to end: :meth:`preempt` frees
        the victim's blocks back to the pool and requeues it at the front;
        :meth:`admit` re-admits it sized for prompt + already-generated
        tokens (the recompute re-prefill needs them all stored again);
      * with ``prefix_sharing`` a :class:`PrefixIndex` is consulted in
        :meth:`admit`: a waiting request whose prompt starts with full
        blocks already resident (another live request's identical prompt
        prefix) is mapped onto those physical blocks
        (``PagedKVCache.share_blocks``) and admission charges only the
        UNSHARED suffix against the free list — the same pool memory
        admits strictly more concurrent requests. The engine reads
        :meth:`shared_prefix_tokens` to slice the prompt before prefill
        (matched blocks are never recomputed);
      * with a :class:`ChunkedPrefillPolicy` (``chunk_tokens`` set),
        admission charges only the FIRST prefill chunk and the scheduler
        carries a per-request prefill cursor (:meth:`prefill_cursor`);
        the engine advances the oldest incomplete prefill by one chunk per
        iteration (:meth:`next_prefill` / :meth:`advance_prefill`) while
        the decode batch — everyone for whom :meth:`prefill_done` — keeps
        decoding. Prefix-index registration follows the WRITES, so a
        waiting request can never match a donor block whose KV is not in
        the pool yet.
    """

    kv: PagedKVCache
    max_batch: int
    policy: SchedulingPolicy = dataclasses.field(default_factory=FCFSPolicy)
    decode_headroom: int = 8
    prefix_sharing: bool = False

    def __post_init__(self):
        self.waiting: List[Request] = []
        self.running: List[Request] = []   # admission order (LIFO eviction)
        self.n_preemptions = 0
        self.prefix_index: Optional[PrefixIndex] = (
            PrefixIndex(self.kv.block_size) if self.prefix_sharing else None)
        self._shared: Dict[int, int] = {}  # rid -> shared prefix tokens
        # rid -> prefill cursor (tokens computed & written so far) for
        # requests admitted CHUNKED and still mid-prefill; absence means the
        # prefill is complete (or the request was admitted one-shot)
        self._prefill_cursor: Dict[int, int] = {}
        if self.chunk_tokens is not None and \
                self.chunk_tokens % self.kv.block_size:
            # EngineConfig validates this too; direct RequestScheduler
            # callers must fail at construction, not mid-run when a
            # misaligned cursor hits the block-aligned gather
            raise ValueError(
                f"prefill chunk_tokens ({self.chunk_tokens}) must be a "
                f"multiple of the KV block size ({self.kv.block_size})")

    @property
    def chunk_tokens(self) -> Optional[int]:
        """Per-iteration prefill token budget (None = one-shot prefill)."""
        return getattr(self.policy, "chunk_tokens", None)

    # ---- queue management ----
    def submit(self, reqs: Sequence[Request]) -> None:
        self.waiting.extend(reqs)

    def stored_tokens(self, req: Request) -> int:
        """Tokens that must be in the pool for `req` to decode: the prompt
        plus every generated token except the still-unstored last one."""
        return len(req.prompt) + max(len(req.output) - 1, 0)

    def shared_prefix_tokens(self, rid: int) -> int:
        """Block-aligned prompt tokens this running request shares with a
        donor (0 without prefix sharing). The engine's prefill/recompute
        slices these off the prompt — their KV is already in the pool."""
        return self._shared.get(rid, 0)

    def _match_prefix(self, req: Request, stored: int
                      ) -> Tuple[Optional[int], int]:
        """Deepest usable prefix match for `req`: capped one block short of
        `stored` tokens so at least one token is left to prefill (the last
        prompt token's logits seed sampling; a recompute needs a non-empty
        suffix too), and capped at the DONOR's allocated length — a chunked
        donor's table grows one chunk per iteration, so a recipient can
        only map onto blocks the donor already has (they are written by
        the time the recipient's own prefill reads them: chunk prefills
        run FCFS over admission order, and the same-wave canonical-fill
        invariant covers the donor's in-flight chunk)."""
        if self.prefix_index is None:
            return None, 0
        donor, matched = self.prefix_index.match(req.prompt)
        bs = self.kv.block_size
        matched = min(matched, ((stored - 1) // bs) * bs)
        if donor is not None:
            matched = min(matched,
                          (self.kv.lengths.get(donor, 0) // bs) * bs)
        if donor is None or matched <= 0:
            return None, 0
        return donor, matched

    def admit(self) -> List[Request]:
        """FCFS-prefix admission: move waiting requests to running while the
        pool can hold their stored tokens + decode headroom. The head of the
        queue blocks the tail (head-of-line blocking is the documented FCFS
        trade-off — a size-aware policy can override this hook). With prefix
        sharing, only the unshared suffix is charged against the pool."""
        admitted = []
        chunk = self.chunk_tokens
        while self.waiting and len(self.running) < self.max_batch:
            req = self.waiting[0]
            stored = self.stored_tokens(req)
            donor, shared = self._match_prefix(req, stored)
            if chunk:
                # chunked admission: charge only the FIRST chunk (plus
                # headroom) up front — later chunks allocate incrementally
                # as the prefill progresses. Guards against admissions
                # that could NEVER complete (they would deadlock
                # mid-prefill instead of surfacing SchedulingStalled):
                # the pool must hold this request outright, and admitting
                # it must leave every OLDER mid-prefill prompt completable
                # (only the oldest prefill progresses, so a younger
                # partial prompt's holdings are stuck until it finishes —
                # decoder holdings, by contrast, free as they retire).
                # capacity_blocks, not num_blocks: a fault-quarantined
                # shard's blocks are not coming back until rejoin
                if self.kv.blocks_needed(stored + self.decode_headroom) > \
                        self.kv.capacity_blocks:
                    break
                first = min(chunk, stored - shared)
                if not self._chunked_commitment_ok(donor, shared, first):
                    break
            else:
                first = stored - shared
            if not self.kv.can_allocate(first + self.decode_headroom):
                break
            self.waiting.pop(0)
            if shared:
                self.kv.share_blocks(donor, req.rid, shared)
            self.kv.allocate(req.rid, shared + first)
            self._shared[req.rid] = shared
            if chunk:
                self._prefill_cursor[req.rid] = shared
            if self.prefix_index is not None:
                # the full prompt is indexable immediately, even though a
                # CHUNKED donor's blocks fill over many iterations, because
                # an allocated block is always eventually written: matches
                # are capped at the donor's ALLOCATED length
                # (_match_prefix), the only reader of a borrowed prefix is
                # the recipient's own prefill (its first chunk / suffix
                # gather) which runs strictly AFTER the older donor's
                # chunks (next_prefill is FCFS over admission order), and a
                # mid-prefill request is never a preemption victim
                # anywhere (decode pool pressure selects only among
                # prefill-complete requests; chunk growth never preempts —
                # llm_engine._free_blocks_for_chunk), so the promise cannot
                # be revoked. One-shot admission keeps the same-wave
                # canonical-fill invariant (serving/kvcache.py).
                self.prefix_index.register(req.rid, req.prompt)
            req.state = State.RUNNING
            self.running.append(req)
            admitted.append(req)
        return admitted

    def admit_prefilled(self, req: Request) -> bool:
        """Transfer-complete admission (disaggregated cluster): `req`'s KV
        is ALREADY resident in this pool — its block table, refcounts, and
        stored length were rebuilt by ``PagedKVCache.prealloc_handoff`` and
        every block's bytes have landed — so admission skips allocation AND
        prefill entirely: the request joins the prebuilt decode batch with
        only batch-slot and bookkeeping work. The ``SchedulingPolicy``
        still governs it from here on (it is a normal ``running`` member
        for victim selection and retirement). Returns False when the batch
        is full this iteration — the caller's WaitingQueue holds the
        request (its blocks stay resident) and retries next step."""
        if len(self.running) >= self.max_batch:
            return False
        if req.rid not in self.kv.tables:
            raise ValueError(
                f"admit_prefilled: request {req.rid} has no imported block "
                f"table in this pool — the handoff transfer must complete "
                f"(prealloc + every block written) before admission")
        self._shared[req.rid] = 0
        if self.prefix_index is not None:
            # an imported request is as good a donor as a locally prefilled
            # one: its blocks are resident and its table covers the prompt
            self.prefix_index.register(req.rid, req.prompt)
        req.state = State.RUNNING
        self.running.append(req)
        return True

    def _chunked_commitment_ok(self, donor: Optional[int], shared: int,
                               first: int) -> bool:
        """Aggregate over-commitment guard for chunked admission: would
        admitting a new partial prompt still leave every OLDER mid-prefill
        request O able to complete? Chunk prefills run strictly FCFS, so
        the PHYSICAL blocks referenced by prefills younger than O (plus the
        new request's) are stuck until O finishes — each O needs its full
        allocation (stored + headroom) to fit in ``num_blocks`` minus
        those stuck holdings. Without this check, several long partial
        prompts admitted together deadlock into PoolExhausted on a pool
        that serves the same workload one-shot (serially) without trouble.

        Stuck blocks are counted as UNIQUE physical ids, excluding O's own
        table — a donor block prefix-shared by K mid-prefill sharers
        counts once, not K times, so co-admitting a common-prefix family
        keeps the capacity win sharing exists for. The new request's
        holdings are its donor's shared blocks (by id) plus
        ``blocks_needed(shared+first) − blocks_needed(shared)`` fresh
        ones (ids unknown until allocation — necessarily disjoint from
        everything live)."""
        mids = [r for r in self.running if r.rid in self._prefill_cursor]
        new_shared = (self.kv.tables[donor][:self.kv.blocks_needed(shared)]
                      if donor is not None else [])
        new_fresh = (self.kv.blocks_needed(shared + first) -
                     self.kv.blocks_needed(shared))
        for i, o in enumerate(mids):
            stuck = {b for y in mids[i + 1:] for b in self.kv.tables[y.rid]}
            stuck.update(new_shared)
            stuck.difference_update(self.kv.tables[o.rid])
            need_o = self.kv.blocks_needed(self.stored_tokens(o) +
                                           self.decode_headroom)
            if need_o + len(stuck) + new_fresh > self.kv.capacity_blocks:
                return False
        return True

    # ---- chunked-prefill cursor surface (ChunkedPrefillPolicy) ----
    def next_prefill(self) -> Optional[Request]:
        """Oldest running request whose chunked prefill is incomplete — the
        one the engine advances by one chunk this iteration (FCFS over the
        admission order; at most one chunk runs per iteration)."""
        for r in self.running:
            if r.rid in self._prefill_cursor:
                return r
        return None

    def prefill_cursor(self, rid: int) -> Optional[int]:
        """Tokens of `rid`'s prompt computed & written so far, or None when
        its prefill is complete (or it was admitted one-shot)."""
        return self._prefill_cursor.get(rid)

    def prefill_done(self, rid: int) -> bool:
        """True when `rid` may join the decode batch (no pending chunks)."""
        return rid not in self._prefill_cursor

    def advance_prefill(self, req: Request, cursor: int) -> None:
        """Record that `req`'s prefill has computed & written `cursor`
        tokens; reaching the stored-token target completes the prefill
        (the request joins the decode batch from the next iteration on)."""
        if cursor >= self.stored_tokens(req):
            self._prefill_cursor.pop(req.rid, None)
        else:
            self._prefill_cursor[req.rid] = cursor

    def _release(self, rid: int) -> None:
        """Drop a request's pool blocks (refcount-aware) and its prefix-
        index registrations — retire and preempt share this path. A block
        another live request still references survives (refcount > 0);
        evicting a sharer can therefore never corrupt its donor or
        recipients."""
        self.kv.free_seq(rid)
        self._shared.pop(rid, None)
        self._prefill_cursor.pop(rid, None)   # a preempted mid-prefill
        # request recomputes from scratch on re-admission (fresh cursor)
        if self.prefix_index is not None:
            self.prefix_index.unregister(rid)

    def preempt(self, req: Request) -> int:
        """Evict `req`: release its block refs (physical blocks return to
        the pool only when no other live request still references them) and
        requeue it at the FRONT of the waiting queue (preempted requests
        have priority). Returns the number of physical blocks freed."""
        free_before = sum(len(s) for s in self.kv._free_shard)
        self._release(req.rid)
        freed = sum(len(s) for s in self.kv._free_shard) - free_before
        self.running.remove(req)
        req.state = State.PREEMPTED
        self.waiting.insert(0, req)
        self.n_preemptions += 1
        return freed

    def retire_finished(self) -> List[Request]:
        done = [r for r in self.running if r.state == State.FINISHED]
        for r in done:
            self._release(r.rid)
        self.running = [r for r in self.running if r.state != State.FINISHED]
        return done

    def cancel_all(self) -> List[Request]:
        """Cleanly cancel every in-flight request (graceful shutdown):
        running requests release their pool blocks (refcount-aware, same
        path as retire/preempt), waiting requests are simply dequeued.
        Returns every cancelled request, running first — the caller marks
        states and emits events."""
        cancelled = list(self.running) + list(self.waiting)
        for r in self.running:
            self._release(r.rid)
        self.running = []
        self.waiting = []
        return cancelled

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
