"""Continuous (iteration-level) batching scheduler — Orca-style, the policy
vLLM uses and the paper's baseline runs. Admits waiting requests whenever the
paged pool can hold their prompt plus a decode-headroom margin, up to
max_batch concurrent sequences; finished sequences release their blocks
immediately."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.serving.kvcache import PagedKVCache
from repro.serving.request import Request, State


@dataclasses.dataclass
class Scheduler:
    kv: PagedKVCache
    max_batch: int
    decode_headroom: int = 8     # extra tokens reserved per admitted request

    def __post_init__(self):
        self.waiting: List[Request] = []
        self.running: List[Request] = []

    def submit(self, reqs: List[Request]) -> None:
        self.waiting.extend(reqs)

    def admit(self) -> List[Request]:
        """Move as many waiting requests to running as memory allows.
        Returns the newly admitted requests (they need prefill)."""
        admitted = []
        while self.waiting and len(self.running) < self.max_batch:
            req = self.waiting[0]
            need = len(req.prompt) + self.decode_headroom
            if not self.kv.can_allocate(need):
                break
            self.waiting.pop(0)
            self.kv.allocate(req.rid, len(req.prompt))
            req.state = State.RUNNING
            self.running.append(req)
            admitted.append(req)
        return admitted

    def retire_finished(self) -> List[Request]:
        done = [r for r in self.running if r.state == State.FINISHED]
        for r in done:
            self.kv.free_seq(r.rid)
        self.running = [r for r in self.running if r.state != State.FINISHED]
        return done

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
