"""Memory-device worker pools + wire-byte accounting (paper §4.2.2, §7).

Canonical home of the pieces every placement strategy composes, rehomed
from the deleted legacy engine modules (``disagg_engine.py`` /
``moe_offload.py`` — their ``Engine``/``DisaggEngine``/``MoEOffloadEngine``
classes survived only as parity oracles and are gone; LLMEngine-vs-LLMEngine
cross-config checks replaced them):

  * :class:`AttentionWorkerPool` — owns partitioning + accounting of
    attention work over the engine's paged block pool, one of three ways:
    "head" (each worker owns Hkv/n heads of every pool block — Lamina's
    choice), "block" (the pool's block axis is sharded and a single
    sequence's round-robin-placed blocks span every worker; per-worker
    §4.2.2 partials merge exactly via the combine identity), or "request"
    (batch-sharded, the load-imbalance baseline). NO partition ever
    materialises a dense seq-major KV view — each worker reads its own
    slice of the block pool in place (the no-densify invariant,
    core/attention_parallel.py);
  * :func:`expected_transfer_bytes` — the paper's §3.1 per-iteration wire
    formula (2 + 2/G)·e·d_q·B·L that tests assert the pool's log matches;
  * :class:`ExpertWorkerPool` — MoE expert offloading (paper §7): expert
    weights live on memory-optimized workers with the same byte-accounting
    contract, plus the analytic bounds ``transfer_bytes_moe`` /
    ``min_bandwidth_moe``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.models.common import ModelConfig

BYTES = 2  # bf16/fp16 wire format (paper Table 2 "e")


@dataclasses.dataclass
class TransferLog:
    q_bytes: int = 0
    kv_bytes: int = 0
    out_bytes: int = 0
    transfers: int = 0

    @property
    def total(self) -> int:
        return self.q_bytes + self.kv_bytes + self.out_bytes


class AttentionWorkerPool:
    """The memory-device pool: stores nothing here (the paged pool is the
    engine's), but owns partitioning + accounting of attention work."""

    def __init__(self, cfg: ModelConfig, n_workers: int = 2,
                 partition: str = "head", backend: str = "jnp",
                 kv_dtype: str = "bf16"):
        self.cfg = cfg
        self.n = n_workers
        self.partition = partition
        self.backend = backend
        self.kv_dtype = kv_dtype
        self.log = TransferLog()
        self.per_worker_kv_bytes = [0] * n_workers
        if partition not in ("head", "request", "block"):
            raise ValueError(f"unknown partition {partition!r}")
        if partition == "head" and cfg.num_kv_heads % n_workers:
            raise ValueError(
                f"head partition needs kv_heads ({cfg.num_kv_heads}) "
                f"divisible by workers ({n_workers}) — paper §5")

    def _account(self, q, k_new, v_new, out, enabled: bool):
        # Only for direct (non-jit) calls: python side effects do not fire
        # per-execution under jit — the engine logs analytically instead.
        if not enabled:
            return
        self.log.q_bytes += q.size * BYTES
        self.log.kv_bytes += (k_new.size + v_new.size) * BYTES
        self.log.out_bytes += out.size * BYTES
        self.log.transfers += 2  # QKV out + result back

    def log_iteration(self, batch: int) -> None:
        """Shape-derived per-iteration accounting (jit-safe path)."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        L = cfg.num_layers
        self.log.q_bytes += batch * cfg.num_heads * hd * BYTES * L
        self.log.kv_bytes += 2 * batch * cfg.num_kv_heads * hd * BYTES * L
        self.log.out_bytes += batch * cfg.num_heads * hd * BYTES * L
        self.log.transfers += 2 * L

    def attend(self, q, k_cache, v_cache, cache_len, k_new, v_new, *,
               sliding_window: int = 0, logit_softcap: float = 0.0,
               account: bool = False) -> jax.Array:
        """q: (B, H, hd); caches HEAD-MAJOR (B, Hkv, S, hd) hold the STORED prefix
        (cache_len tokens); k_new/v_new (B, Hkv, hd) arrive over the wire.
        Each worker computes combine(prefix partial, new partial) on its
        partition (§4.2.2 across workers too). Returns (B, H, hd)."""
        from repro.models.attention import decode_attention_combine

        B, H, hd = q.shape
        Hkv = k_cache.shape[1]
        kw = dict(sliding_window=sliding_window, logit_softcap=logit_softcap,
                  backend=self.backend)
        if self.partition == "head":
            hk = Hkv // self.n
            g = H // Hkv
            outs = []
            for wid in range(self.n):
                sl = slice(wid * hk, (wid + 1) * hk)
                qs = q.reshape(B, Hkv, g, hd)[:, sl].reshape(B, hk * g, hd)
                o = decode_attention_combine(
                    qs, k_cache[:, sl], v_cache[:, sl], cache_len,
                    k_new[:, sl], v_new[:, sl], **kw)
                outs.append(o.reshape(B, hk, g, hd))
                self.per_worker_kv_bytes[wid] += \
                    2 * k_cache[:, sl].size * BYTES
            out = jnp.concatenate(outs, axis=1).reshape(B, H, hd)
        elif self.partition == "request":
            splits = jnp.array_split(jnp.arange(B), self.n)
            outs = []
            for wid, idx in enumerate(splits):
                if len(idx) == 0:
                    continue
                o = decode_attention_combine(
                    q[idx], k_cache[idx], v_cache[idx], cache_len[idx],
                    k_new[idx], v_new[idx], **kw)
                outs.append(o)
                self.per_worker_kv_bytes[wid] += \
                    2 * k_cache[idx].size * BYTES
            out = jnp.concatenate(outs, axis=0)
        else:
            raise ValueError(self.partition)
        self._account(q, k_new, v_new, out, account)
        return out

    def attend_paged(self, q, k_pool, v_pool, block_tables, cache_len,
                     k_new, v_new, *, sliding_window: int = 0,
                     attention_sinks: int = 0,
                     logit_softcap: float = 0.0,
                     shard_tables=None, shard_positions=None,
                     k_scale=None, v_scale=None) -> jax.Array:
        """Paged variant of :meth:`attend` — the engine's decode hot path.

        q: (B, H, hd); k_pool/v_pool: one layer's HEAD-MAJOR pool slice
        (Hkv, num_blocks, block_size, hd) holding the STORED prefix;
        block_tables (B, nb); k_new/v_new (B, Hkv, hd) arrive over the wire.
        Each worker reads its partition of the pool *in place* (head-sliced
        pool, block-sliced pool, or request-sliced table) and the per-worker
        partials merge with the new token via §4.2.2.

        Block partition: shard_tables/shard_positions (n, B, nbl) are the
        COMPACTED per-worker local tables (PagedKVCache.block_table_shards)
        — each worker walks only its ~1/n of the sequence's blocks, the
        whole point of the split. When absent (direct callers without the
        cache at hand) an owner-masked view of the global table is derived
        in-trace instead: equally exact, but every worker then walks all nb
        slots, reading ~n× the live KV.

        Int8 pools (``kv_dtype="int8"``): k_scale/v_scale are the per-layer
        scale pools (Hkv, num_blocks, block_size) and each worker's slice
        of them follows its pool slice exactly — head partition slices the
        head axis, block partition the block axis, request partition
        replicates (scales-follow-blocks invariant). Dequant stays fused
        inside each worker's backend; the partial-merge math is unchanged.

        No per-worker byte accounting happens here — this method runs
        inside the engine's jitted step, where python side effects fire at
        trace time only; the engine logs live-token bytes host-side per
        iteration via :meth:`log_paged_kv`."""
        from repro.core import combine as C
        from repro.models.attention import (_new_token_partial,
                                            paged_decode_attention_combine,
                                            paged_decode_attention_partial_pos)

        B, H, hd = q.shape
        Hkv, NB, bs, _ = k_pool.shape
        kw = dict(sliding_window=sliding_window,
                  attention_sinks=attention_sinks,
                  logit_softcap=logit_softcap)
        if self.partition == "head":
            hk = Hkv // self.n
            g = H // Hkv
            outs = []
            for wid in range(self.n):
                sl = slice(wid * hk, (wid + 1) * hk)
                qs = q.reshape(B, Hkv, g, hd)[:, sl].reshape(B, hk * g, hd)
                skw = {} if k_scale is None else dict(
                    k_scale=k_scale[sl], v_scale=v_scale[sl])
                o = paged_decode_attention_combine(
                    qs, k_pool[sl], v_pool[sl], block_tables, cache_len,
                    k_new[:, sl], v_new[:, sl], backend=self.backend,
                    **kw, **skw)
                outs.append(o.reshape(B, hk, g, hd))
            out = jnp.concatenate(outs, axis=1).reshape(B, H, hd)
        elif self.partition == "block":
            # the pool's block axis is cut into n contiguous shard slices
            # (PagedKVCache round-robins a sequence's blocks across them);
            # each worker computes the §4.2.2 partial over ITS live blocks
            # only — derived in-trace from the global table by masking the
            # slots it does not own (POS_PAD positions kill every row), so
            # the jitted step needs no per-shard host tables
            from repro.serving.kvcache import POS_PAD

            if NB % self.n:
                raise ValueError(
                    f"block partition needs num_blocks ({NB}) divisible by "
                    f"workers ({self.n}) — PagedKVCache(n_shards=...)")
            npb = NB // self.n
            if shard_tables is None:
                # fallback: owner-mask the global table in-trace (full walk)
                nb = block_tables.shape[1]
                base = jnp.arange(nb, dtype=jnp.int32)[None, :] * bs
                owner = block_tables // npb
                local = block_tables % npb
                per_worker = [(local, jnp.where(owner == wid, base, POS_PAD))
                              for wid in range(self.n)]
            else:
                per_worker = [(shard_tables[wid], shard_positions[wid])
                              for wid in range(self.n)]
            partials = []
            for wid, (bt_w, pos_w) in enumerate(per_worker):
                bsl = slice(wid * npb, (wid + 1) * npb)
                skw = {} if k_scale is None else dict(
                    k_scale=k_scale[:, bsl], v_scale=v_scale[:, bsl])
                partials.append(paged_decode_attention_partial_pos(
                    q, k_pool[:, bsl], v_pool[:, bsl],
                    bt_w, pos_w, cache_len, backend=self.backend,
                    **kw, **skw))
            p_new = _new_token_partial(q, k_new, v_new,
                                       logit_softcap=logit_softcap)
            out = C.finalize(C.combine(C.combine_many(partials),
                                       p_new)).astype(q.dtype)
        elif self.partition == "request":
            splits = jnp.array_split(jnp.arange(B), self.n)
            outs = []
            for wid, idx in enumerate(splits):
                if len(idx) == 0:
                    continue
                skw = {} if k_scale is None else dict(
                    k_scale=k_scale, v_scale=v_scale)
                o = paged_decode_attention_combine(
                    q[idx], k_pool, v_pool, block_tables[idx],
                    cache_len[idx], k_new[idx], v_new[idx],
                    backend=self.backend, **kw, **skw)
                outs.append(o)
            out = jnp.concatenate(outs, axis=0)
        else:
            raise ValueError(self.partition)
        return out

    def log_paged_kv(self, worker_tokens, n_layers: int,
                     kv_head_fraction: float = 1.0) -> None:
        """Per-worker live-token KV-read accounting for the paged hot path.

        worker_tokens: (n_workers,) live tokens each worker's partition
        reads this iteration (data-dependent, so logged host-side — see
        LLMEngine._decode_iteration, which derives them per partition);
        kv_head_fraction scales for head partitioning (each worker reads
        only Hkv/n heads of every token). Per-token-head bytes follow the
        pool's kv_dtype: bf16 reads hd·2 bytes, int8 reads hd·1 plus the
        fp32 scale (hd + 4) — the ~2× stream reduction the quantized pool
        buys on the decode hot path."""
        hd = self.cfg.resolved_head_dim
        per_head = hd + 4 if self.kv_dtype == "int8" else hd * BYTES
        per_tok = 2 * self.cfg.num_kv_heads * kv_head_fraction * \
            per_head * n_layers
        for wid in range(self.n):
            self.per_worker_kv_bytes[wid] += int(worker_tokens[wid] * per_tok)

    # overlap mode shares the same math (combine is exact); the distinction
    # is the *schedule* — prev-partial issues right after send-Q, the new
    # token merges after send-KV — which the latency model in
    # benchmarks/bench_overlap.py prices. The engine's hot path is PAGED, so
    # overlap shares the paged path (not the dense test-oracle one).
    attend_overlapped = attend_paged


def expected_transfer_bytes(cfg: ModelConfig, batch: int) -> int:
    """Paper §3.1: (2 + 2/G)·e·d_q·B·L per iteration."""
    G = cfg.gqa_group
    return int((2 + 2 / G) * BYTES * cfg.q_dim * batch * cfg.num_layers)


def transfer_bytes_moe(cfg: ModelConfig, batch: int) -> int:
    """Per-iteration wire bytes for expert offloading: token activations to
    the pool and expert outputs back, per MoE layer."""
    return int(2 * BYTES * cfg.d_model * batch * cfg.num_layers)


def min_bandwidth_moe(cfg: ModelConfig, batch: int, seq_len: float,
                      hw_model: cm.HardwareSpec, hw_exp: cm.HardwareSpec,
                      alpha: float = 0.2) -> float:
    """Paper-§3.1 style minimum-bandwidth bound for the MoE boundary."""
    t = cm.mtime(cfg, batch, hw_model) + cm.atime(cfg, batch, seq_len,
                                                  hw_model)
    return transfer_bytes_moe(cfg, batch) / (alpha * t)


class ExpertWorkerPool:
    """Memory-device pool owning the expert weights + FFN compute."""

    def __init__(self, cfg: ModelConfig, n_workers: int = 2):
        if cfg.num_experts % max(n_workers, 1):
            raise ValueError(
                f"expert partition needs num_experts ({cfg.num_experts}) "
                f"divisible by workers ({n_workers})")
        self.cfg = cfg
        self.n = n_workers
        self.log = TransferLog()
        self.per_worker_tokens = [0] * n_workers

    def run_experts(self, moe_params: Dict, x: jax.Array,
                    account: bool = False) -> jax.Array:
        """x: (B, S, d) routed-token activations arriving over the wire.
        Expert-partitioned across workers: each worker computes the routed
        contribution of its expert shard; outputs sum (experts are disjoint
        per token choice, so partial outputs add exactly)."""
        from repro.models.moe import moe_forward

        cfg = self.cfg
        y, _ = moe_forward(moe_params, cfg, x)
        if account:
            self.log.q_bytes += x.size * BYTES       # activations out
            self.log.out_bytes += y.size * BYTES     # expert outputs back
            self.log.transfers += 2
        return y

    def log_iteration(self, batch: int) -> None:
        d, L = self.cfg.d_model, self.cfg.num_layers
        self.log.q_bytes += batch * d * BYTES * L
        self.log.out_bytes += batch * d * BYTES * L
        self.log.transfers += 2 * L
