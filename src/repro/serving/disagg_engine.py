"""Lamina: the model-attention disaggregated serving engine (paper §4).

Logical realisation of the paper's architecture, runnable on CPU and
lowerable on the TPU mesh:

  * model workers execute the converter's slices (norm/QKV then
    o-proj/FFN) — the slice boundaries are exactly the min-cut the
    converter finds (context = the residual stream);
  * an AttentionWorkerPool owns the attention computation, partitioned
    head-level across the DOP's `b` workers (paper §5, Fig. 9) with
    request-level as the load-imbalance baseline;
  * every per-layer transfer (send-Q, send-KV, recv-output) is accounted in
    bytes — tests assert the per-iteration total equals the paper's
    (2 + 2/G)·e·d·B·L formula (§3.1);
  * the pool's KV read is PAGED: workers attend over the engine's head-major
    block pool in place through per-sequence block tables
    (``attend_paged``) — per-step traffic is one pass over the live KV, with
    no dense gather or transposes on the hot path;
  * resource-utilisation overlapping (§4.2.2): attention over the `prev`
    tokens is issued as soon as q is available; the `new` token's
    contribution is merged with the combine identity after K/V arrive. The
    engine tracks the two sub-latencies so the overlap benchmark (Fig. 14)
    can report hidden-vs-exposed time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import combine as C
from repro.models import transformer
from repro.models.attention import qkv_project, out_project
from repro.models.common import ModelConfig, rms_norm
from repro.models.ffn import ffn_forward
from repro.models.moe import moe_forward
from repro.serving.engine import Engine

BYTES = 2  # bf16/fp16 wire format (paper Table 2 "e")


def _tree_index(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


@dataclasses.dataclass
class TransferLog:
    q_bytes: int = 0
    kv_bytes: int = 0
    out_bytes: int = 0
    transfers: int = 0

    @property
    def total(self) -> int:
        return self.q_bytes + self.kv_bytes + self.out_bytes


class AttentionWorkerPool:
    """The memory-device pool: stores nothing here (the paged pool is the
    engine's), but owns partitioning + accounting of attention work."""

    def __init__(self, cfg: ModelConfig, n_workers: int = 2,
                 partition: str = "head", backend: str = "jnp"):
        self.cfg = cfg
        self.n = n_workers
        self.partition = partition
        self.backend = backend
        self.log = TransferLog()
        self.per_worker_kv_bytes = [0] * n_workers
        if partition == "head" and cfg.num_kv_heads % n_workers:
            raise ValueError(
                f"head partition needs kv_heads ({cfg.num_kv_heads}) "
                f"divisible by workers ({n_workers}) — paper §5")

    def _account(self, q, k_new, v_new, out, enabled: bool):
        # Only for direct (non-jit) calls: python side effects do not fire
        # per-execution under jit — the engine logs analytically instead.
        if not enabled:
            return
        self.log.q_bytes += q.size * BYTES
        self.log.kv_bytes += (k_new.size + v_new.size) * BYTES
        self.log.out_bytes += out.size * BYTES
        self.log.transfers += 2  # QKV out + result back

    def log_iteration(self, batch: int) -> None:
        """Shape-derived per-iteration accounting (jit-safe path)."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        L = cfg.num_layers
        self.log.q_bytes += batch * cfg.num_heads * hd * BYTES * L
        self.log.kv_bytes += 2 * batch * cfg.num_kv_heads * hd * BYTES * L
        self.log.out_bytes += batch * cfg.num_heads * hd * BYTES * L
        self.log.transfers += 2 * L

    def attend(self, q, k_cache, v_cache, cache_len, k_new, v_new, *,
               sliding_window: int = 0, logit_softcap: float = 0.0,
               account: bool = False) -> jax.Array:
        """q: (B, H, hd); caches HEAD-MAJOR (B, Hkv, S, hd) hold the STORED prefix
        (cache_len tokens); k_new/v_new (B, Hkv, hd) arrive over the wire.
        Each worker computes combine(prefix partial, new partial) on its
        partition (§4.2.2 across workers too). Returns (B, H, hd)."""
        from repro.models.attention import decode_attention_combine

        B, H, hd = q.shape
        Hkv = k_cache.shape[1]
        kw = dict(sliding_window=sliding_window, logit_softcap=logit_softcap,
                  backend=self.backend)
        if self.partition == "head":
            hk = Hkv // self.n
            g = H // Hkv
            outs = []
            for wid in range(self.n):
                sl = slice(wid * hk, (wid + 1) * hk)
                qs = q.reshape(B, Hkv, g, hd)[:, sl].reshape(B, hk * g, hd)
                o = decode_attention_combine(
                    qs, k_cache[:, sl], v_cache[:, sl], cache_len,
                    k_new[:, sl], v_new[:, sl], **kw)
                outs.append(o.reshape(B, hk, g, hd))
                self.per_worker_kv_bytes[wid] += \
                    2 * k_cache[:, sl].size * BYTES
            out = jnp.concatenate(outs, axis=1).reshape(B, H, hd)
        elif self.partition == "request":
            splits = jnp.array_split(jnp.arange(B), self.n)
            outs = []
            for wid, idx in enumerate(splits):
                if len(idx) == 0:
                    continue
                o = decode_attention_combine(
                    q[idx], k_cache[idx], v_cache[idx], cache_len[idx],
                    k_new[idx], v_new[idx], **kw)
                outs.append(o)
                self.per_worker_kv_bytes[wid] += \
                    2 * k_cache[idx].size * BYTES
            out = jnp.concatenate(outs, axis=0)
        else:
            raise ValueError(self.partition)
        self._account(q, k_new, v_new, out, account)
        return out

    def attend_paged(self, q, k_pool, v_pool, block_tables, cache_len,
                     k_new, v_new, *, sliding_window: int = 0,
                     logit_softcap: float = 0.0) -> jax.Array:
        """Paged variant of :meth:`attend` — the engine's decode hot path.

        q: (B, H, hd); k_pool/v_pool: one layer's HEAD-MAJOR pool slice
        (Hkv, num_blocks, block_size, hd) holding the STORED prefix;
        block_tables (B, nb); k_new/v_new (B, Hkv, hd) arrive over the wire.
        Each worker reads its partition of the pool *in place* (head-sliced
        pool, or request-sliced table) and computes
        combine(pool partial, new partial) — §4.2.2 across workers too.
        Per-worker bytes are the allocated table footprint (static shapes;
        live-token balance is what the head/request benchmark measures)."""
        from repro.models.attention import paged_decode_attention_combine

        B, H, hd = q.shape
        Hkv, _, bs, _ = k_pool.shape
        S_alloc = block_tables.shape[1] * bs
        kw = dict(sliding_window=sliding_window, logit_softcap=logit_softcap,
                  backend=self.backend)
        if self.partition == "head":
            hk = Hkv // self.n
            g = H // Hkv
            outs = []
            for wid in range(self.n):
                sl = slice(wid * hk, (wid + 1) * hk)
                qs = q.reshape(B, Hkv, g, hd)[:, sl].reshape(B, hk * g, hd)
                o = paged_decode_attention_combine(
                    qs, k_pool[sl], v_pool[sl], block_tables, cache_len,
                    k_new[:, sl], v_new[:, sl], **kw)
                outs.append(o.reshape(B, hk, g, hd))
                self.per_worker_kv_bytes[wid] += \
                    2 * B * hk * S_alloc * hd * BYTES
            out = jnp.concatenate(outs, axis=1).reshape(B, H, hd)
        elif self.partition == "request":
            splits = jnp.array_split(jnp.arange(B), self.n)
            outs = []
            for wid, idx in enumerate(splits):
                if len(idx) == 0:
                    continue
                o = paged_decode_attention_combine(
                    q[idx], k_pool, v_pool, block_tables[idx],
                    cache_len[idx], k_new[idx], v_new[idx], **kw)
                outs.append(o)
                self.per_worker_kv_bytes[wid] += \
                    2 * len(idx) * Hkv * S_alloc * hd * BYTES
            out = jnp.concatenate(outs, axis=0)
        else:
            raise ValueError(self.partition)
        return out

    # overlap mode shares the same math (combine is exact); the distinction
    # is the *schedule* — prev-partial issues right after send-Q, the new
    # token merges after send-KV — which the latency model in
    # benchmarks/bench_overlap.py prices. Alias kept for clarity.
    attend_overlapped = attend


def expected_transfer_bytes(cfg: ModelConfig, batch: int) -> int:
    """Paper §3.1: (2 + 2/G)·e·d_q·B·L per iteration."""
    G = cfg.gqa_group
    return int((2 + 2 / G) * BYTES * cfg.q_dim * batch * cfg.num_layers)


class DisaggEngine(Engine):
    """Engine with model-attention disaggregation replacing the fused step."""

    def __init__(self, cfg: ModelConfig, params, *, n_attention_workers=2,
                 partition: str = "head", overlap: bool = True, **kw):
        super().__init__(cfg, params, **kw)
        self.pool = AttentionWorkerPool(cfg, n_attention_workers, partition,
                                        kw.get("decode_backend", "jnp"))
        self.overlap = overlap
        self._decode_jit = jax.jit(self._disagg_decode)

    # ----- the sliced decode step (converter output, executed) -----
    def _disagg_decode(self, params, tokens, k_pool, v_pool, block_tables,
                       lens):
        cfg = self.cfg
        cur_len = lens  # stored tokens
        x = jnp.take(params["embed"], tokens[:, None], axis=0)
        if cfg.tie_embeddings:
            x = x * jnp.asarray(jnp.sqrt(float(cfg.d_model)), x.dtype)
        positions = cur_len[:, None]
        ks, vs = [], []
        for layer in range(cfg.num_layers):
            p = _tree_index(params["layers"], layer)
            is_local = cfg.local_global and layer % 2 == 0
            window = cfg.sliding_window if (is_local or not cfg.local_global) \
                else 0
            # ---- model slice 0: norm1 + QKV (send q early — §4.2.2) ----
            h = rms_norm(x, p["norm1"], cfg.norm_eps)
            q, k, v = qkv_project(p["attn"], cfg, h, positions)
            ks.append(k[:, 0])
            vs.append(v[:, 0])
            # ---- attention pool: workers read the paged pool in place ----
            attn = self.pool.attend_paged(
                q[:, 0], k_pool[layer], v_pool[layer], block_tables, cur_len,
                k[:, 0], v[:, 0], sliding_window=int(window),
                logit_softcap=cfg.attn_logit_softcap)
            # ---- model slice 1: o-proj + residual + FFN ----
            attn_out = out_project(p["attn"], attn[:, None])
            if cfg.post_norms:
                attn_out = rms_norm(attn_out, p["norm_post_attn"],
                                    cfg.norm_eps)
            x = x + attn_out
            h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
            if "moe" in p:
                f, _ = moe_forward(p["moe"], cfg, h2)
            else:
                f = ffn_forward(p["ffn"], h2)
            if cfg.post_norms:
                f = rms_norm(f, p["norm_post_ffn"], cfg.norm_eps)
            x = x + f
        updates = {"k_new": jnp.stack(ks), "v_new": jnp.stack(vs),
                   "len": cur_len + 1}
        logits = transformer._head(params, cfg, x[:, 0])
        return logits, updates

    def _decode_iteration(self) -> None:
        from repro.serving.request import State
        n = len([r for r in self.sched.running if r.state == State.RUNNING])
        super()._decode_iteration()
        if n:
            self.pool.log_iteration(n)

    # ------------------------------------------------------------------
    # Fault tolerance (paper §5): all request state (KV) lives on the
    # attention pool, so a model-worker loss costs nothing; an attention-
    # worker loss is recovered by re-prefilling from the request's prompt +
    # already-generated tokens, which the front-end retains.
    # ------------------------------------------------------------------
    def fail_model_worker(self) -> None:
        """Model workers are stateless — swap in a spare: re-jit only."""
        self._decode_jit = jax.jit(self._disagg_decode)

    def fail_attention_worker(self) -> None:
        """Drop the pool's KV for every running request and rebuild it from
        prompt + generated tokens (minus the last, still-unstored token)."""
        from repro.serving.request import State
        for req in self.sched.running:
            if req.state != State.RUNNING:
                continue
            known = req.prompt + req.output[:-1]
            self.kv.free_seq(req.rid)
            self.kv.allocate(req.rid, len(known))
            toks = jnp.asarray([known], jnp.int32)
            _, cache = self._prefill_jit(self.params, {"tokens": toks})
            # prefill cache is head-major (L, 1, Hkv, S, hd) — pool layout
            self.kv.write_prefill(req.rid, cache["k"][:, 0],
                                  cache["v"][:, 0])
