"""Lamina: the model-attention disaggregated serving engine (paper §4).

Logical realisation of the paper's architecture, runnable on CPU and
lowerable on the TPU mesh:

  * model workers execute the converter's slices (norm/QKV then
    o-proj/FFN) — the slice boundaries are exactly the min-cut the
    converter finds (context = the residual stream);
  * an AttentionWorkerPool owns the attention computation, partitioned
    across the DOP's `b` workers (paper §5, Fig. 9) one of three ways:
    "head" (each worker owns Hkv/n heads of every pool block — Lamina's
    choice), "block" (the pool's block axis is sharded and a single
    sequence's round-robin-placed blocks span every worker; per-worker
    §4.2.2 partials merge exactly via the combine identity — the partition
    that serves `long_500k` where one request's KV exceeds one chip), or
    "request" (batch-sharded, the load-imbalance baseline). NO partition
    ever materialises a dense seq-major KV view — each worker reads its own
    slice of the block pool in place (the no-densify invariant,
    core/attention_parallel.py);
  * every per-layer transfer (send-Q, send-KV, recv-output) is accounted in
    bytes — tests assert the per-iteration total equals the paper's
    (2 + 2/G)·e·d·B·L formula (§3.1);
  * the pool's KV read is PAGED: workers attend over the engine's head-major
    block pool in place through per-sequence block tables
    (``attend_paged``) — per-step traffic is one pass over the live KV, with
    no dense gather or transposes on the hot path;
  * resource-utilisation overlapping (§4.2.2): attention over the `prev`
    tokens is issued as soon as q is available; the `new` token's
    contribution is merged with the combine identity after K/V arrive. The
    engine tracks the two sub-latencies so the overlap benchmark (Fig. 14)
    can report hidden-vs-exposed time.

DEPRECATED (DisaggEngine only): new code should use
:class:`repro.serving.llm_engine.LLMEngine` with
``EngineConfig(placement="attention_pool", partition=...)`` — the sliced
decode step now lives in ``serving/placement.py`` as a composable strategy
instead of a subclass override. ``DisaggEngine`` is kept verbatim as the
greedy-parity oracle for the facade's tests. ``AttentionWorkerPool`` (and
its transfer accounting) remains canonical and is what the new placement
strategies compose.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import combine as C
from repro.models import transformer
from repro.models.attention import qkv_project, out_project
from repro.models.common import ModelConfig, rms_norm
from repro.models.ffn import ffn_forward
from repro.models.moe import moe_forward
from repro.serving.engine import Engine

BYTES = 2  # bf16/fp16 wire format (paper Table 2 "e")


def _tree_index(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


@dataclasses.dataclass
class TransferLog:
    q_bytes: int = 0
    kv_bytes: int = 0
    out_bytes: int = 0
    transfers: int = 0

    @property
    def total(self) -> int:
        return self.q_bytes + self.kv_bytes + self.out_bytes


class AttentionWorkerPool:
    """The memory-device pool: stores nothing here (the paged pool is the
    engine's), but owns partitioning + accounting of attention work."""

    def __init__(self, cfg: ModelConfig, n_workers: int = 2,
                 partition: str = "head", backend: str = "jnp"):
        self.cfg = cfg
        self.n = n_workers
        self.partition = partition
        self.backend = backend
        self.log = TransferLog()
        self.per_worker_kv_bytes = [0] * n_workers
        if partition not in ("head", "request", "block"):
            raise ValueError(f"unknown partition {partition!r}")
        if partition == "head" and cfg.num_kv_heads % n_workers:
            raise ValueError(
                f"head partition needs kv_heads ({cfg.num_kv_heads}) "
                f"divisible by workers ({n_workers}) — paper §5")

    def _account(self, q, k_new, v_new, out, enabled: bool):
        # Only for direct (non-jit) calls: python side effects do not fire
        # per-execution under jit — the engine logs analytically instead.
        if not enabled:
            return
        self.log.q_bytes += q.size * BYTES
        self.log.kv_bytes += (k_new.size + v_new.size) * BYTES
        self.log.out_bytes += out.size * BYTES
        self.log.transfers += 2  # QKV out + result back

    def log_iteration(self, batch: int) -> None:
        """Shape-derived per-iteration accounting (jit-safe path)."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        L = cfg.num_layers
        self.log.q_bytes += batch * cfg.num_heads * hd * BYTES * L
        self.log.kv_bytes += 2 * batch * cfg.num_kv_heads * hd * BYTES * L
        self.log.out_bytes += batch * cfg.num_heads * hd * BYTES * L
        self.log.transfers += 2 * L

    def attend(self, q, k_cache, v_cache, cache_len, k_new, v_new, *,
               sliding_window: int = 0, logit_softcap: float = 0.0,
               account: bool = False) -> jax.Array:
        """q: (B, H, hd); caches HEAD-MAJOR (B, Hkv, S, hd) hold the STORED prefix
        (cache_len tokens); k_new/v_new (B, Hkv, hd) arrive over the wire.
        Each worker computes combine(prefix partial, new partial) on its
        partition (§4.2.2 across workers too). Returns (B, H, hd)."""
        from repro.models.attention import decode_attention_combine

        B, H, hd = q.shape
        Hkv = k_cache.shape[1]
        kw = dict(sliding_window=sliding_window, logit_softcap=logit_softcap,
                  backend=self.backend)
        if self.partition == "head":
            hk = Hkv // self.n
            g = H // Hkv
            outs = []
            for wid in range(self.n):
                sl = slice(wid * hk, (wid + 1) * hk)
                qs = q.reshape(B, Hkv, g, hd)[:, sl].reshape(B, hk * g, hd)
                o = decode_attention_combine(
                    qs, k_cache[:, sl], v_cache[:, sl], cache_len,
                    k_new[:, sl], v_new[:, sl], **kw)
                outs.append(o.reshape(B, hk, g, hd))
                self.per_worker_kv_bytes[wid] += \
                    2 * k_cache[:, sl].size * BYTES
            out = jnp.concatenate(outs, axis=1).reshape(B, H, hd)
        elif self.partition == "request":
            splits = jnp.array_split(jnp.arange(B), self.n)
            outs = []
            for wid, idx in enumerate(splits):
                if len(idx) == 0:
                    continue
                o = decode_attention_combine(
                    q[idx], k_cache[idx], v_cache[idx], cache_len[idx],
                    k_new[idx], v_new[idx], **kw)
                outs.append(o)
                self.per_worker_kv_bytes[wid] += \
                    2 * k_cache[idx].size * BYTES
            out = jnp.concatenate(outs, axis=0)
        else:
            raise ValueError(self.partition)
        self._account(q, k_new, v_new, out, account)
        return out

    def attend_paged(self, q, k_pool, v_pool, block_tables, cache_len,
                     k_new, v_new, *, sliding_window: int = 0,
                     attention_sinks: int = 0,
                     logit_softcap: float = 0.0,
                     shard_tables=None, shard_positions=None) -> jax.Array:
        """Paged variant of :meth:`attend` — the engine's decode hot path.

        q: (B, H, hd); k_pool/v_pool: one layer's HEAD-MAJOR pool slice
        (Hkv, num_blocks, block_size, hd) holding the STORED prefix;
        block_tables (B, nb); k_new/v_new (B, Hkv, hd) arrive over the wire.
        Each worker reads its partition of the pool *in place* (head-sliced
        pool, block-sliced pool, or request-sliced table) and the per-worker
        partials merge with the new token via §4.2.2.

        Block partition: shard_tables/shard_positions (n, B, nbl) are the
        COMPACTED per-worker local tables (PagedKVCache.block_table_shards)
        — each worker walks only its ~1/n of the sequence's blocks, the
        whole point of the split. When absent (direct callers without the
        cache at hand) an owner-masked view of the global table is derived
        in-trace instead: equally exact, but every worker then walks all nb
        slots, reading ~n× the live KV.

        No per-worker byte accounting happens here — this method runs
        inside the engine's jitted step, where python side effects fire at
        trace time only; the engine logs live-token bytes host-side per
        iteration via :meth:`log_paged_kv`."""
        from repro.core import combine as C
        from repro.models.attention import (_new_token_partial,
                                            paged_decode_attention_combine,
                                            paged_decode_attention_partial_pos)

        B, H, hd = q.shape
        Hkv, NB, bs, _ = k_pool.shape
        kw = dict(sliding_window=sliding_window,
                  attention_sinks=attention_sinks,
                  logit_softcap=logit_softcap)
        if self.partition == "head":
            hk = Hkv // self.n
            g = H // Hkv
            outs = []
            for wid in range(self.n):
                sl = slice(wid * hk, (wid + 1) * hk)
                qs = q.reshape(B, Hkv, g, hd)[:, sl].reshape(B, hk * g, hd)
                o = paged_decode_attention_combine(
                    qs, k_pool[sl], v_pool[sl], block_tables, cache_len,
                    k_new[:, sl], v_new[:, sl], backend=self.backend, **kw)
                outs.append(o.reshape(B, hk, g, hd))
            out = jnp.concatenate(outs, axis=1).reshape(B, H, hd)
        elif self.partition == "block":
            # the pool's block axis is cut into n contiguous shard slices
            # (PagedKVCache round-robins a sequence's blocks across them);
            # each worker computes the §4.2.2 partial over ITS live blocks
            # only — derived in-trace from the global table by masking the
            # slots it does not own (POS_PAD positions kill every row), so
            # the jitted step needs no per-shard host tables
            from repro.serving.kvcache import POS_PAD

            if NB % self.n:
                raise ValueError(
                    f"block partition needs num_blocks ({NB}) divisible by "
                    f"workers ({self.n}) — PagedKVCache(n_shards=...)")
            npb = NB // self.n
            if shard_tables is None:
                # fallback: owner-mask the global table in-trace (full walk)
                nb = block_tables.shape[1]
                base = jnp.arange(nb, dtype=jnp.int32)[None, :] * bs
                owner = block_tables // npb
                local = block_tables % npb
                per_worker = [(local, jnp.where(owner == wid, base, POS_PAD))
                              for wid in range(self.n)]
            else:
                per_worker = [(shard_tables[wid], shard_positions[wid])
                              for wid in range(self.n)]
            partials = []
            for wid, (bt_w, pos_w) in enumerate(per_worker):
                partials.append(paged_decode_attention_partial_pos(
                    q, k_pool[:, wid * npb:(wid + 1) * npb],
                    v_pool[:, wid * npb:(wid + 1) * npb],
                    bt_w, pos_w, cache_len, backend=self.backend, **kw))
            p_new = _new_token_partial(q, k_new, v_new,
                                       logit_softcap=logit_softcap)
            out = C.finalize(C.combine(C.combine_many(partials),
                                       p_new)).astype(q.dtype)
        elif self.partition == "request":
            splits = jnp.array_split(jnp.arange(B), self.n)
            outs = []
            for wid, idx in enumerate(splits):
                if len(idx) == 0:
                    continue
                o = paged_decode_attention_combine(
                    q[idx], k_pool, v_pool, block_tables[idx],
                    cache_len[idx], k_new[idx], v_new[idx],
                    backend=self.backend, **kw)
                outs.append(o)
            out = jnp.concatenate(outs, axis=0)
        else:
            raise ValueError(self.partition)
        return out

    def log_paged_kv(self, worker_tokens, n_layers: int,
                     kv_head_fraction: float = 1.0) -> None:
        """Per-worker live-token KV-read accounting for the paged hot path.

        worker_tokens: (n_workers,) live tokens each worker's partition
        reads this iteration (data-dependent, so logged host-side — see
        DisaggEngine._decode_iteration, which derives them per partition);
        kv_head_fraction scales for head partitioning (each worker reads
        only Hkv/n heads of every token)."""
        hd = self.cfg.resolved_head_dim
        per_tok = 2 * self.cfg.num_kv_heads * kv_head_fraction * hd * \
            BYTES * n_layers
        for wid in range(self.n):
            self.per_worker_kv_bytes[wid] += int(worker_tokens[wid] * per_tok)

    # overlap mode shares the same math (combine is exact); the distinction
    # is the *schedule* — prev-partial issues right after send-Q, the new
    # token merges after send-KV — which the latency model in
    # benchmarks/bench_overlap.py prices. The engine's hot path is PAGED, so
    # overlap shares the paged path (not the dense test-oracle one).
    attend_overlapped = attend_paged


def expected_transfer_bytes(cfg: ModelConfig, batch: int) -> int:
    """Paper §3.1: (2 + 2/G)·e·d_q·B·L per iteration."""
    G = cfg.gqa_group
    return int((2 + 2 / G) * BYTES * cfg.q_dim * batch * cfg.num_layers)


class DisaggEngine(Engine):
    """Engine with model-attention disaggregation replacing the fused step."""

    def __init__(self, cfg: ModelConfig, params, *, n_attention_workers=2,
                 partition: str = "head", overlap: bool = True, **kw):
        if partition == "block":
            # the pool's block axis is sharded over the workers: the cache
            # must place blocks round-robin across exactly that many shards
            kw.setdefault("kv_shards", n_attention_workers)
            if kw["kv_shards"] != n_attention_workers:
                raise ValueError(
                    f"block partition shards the pool over the workers: "
                    f"kv_shards ({kw['kv_shards']}) must equal "
                    f"n_attention_workers ({n_attention_workers})")
        super().__init__(cfg, params, **kw)
        self.pool = AttentionWorkerPool(cfg, n_attention_workers, partition,
                                        kw.get("decode_backend", "jnp"))
        self.overlap = overlap
        self._pending_shard_args = None  # block partition, per iteration
        self._decode_jit = jax.jit(self._disagg_decode)

    def _decode_extra_args(self, ids) -> tuple:
        """Block partition: ride the COMPACTED per-shard local tables +
        positions into the jitted step so each worker walks only its own
        ~1/n of the live blocks (block_table_shards). Normally stashed by
        _decode_iteration (which also consumes the live-token counts for
        accounting — one table walk, not two); computed fresh for callers
        that bypass it (MoEOffloadEngine's iteration)."""
        if self.pool.partition != "block":
            return ()
        args, self._pending_shard_args = self._pending_shard_args, None
        if args is None:
            lt, lp, _ = self.kv.block_table_shards(ids)
            args = (jnp.asarray(lt), jnp.asarray(lp))
        return args

    # ----- the sliced decode step (converter output, executed) -----
    def _disagg_decode(self, params, tokens, k_pool, v_pool, block_tables,
                       lens, shard_tables=None, shard_positions=None):
        cfg = self.cfg
        cur_len = lens  # stored tokens
        x = jnp.take(params["embed"], tokens[:, None], axis=0)
        if cfg.tie_embeddings:
            x = x * jnp.asarray(jnp.sqrt(float(cfg.d_model)), x.dtype)
        positions = cur_len[:, None]
        ks, vs = [], []
        for layer in range(cfg.num_layers):
            p = _tree_index(params["layers"], layer)
            is_local = cfg.local_global and layer % 2 == 0
            window = cfg.sliding_window if (is_local or not cfg.local_global) \
                else 0
            # ---- model slice 0: norm1 + QKV (send q early — §4.2.2) ----
            h = rms_norm(x, p["norm1"], cfg.norm_eps)
            q, k, v = qkv_project(p["attn"], cfg, h, positions)
            ks.append(k[:, 0])
            vs.append(v[:, 0])
            # ---- attention pool: workers read the paged pool in place ----
            attn = self.pool.attend_paged(
                q[:, 0], k_pool[layer], v_pool[layer], block_tables, cur_len,
                k[:, 0], v[:, 0], sliding_window=int(window),
                attention_sinks=cfg.attention_sinks if window else 0,
                logit_softcap=cfg.attn_logit_softcap,
                shard_tables=shard_tables, shard_positions=shard_positions)
            # ---- model slice 1: o-proj + residual + FFN ----
            attn_out = out_project(p["attn"], attn[:, None])
            if cfg.post_norms:
                attn_out = rms_norm(attn_out, p["norm_post_attn"],
                                    cfg.norm_eps)
            x = x + attn_out
            h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
            if "moe" in p:
                f, _ = moe_forward(p["moe"], cfg, h2)
            else:
                f = ffn_forward(p["ffn"], h2)
            if cfg.post_norms:
                f = rms_norm(f, p["norm_post_ffn"], cfg.norm_eps)
            x = x + f
        updates = {"k_new": jnp.stack(ks), "v_new": jnp.stack(vs),
                   "len": cur_len + 1}
        logits = transformer._head(params, cfg, x[:, 0])
        return logits, updates

    def _decode_iteration(self) -> None:
        import numpy as np

        from repro.serving.request import State
        running = [r for r in self.sched.running if r.state == State.RUNNING]
        if running:
            # per-worker live-token KV-read accounting (data-dependent, so
            # host-side: the jitted step's python body fires at trace only)
            ids = [r.rid for r in running]
            L = self.cfg.num_layers
            if self.pool.partition == "block":
                # one table walk serves both the jitted step's compacted
                # shard tables and the live-token accounting
                lt, lp, shard_tokens = self.kv.block_table_shards(ids)
                self._pending_shard_args = (jnp.asarray(lt), jnp.asarray(lp))
                self.pool.log_paged_kv(shard_tokens.sum(axis=1), L)
            elif self.pool.partition == "head":
                total = sum(self.kv.lengths[i] for i in ids)
                self.pool.log_paged_kv([total] * self.pool.n, L,
                                       kv_head_fraction=1.0 / self.pool.n)
            else:  # request: each worker walks only its requests' tables
                toks = [sum(self.kv.lengths[ids[i]] for i in idx)
                        for idx in np.array_split(np.arange(len(ids)),
                                                  self.pool.n)]
                self.pool.log_paged_kv(toks, L)
        super()._decode_iteration()
        if running:
            self.pool.log_iteration(len(running))

    # ------------------------------------------------------------------
    # Fault tolerance (paper §5): all request state (KV) lives on the
    # attention pool, so a model-worker loss costs nothing; an attention-
    # worker loss is recovered by re-prefilling from the request's prompt +
    # already-generated tokens, which the front-end retains.
    # ------------------------------------------------------------------
    def fail_model_worker(self) -> None:
        """Model workers are stateless — swap in a spare: re-jit only."""
        self._decode_jit = jax.jit(self._disagg_decode)

    def fail_attention_worker(self) -> None:
        """Drop the pool's KV for every running request and rebuild it from
        prompt + generated tokens (minus the last, still-unstored token)."""
        from repro.serving.request import State
        for req in self.sched.running:
            if req.state != State.RUNNING:
                continue
            known = req.prompt + req.output[:-1]
            self.kv.free_seq(req.rid)
            self.kv.allocate(req.rid, len(known))
            toks = jnp.asarray([known], jnp.int32)
            _, cache = self._prefill_jit(self.params, {"tokens": toks})
            # prefill cache is head-major (L, 1, Hkv, S, hd) — pool layout
            self.kv.write_prefill(req.rid, cache["k"][:, 0],
                                  cache["v"][:, 0])
