"""Token sampling: greedy / temperature / top-k — batch-uniform and
per-request variants (a continuous batch mixes every request's own
SamplingParams in one decode iteration)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, key, temperature: float = 0.0,
           top_k: int = 0) -> jax.Array:
    """logits: (B, V) -> (B,) int32. One set of params for the whole batch
    (prefill / single-request paths)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def request_key(seed: int, token_index: int) -> jax.Array:
    """Per-request PRNG stream honoring ``SamplingParams.seed``: token `i`
    of a request seeded `s` is always drawn from fold_in(PRNGKey(s), i) —
    independent of batch composition, admission order, or preemption, so
    identical requests reproduce identically wherever they run."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), token_index)


def _scale_and_mask(logits: jax.Array, temperatures, top_ks):
    """Shared per-row temperature scaling + top-k cutoff for the batch
    samplers. Returns (greedy, scaled, temps): greedy argmax per row, the
    scaled logits with sub-cutoff entries at -inf (a sort-based cutoff,
    since ``lax.top_k`` needs a static k and k varies per row), and the
    float temps. The two samplers differ ONLY in how they draw from
    `scaled` — keep any cutoff/tie semantics change here so they can't
    diverge."""
    logits = logits.astype(jnp.float32)
    V = logits.shape[-1]
    temps = jnp.asarray(temperatures, jnp.float32)
    ks = jnp.asarray(top_ks, jnp.int32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    srt = jnp.sort(scaled, axis=-1)[:, ::-1]            # descending
    kidx = jnp.where(ks > 0, jnp.minimum(ks, V) - 1, V - 1)
    cutoff = jnp.take_along_axis(srt, kidx[:, None], axis=-1)
    scaled = jnp.where(scaled < cutoff, -jnp.inf, scaled)
    return greedy, scaled, temps


def sample_per_request(logits: jax.Array, keys: jax.Array,
                       temperatures, top_ks) -> jax.Array:
    """Per-request sampling with per-request PRNG streams.

    logits: (B, V); keys: (B, 2) uint32 — one :func:`request_key` per row;
    temperatures: (B,) float (<= 0 → greedy for that row); top_ks: (B,) int
    (0 → full softmax). Same cutoff semantics as :func:`sample_batch`, but
    each row draws from its own key, so a request's stochastic stream is a
    pure function of (its seed, its token index). Returns (B,) int32."""
    greedy, scaled, temps = _scale_and_mask(logits, temperatures, top_ks)
    drawn = jax.vmap(lambda k, row: jax.random.categorical(k, row))(
        keys, scaled).astype(jnp.int32)
    return jnp.where(temps <= 0.0, greedy, drawn)


def sample_batch(logits: jax.Array, key, temperatures: jax.Array,
                 top_ks: jax.Array) -> jax.Array:
    """Per-request sampling for a continuous batch, one shared batch key.

    logits: (B, V); temperatures: (B,) float (<= 0 → greedy for that row);
    top_ks: (B,) int (0 → full softmax). Rows are independent: each gets its
    own temperature scaling and top-k cutoff. Greedy rows are argmax
    regardless of the drawn sample. Returns (B,) int32."""
    greedy, scaled, temps = _scale_and_mask(logits, temperatures, top_ks)
    drawn = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temps <= 0.0, greedy, drawn)
