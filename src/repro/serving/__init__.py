"""Serving subsystem — public API.

The canonical surface is the unified streaming facade::

    from repro.serving import LLMEngine, EngineConfig, Request, SamplingParams

    engine = LLMEngine(cfg, params, EngineConfig(
        placement="attention_pool", partition="block",
        attention_workers=4, scheduler="preempt"))
    handle = engine.generate(prompt_tokens)
    for token in handle:          # tokens stream as they are generated
        ...
    for ev in engine.events():    # admit / preempt / readmit / finish
        ...

A prefill/decode disaggregated deployment fronts K engine replicas with
the cluster layer (``repro.serving.cluster``)::

    from repro.serving.cluster import DisaggCluster

    cluster = DisaggCluster(cfg, params, econf, replicas=4)
    cluster.submit(requests)      # prefix-affinity routed
    cluster.run()
"""
from repro.serving.config import DisaggConfig, EngineConfig
from repro.serving.faults import (FaultEvent, FaultInjector, FaultScenario,
                                  ShardHealthTracker)
from repro.serving.kvcache import (KVHandoffPayload, OutOfBlocks,
                                   PagedKVCache, PoolExhausted)
from repro.serving.llm_engine import (CorruptedLogitsError, EngineEvent,
                                      LLMEngine, RequestHandle,
                                      SchedulingStalled)
from repro.serving.placement import PlacementStrategy, make_placement
from repro.serving.request import Request, SamplingParams, State
from repro.serving.sampler import request_key, sample_per_request
from repro.serving.scheduler import (ChunkedPrefillPolicy, FCFSPolicy,
                                     PreemptingPolicy, PrefixIndex,
                                     RequestScheduler, SchedulingPolicy,
                                     make_policy)
from repro.serving.stats import EngineStats

__all__ = [
    "EngineConfig", "DisaggConfig", "EngineStats", "EngineEvent",
    "LLMEngine", "RequestHandle", "SchedulingStalled",
    "CorruptedLogitsError",
    "FaultEvent", "FaultInjector", "FaultScenario", "ShardHealthTracker",
    "PlacementStrategy",
    "make_placement", "Request", "SamplingParams", "State",
    "PagedKVCache", "KVHandoffPayload", "OutOfBlocks", "PoolExhausted",
    "request_key", "sample_per_request",
    "ChunkedPrefillPolicy", "FCFSPolicy", "PreemptingPolicy", "PrefixIndex",
    "RequestScheduler", "SchedulingPolicy", "make_policy",
]
