"""Synthetic data pipeline: a deterministic Zipfian "language" with enough
local structure (bigram templates) that a ~100M model's loss visibly drops —
so training examples demonstrate real learning without external datasets.
Includes sequence packing with document boundaries."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class SyntheticCorpus:
    """Markov bigram corpus over a Zipf vocabulary."""

    def __init__(self, vocab_size: int, seed: int = 0,
                 branching: int = 8):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        # each token deterministically prefers `branching` successors
        self.next_tokens = rng.integers(0, vocab_size,
                                        size=(vocab_size, branching))
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.start_p = p / p.sum()

    def document(self, rng: np.random.Generator, length: int) -> np.ndarray:
        doc = np.empty(length, np.int64)
        doc[0] = rng.choice(self.vocab, p=self.start_p)
        choices = rng.integers(0, self.next_tokens.shape[1], size=length)
        noise = rng.random(length)
        for i in range(1, length):
            if noise[i] < 0.1:  # 10% noise keeps entropy non-trivial
                doc[i] = rng.integers(0, self.vocab)
            else:
                doc[i] = self.next_tokens[doc[i - 1], choices[i]]
        return doc


def packed_batches(vocab_size: int, batch: int, seq_len: int,
                   seed: int = 0, doc_len_range=(64, 512),
                   frontend_shape=None, frames_shape=None,
                   dtype=None) -> Iterator[Dict]:
    """Yields {'tokens', 'labels', 'mask'} batches of packed documents.
    Optionally attaches stub modality inputs (vlm/audio smoke paths)."""
    import jax.numpy as jnp

    corpus = SyntheticCorpus(vocab_size, seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        toks = np.empty((batch, seq_len), np.int32)
        mask = np.ones((batch, seq_len), np.float32)
        for b in range(batch):
            pos = 0
            while pos < seq_len:
                n = int(rng.integers(*doc_len_range))
                doc = corpus.document(rng, n)[: seq_len - pos]
                toks[b, pos:pos + len(doc)] = doc
                pos += len(doc)
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        mask[:, -1] = 0.0
        out = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels),
               "mask": jnp.asarray(mask)}
        if frontend_shape is not None:
            out["frontend"] = jnp.asarray(
                rng.standard_normal(frontend_shape), dtype)
        if frames_shape is not None:
            out["frames"] = jnp.asarray(
                rng.standard_normal(frames_shape), dtype)
        yield out
