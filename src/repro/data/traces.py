"""Request-trace generator matching the paper's Table 4 production traces.

The real Azure/Kimi traces only expose sequence-length distributions (the
paper itself uses dummy tokens of the right lengths — §6 "Workloads"); we
generate synthetic traces with the published mean prompt/generation lengths
using log-normal length distributions (standard for LLM serving traces).
A `scale` knob shrinks lengths proportionally for CPU-scale engine runs
while preserving the prompt:generation ratios that drive the paper's
batch-size and throughput effects.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.serving.request import Request, SamplingParams


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    name: str
    n_requests: int
    mean_prompt: float
    mean_gen: float


# paper Table 4
TRACES: Dict[str, TraceSpec] = {
    "azure-conv": TraceSpec("azure-conv", 19366, 1154.7, 211.1),
    "azure-code": TraceSpec("azure-code", 8819, 2047.8, 27.9),
    "kimi-conv": TraceSpec("kimi-conv", 12031, 12035.1, 342.6),
    "kimi-ta": TraceSpec("kimi-ta", 23608, 8560.0, 182.1),
}


def _lognormal_lengths(rng, mean: float, n: int, sigma: float = 0.6,
                       lo: int = 1) -> np.ndarray:
    mu = np.log(mean) - sigma ** 2 / 2.0
    out = rng.lognormal(mu, sigma, size=n).astype(np.int64)
    return np.maximum(out, lo)


def generate(trace: str, n_requests: int = 64, vocab_size: int = 1000,
             scale: float = 1.0, seed: int = 0,
             max_prompt: int = 0) -> List[Request]:
    spec = TRACES[trace]
    rng = np.random.default_rng(seed)
    prompts = _lognormal_lengths(rng, max(spec.mean_prompt * scale, 2),
                                 n_requests, lo=2)
    gens = _lognormal_lengths(rng, max(spec.mean_gen * scale, 4),
                              n_requests, lo=2)
    if max_prompt:
        prompts = np.minimum(prompts, max_prompt)
    reqs = []
    for p, g in zip(prompts, gens):
        toks = rng.integers(0, vocab_size, size=int(p)).tolist()
        reqs.append(Request(prompt=toks,
                            params=SamplingParams(max_new_tokens=int(g))))
    return reqs


def stats(trace: str, scale: float = 1.0) -> Dict[str, float]:
    spec = TRACES[trace]
    return {"mean_prompt": spec.mean_prompt * scale,
            "mean_gen": spec.mean_gen * scale,
            "n_requests": spec.n_requests}
