"""Analytical performance/cost model (paper §2, §3.1).

Implements the paper's roofline-style operator timing — MTIME(B) for the
non-attention (GEMM) part and ATIME(B, l) for the attention (BGEMV) part —
the minimum-interconnect-bandwidth formula (Fig. 4), the heterogeneous
DOP=(a,b) throughput estimator (Fig. 10/11), and the network stack latency
model (Fig. 13). Hardware specs follow paper Table 1 plus the TPU v5e
constants this repo's dry-run targets.

This model is how the repo reproduces the paper's *measured* GPU results on
CPU-only infrastructure: every benchmark that cites a paper figure states
whether its numbers come from this calibrated model or from compiled-HLO
artifacts (launch/roofline.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.models.common import ModelConfig


# ---------------------------------------------------------------------------
# Hardware database (paper Table 1 + TPU v5e dry-run target)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    tflops_bf16: float          # peak dense bf16/fp16 TFLOP/s
    mem_gb: float               # HBM capacity
    mem_bw_gbs: float           # HBM bandwidth GB/s
    ici_gbs: float              # inter-chip interconnect GB/s (per direction)
    net_gbs: float              # datacenter network GB/s (NIC line rate)
    price_hr: float             # $/chip/hr (paper Table 1 sources)
    power_w: float = 0.0

    @property
    def flops(self) -> float:
        return self.tflops_bf16 * 1e12

    @property
    def mem_bw(self) -> float:
        return self.mem_bw_gbs * 1e9

    @property
    def mem_bytes(self) -> float:
        return self.mem_gb * (1 << 30)


HARDWARE: Dict[str, HardwareSpec] = {
    "h100": HardwareSpec("h100", 989.0, 80.0, 3350.0, 450.0, 50.0, 11.06, 700),
    "h20": HardwareSpec("h20", 148.0, 96.0, 4000.0, 450.0, 50.0, 4.63, 400),
    "tpu_v6e": HardwareSpec("tpu_v6e", 918.0, 32.0, 1640.0, 448.0, 25.0, 2.70),
    # dry-run/roofline target (constants given in the assignment)
    "tpu_v5e": HardwareSpec("tpu_v5e", 197.0, 16.0, 819.0, 50.0, 25.0, 1.20),
}

BYTES_PER_EL = 2  # bf16/fp16, paper Table 2 "e"


# ---------------------------------------------------------------------------
# Model-level parameter / KV accounting
# ---------------------------------------------------------------------------
def param_count(cfg: ModelConfig) -> float:
    """Total parameters N (embedding + layers + head)."""
    d, L = cfg.d_model, cfg.num_layers
    hd = cfg.resolved_head_dim
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    n = emb
    if cfg.family in ("dense", "vlm", "moe"):
        attn = d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd + \
            cfg.num_heads * hd * d
        if cfg.family == "moe":
            ffn = cfg.num_experts * 3 * d * cfg.moe_d_ff + d * cfg.num_experts
        else:
            ffn = 3 * d * cfg.d_ff
        n += L * (attn + ffn)
    elif cfg.family == "ssm":
        lora = max(32, d // 64)
        tmix = 5 * d * lora * 2 + 5 * d * d
        cmix = 2 * d * cfg.d_ff + d * d
        n += L * (tmix + cmix)
    elif cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * d
        H = d_inner // cfg.ssm_head_dim
        N = cfg.ssm_state
        mamba = d * (2 * d_inner + 2 * N + H) + d_inner * d
        attn_blk = d * cfg.num_heads * hd * 2 + 2 * d * cfg.num_kv_heads * hd \
            + 3 * d * cfg.d_ff
        n += L * mamba + attn_blk  # shared attention counted once
    elif cfg.family == "audio":
        attn = 4 * d * cfg.num_heads * hd
        ffn = 3 * d * cfg.d_ff
        n += cfg.encoder_layers * (attn + ffn)
        n += L * (2 * attn + ffn)  # self + cross + ffn
    return float(n)


def active_param_count(cfg: ModelConfig) -> float:
    """Activated parameters per token (= N for dense; router-selected for
    MoE) — used for MODEL_FLOPS = 6·N_active·D."""
    if cfg.family != "moe":
        return param_count(cfg)
    d, L = cfg.d_model, cfg.num_layers
    hd = cfg.resolved_head_dim
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    attn = d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd + \
        cfg.num_heads * hd * d
    ffn = cfg.experts_per_token * 3 * d * cfg.moe_d_ff + d * cfg.num_experts
    return float(emb + L * (attn + ffn))


def kv_bytes_per_token(cfg: ModelConfig) -> float:
    """KV-cache bytes per token per request: 2·e·L_kv·Hkv·hd."""
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        return 0.0
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.shared_attn_period
        return 2.0 * BYTES_PER_EL * n_attn * cfg.num_kv_heads * hd
    L = cfg.num_layers
    return 2.0 * BYTES_PER_EL * L * cfg.num_kv_heads * hd


# ---------------------------------------------------------------------------
# Paper §2: MTIME / ATIME rooflines
# ---------------------------------------------------------------------------
def mtime(cfg: ModelConfig, batch: int, hw: HardwareSpec,
          n_devices: int = 1, efficiency: float = 0.8) -> float:
    """One decode iteration of all non-attention operators (paper §2.2.1).

    flops = 2·N_active·B; bytes = e·N_active + 2·e·B·d·L (params once,
    activations per layer)."""
    n_act = active_param_count(cfg)
    flops = 2.0 * n_act * batch
    bytes_ = BYTES_PER_EL * (n_act + 2.0 * batch * cfg.d_model *
                             cfg.num_layers)
    t_compute = flops / (n_devices * hw.flops * efficiency)
    t_memory = bytes_ / (n_devices * hw.mem_bw * efficiency)
    return max(t_compute, t_memory)


def kv_quant_factor(cfg: ModelConfig) -> float:
    """Per-token KV byte ratio of the int8 quantized pool vs the bf16
    baseline: (hd·1 + 4 fp32-scale bytes) / (hd·e) per token-head — the
    §7 extension the serving engines implement (``kv_dtype="int8"``).
    ≈ 0.53 for hd = 128; both capacity (max batch) and per-iteration
    attention reads scale by it."""
    hd = cfg.resolved_head_dim
    return (hd + 4.0) / (hd * BYTES_PER_EL)


def atime(cfg: ModelConfig, batch: int, seq_len: float, hw: HardwareSpec,
          n_devices: int = 1, efficiency: float = 0.8,
          kv_byte_factor: float = 1.0) -> float:
    """One decode iteration of all attention operators (paper §2.2.2).

    BGEMV: every KV byte is read once; flops = 4·B·l·d_kv·G per layer pair
    (qk + pv); arithmetic intensity ≈ G, constant in B.
    ``kv_byte_factor`` scales the per-token KV footprint (int8 quantized
    pool: :func:`kv_quant_factor`)."""
    kv_bytes = kv_bytes_per_token(cfg) * batch * seq_len
    if kv_bytes == 0.0:  # attention-free
        return 0.0
    G = cfg.gqa_group
    # flops follow the DEQUANTIZED elements (quantization shrinks bytes
    # read, not MACs); memory follows the wire/pool bytes
    flops = kv_bytes / BYTES_PER_EL * 2.0 * G
    t_compute = flops / (n_devices * hw.flops * efficiency)
    t_memory = kv_bytes * kv_byte_factor / (n_devices * hw.mem_bw *
                                            efficiency)
    return max(t_compute, t_memory)


def mfu_nonattention(cfg: ModelConfig, batch: int, hw: HardwareSpec) -> float:
    """Fig. 2: model FLOPS utilisation of the non-attention part."""
    n_act = active_param_count(cfg)
    flops = 2.0 * n_act * batch
    return flops / hw.flops / mtime(cfg, batch, hw, efficiency=1.0)


def mbu_attention(cfg: ModelConfig, batch: int, seq_len: float,
                  hw: HardwareSpec) -> float:
    """Fig. 3: memory-bandwidth utilisation of the attention part."""
    kv_bytes = kv_bytes_per_token(cfg) * batch * seq_len
    return kv_bytes / hw.mem_bw / atime(cfg, batch, seq_len, hw,
                                        efficiency=1.0)


# ---------------------------------------------------------------------------
# Paper §3.1: minimum interconnect bandwidth (Fig. 4)
# ---------------------------------------------------------------------------
def transfer_bytes_per_iteration(cfg: ModelConfig, batch: int) -> float:
    """(2 + 2/G)·e·d·B·L — q + attn output (2·e·d·B·L) and k,v (2/G·e·d·B·L)
    per layer, both directions combined (paper §3.1)."""
    G = cfg.gqa_group
    return (2.0 + 2.0 / G) * BYTES_PER_EL * cfg.q_dim * batch * \
        cfg.num_layers


def minimum_bandwidth(cfg: ModelConfig, batch: int, seq_len: float,
                      hw_model: HardwareSpec, hw_attn: HardwareSpec,
                      alpha: float = 0.2, dop: Tuple[int, int] = (1, 1)
                      ) -> float:
    """Minimum DCN bandwidth (bytes/s) for ≤ α latency slow-down."""
    a, b = dop
    t = mtime(cfg, batch, hw_model, a) + atime(cfg, batch, seq_len, hw_attn, b)
    return transfer_bytes_per_iteration(cfg, batch) / (alpha * t)


# ---------------------------------------------------------------------------
# Network stack model (paper §6.3, Fig. 13)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NetworkStack:
    name: str
    base_rtt_us: float     # small-message GPU-to-GPU round trip
    peak_gbs: float        # achievable point-to-point bandwidth
    launch_overhead_us: float  # host kernel-launch on the critical path


NETWORK_STACKS: Dict[str, NetworkStack] = {
    # measured values from paper Fig. 13 / §4.1
    "fhbn": NetworkStack("fhbn", 33.0, 45.7, 0.0),
    "nccl": NetworkStack("nccl", 66.6, 35.5, 20.0),
    "nccl_no_gdr": NetworkStack("nccl_no_gdr", 83.0, 21.0, 20.0),
    "gloo": NetworkStack("gloo", 120.0, 15.0, 20.0),
    # TPU-native: compiler-scheduled ICI/DCN collectives, no host involvement
    # by construction (DESIGN.md §3.2) — modelled as link-rate with ~1us DMA
    "xla_ici": NetworkStack("xla_ici", 1.0, 45.0, 0.0),
}


def pingpong_rtt_us(stack: NetworkStack, payload_bytes: float) -> float:
    """Round-trip time of the Fig. 13 microbenchmark."""
    wire = 2.0 * payload_bytes / (stack.peak_gbs * 1e9) * 1e6
    return stack.base_rtt_us + stack.launch_overhead_us + wire


def network_time_per_iteration(cfg: ModelConfig, batch: int,
                               stack: NetworkStack,
                               overlap_fraction: float = 0.0) -> float:
    """Per-iteration DCN time for model-attention disaggregation: 2 transfers
    per layer (QKV out, attention result back), RTT-dominated for small B.

    overlap_fraction: fraction hidden behind compute by the §4.2.2 schedule.
    """
    payload = transfer_bytes_per_iteration(cfg, batch) / cfg.num_layers
    per_layer = (stack.base_rtt_us + stack.launch_overhead_us) * 1e-6 + \
        payload / (stack.peak_gbs * 1e9)
    return cfg.num_layers * per_layer * (1.0 - overlap_fraction)


# ---------------------------------------------------------------------------
# Serving throughput / cost estimator (Fig. 10, 11)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServingEstimate:
    system: str
    dop: Tuple[int, int]
    batch: int
    tbt_s: float               # time between tokens
    throughput_tok_s: float
    cost_hr: float
    tok_per_dollar: float


def max_batch_homogeneous(cfg: ModelConfig, seq_len: float,
                          hw: HardwareSpec, n_devices: int,
                          mem_util: float = 0.9) -> int:
    """Largest batch whose weights+KV fit n_devices of `hw` (vLLM-style)."""
    budget = n_devices * hw.mem_bytes * mem_util - \
        BYTES_PER_EL * param_count(cfg)
    per_req = kv_bytes_per_token(cfg) * seq_len
    return max(int(budget / per_req), 0) if per_req > 0 else 1 << 16


def max_batch_disaggregated(cfg: ModelConfig, seq_len: float,
                            hw_attn: HardwareSpec, n_attn: int,
                            mem_util: float = 0.9,
                            kv_byte_factor: float = 1.0) -> int:
    """KV lives only on the attention pool (paper §4: model workers hold
    weights, attention workers hold KV). ``kv_byte_factor`` scales the
    per-token footprint (int8 pool admits ~2× the batch)."""
    budget = n_attn * hw_attn.mem_bytes * mem_util
    per_req = kv_bytes_per_token(cfg) * kv_byte_factor * seq_len
    return max(int(budget / per_req), 0) if per_req > 0 else 1 << 16


def estimate_vllm(cfg: ModelConfig, seq_len: float, hw: HardwareSpec,
                  n_devices: int, batch: Optional[int] = None
                  ) -> ServingEstimate:
    B = batch or max_batch_homogeneous(cfg, seq_len, hw, n_devices)
    B = max(B, 1)
    t = mtime(cfg, B, hw, n_devices) + atime(cfg, B, seq_len, hw, n_devices)
    cost = n_devices * hw.price_hr
    thr = B / t
    return ServingEstimate("vllm", (n_devices, 0), B, t, thr, cost,
                           thr * 3600.0 / cost)


def estimate_lamina(cfg: ModelConfig, seq_len: float,
                    hw_model: HardwareSpec, hw_attn: HardwareSpec,
                    dop: Tuple[int, int], batch: Optional[int] = None,
                    stack: NetworkStack = NETWORK_STACKS["fhbn"],
                    pipelined: bool = True,
                    overlap_fraction: float = 0.3,
                    kv_byte_factor: float = 1.0) -> ServingEstimate:
    """Paper's system: model on `a` compute devices, attention on `b` memory
    devices, staggered pipelining overlaps the two pools (§4.3).
    ``kv_byte_factor`` < 1 models the quantized KV pool (§7): the pool
    admits a proportionally larger batch AND each iteration reads
    proportionally fewer KV bytes."""
    a, b = dop
    B = batch or max_batch_disaggregated(cfg, seq_len, hw_attn, b,
                                         kv_byte_factor=kv_byte_factor)
    B = max(B, 1)
    t_m = mtime(cfg, B, hw_model, a)
    t_a = atime(cfg, B, seq_len, hw_attn, b, kv_byte_factor=kv_byte_factor)
    t_net = network_time_per_iteration(cfg, B, stack, overlap_fraction)
    tbt = t_m + t_a + t_net
    if pipelined:
        # with rotational staggered pipelining both pools stay busy; the
        # system completes one iteration per max(t_m, t_a + t_net) in steady
        # state (§4.3) while per-token latency stays ≈ tbt
        iter_time = max(t_m, t_a + t_net)
    else:
        iter_time = tbt
    cost = a * hw_model.price_hr + b * hw_attn.price_hr
    thr = B / iter_time
    return ServingEstimate("lamina", dop, B, tbt, thr, cost,
                           thr * 3600.0 / cost)
