"""Rotational staggered pipelining (paper §4.3, Fig. 8).

n concurrent batches, R = n-1 model replicas, one shared attention pool.
t_m = time of ONE model slice, t_a = time of one attention call; the pool is
sized so t_a = t_m / (n-1). Batch j starts j·t_a after batch 0; slice k of
batch j runs on replica (j+k) mod R; its attention call follows immediately.

With these choices the schedule is exactly tight:
  * replica r executes model slices back-to-back at times r·t_a + q·t_m,
  * the attention pool executes calls back-to-back at consecutive multiples
    of t_a (index j + k·n + R is a distinct integer per (j, k)),
so both pools are conflict-free AND bubble-free — `validate` proves this
discretely (Fractions, no float fuzz) and the hypothesis tests sweep it.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class Event:
    batch: int
    step: int            # slice index within the iteration
    device: str          # "model:<r>" or "attn"
    start: Fraction
    end: Fraction


@dataclasses.dataclass
class Schedule:
    n_batches: int
    n_steps: int
    events: List[Event]
    t_model: Fraction    # one model slice
    t_attn: Fraction     # one attention call = t_model / (n-1)

    @property
    def makespan(self) -> Fraction:
        return max(e.end for e in self.events)


def rotational_schedule(n_batches: int, n_steps: int,
                        t_model: float = 1.0) -> Schedule:
    if n_batches < 2:
        raise ValueError("staggered pipelining needs >= 2 batches")
    n, R = n_batches, n_batches - 1
    tm = Fraction(t_model).limit_denominator(10**9)
    ta = tm / R
    events: List[Event] = []
    for j in range(n):
        for k in range(n_steps):
            start = j * ta + k * (tm + ta)
            r = (j + k) % R
            events.append(Event(j, k, f"model:{r}", start, start + tm))
            events.append(Event(j, k, "attn", start + tm, start + tm + ta))
    return Schedule(n, n_steps, events, tm, ta)


def validate(s: Schedule) -> Dict[str, bool]:
    """Prove: conflict-free on every device, sequential per batch,
    bubble-free on the attention pool in the steady-state window."""
    by_device: Dict[str, List[Event]] = {}
    for e in s.events:
        by_device.setdefault(e.device, []).append(e)
    conflict_free = True
    for dev, evs in by_device.items():
        evs = sorted(evs, key=lambda e: e.start)
        for a, b in zip(evs, evs[1:]):
            if b.start < a.end:
                conflict_free = False
    sequential = True
    for j in range(s.n_batches):
        evs = sorted([e for e in s.events if e.batch == j],
                     key=lambda e: (e.start, e.device != "attn"))
        for a, b in zip(evs, evs[1:]):
            if b.start < a.end:
                sequential = False
    attn = sorted([e for e in s.events if e.device == "attn"],
                  key=lambda e: e.start)
    # steady state: from the last batch's first attention to the first
    # batch's last attention
    lo = max(e.start for e in attn if e.step == 0)
    hi = min(max(e.end for e in attn if e.batch == j)
             for j in range(s.n_batches))
    busy = sum(min(e.end, hi) - max(e.start, lo)
               for e in attn if e.end > lo and e.start < hi)
    # vacuously bubble-free when the steady-state window is empty (short runs)
    bubble_free = (hi <= lo) or busy == (hi - lo)
    return {"conflict_free": conflict_free, "sequential": sequential,
            "attn_bubble_free": bubble_free}


def utilisation(s: Schedule) -> Dict[str, float]:
    span = float(s.makespan)
    out: Dict[str, float] = {}
    for e in s.events:
        out[e.device] = out.get(e.device, 0.0) + float(e.end - e.start)
    return {d: b / span for d, b in out.items()}


def throughput_speedup(n_batches: int) -> float:
    """Aggregate-throughput multiplier vs one non-pipelined batch on the SAME
    hardware (R replicas idle when attention runs): n batches complete an
    iteration every (t_m + t_a) per slice vs 1 batch per (t_m + t_a) —
    the win is n× more sequences at (n-1)× replicas + shared pool, i.e.
    per-replica efficiency n/(n-1) and zero attention-pool idle time."""
    n = n_batches
    return n / (n - 1)


# ---------------------------------------------------------------------------
# Executable demonstration: run real sliced programs under the rotation
# ---------------------------------------------------------------------------
def run_rotational(sliced_programs, batches_inputs, attention_fn
                   ) -> Tuple[List[dict], List[Tuple]]:
    """Execute n batches through their sliced block programs in the exact
    global order the schedule prescribes (single-host simulation). Logs
    (batch, slice, replica) tuples so tests can assert the rotation law
    (j + k) mod (n-1). The schedule order is realised by sorting events by
    start time; data dependencies hold because batch j's slice k+1 starts
    strictly after its attention k completes."""
    n = len(batches_inputs)
    n_steps = len(sliced_programs[0].slices)
    envs = [dict(b) for b in batches_inputs]
    log: List[Tuple[int, int, int]] = []
    if n >= 2:
        sched = rotational_schedule(n, n_steps)
        order = sorted([e for e in sched.events
                        if e.device.startswith("model:")],
                       key=lambda e: (e.start, e.batch))
    else:
        order = [Event(0, k, "model:0", Fraction(k), Fraction(k + 1))
                 for k in range(n_steps)]
    for ev in order:
        j, k = ev.batch, ev.step
        replica = (j + k) % max(n - 1, 1)
        sp = sliced_programs[j]
        sl = sp.slices[k]
        if sl.recv_attn is not None:
            envs[j][sl.recv_attn] = attention_fn(j, sl.recv_attn, envs[j])
        for name in sl.program:
            op = sp.graph.ops[name]
            if op.kind != "input":
                envs[j][name] = op.fn(*[envs[j][i] for i in op.inputs])
        log.append((j, k, replica))
    return envs, log
