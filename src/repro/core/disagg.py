"""Model-attention disaggregation config + sharding rules (paper §3/§4).

On the TPU mesh the paper's two device pools become two *sharding domains*
(DESIGN.md §3.1):

  * dense weights — tensor-parallel over the ``model`` axis (Megatron-style
    col/row pairs), optionally FSDP over ``data`` for the 1T-param config;
  * KV caches / recurrent state — the "memory pool": batch over ``data``,
    and the attention partition over the pool axis — ``head`` (paper's
    choice), ``seq`` (partial-combine, used when kv-heads don't divide or
    batch=1 long-context), or ``request`` (the rejected baseline).

``specs_for_params`` mirrors any params pytree with PartitionSpecs using
semantic rules for known structures + a divisibility-guarded generic rule,
so every assigned architecture lowers on the production mesh without
hand-written per-arch tables.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class DisaggConfig:
    """Degrees of parallelism and partition strategy (paper §3.1, §5)."""
    dop: Tuple[int, int] = (2, 4)          # (model workers, attention workers)
    attention_partition: str = "head"       # head | seq | request
    fsdp: bool = False                      # shard weights over data too
    decode_backend: str = "jnp"             # jnp | pallas


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.shape and n % mesh.shape[axis] == 0


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# Parameter sharding
# ---------------------------------------------------------------------------
def specs_for_params(cfg: ModelConfig, params_shape: Any, mesh: Mesh,
                     fsdp: bool = False) -> Any:
    """PartitionSpec pytree mirroring `params_shape` (a ShapeDtypeStruct
    tree from jax.eval_shape(init_params, ...))."""

    def rule(path, leaf) -> P:
        name = _path_str(path)
        shape = leaf.shape
        parts = name.split("/")
        stacked = name.startswith(("layers", "enc_layers", "tail"))
        # number of leading stacking dims (zamba mamba layers are (S, P, ...))
        # — reduced by list-layout path indices ("layers/3/..." has none)
        lead = 0
        if stacked:
            lead = 1
            if name.startswith("layers") and cfg.family == "hybrid":
                lead = 2
            lead -= sum(1 for p in parts[1:3] if p.isdigit())
            lead = max(lead, 0)
        dims: list = [None] * len(shape)

        def set_axis(i, axis):
            dims[i] = axis

        base = name.split("/")[-1]
        if name == "embed":
            if _div(shape[0], mesh, "model"):
                set_axis(0, "model")
            return P(*dims)
        if name == "lm_head":
            if _div(shape[1], mesh, "model"):
                set_axis(1, "model")
            return P(*dims)
        if len(shape) - lead < 2:  # norms, biases, scalars
            return P(*dims)

        if base in ("wq", "wk", "wv"):           # (..., d, H, hd)
            h_i = lead + 1
            if _div(shape[h_i], mesh, "model"):
                set_axis(h_i, "model")
            elif _div(shape[h_i + 1], mesh, "model") and \
                    shape[h_i + 1] // mesh.shape["model"] >= 8:
                # kv-heads don't divide the axis (llama kv=8, glm kv=2 at
                # 16-way): shard head_dim instead of replicating — keeps the
                # K/V projections (and their fp32 Adam moments) distributed
                # (§Perf follow-up; RoPE pairs stay intact because hd/16 >= 8
                # keeps the rotate-half split aligned per shard... see note)
                set_axis(h_i + 1, "model")
            elif fsdp and _div(shape[lead], mesh, "data"):
                set_axis(lead, "data")
            if fsdp and dims[lead] is None and _div(shape[lead], mesh, "data"):
                set_axis(lead, "data")
            return P(*dims)
        if base == "wo":                          # (..., H, hd, d)
            if _div(shape[lead], mesh, "model"):
                set_axis(lead, "model")
            if fsdp and _div(shape[-1], mesh, "data"):
                set_axis(len(shape) - 1, "data")
            return P(*dims)
        if "moe" in name and base in ("w_gate", "w_up", "w_down"):
            # (..., E, d, f) expert-parallel over model
            if _div(shape[lead], mesh, "model"):
                set_axis(lead, "model")
            if fsdp and _div(shape[lead + 1], mesh, "data"):
                set_axis(lead + 1, "data")
            return P(*dims)
        if base in ("w_gate", "w_up", "w_fc"):    # (..., d, f) col-parallel
            if _div(shape[-1], mesh, "model"):
                set_axis(len(shape) - 1, "model")
            if fsdp and _div(shape[-2], mesh, "data"):
                set_axis(len(shape) - 2, "data")
            return P(*dims)
        if base in ("w_down", "w_proj"):          # (..., f, d) row-parallel
            if _div(shape[-2], mesh, "model"):
                set_axis(len(shape) - 2, "model")
            if fsdp and _div(shape[-1], mesh, "data"):
                set_axis(len(shape) - 1, "data")
            return P(*dims)
        if base == "router":
            return P(*dims)                       # small, replicated
        # generic 2D+ rule: last dim over model if divisible, else previous
        if _div(shape[-1], mesh, "model"):
            set_axis(len(shape) - 1, "model")
        elif _div(shape[-2], mesh, "model"):
            set_axis(len(shape) - 2, "model")
        if fsdp:
            for i in range(lead, len(shape)):
                if dims[i] is None and _div(shape[i], mesh, "data"):
                    set_axis(i, "data")
                    break
        return P(*dims)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# ---------------------------------------------------------------------------
# Batch / cache / activation sharding
# ---------------------------------------------------------------------------
def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that carry the global batch: ('pod','data') on multi-pod."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def specs_for_batch(cfg: ModelConfig, batch_shape: Dict, mesh: Mesh) -> Dict:
    baxes = batch_axes(mesh)

    def rule(path, leaf):
        B = leaf.shape[0]
        total = 1
        use = []
        for a in baxes:
            if B % (total * mesh.shape[a]) == 0:
                use.append(a)
                total *= mesh.shape[a]
        spec = [tuple(use) if use else None] + [None] * (len(leaf.shape) - 1)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def specs_for_cache(cfg: ModelConfig, cache_shape: Dict, mesh: Mesh,
                    attention_partition: str = "auto") -> Dict:
    """The memory-pool shardings (paper §5 'Attention parallelism').

    head  — KV head dim over `model` (needs divisibility)
    seq   — KV sequence dim over `model` (+ data when batch can't shard)
    auto  — head if divisible else seq (logged by the launcher)

    Handles both layouts: stacked ((L, B, S, ...) single buffers) and the
    per-layer LIST layout used by the unrolled cost/production lowering
    (paths like "k/3" with the leading layer dims gone).
    """
    baxes = batch_axes(mesh)

    def batch_spec(B):
        use, total = [], 1
        for a in baxes:
            if B % (total * mesh.shape[a]) == 0:
                use.append(a)
                total *= mesh.shape[a]
        return tuple(use) if use else None, total

    def rule(path, leaf):
        parts = _path_str(path).split("/")
        base = parts[0]
        shape = leaf.shape
        if base == "len":
            bs, _ = batch_spec(shape[0])
            return P(bs)

        def dims_for(expected_rank, fill):
            """Build a spec for a leaf whose last `expected_rank` dims carry
            the semantics in `fill` (leading stacking dims -> None)."""
            lead = len(shape) - expected_rank
            return P(*([None] * lead + fill))

        if base in ("k", "v", "ck", "cv"):
            # semantic dims: HEAD-MAJOR (B, Hkv, S, hd)
            B, Hkv, S = shape[-4], shape[-3], shape[-2]
            bs, _ = batch_spec(B)
            part = attention_partition
            if part == "auto":
                part = "head" if _div(Hkv, mesh, "model") else "seq"
            fill = [bs, None, None, None]
            if part == "head" and _div(Hkv, mesh, "model"):
                fill[1] = "model"
            elif _div(S, mesh, "model"):
                fill[2] = "model"
                if bs is None:  # batch=1 long-context: spread S wider
                    extra = [a for a in baxes
                             if S % (mesh.shape[a] * mesh.shape["model"])
                             == 0]
                    if extra:
                        fill[2] = (extra[0], "model")
            return dims_for(4, fill)
        if base in ("k_scale", "v_scale"):  # int8 KV scales (B, Hkv, S)
            B, Hkv, S = shape[-3], shape[-2], shape[-1]
            bs, _ = batch_spec(B)
            part = attention_partition
            if part == "auto":
                part = "head" if _div(Hkv, mesh, "model") else "seq"
            fill = [bs, None, None]
            if part == "head" and _div(Hkv, mesh, "model"):
                fill[1] = "model"
            elif _div(S, mesh, "model"):
                fill[2] = "model"
                if bs is None:
                    extra = [a for a in baxes
                             if S % (mesh.shape[a] * mesh.shape["model"])
                             == 0]
                    if extra:
                        fill[2] = (extra[0], "model")
            return dims_for(3, fill)
        if base in ("k_new", "v_new"):  # (B, Hkv, hd)
            bs, _ = batch_spec(shape[-3])
            return dims_for(3, [bs, "model" if _div(shape[-2], mesh, "model")
                                else None, None])
        if base == "S":                 # rwkv state (B, H, P, P)
            bs, _ = batch_spec(shape[-4])
            return dims_for(4, [bs, "model" if _div(shape[-3], mesh, "model")
                                else None, None, None])
        if base in ("h", "tail_h"):     # mamba (B, H, P, N)
            bs, _ = batch_spec(shape[-4])
            return dims_for(4, [bs, "model" if _div(shape[-3], mesh, "model")
                                else None, None, None])
        if base in ("conv", "tail_conv"):  # (B, K-1, ch)
            bs, _ = batch_spec(shape[-3])
            return dims_for(3, [bs, None,
                                "model" if _div(shape[-1], mesh, "model")
                                else None])
        if base in ("x_tm", "x_cm"):    # (B, d)
            bs, _ = batch_spec(shape[-2])
            return dims_for(2, [bs, "model" if _div(shape[-1], mesh, "model")
                                else None])
        bs, _ = batch_spec(shape[0]) if shape else (None, 1)
        return P(*([bs] + [None] * (len(shape) - 1)))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def logits_spec(cfg: ModelConfig, mesh: Mesh, batch: int) -> P:
    baxes, total = [], 1
    for a in batch_axes(mesh):
        if batch % (total * mesh.shape[a]) == 0:
            baxes.append(a)
            total *= mesh.shape[a]
    return P(tuple(baxes) if baxes else None,
             "model" if _div(cfg.vocab_size, mesh, "model") else None)
