"""Automated model converter (paper §4.2).

Takes a transformer block expressed as a weighted operator graph, removes
each attention operator, computes the *minimum weighted cut* between the
attention input's side and the attention output's side (edge weight = bytes
of the tensor on that edge), and emits ``n+1`` executable model slices with
explicit ``SendQ`` / ``SendKV`` / ``RecvAttn`` instructions. Within each
slice the serial program is a topological order that hoists Q-Proj (and its
dependencies) as early as possible so the q transfer overlaps the K/V
projections (paper §4.2.2 / Fig. 7).

The graph is genuinely executable — ``SlicedProgram.run`` reproduces the
unsliced block bit-for-bit given an attention callback — which is how the
tests validate the cut.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class OpNode:
    name: str
    kind: str                      # 'input' | 'attention' | compute kinds
    inputs: List[str]
    out_bytes: int                 # edge weight for every out-edge
    fn: Optional[Callable] = None  # (*input_arrays) -> array


class OpGraph:
    def __init__(self):
        self.ops: Dict[str, OpNode] = {}
        self.order: List[str] = []

    def add(self, name: str, kind: str, inputs: Sequence[str],
            out_bytes: int, fn: Optional[Callable] = None) -> str:
        assert name not in self.ops, name
        for i in inputs:
            assert i in self.ops, f"unknown input {i} of {name}"
        self.ops[name] = OpNode(name, kind, list(inputs), out_bytes, fn)
        self.order.append(name)
        return name

    def consumers(self) -> Dict[str, List[str]]:
        out = defaultdict(list)
        for op in self.ops.values():
            for i in op.inputs:
                out[i].append(op.name)
        return out

    def attention_ops(self) -> List[str]:
        return [n for n in self.order if self.ops[n].kind == "attention"]


# ---------------------------------------------------------------------------
# Max-flow / min-cut (Edmonds–Karp; graphs are ~10-100 nodes)
# ---------------------------------------------------------------------------
def _min_cut(nodes: List[str], edges: List[Tuple[str, str, int]],
             source: str, sink: str) -> Tuple[int, set]:
    """Returns (flow, set of nodes on the source side)."""
    cap: Dict[Tuple[str, str], int] = defaultdict(int)
    adj: Dict[str, set] = defaultdict(set)
    for u, v, c in edges:
        cap[(u, v)] += c
        adj[u].add(v)
        adj[v].add(u)  # residual
    flow = 0
    while True:
        parent = {source: None}
        q = deque([source])
        while q and sink not in parent:
            u = q.popleft()
            for v in adj[u]:
                if v not in parent and cap[(u, v)] > 0:
                    parent[v] = u
                    q.append(v)
        if sink not in parent:
            break
        # bottleneck
        path, v = [], sink
        while parent[v] is not None:
            path.append((parent[v], v))
            v = parent[v]
        aug = min(cap[e] for e in path)
        for u, v in path:
            cap[(u, v)] -= aug
            cap[(v, u)] += aug
        flow += aug
    # source side = reachable in residual graph
    side = {source}
    q = deque([source])
    while q:
        u = q.popleft()
        for v in adj[u]:
            if v not in side and cap[(u, v)] > 0:
                side.add(v)
                q.append(v)
    return flow, side


# ---------------------------------------------------------------------------
# Slicing
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Slice:
    index: int
    program: List[str]             # topologically ordered op names
    context_in: List[str]          # ops whose values arrive from prev slice
    context_out: List[str]         # ops whose values must be saved (min cut)
    sends: Dict[str, str]          # op name -> 'q' | 'kv' (transfer markers)
    recv_attn: Optional[str] = None  # attention op whose output this consumes


@dataclasses.dataclass
class SlicedProgram:
    graph: OpGraph
    slices: List[Slice]
    cut_bytes: List[int]           # saved-context bytes per boundary

    def run(self, inputs: Dict[str, object],
            attention_fn: Callable[[str, Dict[str, object]], object],
            trace: Optional[List[str]] = None) -> Dict[str, object]:
        """Execute the sliced program. ``attention_fn(op_name, env)`` plays
        the role of the remote attention workers."""
        env = dict(inputs)
        for sl in self.slices:
            if sl.recv_attn is not None:
                env[sl.recv_attn] = attention_fn(sl.recv_attn, env)
                if trace is not None:
                    trace.append(f"recv_attn:{sl.recv_attn}")
            for name in sl.program:
                op = self.graph.ops[name]
                if op.kind == "input":
                    continue
                env[name] = op.fn(*[env[i] for i in op.inputs])
                if trace is not None:
                    trace.append(name)
                    if name in sl.sends:
                        trace.append(f"send_{sl.sends[name]}:{name}")
        return env


def _ancestors(graph: OpGraph, target: str) -> set:
    anc, stack = set(), [target]
    while stack:
        n = stack.pop()
        for i in graph.ops[n].inputs:
            if i not in anc:
                anc.add(i)
                stack.append(i)
    return anc


def _topo_q_early(graph: OpGraph, members: set, q_ops: set) -> List[str]:
    """Kahn topological sort restricted to `members`; ops that q-proj depends
    on (and q-proj itself) are dequeued first (paper §4.2.2)."""
    indeg = {n: 0 for n in members}
    cons = defaultdict(list)
    for n in members:
        for i in graph.ops[n].inputs:
            if i in members:
                indeg[n] += 1
                cons[i].append(n)
    ready = sorted([n for n, d in indeg.items() if d == 0],
                   key=lambda n: (n not in q_ops, graph.order.index(n)))
    out = []
    while ready:
        n = ready.pop(0)
        out.append(n)
        for c in cons[n]:
            indeg[c] -= 1
            if indeg[c] == 0:
                ready.append(c)
        ready.sort(key=lambda x: (x not in q_ops, graph.order.index(x)))
    assert len(out) == len(members), "cycle in op graph"
    return out


def split_at_attention(graph: OpGraph) -> SlicedProgram:
    """Cut the graph at every attention op (paper Fig. 6): n attention ops
    yield n+1 slices. The saved context across each boundary is the minimum
    weighted edge cut of the graph with that attention op removed.

    The max-flow formulation adds an INF reverse edge per data edge: cutting
    "backwards" is impossible, which enforces dependency closure (if a
    consumer lands before the boundary, so does its producer).
    """
    attn_ops = graph.attention_ops()
    cons = graph.consumers()
    INF = 1 << 60
    assigned: set = set()          # ops executed in earlier slices
    slices: List[Slice] = []
    cut_bytes: List[int] = []
    prev_context: List[str] = []
    prev_attn: Optional[str] = None

    for idx, attn in enumerate(attn_ops):
        members = set(graph.order) - set(attn_ops[:idx]) - {attn}
        edges = []
        for n in members:
            for c in cons.get(n, []):
                if c in members:
                    edges.append((n, c, graph.ops[n].out_bytes))
                    edges.append((c, n, INF))  # dependency closure
        for n in members:
            if graph.ops[n].kind == "input" or n in assigned:
                edges.append(("__SRC__", n, INF))
        for i in graph.ops[attn].inputs:
            if i in members:  # attention inputs are computed pre-boundary
                edges.append(("__SRC__", i, INF))
        for t in cons.get(attn, []):
            if t in members:  # attention consumers are post-boundary
                edges.append((t, "__SNK__", INF))
        nodes = list(members) + ["__SRC__", "__SNK__"]
        _, side = _min_cut(nodes, edges, "__SRC__", "__SNK__")
        this_side = (side - {"__SRC__"}) & members
        for later in attn_ops[idx + 1:]:
            assert later not in this_side, \
                "converter: attention op landed inside a model slice"
        # saved context: values computed up to here but consumed after
        context = sorted({n for n in this_side
                          for c in cons.get(n, [])
                          if c in members and c not in this_side},
                         key=graph.order.index)
        cut_bytes.append(sum(graph.ops[n].out_bytes for n in context))

        program_members = this_side - assigned
        q_anc = set()
        for i in graph.ops[attn].inputs:
            if graph.ops[i].kind.startswith("q"):
                q_anc = _ancestors(graph, i) | {i}
        program = _topo_q_early(graph, program_members, q_anc)
        sends = {i: ("q" if i in q_anc else "kv")
                 for i in graph.ops[attn].inputs if i in program}
        slices.append(Slice(index=idx, program=program,
                            context_in=list(prev_context),
                            context_out=context, sends=sends,
                            recv_attn=prev_attn))
        prev_context = context
        prev_attn = attn
        assigned |= this_side

    final_members = set(graph.order) - assigned - set(attn_ops)
    program = _topo_q_early(graph, final_members, set())
    slices.append(Slice(index=len(attn_ops), program=program,
                        context_in=list(prev_context), context_out=[],
                        sends={}, recv_attn=prev_attn))
    return SlicedProgram(graph=graph, slices=slices, cut_bytes=cut_bytes)


# ---------------------------------------------------------------------------
# Concrete graph builder: one GQA transformer block, numpy-executable
# ---------------------------------------------------------------------------
def build_block_graph(cfg, weights: Optional[Dict] = None,
                      batch: int = 1) -> OpGraph:
    """Builds the paper's Figure-6 graph for one transformer block of `cfg`.
    Edge weights are activation bytes for `batch` decode tokens. If `weights`
    (the dense_block params pytree) is given, ops are executable via numpy.
    """
    import numpy as np

    e = 2  # bf16
    d = cfg.d_model
    hq, hkv = cfg.q_dim, cfg.kv_dim
    g = OpGraph()

    def w(key1, key2=None):
        if weights is None:
            return None
        arr = weights[key1]
        if key2 is not None:
            arr = arr[key2]
        return np.asarray(arr, np.float32)

    def rms(x, gamma):
        nx = x / np.sqrt((x * x).mean(-1, keepdims=True) + 1e-6)
        return nx * (1.0 + gamma)

    g.add("x", "input", [], batch * d * e)
    g.add("norm1", "norm", ["x"], batch * d * e,
          fn=(lambda x: rms(x, w("norm1"))) if weights else None)
    g.add("q_proj", "q_proj", ["norm1"], batch * hq * e,
          fn=(lambda h: np.einsum("bd,dhk->bhk", h, w("attn", "wq")))
          if weights else None)
    g.add("k_proj", "kv_proj", ["norm1"], batch * hkv * e,
          fn=(lambda h: np.einsum("bd,dhk->bhk", h, w("attn", "wk")))
          if weights else None)
    g.add("v_proj", "kv_proj", ["norm1"], batch * hkv * e,
          fn=(lambda h: np.einsum("bd,dhk->bhk", h, w("attn", "wv")))
          if weights else None)
    g.add("attention", "attention", ["q_proj", "k_proj", "v_proj"],
          batch * hq * e)
    g.add("o_proj", "proj", ["attention"], batch * d * e,
          fn=(lambda a: np.einsum("bhk,hkd->bd", a, w("attn", "wo")))
          if weights else None)
    g.add("residual1", "add", ["x", "o_proj"], batch * d * e,
          fn=(lambda x, o: x + o) if weights else None)
    g.add("norm2", "norm", ["residual1"], batch * d * e,
          fn=(lambda x: rms(x, w("norm2"))) if weights else None)
    g.add("ffn_gate", "proj", ["norm2"], batch * cfg.d_ff * e,
          fn=(lambda h: h @ w("ffn", "w_gate")) if weights else None)
    g.add("ffn_up", "proj", ["norm2"], batch * cfg.d_ff * e,
          fn=(lambda h: h @ w("ffn", "w_up")) if weights else None)
    g.add("ffn_act", "act", ["ffn_gate", "ffn_up"], batch * cfg.d_ff * e,
          fn=(lambda a, b: (a / (1 + np.exp(-a))) * b) if weights else None)
    g.add("ffn_down", "proj", ["ffn_act"], batch * d * e,
          fn=(lambda h: h @ w("ffn", "w_down")) if weights else None)
    g.add("residual2", "add", ["residual1", "ffn_down"], batch * d * e,
          fn=(lambda x, f: x + f) if weights else None)
    return g
