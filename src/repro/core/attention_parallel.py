"""Multi-device attention partitioning (paper §5 "Attention parallelism").

The paper distributes decode attention over a pool of memory devices either
request-level (imbalanced) or head-level (balanced, chosen by Lamina). On the
TPU mesh we express both, plus the split the §4.2.2 combine identity makes
exact — the variant that serves `long_500k` where a single request's KV
exceeds one chip. Three PAGED partitions of the serving engines' block pool:

  * head-level:    pool head axis sharded; each device owns its heads'
                   blocks wholesale; no combine (heads are independent)
  * block-level:   pool BLOCK axis sharded; a sequence's round-robin-placed
                   blocks span every device; each device computes the §4.2.2
                   partial (a, s, m) over its local blocks and psum_combine
                   merges — only the tiny triple crosses chips, never KV
  * request-level: batch/table sharded, pool replicated (the paper's
                   rejected baseline, kept for the load-imbalance benchmark)

NO-DENSIFY INVARIANT: every paged backend attends over the pool *in place*
through its (local) block table — the Pallas paged flash-decode kernel on
TPU, its head-major jnp reference on CPU. No backend gathers the pool into a
dense seq-major (B, S, Hkv, hd) slab; per-device KV traffic is exactly one
pass over that device's live blocks (Adrenaline, arXiv:2503.20552, makes the
same single-pass argument for attention-disaggregated throughput).

Dense-slab variants (seq/head/request over contiguous caches) survive below
for the non-paged kernel sweeps. All are written with ``shard_map`` so the
per-layer boundary communication is explicit — these collectives are the TPU
rendering of the paper's per-layer DCN transfers, and the dry-run's
collective roofline term measures them.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.5 promotes shard_map out of experimental
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover — older jax in the container
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core import combine as C


def _shard_map_norep(fn, **kw):
    """shard_map without the replication checker: pallas_call has no
    replication rule, and the paged backends may run the kernel in-shard.
    jax >= 0.7 renamed check_rep to check_vma."""
    try:
        return _shard_map(fn, check_rep=False, **kw)
    except TypeError:  # pragma: no cover — newer jax
        return _shard_map(fn, check_vma=False, **kw)


def _masked_partial(q, k_cache, v_cache, valid, logit_softcap=0.0):
    """q: (B, H, hd); caches (B, S, Hkv, hd); valid: (B, S)."""
    B, H, hd = q.shape
    Hkv = k_cache.shape[2]
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, hd)
    # scores per kv head without materialising repeated KV
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bhgk,bshk->bhgs", qg.astype(jnp.float32) * scale,
                   k_cache.astype(jnp.float32))
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe[..., None]), 0.0)
    denom = jnp.sum(p, axis=-1)
    a = jnp.einsum("bhgs,bshk->bhgk", p, v_cache.astype(jnp.float32))
    return C.Partial(a=a.reshape(B, H, hd), s=denom.reshape(B, H),
                     m=jnp.where(jnp.isfinite(m), m, -jnp.inf).reshape(B, H))


# ---------------------------------------------------------------------------
# Sequence-level split (partial-combine across the pool axis)
# ---------------------------------------------------------------------------
def seq_parallel_decode_attention(mesh: Mesh, axis: str, q, k_cache, v_cache,
                                  cache_len, *, sliding_window: int = 0,
                                  logit_softcap: float = 0.0,
                                  batch_axis: Optional[str] = None):
    """Decode attention with the KV sequence sharded over `axis`.

    q: (B, H, hd) replicated over `axis`; caches (B, S, Hkv, hd) with S
    sharded over `axis`; cache_len (B,). Each shard computes its partial
    (A, S, m) over its KV slice; psum_combine merges — the cross-chip form
    of paper §4.2.2.
    """
    n = mesh.shape[axis]
    S = k_cache.shape[1]
    S_shard = S // n
    bspec = P(batch_axis) if batch_axis else P()

    def shard_fn(q, kc, vc, clen):
        idx = jax.lax.axis_index(axis)
        pos = idx * S_shard + jnp.arange(S_shard)[None, :]  # global positions
        valid = pos < clen[:, None]
        if sliding_window > 0:
            valid &= pos >= (clen[:, None] - sliding_window)
        part = _masked_partial(q, kc, vc, valid, logit_softcap)
        return C.finalize(C.psum_combine(part, axis)).astype(q.dtype)

    return _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(batch_axis, None, None), P(batch_axis, axis, None, None),
                  P(batch_axis, axis, None, None), bspec),
        out_specs=P(batch_axis, None, None),
    )(q, k_cache, v_cache, cache_len)


# ---------------------------------------------------------------------------
# Head-level split (the paper's choice for Lamina)
# ---------------------------------------------------------------------------
def head_parallel_decode_attention(mesh: Mesh, axis: str, q, k_cache, v_cache,
                                   cache_len, *, sliding_window: int = 0,
                                   logit_softcap: float = 0.0,
                                   batch_axis: Optional[str] = None):
    """KV heads sharded over `axis`; each device handles its heads fully.
    Requires Hkv % mesh.shape[axis] == 0 (the paper's divisibility caveat).
    """
    Hkv = k_cache.shape[2]
    n = mesh.shape[axis]
    if Hkv % n:
        raise ValueError(
            f"head-level partitioning needs kv_heads ({Hkv}) divisible by "
            f"pool size ({n}) — paper §5; use seq-level instead")
    bspec = P(batch_axis) if batch_axis else P()

    def shard_fn(q, kc, vc, clen):
        S = kc.shape[1]
        pos = jnp.arange(S)[None, :]
        valid = pos < clen[:, None]
        if sliding_window > 0:
            valid &= pos >= (clen[:, None] - sliding_window)
        part = _masked_partial(q, kc, vc, valid, logit_softcap)
        return C.finalize(part).astype(q.dtype)

    return _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(batch_axis, axis, None), P(batch_axis, None, axis, None),
                  P(batch_axis, None, axis, None), bspec),
        out_specs=P(batch_axis, axis, None),
    )(q, k_cache, v_cache, cache_len)


# ---------------------------------------------------------------------------
# Paged variants: the pool-native backends. The KV operand is the serving
# engines' block pool (Hkv, num_blocks, block_size, hd) + a (B, nb) block
# table — what the paged flash-decode kernel consumes IN PLACE, in-shard.
# Head-level shards the pool's head axis (each device owns its heads' blocks
# wholesale); block-level shards the pool's BLOCK axis (a sequence spans
# devices, partials psum-combined); request-level shards the table/batch and
# replicates the pool. See the module docstring's no-densify invariant.
# ---------------------------------------------------------------------------
def _paged_shard_attend(q, kp, vp, bt, clen, *, sliding_window: int,
                        attention_sinks: int, logit_softcap: float,
                        backend: str, interpret: bool,
                        k_scale=None, v_scale=None):
    """Finalized paged attention over one device's pool slice, in place.

    q: (B, H_local, hd); kp/vp: (Hkv_local, NB, bs, hd); bt: (B, nb);
    clen: (B,). 'pallas' runs the paged flash-decode kernel; 'jnp' its
    head-major gather reference (the CPU data path). Int8 pool slices
    carry their (Hkv_local, NB, bs) scale slices; dequant fuses in-shard
    inside the backend (no dense dequantized slab per device either)."""
    from repro.kernels.paged_decode_attention import (paged_decode_attention,
                                                     paged_decode_attention_jnp)

    B, H, hd = q.shape
    Hkv = kp.shape[0]
    qg = q.reshape(B, Hkv, H // Hkv, hd)
    fn = paged_decode_attention_jnp if backend == "jnp" else functools.partial(
        paged_decode_attention, interpret=interpret)
    skw = {} if k_scale is None else dict(k_scale=k_scale, v_scale=v_scale)
    out = fn(qg, kp, vp, bt, clen, sliding_window=sliding_window,
             attention_sinks=attention_sinks, logit_softcap=logit_softcap,
             **skw)
    return out.reshape(B, H, hd).astype(q.dtype)


def head_parallel_paged_decode_attention(mesh: Mesh, axis: str, q, k_pool,
                                         v_pool, block_tables, cache_len, *,
                                         sliding_window: int = 0,
                                         attention_sinks: int = 0,
                                         logit_softcap: float = 0.0,
                                         batch_axis: Optional[str] = None,
                                         backend: str = "jnp",
                                         interpret: bool = False,
                                         k_scale=None, v_scale=None):
    """Head-level split over the paged pool: each device owns Hkv/n heads of
    every pool block (pool head axis sharded over `axis`); the block table
    and lengths are replicated scalars. Each device runs the paged kernel
    (or its jnp reference) over its head slice in place — no dense view, no
    combine (heads are independent). Requires Hkv % mesh.shape[axis] == 0
    (paper §5). Int8 pools: the (Hkv, NB, bs) scale pools shard with the
    same head axis as the value pools (scales-follow-blocks)."""
    Hkv = k_pool.shape[0]
    n = mesh.shape[axis]
    if Hkv % n:
        raise ValueError(
            f"head-level partitioning needs kv_heads ({Hkv}) divisible by "
            f"pool size ({n}) — paper §5; use block-level instead")
    bspec = P(batch_axis) if batch_axis else P()
    btspec = P(batch_axis, None) if batch_axis else P()
    kw = dict(sliding_window=sliding_window, attention_sinks=attention_sinks,
              logit_softcap=logit_softcap, backend=backend,
              interpret=interpret)

    def shard_fn(q, kp, vp, bt, clen, *scales):
        skw = dict(zip(("k_scale", "v_scale"), scales))
        return _paged_shard_attend(q, kp, vp, bt, clen, **kw, **skw)

    operands = [q, k_pool, v_pool, block_tables, cache_len]
    in_specs = [P(batch_axis, axis, None), P(axis, None, None, None),
                P(axis, None, None, None), btspec, bspec]
    if k_scale is not None:
        operands += [k_scale, v_scale]
        in_specs += [P(axis, None, None)] * 2
    return _shard_map_norep(
        shard_fn, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=P(batch_axis, axis, None),
    )(*operands)


def request_parallel_paged_decode_attention(mesh: Mesh, axis: str, q, k_pool,
                                            v_pool, block_tables, cache_len,
                                            *, sliding_window: int = 0,
                                            attention_sinks: int = 0,
                                            logit_softcap: float = 0.0,
                                            backend: str = "jnp",
                                            interpret: bool = False,
                                            k_scale=None, v_scale=None):
    """Request-level split over the paged pool: the batch (q, block table,
    lengths) is sharded; the pool is replicated — each device walks only its
    requests' tables through the paged kernel (or its jnp reference), in
    place (the paper's load-imbalance baseline, pool-native). Int8 pools:
    the scale pools replicate exactly like the value pools they describe."""
    kw = dict(sliding_window=sliding_window, attention_sinks=attention_sinks,
              logit_softcap=logit_softcap, backend=backend,
              interpret=interpret)

    def shard_fn(q, kp, vp, bt, clen, *scales):
        skw = dict(zip(("k_scale", "v_scale"), scales))
        return _paged_shard_attend(q, kp, vp, bt, clen, **kw, **skw)

    operands = [q, k_pool, v_pool, block_tables, cache_len]
    in_specs = [P(axis, None, None), P(None, None, None, None),
                P(None, None, None, None), P(axis, None), P(axis)]
    if k_scale is not None:
        operands += [k_scale, v_scale]
        in_specs += [P(None, None, None)] * 2
    return _shard_map_norep(
        shard_fn, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=P(axis, None, None),
    )(*operands)


def block_parallel_paged_decode_attention(mesh: Mesh, axis: str, q, k_pool,
                                          v_pool, shard_tables,
                                          shard_positions, cache_len, *,
                                          sliding_window: int = 0,
                                          attention_sinks: int = 0,
                                          logit_softcap: float = 0.0,
                                          backend: str = "jnp",
                                          interpret: bool = False,
                                          k_scale=None, v_scale=None):
    """Block-level split: ONE sequence's KV spans every pool device.

    The pool's block axis is sharded over `axis` (device s holds global
    blocks [s·npb, (s+1)·npb) — the PagedKVCache shard layout); q and
    cache_len are replicated. shard_tables/shard_positions (n, B, nbl) carry
    each device's LOCAL table + the global base position of every slot
    (``PagedKVCache.block_table_shards``) — positions, not slot indices,
    anchor the causal/window/sink masks because a shard's walk is
    non-contiguous in the sequence. Each device computes the §4.2.2 partial
    (a, s, m) over exactly one pass of its local live blocks — the paged
    kernel with return_partials=True, or the positions-aware jnp reference —
    and ``psum_combine`` merges exactly; only the tiny triple crosses chips,
    never KV. A device with zero live blocks for a sequence contributes the
    empty partial (s = 0, m = -inf), the combine identity. Int8 pools: the
    scale pools shard on the same BLOCK axis as the value pools — each
    device's partial dequantizes in-shard, and because dequant folds into
    the per-tile score/PV products before the combine, the psum partial
    merge is untouched (scales-follow-blocks under partitioning too)."""
    kernel_partials = backend != "jnp"

    def shard_fn(q, kp, vp, bt, bp, clen, *scales):
        from repro.kernels.ops import _triple_to_partial
        from repro.kernels.paged_decode_attention import paged_decode_attention
        from repro.models.attention import \
            paged_decode_attention_partial_pos_jnp

        skw = dict(zip(("k_scale", "v_scale"), scales))
        bt, bp = bt[0], bp[0]
        B, H, hd = q.shape
        if kernel_partials:
            Hkv = kp.shape[0]
            o, l, m = paged_decode_attention(
                q.reshape(B, Hkv, H // Hkv, hd), kp, vp, bt, clen,
                block_positions=bp, sliding_window=sliding_window,
                attention_sinks=attention_sinks, logit_softcap=logit_softcap,
                interpret=interpret, return_partials=True, **skw)
            part = _triple_to_partial(o, l, m, B, H, hd)
        else:
            part = paged_decode_attention_partial_pos_jnp(
                q, kp, vp, bt, bp, clen, window_total=clen,
                sliding_window=sliding_window,
                attention_sinks=attention_sinks, logit_softcap=logit_softcap,
                **skw)
        return C.finalize(C.psum_combine(part, axis)).astype(q.dtype)

    operands = [q, k_pool, v_pool, shard_tables, shard_positions, cache_len]
    in_specs = [P(), P(None, axis, None, None), P(None, axis, None, None),
                P(axis, None, None), P(axis, None, None), P()]
    if k_scale is not None:
        operands += [k_scale, v_scale]
        in_specs += [P(None, axis, None)] * 2
    return _shard_map_norep(
        shard_fn, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=P(),
    )(*operands)


# ---------------------------------------------------------------------------
# Request-level split (paper's rejected baseline, for the imbalance bench)
# ---------------------------------------------------------------------------
def request_parallel_decode_attention(mesh: Mesh, axis: str, q, k_cache,
                                      v_cache, cache_len, *,
                                      sliding_window: int = 0,
                                      logit_softcap: float = 0.0):
    def shard_fn(q, kc, vc, clen):
        S = kc.shape[1]
        pos = jnp.arange(S)[None, :]
        valid = pos < clen[:, None]
        if sliding_window > 0:
            valid &= pos >= (clen[:, None] - sliding_window)
        return C.finalize(_masked_partial(q, kc, vc, valid,
                                          logit_softcap)).astype(q.dtype)

    return _shard_map_norep(
        shard_fn, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None, None, None),
                  P(axis, None, None, None), P(axis)),
        out_specs=P(axis, None, None),
    )(q, k_cache, v_cache, cache_len)
