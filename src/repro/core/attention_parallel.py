"""Multi-device attention partitioning (paper §5 "Attention parallelism").

The paper distributes decode attention over a pool of memory devices either
request-level (imbalanced) or head-level (balanced, chosen by Lamina). On the
TPU mesh we express both, plus the sequence-level split that the §4.2.2
combine identity makes exact — the variant that serves `long_500k` where a
single request's KV exceeds one chip:

  * head-level:    KV cache heads sharded over the pool axis, no combine
  * sequence-level: KV cache sequence sharded, partial triple + psum-combine
  * request-level: batch sharded (the paper's rejected baseline, kept for the
                    load-imbalance benchmark)

All are written with ``shard_map`` so the per-layer boundary communication is
explicit — these collectives are the TPU rendering of the paper's per-layer
DCN transfers, and the dry-run's collective roofline term measures them.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 promotes shard_map out of experimental
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover — older jax in the container
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core import combine as C


def _masked_partial(q, k_cache, v_cache, valid, logit_softcap=0.0):
    """q: (B, H, hd); caches (B, S, Hkv, hd); valid: (B, S)."""
    B, H, hd = q.shape
    Hkv = k_cache.shape[2]
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, hd)
    # scores per kv head without materialising repeated KV
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bhgk,bshk->bhgs", qg.astype(jnp.float32) * scale,
                   k_cache.astype(jnp.float32))
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe[..., None]), 0.0)
    denom = jnp.sum(p, axis=-1)
    a = jnp.einsum("bhgs,bshk->bhgk", p, v_cache.astype(jnp.float32))
    return C.Partial(a=a.reshape(B, H, hd), s=denom.reshape(B, H),
                     m=jnp.where(jnp.isfinite(m), m, -jnp.inf).reshape(B, H))


# ---------------------------------------------------------------------------
# Sequence-level split (partial-combine across the pool axis)
# ---------------------------------------------------------------------------
def seq_parallel_decode_attention(mesh: Mesh, axis: str, q, k_cache, v_cache,
                                  cache_len, *, sliding_window: int = 0,
                                  logit_softcap: float = 0.0,
                                  batch_axis: Optional[str] = None):
    """Decode attention with the KV sequence sharded over `axis`.

    q: (B, H, hd) replicated over `axis`; caches (B, S, Hkv, hd) with S
    sharded over `axis`; cache_len (B,). Each shard computes its partial
    (A, S, m) over its KV slice; psum_combine merges — the cross-chip form
    of paper §4.2.2.
    """
    n = mesh.shape[axis]
    S = k_cache.shape[1]
    S_shard = S // n
    bspec = P(batch_axis) if batch_axis else P()

    def shard_fn(q, kc, vc, clen):
        idx = jax.lax.axis_index(axis)
        pos = idx * S_shard + jnp.arange(S_shard)[None, :]  # global positions
        valid = pos < clen[:, None]
        if sliding_window > 0:
            valid &= pos >= (clen[:, None] - sliding_window)
        part = _masked_partial(q, kc, vc, valid, logit_softcap)
        return C.finalize(C.psum_combine(part, axis)).astype(q.dtype)

    return _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(batch_axis, None, None), P(batch_axis, axis, None, None),
                  P(batch_axis, axis, None, None), bspec),
        out_specs=P(batch_axis, None, None),
    )(q, k_cache, v_cache, cache_len)


# ---------------------------------------------------------------------------
# Head-level split (the paper's choice for Lamina)
# ---------------------------------------------------------------------------
def head_parallel_decode_attention(mesh: Mesh, axis: str, q, k_cache, v_cache,
                                   cache_len, *, sliding_window: int = 0,
                                   logit_softcap: float = 0.0,
                                   batch_axis: Optional[str] = None):
    """KV heads sharded over `axis`; each device handles its heads fully.
    Requires Hkv % mesh.shape[axis] == 0 (the paper's divisibility caveat).
    """
    Hkv = k_cache.shape[2]
    n = mesh.shape[axis]
    if Hkv % n:
        raise ValueError(
            f"head-level partitioning needs kv_heads ({Hkv}) divisible by "
            f"pool size ({n}) — paper §5; use seq-level instead")
    bspec = P(batch_axis) if batch_axis else P()

    def shard_fn(q, kc, vc, clen):
        S = kc.shape[1]
        pos = jnp.arange(S)[None, :]
        valid = pos < clen[:, None]
        if sliding_window > 0:
            valid &= pos >= (clen[:, None] - sliding_window)
        part = _masked_partial(q, kc, vc, valid, logit_softcap)
        return C.finalize(part).astype(q.dtype)

    return _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(batch_axis, axis, None), P(batch_axis, None, axis, None),
                  P(batch_axis, None, axis, None), bspec),
        out_specs=P(batch_axis, axis, None),
    )(q, k_cache, v_cache, cache_len)


# ---------------------------------------------------------------------------
# Paged variants: the pool-native backends. The KV operand is the serving
# engines' block pool (Hkv, num_blocks, block_size, hd) + a (B, nb) block
# table — what the paged flash-decode kernel consumes in place. Head-level
# shards the pool's head axis (each device owns its heads' blocks wholesale);
# request-level shards the table/batch and replicates the pool. Sharding by
# blocks rather than dense slabs is the layout the cross-chip sequence
# partition will split on (ROADMAP follow-on).
# ---------------------------------------------------------------------------
def _paged_dense_view(k_pool, v_pool, block_tables):
    """(Hkv, NB, bs, hd) pools + (B, nb) table -> seq-major dense
    (B, nb·bs, Hkv, hd) views for ``_masked_partial``."""
    Hkv, _, bs, hd = k_pool.shape
    B, nb = block_tables.shape
    kc = jnp.transpose(k_pool[:, block_tables], (1, 2, 3, 0, 4)).reshape(
        B, nb * bs, Hkv, hd)
    vc = jnp.transpose(v_pool[:, block_tables], (1, 2, 3, 0, 4)).reshape(
        B, nb * bs, Hkv, hd)
    return kc, vc


def head_parallel_paged_decode_attention(mesh: Mesh, axis: str, q, k_pool,
                                         v_pool, block_tables, cache_len, *,
                                         sliding_window: int = 0,
                                         logit_softcap: float = 0.0,
                                         batch_axis: Optional[str] = None):
    """Head-level split over the paged pool: each device owns Hkv/n heads of
    every pool block (pool head axis sharded over `axis`); the block table
    and lengths are replicated scalars. No combine needed — heads are
    independent. Requires Hkv % mesh.shape[axis] == 0 (paper §5)."""
    Hkv = k_pool.shape[0]
    n = mesh.shape[axis]
    if Hkv % n:
        raise ValueError(
            f"head-level partitioning needs kv_heads ({Hkv}) divisible by "
            f"pool size ({n}) — paper §5; use seq-level instead")
    bspec = P(batch_axis) if batch_axis else P()
    btspec = P(batch_axis, None) if batch_axis else P()

    def shard_fn(q, kp, vp, bt, clen):
        kc, vc = _paged_dense_view(kp, vp, bt)
        S = kc.shape[1]
        pos = jnp.arange(S)[None, :]
        valid = pos < clen[:, None]
        if sliding_window > 0:
            valid &= pos >= (clen[:, None] - sliding_window)
        part = _masked_partial(q, kc, vc, valid, logit_softcap)
        return C.finalize(part).astype(q.dtype)

    return _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(batch_axis, axis, None), P(axis, None, None, None),
                  P(axis, None, None, None), btspec, bspec),
        out_specs=P(batch_axis, axis, None),
    )(q, k_pool, v_pool, block_tables, cache_len)


def request_parallel_paged_decode_attention(mesh: Mesh, axis: str, q, k_pool,
                                            v_pool, block_tables, cache_len,
                                            *, sliding_window: int = 0,
                                            logit_softcap: float = 0.0):
    """Request-level split over the paged pool: the batch (q, block table,
    lengths) is sharded; the pool is replicated — each device walks only its
    requests' tables (the paper's load-imbalance baseline, pool-native)."""
    def shard_fn(q, kp, vp, bt, clen):
        kc, vc = _paged_dense_view(kp, vp, bt)
        S = kc.shape[1]
        pos = jnp.arange(S)[None, :]
        valid = pos < clen[:, None]
        if sliding_window > 0:
            valid &= pos >= (clen[:, None] - sliding_window)
        return C.finalize(_masked_partial(q, kc, vc, valid,
                                          logit_softcap)).astype(q.dtype)

    return _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis, None, None), P(None, None, None, None),
                  P(None, None, None, None), P(axis, None), P(axis)),
        out_specs=P(axis, None, None),
    )(q, k_pool, v_pool, block_tables, cache_len)


# ---------------------------------------------------------------------------
# Request-level split (paper's rejected baseline, for the imbalance bench)
# ---------------------------------------------------------------------------
def request_parallel_decode_attention(mesh: Mesh, axis: str, q, k_cache,
                                      v_cache, cache_len, *,
                                      sliding_window: int = 0,
                                      logit_softcap: float = 0.0):
    def shard_fn(q, kc, vc, clen):
        S = kc.shape[1]
        pos = jnp.arange(S)[None, :]
        valid = pos < clen[:, None]
        if sliding_window > 0:
            valid &= pos >= (clen[:, None] - sliding_window)
        return C.finalize(_masked_partial(q, kc, vc, valid,
                                          logit_softcap)).astype(q.dtype)

    return _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None, None, None),
                  P(axis, None, None, None), P(axis)),
        out_specs=P(axis, None, None),
    )(q, k_cache, v_cache, cache_len)
