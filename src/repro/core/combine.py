"""Partial-softmax attention combine (paper §4.2.2).

Given a disjoint split of the token set I = I1 ∪ I2, with per-subset partial
results A_q(I) = Σ softmax-weighted values and S_q(I) = Σ exp(scores):

    A_q(I) = (A_q(I1)·S_q(I1) + A_q(I2)·S_q(I2)) / (S_q(I1) + S_q(I2))

This identity is what lets Lamina (a) split the KV set across memory devices
(head- or sequence-wise), (b) overlap the `prev`-token attention with the
K/V projection and transfer of the `new` token, and (c) tile the decode
kernel over KV blocks in VMEM. We carry the running max `m` alongside
(A, S) for numerical stability — the standard flash/online-softmax triple.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Partial(NamedTuple):
    """Partial attention state for some subset of KV tokens.

    a: (..., head_dim)  — softmax-weighted value sum, normalised *within* the
                          subset relative to `m` (i.e. Σ exp(s-m) v / 1)
    s: (...)            — Σ exp(score - m) over the subset
    m: (...)            — max score over the subset
    """
    a: jax.Array
    s: jax.Array
    m: jax.Array


def partial_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      mask: jax.Array | None = None,
                      logit_softcap: float = 0.0) -> Partial:
    """Compute the partial triple over one KV subset.

    q: (..., hd); k, v: (..., n, hd); mask: (..., n) True=attend.
    """
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("...k,...nk->...n", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    if mask is not None:
        s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)  # empty subsets
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    denom = jnp.sum(p, axis=-1)
    a = jnp.einsum("...n,...nk->...k", p, v.astype(jnp.float32))
    return Partial(a=a, s=denom, m=jnp.where(jnp.isfinite(m), m, -jnp.inf))


def combine(p1: Partial, p2: Partial) -> Partial:
    """Associative, commutative merge of two disjoint partials."""
    m = jnp.maximum(p1.m, p2.m)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    w1 = jnp.where(jnp.isfinite(p1.m), jnp.exp(p1.m - m_safe), 0.0)
    w2 = jnp.where(jnp.isfinite(p2.m), jnp.exp(p2.m - m_safe), 0.0)
    return Partial(
        a=p1.a * w1[..., None] + p2.a * w2[..., None],
        s=p1.s * w1 + p2.s * w2,
        m=m,
    )


def finalize(p: Partial) -> jax.Array:
    """Partial -> attention output (normalise by the denominator)."""
    return p.a / jnp.maximum(p.s, 1e-30)[..., None]


def combine_many(partials: list[Partial]) -> Partial:
    out = partials[0]
    for p in partials[1:]:
        out = combine(out, p)
    return out


def psum_combine(p: Partial, axis_name: str) -> Partial:
    """Cross-device combine over a mesh axis (inside shard_map).

    Rebases every shard's partial onto the global max, then psums — the
    cross-chip form of the paper's A/S merge used for sequence-parallel
    attention (DESIGN.md §3.3).
    """
    m_global = jax.lax.pmax(p.m, axis_name)
    m_safe = jnp.where(jnp.isfinite(m_global), m_global, 0.0)
    w = jnp.where(jnp.isfinite(p.m), jnp.exp(p.m - m_safe), 0.0)
    a = jax.lax.psum(p.a * w[..., None], axis_name)
    s = jax.lax.psum(p.s * w, axis_name)
    return Partial(a=a, s=s, m=m_global)
