"""qwen3-moe-30b-a3b — MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,   # listed d_ff is the per-expert dim
    moe_d_ff=768,
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    qk_norm=True,
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen3-30B-A3B",
)
