"""kimi-k2-1t-a32b — trillion-parameter MoE, 384 experts top-8 (paper-table
scale entry) [arXiv:2501.kimi2]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=2048,  # per-expert dim
    moe_d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    rope_theta=50000.0,
    source="arXiv:2501.kimi2",
)
