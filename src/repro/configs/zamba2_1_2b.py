"""zamba2-1.2b — hybrid: Mamba2 backbone + shared attention block
[arXiv:2411.15242]. 38 mamba2 layers; one weight-shared attention+MLP block
applied every `shared_attn_period` layers (6 invocations + 2 tail layers)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,  # MHA in the shared block
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_period=6,
    source="arXiv:2411.15242",
)
