"""rwkv6-7b — Finch: attention-free, data-dependent decay [arXiv:2404.05892].

The paper's model-attention disaggregation is INAPPLICABLE here (no KV cache,
no attention operator) — see DESIGN.md §4. Implemented without the technique;
the recurrent state is head-sharded over the `model` mesh axis.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,       # d_model / rwkv_head_dim
    num_kv_heads=64,
    head_dim=64,
    rwkv_head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    source="arXiv:2404.05892",
)
