"""glm4-9b — dense, RoPE, extreme GQA (kv=2) [hf:THUDM/glm-4-9b]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=10000.0,
    source="hf:THUDM/glm-4-9b",
)

# StreamingLLM-style long-context variant (paper §7 sparse attention): 4
# sink tokens + 8k window make the 524k-decode sub-quadratic in *attended*
# tokens while preserving the sink positions that stabilise quality.
CONFIG_SINKS = CONFIG.replace(name="glm4-9b-sinks", sliding_window=8192,
                              attention_sinks=4)
