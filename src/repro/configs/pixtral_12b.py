"""pixtral-12b — VLM: pixtral-ViT frontend (stubbed to patch embeddings) +
mistral-nemo-style decoder backbone [hf:mistralai/Pixtral-12B-2409]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1000000.0,
    modality="vision",
    frontend_tokens=1024,  # max patch embeddings prepended (stub frontend)
    source="hf:mistralai/Pixtral-12B-2409",
)
