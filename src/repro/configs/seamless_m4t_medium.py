"""seamless-m4t-medium — encoder-decoder, multimodal speech/text
[arXiv:2308.11596]. Audio frontend (mel + conv feature extractor) is stubbed:
the encoder consumes precomputed (B, S_enc, d) frame embeddings."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    is_encoder_decoder=True,
    encoder_layers=12,
    num_layers=12,  # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    modality="audio_frames",
    source="arXiv:2308.11596",
)
