"""llama3-70b — the paper's own analysis/eval model (Table 2/3)
[arXiv:2407.21783]. Used by the paper-figure benchmarks, not in the assigned
10-arch pool."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3-70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,
    source="arXiv:2407.21783 / paper Table 2",
)
