"""Input-shape definitions, ShapeDtypeStruct builders, and reduced (smoke)
config derivation shared by all architecture configs."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Modality frontend stub sizes (see DESIGN.md — the one allowed stub):
# pixtral gets `frontend_tokens` patch embeddings prepended; seamless consumes
# (B, S_enc, d) frame embeddings in the encoder.
VLM_PATCHES_FRACTION = 0.25  # of seq_len, capped at frontend_tokens


def frontend_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.modality != "vision":
        return 0
    return min(cfg.frontend_tokens, max(16, int(seq_len * VLM_PATCHES_FRACTION)))


def input_specs(cfg: ModelConfig, shape_name: str,
                max_seq: Optional[int] = None) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of the entry point.

    train  -> kwargs of train_step(batch=...)
    prefill-> kwargs of prefill_step(batch=...)
    decode -> kwargs of serve_step(tokens=..., cache=...)
    """
    from repro.models import transformer

    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    i32 = jnp.int32

    def sds(shape, dtype=i32):
        return jax.ShapeDtypeStruct(shape, dtype)

    if shp.kind in ("train", "prefill"):
        if cfg.family == "audio":
            # encoder consumes S frame-embeddings, decoder S//8 text tokens
            S_dec = max(32, S // 8)
            batch = {
                "frames": sds((B, S, cfg.d_model), cfg.dtype),
                "tokens": sds((B, S_dec)),
            }
            if shp.kind == "train":
                batch["labels"] = sds((B, S_dec))
        elif cfg.modality == "vision":
            F = frontend_len(cfg, S)
            batch = {
                "frontend": sds((B, F, cfg.d_model), cfg.dtype),
                "tokens": sds((B, S - F)),
            }
            if shp.kind == "train":
                batch["labels"] = sds((B, S - F))
        else:
            batch = {"tokens": sds((B, S))}
            if shp.kind == "train":
                batch["labels"] = sds((B, S))
        return {"batch": batch}

    # decode: ONE new token against a cache of length seq_len
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, max_seq or S))
    if cfg.family == "audio":
        # cross-attention KV over the encoded utterance (S//8 frames kept)
        hd = cfg.resolved_head_dim
        S_enc = max(32, S // 8)
        cache = dict(cache)
        cache["ck"] = sds((cfg.num_layers, B, cfg.num_kv_heads, S_enc, hd),
                          cfg.dtype)
        cache["cv"] = sds((cfg.num_layers, B, cfg.num_kv_heads, S_enc, hd),
                          cfg.dtype)
    return {"tokens": sds((B,)), "cache": cache}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant of the same family: 2 layers, d_model<=512,
    <=4 experts, tiny vocab."""
    kw = dict(
        num_layers=2,
        d_model=min(cfg.d_model, 256),
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads else 0,
        head_dim=64,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        dtype=jnp.float32,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, experts_per_token=2,
                  moe_d_ff=min(cfg.moe_d_ff, 128))
    if cfg.family == "hybrid":
        kw.update(num_layers=5, shared_attn_period=2, num_heads=4,
                  num_kv_heads=4, ssm_state=16, ssm_head_dim=32)
    if cfg.family == "ssm":
        kw.update(rwkv_head_dim=32)
    if cfg.family == "audio":
        kw.update(encoder_layers=2)
    if cfg.local_global:
        kw.update(num_layers=2, sliding_window=64)
    if cfg.modality == "vision":
        kw.update(frontend_tokens=16)
    kw.update(overrides)
    return cfg.replace(**kw)
