"""Architecture registry: ``--arch <id>`` resolution, smoke variants, and
per-arch input-shape applicability (DESIGN.md §4)."""
from __future__ import annotations

import importlib
from typing import List, Optional

from repro.configs.base import reduced
from repro.models.common import ModelConfig

_MODULES = {
    "llama3-8b": "repro.configs.llama3_8b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "glm4-9b": "repro.configs.glm4_9b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    # the paper's own model, used by the figure benchmarks
    "llama3-70b": "repro.configs.llama3_70b",
}

ASSIGNED = [a for a in _MODULES if a != "llama3-70b"]

# long_500k applicability (DESIGN.md §4). Entries absent here run all shapes.
LONG_500K = {
    "rwkv6-7b": "runs — O(1) recurrent state",
    "zamba2-1.2b": "runs — mamba state + seq-sharded shared-attn KV",
    "gemma2-27b": "runs — native sliding-window local layers; global layers "
                  "use sequence-sharded KV + partial combine",
    "llama3-8b": "runs — via CONFIG_SW sliding-window(8192) variant",
    "pixtral-12b": "skip — pure full attention (see DESIGN.md §4)",
    "qwen3-moe-30b-a3b": "skip — pure full attention",
    "glm4-9b": "runs — via CONFIG_SINKS StreamingLLM variant "
               "(4 sinks + 8k window, paper §7 sparse attention)",
    "kimi-k2-1t-a32b": "skip — pure full attention",
    "tinyllama-1.1b": "skip — pure full attention",
    "seamless-m4t-medium": "skip — 524k-frame decode outside enc-dec "
                           "operating range (N/A)",
}


def get_config(arch: str, variant: Optional[str] = None) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    if variant:
        return getattr(mod, f"CONFIG_{variant.upper()}")
    return mod.CONFIG


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch), **overrides)


def applicable_shapes(arch: str) -> List[str]:
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    note = LONG_500K.get(arch, "runs")
    if note.startswith("runs"):
        shapes.append("long_500k")
    return shapes


def config_for_shape(arch: str, shape: str) -> ModelConfig:
    """Resolve arch+shape to the concrete config (handles the llama3-8b
    sliding-window variant for long_500k)."""
    if shape == "long_500k" and arch == "llama3-8b":
        return get_config(arch, variant="sw")
    if shape == "long_500k" and arch == "glm4-9b":
        return get_config(arch, variant="sinks")
    return get_config(arch)


def list_archs() -> List[str]:
    return list(_MODULES)
