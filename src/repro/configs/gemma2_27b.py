"""gemma2-27b — dense, alternating local(4096)/global attention, logit
softcaps, sandwich norms, tied embeddings [arXiv:2408.00118]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    local_global=True,
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_norms=True,
    tie_embeddings=True,
    source="arXiv:2408.00118",
)
