"""Analytic FLOP accounting: MODEL_FLOPS reference (6·N·D / 2·N·D) and the
recurrence corrections for time-dimension scans that remain rolled in the
cost lowering (rwkv/mamba sequence loops — cost_analysis counts their bodies
once; everything else is unrolled by `lower_unrolled`)."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import INPUT_SHAPES
from repro.core import costmodel as cm
from repro.models.common import ModelConfig


def tokens_processed(cfg: ModelConfig, shape: str) -> int:
    shp = INPUT_SHAPES[shape]
    if shp.kind == "decode":
        return shp.global_batch  # one new token per request
    if cfg.family == "audio":
        return shp.global_batch * max(32, shp.seq_len // 8)  # decoder tokens
    if cfg.modality == "vision":
        return shp.global_batch * shp.seq_len  # patches + text
    return shp.global_batch * shp.seq_len


def model_flops(cfg: ModelConfig, shape: str) -> float:
    """Reference useful FLOPs: 6·N_active·D (train) / 2·N_active·D
    (inference), the §Roofline MODEL_FLOPS numerator. Attention's O(S²)/KV
    term is intentionally excluded — the useful_ratio column surfaces it."""
    d = tokens_processed(cfg, shape)
    n = cm.active_param_count(cfg)
    mult = 6.0 if INPUT_SHAPES[shape].kind == "train" else 2.0
    return mult * n * d


def recurrence_corrections(cfg: ModelConfig, shape: str) -> Dict[str, float]:
    """FLOPs/bytes executed by rolled time-scans beyond the once-counted
    body. Zero for decode shapes (single step) and non-recurrent families."""
    shp = INPUT_SHAPES[shape]
    if shp.kind == "decode" or cfg.family not in ("ssm", "hybrid"):
        return {"flops": 0.0, "bytes": 0.0}
    D = shp.global_batch * shp.seq_len
    steps_uncounted = D - shp.global_batch  # body counted once per batch row
    bwd = 3.0 if shp.kind == "train" else 1.0
    if cfg.family == "ssm":
        H = cfg.d_model // cfg.rwkv_head_dim
        P = cfg.rwkv_head_dim
        per_step = 5.0 * H * P * P  # kv outer + bonus + readout + decay + add
        per_step_bytes = 4.0 * H * P * 4  # r,k,v,w fp32 reads
        L = cfg.num_layers
        # time-mix recurrence + the prefill-style state reconstruction
        flops = bwd * L * steps_uncounted * per_step
        return {"flops": flops, "bytes": L * steps_uncounted * per_step_bytes}
    # hybrid (mamba2)
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    P, N = cfg.ssm_head_dim, cfg.ssm_state
    per_step = 5.0 * H * P * N
    per_step_bytes = (H * P + 2 * N + H) * 4
    L = cfg.num_layers
    return {"flops": bwd * L * steps_uncounted * per_step,
            "bytes": L * steps_uncounted * per_step_bytes}
