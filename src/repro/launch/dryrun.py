import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For one (arch, shape) pair this script:
  1. builds the production mesh — 16x16 single pod, or 2x16x16 with
     --multi-pod — with 512 placeholder host devices (flags above MUST
     precede any jax import: jax locks the device count on first init);
  2. lowers + compiles the entry point (train_step / prefill_step /
     serve_step) with the DisaggConfig shardings — ShapeDtypeStructs only,
     nothing is allocated;
  3. prints memory_analysis() (fits-or-not per chip) and cost_analysis();
  4. for --mode cost, re-lowers the *unrolled* variant for exact HLO
     FLOP/byte totals and parses per-device collective bytes from the
     post-SPMD module (see launch/hlo_analysis.py);
  5. writes a JSON record under experiments/dryrun/ that launch/roofline.py
     aggregates into EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape decode_32k [--multi-pod] [--mode natural|cost|both]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import sys
import time
import traceback



def _cost_dict(compiled):
    """compiled.cost_analysis() compat: jax 0.4.x returns a one-dict-per-
    program list, jax >= 0.5 a flat dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # pragma: no cover — older jax
        ca = ca[0] if ca else {}
    return ca

def run_one(arch: str, shape: str, *, multi_pod: bool, mode: str,
            out_dir: str, attention_partition: str = "auto",
            overrides=None, tag: str = "") -> dict:
    import jax
    from repro.configs import registry
    from repro.launch import analytic, hlo_analysis
    from repro.launch.entrypoints import build_lowering_spec
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    record = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
              "chips": chips, "mode": mode, "tag": tag,
              "attention_partition": attention_partition,
              "overrides": overrides or {}}
    t0 = time.time()

    def lower_compile(unrolled: bool):
        spec = build_lowering_spec(arch, shape, mesh, unrolled=unrolled,
                                   overrides=overrides,
                                   attention_partition=attention_partition)
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings,
                         donate_argnums=spec.donate)
        lowered = jitted.lower(*spec.args)
        compiled = lowered.compile()
        return spec, lowered, compiled

    # --- natural (scan) lowering: compile proof + memory analysis ---
    if mode in ("natural", "both"):
        spec, lowered, compiled = lower_compile(unrolled=False)
        mem = compiled.memory_analysis()
        record["entry"] = spec.name
        record["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
        per_chip = sum(v for v in [record["memory"]["argument_bytes"],
                                   record["memory"]["temp_bytes"]]
                       if v is not None)
        record["memory"]["per_chip_total"] = per_chip
        record["memory"]["fits_v5e_16g"] = bool(per_chip <= 16 * (1 << 30))
        ca = _cost_dict(compiled)
        record["cost_natural"] = {"flops": ca.get("flops"),
                                  "bytes": ca.get("bytes accessed")}
        coll = hlo_analysis.collective_bytes(compiled.as_text())
        record["collectives_natural"] = coll
        record["compile_s_natural"] = time.time() - t0

    # --- unrolled lowering: exact HLO cost + collective bytes ---
    # Large stacks (gemma2-27b, kimi-k2 train) use two-point layer
    # extrapolation: lower u and 2u layers unrolled, extend linearly in L
    # (exact for layer-uniform programs; embedding/head live in the base
    # term). Chosen automatically above `extrapolate_threshold` layers.
    if mode in ("cost", "both") and not multi_pod:
        t1 = time.time()
        cfg0 = registry.config_for_shape(arch, shape)
        unit = 2 if cfg0.local_global else (
            cfg0.shared_attn_period if cfg0.family == "hybrid" else 1)
        heavy = cfg0.num_layers * max(cfg0.d_model, 1) >= 40 * 4096 or \
            cfg0.num_experts >= 128 or \
            cfg0.family in ("ssm", "hybrid")  # time-scan per layer: costly

        if heavy and cfg0.num_layers > 4 * unit:
            L = cfg0.num_layers

            def cost_at(n_layers):
                ov = dict(overrides or {})
                ov["num_layers"] = n_layers
                if cfg0.family == "audio":
                    ov["encoder_layers"] = n_layers
                sp = build_lowering_spec(
                    arch, shape, mesh, unrolled=True, overrides=ov,
                    attention_partition=attention_partition)
                jt = jax.jit(sp.fn, in_shardings=sp.in_shardings,
                             out_shardings=sp.out_shardings,
                             donate_argnums=sp.donate)
                comp = jt.lower(*sp.args).compile()
                c = _cost_dict(comp)
                cb = hlo_analysis.collective_bytes(comp.as_text())
                return (float(c.get("flops", 0.0)),
                        float(c.get("bytes accessed", 0.0)), cb, sp)

            f1, b1, cb1, _ = cost_at(unit)
            f2, b2, cb2, spec = cost_at(2 * unit)
            k = (L - unit) / unit  # extra units beyond the base lowering
            ca = {"flops": f1 + (f2 - f1) * k,
                  "bytes accessed": b1 + (b2 - b1) * k}
            coll = {kk: cb1[kk] + (cb2[kk] - cb1[kk]) * k
                    for kk in cb1}
            record["cost_method"] = f"extrapolated_u{unit}"
        else:
            spec, lowered, compiled = lower_compile(unrolled=True)
            ca = _cost_dict(compiled)
            coll = hlo_analysis.collective_bytes(compiled.as_text())
            record["cost_method"] = "unrolled_full"
        # corrections always use the FULL layer count
        corr = analytic.recurrence_corrections(cfg0, shape)
        # HLO numbers are per-chip (post-SPMD module); corrections are global
        flops = float(ca.get("flops", 0.0)) + corr["flops"] / chips
        hbm = float(ca.get("bytes accessed", 0.0)) + corr["bytes"] / chips
        mf = analytic.model_flops(spec.cfg, shape)
        terms = hlo_analysis.RooflineTerms(
            flops=flops, hbm_bytes=hbm,
            coll_bytes_per_chip=coll["total"], chips=chips, model_flops=mf)
        record["entry"] = spec.name
        record["cost"] = {"flops_hlo": float(ca.get("flops", 0.0)),
                          "bytes_hlo": float(ca.get("bytes accessed", 0.0)),
                          "flops_correction": corr["flops"],
                          "bytes_correction": corr["bytes"]}
        record["collectives"] = coll
        record["roofline"] = terms.as_dict()
        record["compile_s_cost"] = time.time() - t1

    record["ok"] = True
    record["total_s"] = time.time() - t0
    os.makedirs(out_dir, exist_ok=True)
    suffix = "pod2" if multi_pod else "pod1"
    if tag:
        suffix += f"_{tag}"
    path = os.path.join(out_dir, f"{arch}_{shape}_{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="both",
                    choices=["natural", "cost", "both"])
    ap.add_argument("--attention-partition", default="auto",
                    choices=["auto", "head", "seq"])
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides k=v (int/float parsed)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = int(v)
        except ValueError:
            try:
                overrides[k] = float(v)
            except ValueError:
                overrides[k] = v

    from repro.configs import registry

    combos = []
    if args.all:
        for arch in registry.ASSIGNED:
            for shape in registry.applicable_shapes(arch):
                combos.append((arch, shape))
    else:
        combos.append((args.arch, args.shape))

    failures = 0
    for arch, shape in combos:
        try:
            rec = run_one(arch, shape, multi_pod=args.multi_pod,
                          mode=args.mode, out_dir=args.out_dir,
                          attention_partition=args.attention_partition,
                          overrides=overrides or None, tag=args.tag)
            r = rec.get("roofline", {})
            mem = rec.get("memory", {})
            print(f"OK  {arch:24s} {shape:12s} chips={rec['chips']} "
                  f"mem/chip={mem.get('per_chip_total', 0)/(1<<30):.2f}GiB "
                  f"dominant={r.get('dominant', '-')} "
                  f"[{rec['total_s']:.0f}s]")
        except Exception:
            failures += 1
            print(f"FAIL {arch} {shape}", file=sys.stderr)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
