"""Post-SPMD HLO analysis: collective-byte accounting + roofline terms.

``compiled.as_text()`` is the per-device (post-partitioning) module, so every
collective op's operand shape is the LOCAL shard — exactly the per-chip
quantity the collective roofline term wants. We sum operand bytes per
collective kind with the standard ring multipliers and divide by the ICI
(or DCN, for the `pod` axis) bandwidth.

cost_analysis() counts while-loop bodies once (verified in-repo), so callers
pass the *unrolled* lowering for FLOP/byte totals (launch/dryrun.py) and add
the analytic recurrence corrections from launch/analytic.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e roofline constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
DCN_BW = 25e9                # bytes/s / chip (200 Gbps NIC)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ring cost multiplier on operand bytes (per-device bytes on the wire)
_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\)|\S+))\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\((.*)")


def _shape_bytes(shape_str: str, f32_as_bf16: bool = False) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes = _DTYPE_BYTES[dt]
        if f32_as_bf16 and dt == "f32":
            nbytes = 2
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum per-device collective bytes by kind from post-SPMD HLO text.
    `-start` variants (async) are counted; `-done` are not (no shapes moved).

    Two corrections for XLA-CPU backend artifacts (documented in
    EXPERIMENTS.md methodology):
      * f32 collectives whose operand is a `convert*` of bf16 data are
        counted at bf16 width — the CPU backend upcasts bf16 dot operands
        to f32 *before* partitioning; TPU moves them in bf16;
      * `dedup_total` additionally collapses collectives with an identical
        (kind, operand-name) pair — XLA's collective CSE removes these on
        the real target, and the raw `total` keeps them for reference.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    dedup_seen = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=")[1][:60]:
            continue
        out_shape, kind, operands = m.groups()
        f32_artifact = "convert" in operands[:80] and "f32" in out_shape
        nbytes = _shape_bytes(out_shape, f32_as_bf16=f32_artifact) * \
            _MULT[kind]
        out[kind] += nbytes
        out["count"] += 1
        op_name = operands.split(")", 1)[0][:120]
        key = (kind, out_shape, op_name)
        if key not in dedup_seen:
            dedup_seen[key] = nbytes
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["dedup_total"] = float(sum(dedup_seen.values()))
    return out


@dataclasses.dataclass
class RooflineTerms:
    """All HLO quantities are PER-CHIP: compiled.cost_analysis() runs on the
    post-SPMD per-device module (calibrated in-repo with a known sharded
    matmul). model_flops is the GLOBAL analytic reference."""
    flops: float                # HLO flops per chip
    hbm_bytes: float            # HLO bytes accessed per chip
    coll_bytes_per_chip: float  # per-chip collective bytes
    chips: int
    model_flops: float = 0.0    # global 6·N·D / 2·N·D reference

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> Optional[float]:
        total = self.flops * self.chips
        return self.model_flops / total if total else None

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "chips": self.chips, "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
        }
