"""Training launcher: real training on the local devices (CPU-scale smoke
with --smoke) or production-mesh lowering of the same train_step.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 50 --batch 4 --seq 128
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import registry
    from repro.data.synthetic import packed_batches
    from repro.training import optimizer as opt
    from repro.training.train_loop import train

    cfg = registry.get_smoke_config(args.arch) if args.smoke \
        else registry.get_config(args.arch)
    extra = {}
    if cfg.modality == "vision":
        extra["frontend_shape"] = (args.batch, 8, cfg.d_model)
        extra["dtype"] = cfg.dtype
    if cfg.family == "audio":
        extra["frames_shape"] = (args.batch, args.seq, cfg.d_model)
        extra["dtype"] = cfg.dtype
    data = packed_batches(cfg.vocab_size, args.batch, args.seq,
                          seed=args.seed, **extra)
    adamw = opt.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                            total_steps=args.steps)
    train(cfg, adamw, data, args.steps, seed=args.seed,
          checkpoint_dir=args.checkpoint_dir or None,
          checkpoint_every=args.checkpoint_every)


if __name__ == "__main__":
    main()
