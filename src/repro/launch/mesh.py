"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (jax locks the device count on first backend init, and the
dry-run needs the XLA_FLAGS host-device override to land first)."""
from __future__ import annotations

import jax


def _axis_types_kw(n: int) -> dict:
    """jax >= 0.5 takes explicit axis_types; older jax (this container's
    0.4.x) has no AxisType and defaults every axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # pragma: no cover — older jax
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False, attn_pool: int = 0):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e target).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis rides
    the DCN and carries data parallelism.

    attn_pool > 0 carves an ATTENTION-POOL axis `attn` of that many chips
    out of the model dimension (model axis shrinks to 16 // attn_pool): the
    memory devices of the paper's disaggregation. The paged KV pool's block
    axis is sharded over `attn` — `block_parallel_paged_decode_attention`
    round-robins one sequence's blocks across it, so a single `long_500k`
    request's KV spans every pool chip; head-/request-level partitions use
    the same axis. Requires 16 % attn_pool == 0."""
    if attn_pool:
        if 16 % attn_pool:
            raise ValueError(f"attn_pool ({attn_pool}) must divide 16")
        shape = ((2, 16, 16 // attn_pool, attn_pool) if multi_pod
                 else (16, 16 // attn_pool, attn_pool))
        axes = (("pod", "data", "model", "attn") if multi_pod
                else ("data", "model", "attn"))
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU tests (requires host-device override)."""
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(shape)))


def make_test_attn_pool_mesh(n_pool: int = 4, model: int = 2):
    """CPU-test rendering of the disaggregated mesh: a `model` axis for the
    dense slices and an `attn` pool axis the paged KV blocks shard over."""
    return make_test_mesh((model, n_pool), ("model", "attn"))
