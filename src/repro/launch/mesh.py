"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (jax locks the device count on first backend init, and the
dry-run needs the XLA_FLAGS host-device override to land first)."""
from __future__ import annotations

import jax


def _axis_types_kw(n: int) -> dict:
    """jax >= 0.5 takes explicit axis_types; older jax (this container's
    0.4.x) has no AxisType and defaults every axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # pragma: no cover — older jax
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e target).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis rides
    the DCN and carries data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU tests (requires host-device override)."""
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(shape)))
