"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (jax locks the device count on first backend init, and the
dry-run needs the XLA_FLAGS host-device override to land first)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e target).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis rides
    the DCN and carries data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU tests (requires host-device override)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
