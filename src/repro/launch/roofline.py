"""Roofline report generator (deliverable g): aggregates the dry-run JSON
records into the EXPERIMENTS.md §Dry-run and §Roofline tables.

  PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
      [--format md|csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

SUGGESTIONS = {
    "compute": "shard more FLOPs (TP/EP) or cut redundant compute (remat "
               "policy, fused kernels)",
    "memory": "reduce bytes: fused attention (no KV up-repeat), narrower "
              "dtypes, better layouts",
    "collective": "reshard to cut boundary collectives (head- vs seq-"
                  "partition, overlap collectives with compute)",
}


def load(dir_: str) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def dryrun_table(recs: List[Dict]) -> List[str]:
    rows = ["| arch | shape | mesh | compile | mem/chip (GiB) | fits v5e | "
            "collectives/chip (nat) |",
            "|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"],
                                         x["multi_pod"])):
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        mem = r.get("memory", {})
        per = mem.get("per_chip_total")
        coll = r.get("collectives_natural", {}).get("total")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | "
            f"{'OK' if r.get('ok') else 'FAIL'} | "
            f"{per/(1<<30):.2f} | {mem.get('fits_v5e_16g')} | "
            f"{coll/1e6:.1f} MB |" if per is not None else
            f"| {r['arch']} | {r['shape']} | {mesh} | "
            f"{'OK' if r.get('ok') else 'FAIL'} | - | - | - |")
    return rows


def roofline_table(recs: List[Dict]) -> List[str]:
    rows = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
            "dominant | MODEL_FLOPS | useful ratio | next move |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if r.get("multi_pod") or "roofline" not in r:
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{t['t_compute_s']*1e3:.3f} | {t['t_memory_s']*1e3:.3f} | "
            f"{t['t_collective_s']*1e3:.3f} | **{t['dominant']}** | "
            f"{t['model_flops']:.3g} | "
            f"{t['useful_ratio']:.3f} | {SUGGESTIONS[t['dominant']]} |")
    return rows


def worst_candidates(recs: List[Dict], k: int = 5) -> List[str]:
    scored = []
    for r in recs:
        if r.get("multi_pod") or "roofline" not in r:
            continue
        t = r["roofline"]
        tot = t["t_compute_s"] + t["t_memory_s"] + t["t_collective_s"]
        frac = t["t_compute_s"] / tot if tot else 0.0
        scored.append((frac, t["t_collective_s"] / max(tot, 1e-12), r))
    out = ["worst compute-fraction (hillclimb candidates):"]
    for frac, cfrac, r in sorted(scored, key=lambda x: x[0])[:k]:
        out.append(f"  {r['arch']} x {r['shape']}: compute-frac={frac:.4f} "
                   f"coll-frac={cfrac:.3f} dominant="
                   f"{r['roofline']['dominant']}")
    out.append("most collective-bound:")
    for frac, cfrac, r in sorted(scored, key=lambda x: -x[1])[:k]:
        out.append(f"  {r['arch']} x {r['shape']}: coll-frac={cfrac:.3f}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--candidates", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir)
    print(f"## §Dry-run ({len(recs)} records; "
          f"v5e: {PEAK_FLOPS/1e12:.0f} TF bf16, {HBM_BW/1e9:.0f} GB/s HBM, "
          f"{ICI_BW/1e9:.0f} GB/s ICI)\n")
    print("\n".join(dryrun_table(recs)))
    print("\n## §Roofline (single-pod 16x16; per-chip HLO terms)\n")
    print("\n".join(roofline_table(recs)))
    if args.candidates:
        print()
        print("\n".join(worst_candidates(recs)))


if __name__ == "__main__":
    main()
