"""Entry-point builders shared by dryrun / train / serve launchers.

For each input shape the lowered function is:
  train_4k      -> train_step(params, opt_state, batch)
  prefill_32k   -> prefill_step(params, batch)
  decode_32k,
  long_500k     -> serve_step(params, tokens, cache)   (ONE new token)

``build_lowering_spec`` returns (fn, kwargs-of-ShapeDtypeStructs,
in_shardings, out_shardings) ready for jax.jit(...).lower(...).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import INPUT_SHAPES, input_specs
from repro.core import disagg
from repro.models import transformer
from repro.models.common import ModelConfig
from repro.training import optimizer as opt
from repro.training.train_loop import make_train_step


@dataclasses.dataclass
class LoweringSpec:
    name: str
    fn: Callable
    args: Tuple           # ShapeDtypeStruct pytrees
    in_shardings: Tuple
    out_shardings: Any
    cfg: ModelConfig
    donate: Tuple[int, ...] = ()   # donated arg indices (train: params+opt)


def _named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def resolve_config(arch: str, shape: str, *, unrolled: bool = False,
                   overrides: Optional[Dict] = None) -> ModelConfig:
    cfg = registry.config_for_shape(arch, shape)
    if unrolled:
        cfg = cfg.replace(lower_unrolled=True)
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


def _unstack(tree_shape):
    """(L, ...) ShapeDtypeStruct subtree -> list of L per-layer subtrees.
    Per-layer buffers become separate XLA parameters, so layer fusions are
    charged (and on TPU, DMA) only their own operands — the production
    serving layout (see EXPERIMENTS.md §Perf #2)."""
    leaves = jax.tree.leaves(tree_shape)
    n = leaves[0].shape[0]
    return [jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                         tree_shape) for _ in range(n)]


def unstack_params_shape(cfg: ModelConfig, params_shape):
    out = dict(params_shape)
    if cfg.family == "hybrid":
        out["layers"] = [_unstack(sup) for sup in _unstack(
            params_shape["layers"])]
        if "tail" in params_shape:
            out["tail"] = _unstack(params_shape["tail"])
    else:
        out["layers"] = _unstack(params_shape["layers"])
    if "enc_layers" in params_shape:
        out["enc_layers"] = _unstack(params_shape["enc_layers"])
    return out


def unstack_cache_shape(cfg: ModelConfig, cache_shape):
    out = {}
    for key, val in cache_shape.items():
        if key == "len":
            out[key] = val
        elif key in ("h", "conv") and cfg.family == "hybrid":
            out[key] = [_unstack(sup) for sup in _unstack(val)]
        else:
            out[key] = _unstack(val)
    return out


def install_activation_constraint(cfg: ModelConfig, mesh: Mesh) -> None:
    """Megatron-style activation partitioning over the TP axis: the per-layer
    residual stream (B, S, d) is sharded batch->data(+pod), hidden->model, so
    remat-saved activations scale down with the mesh (DESIGN.md §6).

    MoE exception (§Perf #4): d-axis sharding before the router forces an
    activation all-gather per matmul (~9.4 GB/chip/layer for kimi-k2);
    MoE activations shard batch-only and the dispatch pipeline is pinned by
    the moe sharding hook below."""
    baxes = disagg.batch_axes(mesh)

    def batch_axes_for(B):
        use, total = [], 1
        for a in baxes:
            if B % (total * mesh.shape[a]) == 0:
                use.append(a)
                total *= mesh.shape[a]
        return tuple(use) if use else None

    def constrain(x):
        if x.ndim not in (3, 4):
            return x
        # (B, S, d) residuals and (B, X, S, d) fused-mixer intermediates
        dims = [batch_axes_for(x.shape[0])] + [None] * (x.ndim - 1)
        d = x.shape[-1]
        # hidden-dim sharding only when shards stay >= the 128-lane register
        # width (sub-lane shards are inefficient on TPU and trip a GSPMD
        # gather edge-case for d_model=1024 at 16-way).
        # (§Perf #4a refuted: dropping this for MoE turned the per-layer
        # reduce-scatters into 47 GB of full all-reduces — keep d-sharding.)
        if d % mesh.shape["model"] == 0 and d // mesh.shape["model"] >= 128:
            dims[-1] = "model"
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*dims)))

    transformer.set_activation_constraint(constrain)
    # §Perf #4b refuted: pinning the MoE dispatch pipeline (tokens/dispatch/
    # expert_tokens constraints via moe.set_sharding_hook) conflicted with
    # GSPMD's propagation around the expert einsums and nearly doubled the
    # per-layer collective bytes (41.5 -> 75.8 GB/chip). The hook stays
    # available for experimentation but is NOT installed.


def build_lowering_spec(arch: str, shape: str, mesh: Mesh, *,
                        unrolled: bool = False,
                        overrides: Optional[Dict] = None,
                        attention_partition: str = "auto",
                        grad_accum: Optional[int] = None) -> LoweringSpec:
    cfg = resolve_config(arch, shape, unrolled=unrolled, overrides=overrides)
    # ZeRO/FSDP over `data` whenever params+Adam at model-axis-only sharding
    # would blow the 16 GiB HBM (params*10B/16 > ~8 GiB <=> >12.8B params):
    # gemma2-27b, qwen3-30b, pixtral-12b, kimi-k2 trains (§Perf memory fixes)
    from repro.core.costmodel import param_count
    fsdp = param_count(cfg) > 10e9
    shp = INPUT_SHAPES[shape]
    if shp.kind in ("train", "prefill"):
        install_activation_constraint(cfg, mesh)
    specs = input_specs(cfg, shape)
    params_shape = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    if unrolled:
        # per-layer buffer layout for the cost pass (§Perf #2)
        params_shape = unstack_params_shape(cfg, params_shape)
    pspecs = disagg.specs_for_params(cfg, params_shape, mesh, fsdp=fsdp)

    if shp.kind == "train":
        adamw = opt.AdamWConfig()
        if grad_accum is None:
            # memory-pass default: 8 microbatches of 32 sequences; the cost
            # pass lowers accum=1 (same total FLOPs, scan-free for counting).
            # audio enc-dec carries encoder activations too -> 16 microbatches
            grad_accum = 1 if unrolled else (16 if cfg.family == "audio"
                                             else 8)
        step_fn = make_train_step(cfg, adamw, grad_accum=grad_accum)
        opt_shape = jax.eval_shape(
            lambda: opt.init_opt_state(
                jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             params_shape)))
        ospecs = opt.OptState(step=P(), mu=pspecs, nu=pspecs)
        bspecs = disagg.specs_for_batch(cfg, specs["batch"], mesh)
        metric_specs = {"loss": P(), "ce": P(), "aux": P(),
                        "grad_norm": P(), "lr": P()}
        return LoweringSpec(
            name=f"{arch}:{shape}:train_step",
            fn=step_fn,
            args=(params_shape, opt_shape, specs["batch"]),
            in_shardings=_named(mesh, (pspecs, ospecs, bspecs)),
            out_shardings=_named(mesh, (pspecs, ospecs, metric_specs)),
            cfg=cfg, donate=(0, 1))

    if shp.kind == "prefill":
        max_seq = specs["batch"]["tokens"].shape[1]
        if cfg.modality == "vision":
            max_seq += specs["batch"]["frontend"].shape[1]

        def prefill_step(params, batch):
            return transformer.prefill(params, cfg, batch, max_seq=max_seq)

        bspecs = disagg.specs_for_batch(cfg, specs["batch"], mesh)
        # output cache structure comes from the entry itself (listed layout
        # when unrolled; audio carries cross-KV of the encoder length)
        _, cache_shape = jax.eval_shape(prefill_step, params_shape,
                                        specs["batch"])
        cspecs = disagg.specs_for_cache(cfg, cache_shape, mesh,
                                        attention_partition)
        logits_sp = disagg.logits_spec(cfg, mesh, shp.global_batch)
        return LoweringSpec(
            name=f"{arch}:{shape}:prefill_step",
            fn=prefill_step,
            args=(params_shape, specs["batch"]),
            in_shardings=_named(mesh, (pspecs, bspecs)),
            out_shardings=_named(mesh, (logits_sp, cspecs)),
            cfg=cfg)

    # decode
    def serve_step(params, tokens, cache):
        return transformer.decode_step(params, cfg, tokens, cache)

    cache_shape = specs["cache"]
    if unrolled:
        cache_shape = unstack_cache_shape(cfg, cache_shape)
    cspecs = disagg.specs_for_cache(cfg, cache_shape, mesh,
                                    attention_partition)
    tok_spec = disagg.specs_for_batch(
        cfg, {"tokens": specs["tokens"]}, mesh)["tokens"]
    logits_sp = disagg.logits_spec(cfg, mesh, shp.global_batch)
    # output = (logits, updates): updates has k_new/v_new + refreshed states
    _, updates_shape = jax.eval_shape(serve_step, params_shape,
                                      specs["tokens"], cache_shape)
    uspecs = disagg.specs_for_cache(cfg, updates_shape, mesh,
                                    attention_partition)
    return LoweringSpec(
        name=f"{arch}:{shape}:serve_step",
        fn=serve_step,
        args=(params_shape, specs["tokens"], cache_shape),
        in_shardings=_named(mesh, (pspecs, tok_spec, cspecs)),
        out_shardings=_named(mesh, (logits_sp, uspecs)),
        cfg=cfg)
