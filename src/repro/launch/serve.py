"""Serving launcher: run the baseline or disaggregated engine on a synthetic
trace (CPU-scale with reduced configs).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --engine lamina --trace azure-conv --requests 16
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--engine", default="lamina",
                    choices=["vllm", "lamina"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", default="azure-conv")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--scale", type=float, default=0.02,
                    help="trace length scale (CPU-friendly)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--num-blocks", type=int, default=512)
    ap.add_argument("--attention-workers", type=int, default=2)
    ap.add_argument("--partition", default="head",
                    choices=["head", "block", "request"])
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    from repro.configs import registry
    from repro.data import traces
    from repro.models import transformer
    from repro.serving.disagg_engine import DisaggEngine
    from repro.serving.engine import Engine

    cfg = registry.get_smoke_config(args.arch) if args.smoke \
        else registry.get_config(args.arch)
    params = transformer.init_params(jax.random.PRNGKey(args.seed), cfg)
    reqs = traces.generate(args.trace, args.requests, cfg.vocab_size,
                           scale=args.scale, seed=args.seed)
    if args.engine == "lamina":
        eng = DisaggEngine(cfg, params, max_batch=args.max_batch,
                           num_blocks=args.num_blocks,
                           n_attention_workers=args.attention_workers,
                           partition=args.partition,
                           decode_backend=args.backend)
    else:
        eng = Engine(cfg, params, max_batch=args.max_batch,
                     num_blocks=args.num_blocks,
                     decode_backend=args.backend)
    eng.submit(reqs)
    stats = eng.run()
    print(f"engine={args.engine} trace={args.trace} "
          f"requests={len(reqs)} tokens={stats.tokens_generated} "
          f"mean_batch={stats.mean_batch:.2f} "
          f"throughput={stats.throughput:.1f} tok/s "
          f"mean_tbt={stats.mean_tbt*1000:.1f} ms")
    if args.engine == "lamina":
        log = eng.pool.log
        print(f"pool transfers={log.transfers} bytes={log.total} "
              f"(q={log.q_bytes} kv={log.kv_bytes} out={log.out_bytes})")
        print(f"pool partition={args.partition} per_worker_kv_bytes="
              f"{eng.pool.per_worker_kv_bytes}")


if __name__ == "__main__":
    main()
