"""Serving launcher: run the unified LLMEngine on a synthetic trace
(CPU-scale with reduced configs). Placement is declarative — one engine,
three placements — and the scheduler is pluggable (fcfs | preempt).

  repro-serve --arch llama3-8b --smoke --placement attention_pool \
      --trace azure-conv --requests 16

  (or: PYTHONPATH=src python -m repro.launch.serve ...)

``--mode`` selects the deployment role (serving/cluster/):

  * ``engine``  — the unified single engine (default, the path above);
  * ``router``  — a full disaggregated cluster: ``--replicas`` paired
    prefill/decode engines behind the prefix-affinity router
    (``--routing``), KV handed off block-granularly at
    ``--transfer-blocks-per-step`` blocks per step;
  * ``prefill`` — a standalone prefill tier: admit + prefill + export
    only, handoff payloads drained from the outbox (reports export
    volume and retained prefix donors);
  * ``decode``  — a standalone decode tier fed by an in-process prefill
    feeder (the transport seam a real RPC fabric would replace); reports
    the transfer/handoff-latency surface.

Fault injection (``--fault-scenario``) attaches a deterministic, seeded
fault schedule at the attention-pool boundary — shard death / transient /
corrupt / straggle — and the run reports the recovery counters and
recovery-latency percentiles (in router mode the schedule attaches to
decode replica 0 — the transfer-interruption path). Ctrl-C shuts down
gracefully: in-flight requests are cancelled (partial outputs kept) and
the stats summary always prints.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--mode", default="engine",
                    choices=["engine", "prefill", "decode", "router"],
                    help="deployment role: unified engine (default), "
                         "standalone prefill/decode tier, or the routed "
                         "disaggregated cluster")
    ap.add_argument("--replicas", type=int, default=2,
                    help="prefill/decode replica pairs (--mode router)")
    ap.add_argument("--routing", default="affinity",
                    choices=["affinity", "random", "least_loaded"],
                    help="request routing policy (--mode router)")
    ap.add_argument("--affinity-blocks", type=int, default=2,
                    help="leading full prompt blocks hashed into the "
                         "prefix-affinity routing key")
    ap.add_argument("--transfer-blocks-per-step", type=int, default=8,
                    help="KV blocks a decode replica lands per engine "
                         "step while draining its transfer queue "
                         "(0 = a whole payload per step)")
    ap.add_argument("--no-retain-prefixes", action="store_true",
                    help="free exported prompts immediately instead of "
                         "retaining them as prefix-sharing donors")
    ap.add_argument("--placement", default="attention_pool",
                    choices=["homogeneous", "attention_pool", "moe_offload"])
    ap.add_argument("--engine", default=None, choices=["vllm", "lamina"],
                    help="legacy alias: vllm=homogeneous, "
                         "lamina=attention_pool (overrides --placement)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", default="azure-conv")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--scale", type=float, default=0.02,
                    help="trace length scale (CPU-friendly)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--num-blocks", type=int, default=512)
    ap.add_argument("--attention-workers", type=int, default=2)
    ap.add_argument("--expert-workers", type=int, default=2)
    ap.add_argument("--partition", default="head",
                    choices=["head", "block", "request"])
    ap.add_argument("--scheduler", default="fcfs",
                    choices=["fcfs", "preempt"])
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="refcounted prompt-prefix sharing: map identical "
                         "full prompt blocks onto one set of physical KV "
                         "blocks (copy-on-write on divergence) and skip "
                         "their prefill")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=0,
                    help="chunked paged prefill: per-iteration prefill "
                         "token budget (a multiple of the block size; at "
                         "most one chunk runs per engine step alongside "
                         "the full decode batch). 0 = one-shot prefill")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "int8"],
                    help="KV block pool storage dtype: bf16 (the model "
                         "dtype) or int8 with per-token per-kv-head fp32 "
                         "scales — ~2x pool residency and decode KV-read "
                         "bytes, dequant fused into the attention kernels "
                         "(applies to every mode incl. prefill/decode/"
                         "router tiers; both tiers of a disaggregated "
                         "pair must agree)")
    ap.add_argument("--events", action="store_true",
                    help="print the iteration-level lifecycle event stream")
    ap.add_argument("--kv-shards", type=int, default=0,
                    help="shard the KV pool's block axis over this many "
                         "pool shards (0 = derive: block partition shards "
                         "over the attention workers, otherwise 1). Fault "
                         "injection targets these shards")
    ap.add_argument("--fault-scenario", default=None,
                    help="deterministic fault schedule at the pool "
                         "boundary: inline DSL "
                         "'kind:key=val,...;kind:...' (kinds: shard_death "
                         "| transient | corrupt | straggle; keys: shard, "
                         "step, failures, rejoin, delay_ms) or a path to "
                         "a JSON scenario file")
    ap.add_argument("--fault-retry-limit", type=int, default=3,
                    help="failed probes / corrupted outputs a shard may "
                         "accumulate before being declared dead")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    from repro.configs import registry
    from repro.data import traces
    from repro.models import transformer
    from repro.serving import (EngineConfig, FaultInjector, FaultScenario,
                               LLMEngine)

    placement = {"vllm": "homogeneous", "lamina": "attention_pool",
                 None: args.placement}[args.engine]
    cfg = registry.get_smoke_config(args.arch) if args.smoke \
        else registry.get_config(args.arch)
    params = transformer.init_params(jax.random.PRNGKey(args.seed), cfg)
    reqs = traces.generate(args.trace, args.requests, cfg.vocab_size,
                           scale=args.scale, seed=args.seed)
    econf = EngineConfig(
        placement=placement, partition=args.partition,
        attention_workers=args.attention_workers,
        expert_workers=args.expert_workers,
        max_batch=args.max_batch, num_blocks=args.num_blocks,
        kv_shards=args.kv_shards or None,
        scheduler=args.scheduler, decode_backend=args.backend,
        kv_dtype=args.kv_dtype,
        prefix_sharing=args.prefix_sharing,
        prefill_chunk_tokens=args.prefill_chunk_tokens or None,
        fault_retry_limit=args.fault_retry_limit,
        seed=args.seed)
    injector = None
    if args.fault_scenario:
        injector = FaultInjector(FaultScenario.parse(args.fault_scenario))

    if args.mode != "engine":
        _run_disagg(args, cfg, params, econf, reqs, injector)
        return

    eng = LLMEngine(cfg, params, econf, fault_injector=injector)
    eng.submit(reqs)
    # graceful shutdown: Ctrl-C cancels the in-flight requests (pool blocks
    # freed, partial outputs kept, handle iterators terminate) and the
    # stats summary below ALWAYS prints — an interrupted run still reports
    try:
        if args.events:
            for ev in eng.events():  # events() drives the engine to drain
                print(f"  step {ev.step:4d} {ev.kind:8s} rid={ev.rid} "
                      f"{ev.info}")
        else:
            eng.run()
    except KeyboardInterrupt:
        n = eng.cancel_all()
        print(f"\ninterrupted — cancelled {n} in-flight request(s), "
              f"partial outputs kept; draining stats")
    s = eng.stats.summary()
    print(f"placement={placement} partition={args.partition} "
          f"scheduler={args.scheduler} trace={args.trace} "
          f"requests={len(reqs)} tokens={s['tokens_generated']} "
          f"mean_batch={s['mean_batch']:.2f} "
          f"throughput={s['throughput_tok_s']:.1f} tok/s "
          f"mean_tbt={s['mean_tbt_s']*1000:.1f} ms "
          f"preemptions={s['preemptions']}")
    if args.prefill_chunk_tokens:
        print(f"chunked_prefill chunk_tokens={args.prefill_chunk_tokens} "
              f"prefill_chunks_run={s['prefill_chunks_run']} "
              f"max_prefill_slab_tokens={s['max_prefill_slab_tokens']}")
    if args.kv_dtype != "bf16":
        print(f"kv_pool dtype={args.kv_dtype} "
              f"resident_bytes={s['kv_pool_bytes_resident']} "
              f"read_bytes_per_step={s['kv_bytes_read_per_step']:.0f}")
    if args.prefix_sharing:
        print(f"prefix_sharing blocks_shared={s['blocks_shared']} "
              f"prefill_tokens_skipped={s['prefill_tokens_skipped']} "
              f"cow_forks={eng.kv.cow_forks} "
              f"used_blocks={eng.kv.used_blocks}")
    if args.fault_scenario or s["shard_failures"] or s["fault_retries"]:
        print(f"faults shard_failures={s['shard_failures']} "
              f"rejoins={s['shard_rejoins']} "
              f"transient_recovered={s['transient_faults_recovered']} "
              f"retries={s['fault_retries']} "
              f"straggles={s['straggle_steps']} "
              f"requests_recovered={s['requests_recovered']}")
        print(f"recovery_ms p50={s['recovery_p50_s']*1e3:.1f} "
              f"p90={s['recovery_p90_s']*1e3:.1f} "
              f"p99={s['recovery_p99_s']*1e3:.1f}")
    print(f"ttft_ms p50={s['ttft_p50_s']*1e3:.1f} "
          f"p90={s['ttft_p90_s']*1e3:.1f} p99={s['ttft_p99_s']*1e3:.1f}  "
          f"tbt_ms p50={s['tbt_p50_s']*1e3:.1f} "
          f"p90={s['tbt_p90_s']*1e3:.1f} p99={s['tbt_p99_s']*1e3:.1f}")
    if eng.pool is not None:
        log = eng.pool.log
        print(f"pool transfers={log.transfers} bytes={log.total} "
              f"(q={log.q_bytes} kv={log.kv_bytes} out={log.out_bytes})")
        print(f"pool partition={args.partition} per_worker_kv_bytes="
              f"{eng.pool.per_worker_kv_bytes}")
    if eng.expert_pool is not None:
        elog = eng.expert_pool.log
        print(f"expert pool transfers={elog.transfers} bytes={elog.total}")


def _run_disagg(args, cfg, params, econf, reqs, injector) -> None:
    """The disaggregated roles: standalone prefill / decode tier, or the
    full routed cluster (--mode router)."""
    from repro.serving import DisaggConfig
    from repro.serving.cluster import (DecodeEngine, DisaggCluster,
                                       PrefillEngine)

    disagg = DisaggConfig(
        transfer_blocks_per_step=args.transfer_blocks_per_step,
        retain_prefixes=not args.no_retain_prefixes)

    if args.mode == "router":
        cluster = DisaggCluster(
            cfg, params, econf, replicas=args.replicas,
            disagg=disagg, routing=args.routing,
            affinity_blocks=args.affinity_blocks,
            decode_faults={0: injector} if injector else None,
            seed=args.seed)
        cluster.submit(reqs)
        try:
            cluster.run()
        except KeyboardInterrupt:
            print("\ninterrupted — reporting partial cluster stats")
        s = cluster.summary()
        print(f"mode=router replicas={s['replicas']} "
              f"routing={s['routing']} requests={s['requests']} "
              f"tokens={s['tokens_generated']} "
              f"handoffs={s['handoffs_completed']} "
              f"retries={s['handoff_retries']}")
        print(f"router affinity_hits={s['router_affinity_hits']} "
              f"prefill_tokens_skipped={s['prefill_tokens_skipped']} "
              f"blocks_shared={s['blocks_shared']}")
        print(f"kv_bytes_transferred={s['kv_bytes_transferred']} "
              f"handoff_ms p50={s['handoff_p50_s']*1e3:.1f} "
              f"p90={s['handoff_p90_s']*1e3:.1f} "
              f"p99={s['handoff_p99_s']*1e3:.1f}")
        for p in s["per_replica"]:
            print(f"  replica {p['replica']}: healthy={p['healthy']} "
                  f"handoffs={p['handoffs_completed']} "
                  f"kv_bytes={p['kv_bytes_transferred']} "
                  f"affinity_hits={p['router_affinity_hits']} "
                  f"skipped={p['prefill_tokens_skipped']}")
        return

    if args.mode == "prefill":
        eng = PrefillEngine(cfg, params, econf,
                            disagg=disagg.replace(role="prefill"),
                            fault_injector=injector)
        eng.submit(reqs)
        exported = []
        while eng.has_work():
            eng.step()
            exported.extend(eng.collect_handoffs())
        s = eng.stats
        print(f"mode=prefill requests={len(reqs)} "
              f"exported={len(exported)} "
              f"kv_bytes_exported={s.kv_bytes_transferred} "
              f"payload_blocks={sum(h.payload.n_blocks for h in exported)} "
              f"retained_donors={len(eng.retained_rids)} "
              f"prefill_tokens_skipped={s.prefill_tokens_skipped}")
        return

    # --mode decode: an in-process prefill feeder plays the remote tier
    feeder = PrefillEngine(cfg, params, econf,
                           disagg=disagg.replace(role="prefill"))
    eng = DecodeEngine(cfg, params, econf,
                       disagg=disagg.replace(role="decode"),
                       fault_injector=injector)
    feeder.on_handoff = eng.enqueue_handoff
    feeder.submit(reqs)
    while feeder.has_work() or eng.has_work():
        if feeder.has_work():
            feeder.step()
        if eng.has_work():
            eng.step()
    s = eng.stats.summary()
    print(f"mode=decode requests={len(reqs)} "
          f"tokens={s['tokens_generated']} "
          f"handoffs={s['handoffs_completed']} "
          f"retries={s['handoff_retries']} "
          f"kv_bytes_transferred={s['kv_bytes_transferred']} "
          f"max_prefill_slab_tokens={s['max_prefill_slab_tokens']}")
    print(f"handoff_ms p50={s['handoff_p50_s']*1e3:.1f} "
          f"p90={s['handoff_p90_s']*1e3:.1f} "
          f"p99={s['handoff_p99_s']*1e3:.1f}  "
          f"tbt_ms p50={s['tbt_p50_s']*1e3:.1f} "
          f"p90={s['tbt_p90_s']*1e3:.1f}")


if __name__ == "__main__":
    main()
