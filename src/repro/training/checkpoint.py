"""Dependency-free checkpointing: pytrees -> one .npz + a JSON treedef.

Leaves are saved by flattened index; restore rebuilds the exact pytree
(dtypes included, bf16 round-trips via a uint16 view)."""
from __future__ import annotations

import json
import os
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BF16_TAG = "__bf16__"


def _to_numpy(leaf):
    arr = np.asarray(leaf)
    if arr.dtype == jnp.bfloat16:
        return arr.view(np.uint16), _BF16_TAG
    return arr, str(arr.dtype)


def save(directory: str, params: Any, opt_state: Any = None,
         step: int = 0) -> str:
    os.makedirs(directory, exist_ok=True)
    tree = {"params": params}
    if opt_state is not None:
        tree["opt"] = opt_state
    leaves, treedef = jax.tree.flatten(tree)
    arrays, dtypes = {}, []
    for i, leaf in enumerate(leaves):
        arr, tag = _to_numpy(leaf)
        arrays[f"leaf_{i}"] = arr
        dtypes.append(tag)
    path = os.path.join(directory, f"ckpt_{step:08d}")
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        # restore() rebuilds structure from a template, so we only persist
        # per-leaf dtype tags (bf16 needs the uint16-view marker)
        json.dump({"dtypes": dtypes, "step": step,
                   "num_leaves": len(leaves)}, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else None


def restore(directory: str, template: Any, step: Optional[int] = None
            ) -> Tuple[Any, int]:
    """Restore into the structure of `template` ({'params':..,'opt':..})."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}")
    with np.load(path + ".npz") as data, open(path + ".json") as f:
        meta = json.load(f)
        leaves, treedef = jax.tree.flatten(template)
        out = []
        for i, leaf in enumerate(leaves):
            arr = data[f"leaf_{i}"]
            if meta["dtypes"][i] == _BF16_TAG:
                arr = arr.view(jnp.bfloat16)
            out.append(jnp.asarray(arr))
    return treedef.unflatten(out), step
