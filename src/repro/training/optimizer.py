"""AdamW (decoupled weight decay) implemented on raw pytrees — no optax
dependency. Moments are fp32 regardless of param dtype; the update math runs
in fp32 and casts back, which is the standard bf16-params mixed-precision
recipe the dry-run memory analysis accounts for."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_opt_state(params) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(f32, params),
                    nu=jax.tree.map(f32, params))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig
                  ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard exemption)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (treedef.unflatten(new_p),
            OptState(step, treedef.unflatten(new_m),
                     treedef.unflatten(new_v)),
            {"grad_norm": gnorm, "lr": lr})
