"""Training step + loop. ``make_train_step`` builds the jitted (and, with a
mesh, pjit-sharded) fused fwd/bwd/update used both by the real CPU training
examples and by the train_4k dry-run lowering."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.common import ModelConfig
from repro.training import optimizer as opt


def make_train_step(cfg: ModelConfig, adamw: opt.AdamWConfig,
                    grad_accum: int = 1) -> Callable:
    """Fused fwd/bwd/update. With grad_accum > 1 the global batch is split
    into microbatches scanned sequentially with fp32 gradient accumulation —
    the production memory lever that keeps activations/logits transient at
    1/grad_accum of the global batch (see EXPERIMENTS.md §Dry-run)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: transformer.loss_fn(p, cfg, batch), has_aux=True
        )(params)

    def train_step(params, state: opt.OptState, batch: Dict
                   ) -> Tuple[Any, opt.OptState, Dict]:
        if grad_accum == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((grad_accum, a.shape[0] // grad_accum)
                                    + a.shape[1:]), batch)

            def body(carry, mb):
                gsum, lsum, msum = carry
                (loss, metrics), g = grads_of(params, mb)
                gsum = jax.tree.map(
                    lambda s, x: s + x.astype(jnp.float32), gsum, g)
                msum = jax.tree.map(lambda s, x: s + x, msum, metrics)
                return (gsum, lsum + loss, msum), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            m0 = {"ce": jnp.zeros((), jnp.float32),
                  "aux": jnp.zeros((), jnp.float32)}
            (gsum, loss, msum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32), m0), micro,
                unroll=cfg.lower_unrolled)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = loss / grad_accum
            metrics = jax.tree.map(lambda m: m / grad_accum, msum)
        params, state, om = opt.apply_updates(params, grads, state, adamw)
        metrics = dict(metrics, loss=loss, **om)
        return params, state, metrics

    return train_step


def train(cfg: ModelConfig, adamw: opt.AdamWConfig, data_iter,
          num_steps: int, *, params=None, state=None,
          log_every: int = 10, seed: int = 0,
          checkpoint_dir: Optional[str] = None,
          checkpoint_every: int = 0) -> Tuple[Any, opt.OptState, list]:
    from repro.training import checkpoint as ckpt

    if params is None:
        params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    if state is None:
        state = opt.init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, adamw))
    history = []
    t0 = time.time()
    for i in range(num_steps):
        batch = next(data_iter)
        params, state, metrics = step_fn(params, state, batch)
        if (i + 1) % log_every == 0 or i == 0:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i + 1
            m["wall_s"] = time.time() - t0
            history.append(m)
            print(f"step {i+1:5d} loss={m['loss']:.4f} "
                  f"ce={m['ce']:.4f} gnorm={m['grad_norm']:.3f} "
                  f"lr={m['lr']:.2e} ({m['wall_s']:.1f}s)")
        if checkpoint_dir and checkpoint_every and \
                (i + 1) % checkpoint_every == 0:
            ckpt.save(checkpoint_dir, params, state, step=i + 1)
    return params, state, history
