"""int8 KV-cache quantization (paper §7: reduced-precision KV storage).

Per-token, per-kv-head symmetric max-abs quantization:
    k_int8[b, h, s, :] = round(k[b, h, s, :] / scale[b, h, s] * 127)

Halves the memory-pool capacity per request and the attention-operator read
bytes — the two quantities the paper's DOP sizing (§3.1, Fig. 11) is most
sensitive to. Dequantization fuses into the score/PV einsums (a broadcast
multiply per tile); accuracy impact is bounded by tests (cosine > 0.999 on
attention outputs for unit-scale inputs).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (..., S, hd) head-major KV slab -> (int8 values, fp32 scales
    (..., S))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array,
                  dtype=None) -> jax.Array:
    """Dequantize int8 values back to ``dtype``.

    ``dtype`` comes from the caller (the pool/compute dtype — e.g.
    ``ModelConfig.dtype``); ``None`` keeps the fp32 math dtype rather than
    silently casting to bfloat16, so gemma2/llama3 configs with differing
    activation dtypes round-trip exactly.
    """
    out = q.astype(jnp.float32) * scale[..., None]
    return out if dtype is None else out.astype(dtype)


def quantize_token(k_new: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """k_new: (B, Hkv, hd) single token -> (int8, scale (B, Hkv))."""
    amax = jnp.max(jnp.abs(k_new.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(k_new.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale
