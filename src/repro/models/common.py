"""Common building blocks shared by every architecture family.

Pure-functional JAX: parameters are pytrees of arrays, modules are functions.
Per-layer parameters are *stacked* along a leading L axis so the transformer
stack lowers as a single ``lax.scan`` — this keeps dry-run compiles of 61-layer
models fast and the HLO compact.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config describes every supported family; unused fields stay 0."""

    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- attention variants ---
    sliding_window: int = 0  # 0 = full attention
    # StreamingLLM-style decode: keep `attention_sinks` initial tokens
    # attendable alongside the sliding window (paper §7 proposes sparse
    # attention for cheap memory pools; sinks+window is the production
    # variant that preserves quality). Requires sliding_window > 0.
    attention_sinks: int = 0
    # KV-cache storage width (paper §7: "model quantization uses reduced-
    # precision formats to store KV caches"). 8 -> int8 values + per-token
    # per-head fp scales; halves the memory pool's capacity requirement and
    # the attention read bytes. 16 -> cfg.dtype (default).
    kv_cache_bits: int = 16
    local_global: bool = False  # gemma2-style alternating local/global
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    qk_norm: bool = False
    post_norms: bool = False  # gemma2 pre+post sandwich norms
    # --- SSM / RWKV ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    rwkv_head_dim: int = 64
    # --- hybrid (zamba2): one shared attention block every `period` layers ---
    shared_attn_period: int = 0
    # --- encoder/decoder (seamless) ---
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    # --- modality frontend stubs ---
    modality: str = "text"  # text | vision | audio
    frontend_tokens: int = 0  # patches / audio frames prepended (stub embeds)
    # --- kernels ---
    use_pallas_kernels: bool = False  # route hot-spots through repro.kernels
    # --- training memory policy ---
    # jax.checkpoint each layer body in train mode: activations saved per
    # layer boundary only, attention/FFN recomputed in backward (the llama3
    # train_4k dry-run is 470 GiB/chip without this, ~a few GiB with it).
    remat: bool = True
    # --- lowering mode ---
    # Unroll layer/KV-block scans when lowering. compiled.cost_analysis()
    # counts while-loop bodies ONCE (verified empirically), so the roofline
    # cost pass lowers an unrolled variant for exact HLO FLOP/byte/collective
    # counts; the natural scan variant stays the memory/compile-proof
    # artifact. Time-dimension recurrences (rwkv/mamba) stay loops and get
    # analytic corrections in launch/analytic.py.
    lower_unrolled: bool = False
    # --- numerics ---
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    source: str = ""  # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def gqa_group(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Initialisation helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    std = scale / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * std).astype(dtype)


def stacked(key, n: int, init_fn):
    """Initialise ``n`` per-layer params stacked on axis 0."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# Primitive ops
# ---------------------------------------------------------------------------
# ---------------------------------------------------------------------------
# Activation-sharding hook (installed by the launcher; identity by default).
# Lives here so every model module (transformer stacks, ssm mixers) can pin
# activation layouts without import cycles.
# ---------------------------------------------------------------------------
_ACT_CONSTRAINT = None


def set_activation_constraint(fn) -> None:
    global _ACT_CONSTRAINT
    _ACT_CONSTRAINT = fn


def constrain_activation(x):
    return _ACT_CONSTRAINT(x) if _ACT_CONSTRAINT is not None else x


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,s,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., s, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    gate = jnp.einsum("...d,df->...f", x, w_gate)
    up = jnp.einsum("...d,df->...f", x, w_up)
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("...f,fd->...d", hidden, w_down)


def gelu_mlp(x: jax.Array, w_fc: jax.Array, w_proj: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_fc).astype(jnp.float32))
    return jnp.einsum("...f,fd->...d", h.astype(x.dtype), w_proj)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
