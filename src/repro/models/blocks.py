"""Transformer blocks for every architecture family.

Each block is a function ``(params, cfg, x, ...) -> (x, extras)`` operating on
one layer's (un-stacked) parameters. Stacking/scanning over layers lives in
``transformer.py``. ``mode`` is STATIC: "train" | "prefill" | "decode".
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.attention import (attention_decode_step,
                                    attention_decode_step_paged,
                                    attention_forward, blockwise_attention,
                                    init_attention, out_project, qkv_project)
from repro.models.common import ModelConfig, rms_norm
from repro.models.ffn import ffn_forward, init_ffn
from repro.models.moe import init_moe, moe_forward


# ---------------------------------------------------------------------------
# Dense (llama/glm/tinyllama/pixtral/gemma2) + MoE blocks
# ---------------------------------------------------------------------------
def init_dense_block(key, cfg: ModelConfig, use_moe: bool = False) -> Dict:
    k1, k2 = jax.random.split(key)
    p = {
        "norm1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "norm2": jnp.zeros((cfg.d_model,), cfg.dtype),
        "attn": init_attention(k1, cfg),
    }
    if use_moe:
        p["moe"] = init_moe(k2, cfg)
    else:
        p["ffn"] = init_ffn(k2, cfg)
    if cfg.post_norms:
        p["norm_post_attn"] = jnp.zeros((cfg.d_model,), cfg.dtype)
        p["norm_post_ffn"] = jnp.zeros((cfg.d_model,), cfg.dtype)
    return p


def dense_block(params: Dict, cfg: ModelConfig, x: jax.Array, *,
                mode: str, positions: Optional[jax.Array] = None,
                cache: Optional[Dict] = None, is_local: bool = False,
                backend: str = "jnp", moe_group_size: int = 256,
                prefix_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                paged_prefix: Optional[Tuple[jax.Array, jax.Array,
                                             jax.Array]] = None,
                paged_prefix_scales: Optional[Tuple[jax.Array,
                                                    jax.Array]] = None
                ) -> Tuple[jax.Array, Dict, jax.Array]:
    """Returns (x, new_cache_entries, aux_loss). ``prefix_kv`` (prefill
    only): this layer's head-major (B, Hkv, P, hd) K/V of an already-cached
    prompt prefix; ``paged_prefix`` the paged form — this layer's
    (k_pool, v_pool, block_table) read in place (chunked prefill) — see
    ``attention_forward``."""
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    new_cache: Dict = {}
    if mode == "decode":
        if "k_pool" in cache:  # paged: attend over the block pool in place
            attn, k_new, v_new = attention_decode_step_paged(
                params["attn"], cfg, h, cache["k_pool"], cache["v_pool"],
                cache["block_tables"], cache["len"],
                is_local=is_local, backend=backend,
                k_scale=cache.get("k_scale_pool"),
                v_scale=cache.get("v_scale_pool"))
        else:
            attn, k_new, v_new = attention_decode_step(
                params["attn"], cfg, h, cache["k"], cache["v"], cache["len"],
                is_local=is_local, backend=backend,
                k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"))
        new_cache = {"k_new": k_new, "v_new": v_new}
    else:
        attn, k, v = attention_forward(params["attn"], cfg, h, positions,
                                       is_local=is_local, prefix_kv=prefix_kv,
                                       paged_prefix=paged_prefix,
                                       paged_prefix_scales=paged_prefix_scales,
                                       backend=backend)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
    if cfg.post_norms:
        attn = rms_norm(attn, params["norm_post_attn"], cfg.norm_eps)
    x = x + attn

    h = rms_norm(x, params["norm2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in params:
        f, aux = moe_forward(params["moe"], cfg, h, group_size=moe_group_size)
    else:
        f = ffn_forward(params["ffn"], h)
    if cfg.post_norms:
        f = rms_norm(f, params["norm_post_ffn"], cfg.norm_eps)
    return x + f, new_cache, aux


# ---------------------------------------------------------------------------
# RWKV6 block
# ---------------------------------------------------------------------------
def init_rwkv_block(key, cfg: ModelConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "norm2": jnp.zeros((cfg.d_model,), cfg.dtype),
        "tmix": ssm.init_rwkv_time_mix(k1, cfg),
        "cmix": ssm.init_rwkv_channel_mix(k2, cfg),
    }


def rwkv_block(params: Dict, cfg: ModelConfig, x: jax.Array, *, mode: str,
               state: Optional[Dict] = None) -> Tuple[jax.Array, Dict]:
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    if mode == "decode":
        tm, tstate = ssm.rwkv_time_mix_decode(params["tmix"], cfg, h, state)
    else:
        tm = ssm.rwkv_time_mix_forward(params["tmix"], cfg, h)
        tstate = {"x_tm": h[:, -1]}
        if mode == "prefill":
            # reconstruct final recurrence state for decoding
            tstate = _rwkv_final_state(params["tmix"], cfg, h)
    x = x + tm
    h = rms_norm(x, params["norm2"], cfg.norm_eps)
    if mode == "decode":
        cm, _ = ssm.rwkv_channel_mix_forward(params["cmix"], cfg, h,
                                             state["x_cm"])
    else:
        cm, _ = ssm.rwkv_channel_mix_forward(
            params["cmix"], cfg, h, jnp.zeros_like(h[:, 0]))
    new_state = dict(tstate)
    new_state["x_cm"] = h[:, -1]
    return x + cm, new_state


def _rwkv_final_state(tmix: Dict, cfg: ModelConfig, h: jax.Array) -> Dict:
    """Run the recurrence once more to extract S after the whole prefix."""
    H, P = ssm.rwkv_dims(cfg)
    B_, S, d = h.shape
    x_prev = ssm._token_shift(h, jnp.zeros((B_, d), h.dtype))
    r, k, v, g, w = ssm._rwkv_rkvwg(tmix, cfg, h, x_prev)

    def step(S_h, inp):
        k_t, v_t, w_t = [a.astype(jnp.float32) for a in inp]
        kv = k_t[..., :, None] * v_t[..., None, :]
        return w_t[..., :, None] * S_h + kv, None

    S0 = jnp.zeros((B_, H, P, P), jnp.float32)
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (k, v, w))
    S_fin, _ = jax.lax.scan(step, S0, xs)
    return {"S": S_fin, "x_tm": h[:, -1]}


# ---------------------------------------------------------------------------
# Mamba2 block (zamba2 hybrid)
# ---------------------------------------------------------------------------
def init_mamba_block(key, cfg: ModelConfig) -> Dict:
    return {
        "norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "mamba": ssm.init_mamba(key, cfg),
    }


def mamba_block(params: Dict, cfg: ModelConfig, x: jax.Array, *, mode: str,
                state: Optional[Dict] = None) -> Tuple[jax.Array, Dict]:
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    if mode == "decode":
        y, new_state = ssm.mamba_decode_step(params["mamba"], cfg, h, state)
    else:
        y = ssm.mamba_forward(params["mamba"], cfg, h)
        new_state = {}
        if mode == "prefill":
            new_state = _mamba_final_state(params["mamba"], cfg, h)
    return x + y, new_state


def _mamba_final_state(mp: Dict, cfg: ModelConfig, h: jax.Array) -> Dict:
    d_inner, H, P, N = ssm.mamba_dims(cfg)
    B_, S, _ = h.shape
    z, xh, Bm, Cm, dt, conv_state = ssm._mamba_project(mp, cfg, h)
    decay = jnp.exp(-jnp.exp(mp["a_log"]) * dt)
    xdt = xh.astype(jnp.float32) * dt[..., None]

    def step(hs, inp):
        xdt_t, B_t, C_t, decay_t = inp
        hs = hs * decay_t[:, :, None, None] + \
            xdt_t[..., None] * B_t[:, None, None, :]
        return hs, None

    h0 = jnp.zeros((B_, H, P, N), jnp.float32)
    xs = (xdt.transpose(1, 0, 2, 3), Bm.astype(jnp.float32).transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2), decay.transpose(1, 0, 2))
    h_fin, _ = jax.lax.scan(step, h0, xs)
    # conv state: last K-1 *pre-activation* conv inputs
    proj = jnp.einsum("bsd,de->bse", h, mp["w_in"])
    _, xr, Bm2, Cm2, _ = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    conv_in = jnp.concatenate([xr, Bm2, Cm2], axis=-1)
    K = cfg.ssm_conv
    pad = jnp.pad(conv_in, ((0, 0), (K - 1, 0), (0, 0)))
    return {"h": h_fin, "conv": pad[:, -(K - 1):, :]}


# ---------------------------------------------------------------------------
# Encoder block (bidirectional) + decoder block w/ cross-attention (seamless)
# ---------------------------------------------------------------------------
def init_encoder_block(key, cfg: ModelConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "norm2": jnp.zeros((cfg.d_model,), cfg.dtype),
        "attn": init_attention(k1, cfg),
        "ffn": init_ffn(k2, cfg),
    }


def encoder_block(params: Dict, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array) -> jax.Array:
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    q, k, v = qkv_project(params["attn"], cfg, h, positions)
    out = blockwise_attention(q, k, v, causal=False,
                              q_positions=positions,
                              block_size=max(512, x.shape[1] // 8)
                              if cfg.lower_unrolled else 512,
                              unroll=cfg.lower_unrolled)
    x = x + out_project(params["attn"], out)
    h = rms_norm(x, params["norm2"], cfg.norm_eps)
    return x + ffn_forward(params["ffn"], h)


def init_decoder_block(key, cfg: ModelConfig) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "norm2": jnp.zeros((cfg.d_model,), cfg.dtype),
        "norm3": jnp.zeros((cfg.d_model,), cfg.dtype),
        "attn": init_attention(k1, cfg),
        "cross": init_attention(k2, cfg),
        "ffn": init_ffn(k3, cfg),
    }


def decoder_block(params: Dict, cfg: ModelConfig, x: jax.Array,
                  enc_kv: Tuple[jax.Array, jax.Array], *, mode: str,
                  positions: Optional[jax.Array] = None,
                  cache: Optional[Dict] = None,
                  backend: str = "jnp") -> Tuple[jax.Array, Dict]:
    """enc_kv: precomputed (k, v) of the encoder output for this layer."""
    # self attention
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    new_cache: Dict = {}
    if mode == "decode":
        attn, k_new, v_new = attention_decode_step(
            params["attn"], cfg, h, cache["k"], cache["v"], cache["len"],
            backend=backend)
        new_cache = {"k_new": k_new, "v_new": v_new}
    else:
        attn, k, v = attention_forward(params["attn"], cfg, h, positions)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
    x = x + attn
    # cross attention (encoder K/V are fixed — computed once per request)
    h = rms_norm(x, params["norm2"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, params["cross"]["wq"])
    ek, ev = enc_kv
    if mode == "decode":
        # enc_kv arrives HEAD-MAJOR (B, Hkv, S_enc, hd) from the cache; the
        # single-token cross attention uses the decode partial path directly
        from repro.core import combine as Comb
        from repro.models.attention import decode_attention_partial_jnp
        B = q.shape[0]
        full = jnp.full((B,), ek.shape[2], jnp.int32)
        part = decode_attention_partial_jnp(q[:, 0], ek, ev, full)
        out = Comb.finalize(part).astype(q.dtype)[:, None]
    else:
        out = blockwise_attention(q, ek, ev, causal=False,
                                  block_size=max(512, ek.shape[1] // 8)
                                  if cfg.lower_unrolled else 512,
                                  unroll=cfg.lower_unrolled)
    x = x + out_project(params["cross"], out)
    # ffn
    h = rms_norm(x, params["norm3"], cfg.norm_eps)
    return x + ffn_forward(params["ffn"], h), new_cache


def encoder_cross_kv(params: Dict, cfg: ModelConfig,
                     enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Project encoder output into this decoder layer's cross K/V."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["cross"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["cross"]["wv"])
    return k, v
