"""Dense feed-forward (SwiGLU) layer."""
from __future__ import annotations

from typing import Dict

import jax

from repro.models.common import ModelConfig, dense_init, swiglu


def init_ffn(key, cfg: ModelConfig, d_ff: int = 0, dtype=None) -> Dict:
    dtype = dtype or cfg.dtype
    d_ff = d_ff or cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(kg, (cfg.d_model, d_ff), dtype),
        "w_up": dense_init(ku, (cfg.d_model, d_ff), dtype),
        "w_down": dense_init(kd, (d_ff, cfg.d_model), dtype),
    }


def ffn_forward(params: Dict, x: jax.Array) -> jax.Array:
    return swiglu(x, params["w_gate"], params["w_up"], params["w_down"])
