"""Model assembly: embedding -> layer stack (lax.scan) -> head, for every
assigned architecture family, with a uniform API:

    init_params(key, cfg)                         -> params
    forward(params, cfg, batch)                   -> (logits, aux)
    loss_fn(params, cfg, batch)                   -> (loss, metrics)
    init_cache(cfg, batch_size, max_seq)          -> cache pytree
    prefill(params, cfg, batch, max_seq)          -> (last_logits, cache)
    decode_step(params, cfg, tokens, cache, ...)  -> (logits, cache)

``batch``: {"tokens": (B, S) int32, ["frontend"]: (B, F, d) modality embeds,
["frames"]: (B, S_enc, d) audio frames for enc-dec, ["labels"], ["mask"]}.

Per-layer params are stacked on axis 0 so every stack lowers as one
``lax.scan`` (compact HLO, fast 61-layer dry-run compiles).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks, ssm
from repro.models.common import (ModelConfig, cross_entropy_loss, dense_init,
                                 rms_norm, softcap)

Params = Dict[str, Any]

# Optional activation-sharding hook (Megatron-style sequence/hidden
# activation partitioning over the TP axis). The launcher installs a
# with_sharding_constraint closure before tracing; unset it is identity.
# (Storage lives in models.common so ssm/moe modules can constrain their
# intermediates without import cycles.)
from repro.models.common import (constrain_activation as _constrain,  # noqa
                                 set_activation_constraint)


def _maybe_remat(fn, cfg: ModelConfig, mode: str):
    return jax.checkpoint(fn) if (cfg.remat and mode == "train") else fn


def _tree_index(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _tree_stack_init(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ===========================================================================
# Init
# ===========================================================================
def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model), cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size),
                                       cfg.dtype)
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        use_moe = fam == "moe"
        params["layers"] = _tree_stack_init(
            keys[2], cfg.num_layers,
            lambda k: blocks.init_dense_block(k, cfg, use_moe=use_moe))
    elif fam == "ssm":  # rwkv6
        params["layers"] = _tree_stack_init(
            keys[2], cfg.num_layers, lambda k: blocks.init_rwkv_block(k, cfg))
    elif fam == "hybrid":  # zamba2
        n_super, period, tail = _zamba_split(cfg)
        params["layers"] = jax.tree.map(
            lambda a: a.reshape((n_super, period) + a.shape[1:]),
            _tree_stack_init(keys[2], n_super * period,
                             lambda k: blocks.init_mamba_block(k, cfg)))
        if tail:
            params["tail"] = _tree_stack_init(
                keys[3], tail, lambda k: blocks.init_mamba_block(k, cfg))
        params["shared_attn"] = blocks.init_dense_block(keys[4], cfg)
    elif fam == "audio":  # seamless enc-dec
        enc_cfg = cfg
        params["enc_layers"] = _tree_stack_init(
            keys[2], cfg.encoder_layers,
            lambda k: blocks.init_encoder_block(k, enc_cfg))
        params["enc_norm"] = jnp.zeros((cfg.d_model,), cfg.dtype)
        params["layers"] = _tree_stack_init(
            keys[3], cfg.num_layers,
            lambda k: blocks.init_decoder_block(k, cfg))
    else:
        raise ValueError(f"unknown family {fam}")
    return params


def _zamba_split(cfg: ModelConfig) -> Tuple[int, int, int]:
    period = cfg.shared_attn_period
    n_super = cfg.num_layers // period
    tail = cfg.num_layers - n_super * period
    return n_super, period, tail


# ===========================================================================
# Embedding / head
# ===========================================================================
def _embed(params: Params, cfg: ModelConfig,
           batch: Dict) -> Tuple[jax.Array, jax.Array, int]:
    """Returns (x, positions, n_frontend)."""
    tok = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.tie_embeddings:
        tok = tok * jnp.asarray(jnp.sqrt(float(cfg.d_model)), tok.dtype)
    n_front = 0
    if cfg.modality in ("vision", "audio_embeds") and "frontend" in batch:
        front = batch["frontend"].astype(tok.dtype)
        tok = jnp.concatenate([front, tok], axis=1)
        n_front = front.shape[1]
    B, S = tok.shape[:2]
    positions = jnp.arange(S)[None, :].repeat(B, 0)
    return tok, positions, n_front


def _head(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["lm_head"])
    return softcap(logits, cfg.final_logit_softcap)


# ===========================================================================
# Full-sequence forward (train / prefill)
# ===========================================================================
def _dense_stack(params, cfg: ModelConfig, x, positions, *, mode: str,
                 moe_group_size: int = 256):
    """Scan over dense/moe layers. gemma2 (local_global) scans layer *pairs*
    so local/global get separate static traces. Returns (x, aux, cache_kv).

    ``params["layers"]`` may be a LIST of per-layer trees instead of a
    stacked tree: then layers are separate XLA buffers and the loop is
    unrolled python-side — the production-serving layout (per-layer KV/weight
    buffers) used by the dry-run cost pass, where stacked+sliced layers would
    make every layer fusion charge the whole stack (see EXPERIMENTS.md §Perf
    #2)."""
    pair = 2 if cfg.local_global else 1
    layers = params["layers"]
    if isinstance(layers, (list, tuple)):
        aux = jnp.zeros((), jnp.float32)
        caches = []
        h = x
        for i, p in enumerate(layers):
            is_local = (i % 2 == 0) if cfg.local_global else False

            def run(p_, h_, _loc=is_local):
                return blocks.dense_block(
                    p_, cfg, h_, mode=mode, positions=positions,
                    is_local=_loc, moe_group_size=moe_group_size)

            h, cache, a = _maybe_remat(run, cfg, mode)(p, h)
            h = _constrain(h)
            caches.append(cache)
            aux = aux + a
        return h, aux, caches
    if pair == 2:
        layers = jax.tree.map(
            lambda a: a.reshape((a.shape[0] // 2, 2) + a.shape[1:]), layers)

    def body(carry, layer_p):
        h, aux = carry
        caches = []
        for j in range(pair):
            p = _tree_index(layer_p, j) if pair == 2 else layer_p
            is_local = (j == 0) if cfg.local_global else False

            def run(p_, h_, _loc=is_local):
                return blocks.dense_block(
                    p_, cfg, h_, mode=mode, positions=positions,
                    is_local=_loc, moe_group_size=moe_group_size)

            h, cache, a = _maybe_remat(run, cfg, mode)(p, h)
            h = _constrain(h)
            caches.append(cache)
            aux = aux + a
        ys = jax.tree.map(lambda *c: jnp.stack(c), *caches) if pair == 2 \
            else caches[0]
        return (h, aux), ys

    (x, aux), kv = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                layers, unroll=cfg.lower_unrolled)
    if mode == "prefill" and pair == 2:
        kv = jax.tree.map(
            lambda a: a.reshape((a.shape[0] * 2,) + a.shape[2:]), kv)
    return x, aux, kv


def _rwkv_stack(params, cfg, x, *, mode: str):
    run = _maybe_remat(
        lambda p_, h_: blocks.rwkv_block(p_, cfg, h_, mode=mode), cfg, mode)
    if isinstance(params["layers"], (list, tuple)):
        states = []
        for p in params["layers"]:
            x, st = run(p, x)
            x = _constrain(x)
            states.append(st)
        return x, states

    def body(h, layer_p):
        h, state = run(layer_p, h)
        return _constrain(h), state

    x, states = jax.lax.scan(body, x, params["layers"],
                             unroll=cfg.lower_unrolled)
    return x, states


def _zamba_stack(params, cfg, x, positions, *, mode: str):
    n_super, period, tail = _zamba_split(cfg)

    if isinstance(params["layers"], (list, tuple)):
        attn_caches, msts = [], []
        h = x
        for sup in params["layers"]:  # list over superblocks
            h, attn_cache, _ = blocks.dense_block(
                params["shared_attn"], cfg, h, mode=mode,
                positions=positions)
            sup_states = []
            for mp in sup:  # list over the period's mamba layers
                h, st = blocks.mamba_block(mp, cfg, h, mode=mode)
                sup_states.append(st)
            h = _constrain(h)
            attn_caches.append(attn_cache)
            msts.append(sup_states)
        tail_states = []
        for mp in params["tail"] if tail else []:
            h, st = blocks.mamba_block(mp, cfg, h, mode=mode)
            tail_states.append(st)
        return h, attn_caches, msts, tail_states

    def body(carry, xs):
        h = carry

        def run(xs_, shared_, h_):
            h_, attn_cache, _ = blocks.dense_block(
                shared_, cfg, h_, mode=mode, positions=positions)
            mamba_states = []
            for i in range(period):
                h_, st = blocks.mamba_block(_tree_index(xs_, i), cfg, h_,
                                            mode=mode)
                mamba_states.append(st)
            states = jax.tree.map(lambda *s: jnp.stack(s), *mamba_states) \
                if mamba_states and mamba_states[0] else {}
            return h_, attn_cache, states

        h, attn_cache, states = _maybe_remat(run, cfg, mode)(
            xs, params["shared_attn"], h)
        return _constrain(h), (attn_cache, states)

    x, (attn_kv, mstates) = jax.lax.scan(body, x, params["layers"],
                                         unroll=cfg.lower_unrolled)
    tail_states = []
    for i in range(tail):
        x, st = blocks.mamba_block(_tree_index(params["tail"], i), cfg, x,
                                   mode=mode)
        tail_states.append(st)
    return x, attn_kv, mstates, tail_states


def _encdec_stacks(params, cfg, batch, *, mode: str):
    frames = batch["frames"].astype(cfg.dtype)  # (B, S_enc, d) stub embeds
    B, S_enc, _ = frames.shape
    enc_pos = jnp.arange(S_enc)[None, :].repeat(B, 0)

    enc_run = _maybe_remat(
        lambda p_, h_: blocks.encoder_block(p_, cfg, h_, enc_pos), cfg, mode)
    if isinstance(params["enc_layers"], (list, tuple)):
        enc_out = frames
        for p in params["enc_layers"]:
            enc_out = _constrain(enc_run(p, enc_out))
    else:
        def enc_body(h, layer_p):
            return _constrain(enc_run(layer_p, h)), None

        enc_out, _ = jax.lax.scan(enc_body, frames, params["enc_layers"],
                                  unroll=cfg.lower_unrolled)
    enc_out = rms_norm(enc_out, params["enc_norm"], cfg.norm_eps)

    tok = jnp.take(params["embed"], batch["tokens"], axis=0)
    S_dec = tok.shape[1]
    dec_pos = jnp.arange(S_dec)[None, :].repeat(B, 0)

    def run(p_, h_):
        ekv = blocks.encoder_cross_kv(p_, cfg, enc_out)
        h2, cache = blocks.decoder_block(p_, cfg, h_, ekv, mode=mode,
                                         positions=dec_pos)
        cache = dict(cache, ck=ekv[0], cv=ekv[1]) \
            if mode == "prefill" else cache
        return h2, cache

    dec_run = _maybe_remat(run, cfg, mode)
    if isinstance(params["layers"], (list, tuple)):
        x = tok
        caches = []
        for p in params["layers"]:
            x, cache = dec_run(p, x)
            x = _constrain(x)
            caches.append(cache)
        return x, caches

    def dec_body(h, layer_p):
        h, cache = dec_run(layer_p, h)
        return _constrain(h), cache

    x, caches = jax.lax.scan(dec_body, tok, params["layers"],
                             unroll=cfg.lower_unrolled)
    return x, caches


def forward(params: Params, cfg: ModelConfig,
            batch: Dict) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence logits. Returns (logits, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "audio":
        x, _ = _encdec_stacks(params, cfg, batch, mode="train")
    elif cfg.family == "ssm":
        x_in, _, _ = _embed(params, cfg, batch)
        x, _ = _rwkv_stack(params, cfg, x_in, mode="train")
    elif cfg.family == "hybrid":
        x_in, positions, _ = _embed(params, cfg, batch)
        x, _, _, _ = _zamba_stack(params, cfg, x_in, positions, mode="train")
    else:
        x_in, positions, n_front = _embed(params, cfg, batch)
        x, aux, _ = _dense_stack(params, cfg, x_in, positions, mode="train")
        if n_front:
            x = x[:, n_front:]
    return _head(params, cfg, x), aux


def loss_fn(params: Params, cfg: ModelConfig,
            batch: Dict) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, cfg, batch)
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
    mask = batch.get("mask")
    ce = cross_entropy_loss(logits, labels, mask)
    total = ce + cfg.router_aux_weight * aux
    return total, {"ce": ce, "aux": aux}


# ===========================================================================
# KV cache / recurrent state
# ===========================================================================
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    fam = cfg.family
    cache: Dict[str, Any] = {"len": jnp.zeros((batch,), jnp.int32)}
    if fam in ("dense", "vlm", "moe"):
        # head-major KV layout (B, Hkv, S, hd): both decode einsums contract
        # without layout transposes (§Perf #3)
        kv_dtype = jnp.int8 if cfg.kv_cache_bits == 8 else cfg.dtype
        cache["k"] = jnp.zeros((L, batch, cfg.num_kv_heads, max_seq, hd),
                               kv_dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
        if cfg.kv_cache_bits == 8:  # per-token per-head scales (paper §7)
            cache["k_scale"] = jnp.zeros(
                (L, batch, cfg.num_kv_heads, max_seq), jnp.float32)
            cache["v_scale"] = jnp.zeros_like(cache["k_scale"])
    elif fam == "ssm":
        H, P = ssm.rwkv_dims(cfg)
        cache["S"] = jnp.zeros((L, batch, H, P, P), jnp.float32)
        cache["x_tm"] = jnp.zeros((L, batch, cfg.d_model), cfg.dtype)
        cache["x_cm"] = jnp.zeros((L, batch, cfg.d_model), cfg.dtype)
    elif fam == "hybrid":
        n_super, period, tail = _zamba_split(cfg)
        d_inner, H, P, N = ssm.mamba_dims(cfg)
        conv_ch = d_inner + 2 * N
        cache["k"] = jnp.zeros(
            (n_super, batch, cfg.num_kv_heads, max_seq, hd), cfg.dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
        cache["h"] = jnp.zeros((n_super, period, batch, H, P, N), jnp.float32)
        cache["conv"] = jnp.zeros(
            (n_super, period, batch, cfg.ssm_conv - 1, conv_ch), cfg.dtype)
        if tail:
            cache["tail_h"] = jnp.zeros((tail, batch, H, P, N), jnp.float32)
            cache["tail_conv"] = jnp.zeros(
                (tail, batch, cfg.ssm_conv - 1, conv_ch), cfg.dtype)
    elif fam == "audio":
        cache["k"] = jnp.zeros((L, batch, cfg.num_kv_heads, max_seq, hd),
                               cfg.dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
        # cross KV sized by encoder length — filled at prefill; dry-run decode
        # supplies it via input_specs
        cache["ck"] = jnp.zeros((L, batch, cfg.num_kv_heads, 0, hd), cfg.dtype)
        cache["cv"] = jnp.zeros_like(cache["ck"])
    return cache


# ===========================================================================
# Prefill
# ===========================================================================
def prefill(params: Params, cfg: ModelConfig, batch: Dict,
            max_seq: int) -> Tuple[jax.Array, Dict]:
    """Run the prompt, return (last-position logits, filled cache)."""
    fam = cfg.family
    listed = isinstance(params["layers"], (list, tuple))
    B = batch["tokens"].shape[0]
    cache: Dict[str, Any] = {} if listed else init_cache(cfg, B, max_seq)
    if fam == "audio":
        x, caches = _encdec_stacks(params, cfg, batch, mode="prefill")
        S = x.shape[1]
        if listed:
            cache["k"] = [_pad_seq(_hm(c["k"]), max_seq, axis=2)
                          for c in caches]
            cache["v"] = [_pad_seq(_hm(c["v"]), max_seq, axis=2)
                          for c in caches]
            cache["ck"] = [_hm(c["ck"]) for c in caches]
            cache["cv"] = [_hm(c["cv"]) for c in caches]
        else:
            cache["k"] = _pad_seq(_hm(caches["k"], 2), max_seq, axis=3)
            cache["v"] = _pad_seq(_hm(caches["v"], 2), max_seq, axis=3)
            cache["ck"] = _hm(caches["ck"], 2)
            cache["cv"] = _hm(caches["cv"], 2)
        cache["len"] = jnp.full((x.shape[0],), S, jnp.int32)
    elif fam == "ssm":
        x_in, _, _ = _embed(params, cfg, batch)
        x, states = _rwkv_stack(params, cfg, x_in, mode="prefill")
        if listed:
            for key in states[0]:
                cache[key] = [s[key] for s in states]
        else:
            cache.update(states)
        cache["len"] = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    elif fam == "hybrid":
        x_in, positions, _ = _embed(params, cfg, batch)
        x, attn_kv, mstates, tail_states = _zamba_stack(
            params, cfg, x_in, positions, mode="prefill")
        if listed:
            cache["k"] = [_pad_seq(_hm(c["k"]), max_seq, axis=2)
                          for c in attn_kv]
            cache["v"] = [_pad_seq(_hm(c["v"]), max_seq, axis=2)
                          for c in attn_kv]
            cache["h"] = [[s["h"] for s in sup] for sup in mstates]
            cache["conv"] = [[s["conv"] for s in sup] for sup in mstates]
            if tail_states:
                cache["tail_h"] = [s["h"] for s in tail_states]
                cache["tail_conv"] = [s["conv"] for s in tail_states]
        else:
            cache["k"] = _pad_seq(_hm(attn_kv["k"], 2), max_seq, axis=3)
            cache["v"] = _pad_seq(_hm(attn_kv["v"], 2), max_seq, axis=3)
            cache["h"], cache["conv"] = mstates["h"], mstates["conv"]
            if tail_states:
                cache["tail_h"] = jnp.stack([s["h"] for s in tail_states])
                cache["tail_conv"] = jnp.stack(
                    [s["conv"] for s in tail_states])
        cache["len"] = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    else:
        x_in, positions, n_front = _embed(params, cfg, batch)
        x, aux, kv = _dense_stack(params, cfg, x_in, positions, mode="prefill")
        if listed:
            cache["k"] = [_pad_seq(_hm(c["k"]), max_seq, axis=2) for c in kv]
            cache["v"] = [_pad_seq(_hm(c["v"]), max_seq, axis=2) for c in kv]
            if cfg.kv_cache_bits == 8:
                from repro.models import kv_quant
                kq = [kv_quant.quantize_kv(k) for k in cache["k"]]
                vq = [kv_quant.quantize_kv(v) for v in cache["v"]]
                cache["k"] = [a for a, _ in kq]
                cache["k_scale"] = [b for _, b in kq]
                cache["v"] = [a for a, _ in vq]
                cache["v_scale"] = [b for _, b in vq]
        else:
            cache["k"] = _pad_seq(_hm(kv["k"], 2), max_seq, axis=3)
            cache["v"] = _pad_seq(_hm(kv["v"], 2), max_seq, axis=3)
            if cfg.kv_cache_bits == 8:
                from repro.models import kv_quant
                cache["k"], cache["k_scale"] = kv_quant.quantize_kv(
                    cache["k"])
                cache["v"], cache["v_scale"] = kv_quant.quantize_kv(
                    cache["v"])
        cache["len"] = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    logits = _head(params, cfg, x[:, -1])
    return logits, cache


def prefill_suffix(params: Params, cfg: ModelConfig, batch: Dict,
                   k_prefix: jax.Array, v_prefix: jax.Array
                   ) -> Tuple[jax.Array, Dict]:
    """Prefix-cached prefill: run only a prompt's unshared SUFFIX, with the
    shared prefix's KV supplied from the paged pool — the prefix-sharing
    engine's prefill-skip path (matched blocks are never recomputed).

    batch["tokens"]: (B, S_suf) suffix tokens; k_prefix/v_prefix:
    HEAD-MAJOR (L, B, Hkv, P, hd) — the pool layout
    ``PagedKVCache.gather_prefix`` returns. Suffix queries attend over
    concat(prefix, suffix) keys at global positions, so hidden states,
    suffix KV, and last-position logits are BIT-IDENTICAL to the
    corresponding slice of a full :func:`prefill` over prefix+suffix
    (see ``attention_forward``). Returns (last-position logits,
    {"k", "v", "len"}) with SUFFIX-ONLY head-major KV (L, B, Hkv, S_suf,
    hd) and len = P + S_suf. Dense/vlm/moe stacked-layer stacks only (the
    serving engines' families)."""
    if cfg.family not in ("dense", "vlm", "moe"):
        raise ValueError("prefix-cached prefill serves KV-cache dense "
                         f"stacks; got family={cfg.family}")
    if isinstance(params["layers"], (list, tuple)):
        raise ValueError("prefix-cached prefill requires stacked layer "
                         "params (per-layer buffer layout is the dry-run "
                         "path)")
    P = k_prefix.shape[3]
    x, positions, _ = _embed(params, cfg, batch)
    positions = positions + P           # suffix tokens sit at P + i
    pair = 2 if cfg.local_global else 1
    layers, kp, vp = params["layers"], k_prefix, v_prefix
    if pair == 2:
        layers, kp, vp = jax.tree.map(
            lambda a: a.reshape((a.shape[0] // 2, 2) + a.shape[1:]),
            (layers, kp, vp))

    def body(carry, xs):
        h, aux = carry
        layer_p, kp_l, vp_l = xs
        caches = []
        for j in range(pair):
            p = _tree_index(layer_p, j) if pair == 2 else layer_p
            is_local = (j == 0) if cfg.local_global else False
            h, c, a = blocks.dense_block(
                p, cfg, h, mode="prefill", positions=positions,
                is_local=is_local,
                prefix_kv=(kp_l[j] if pair == 2 else kp_l,
                           vp_l[j] if pair == 2 else vp_l))
            caches.append(c)
            aux = aux + a
        ys = jax.tree.map(lambda *c: jnp.stack(c), *caches) if pair == 2 \
            else caches[0]
        return (h, aux), ys

    (x, _), kv = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                              (layers, kp, vp), unroll=cfg.lower_unrolled)
    if pair == 2:
        kv = jax.tree.map(
            lambda a: a.reshape((a.shape[0] * 2,) + a.shape[2:]), kv)
    cache = {"k": _hm(kv["k"], 2), "v": _hm(kv["v"], 2),
             "len": jnp.full((x.shape[0],), P + x.shape[1], jnp.int32)}
    return _head(params, cfg, x[:, -1]), cache


def prefill_chunk(params: Params, cfg: ModelConfig, batch: Dict,
                  k_pool: jax.Array, v_pool: jax.Array,
                  prefix_blocks: jax.Array, *, backend: str = "jnp",
                  k_scale_pool: Optional[jax.Array] = None,
                  v_scale_pool: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, Dict]:
    """Chunked paged prefill: run ONE block-aligned chunk of a prompt, its
    queries attending over the ALREADY-WRITTEN pool blocks plus the
    in-chunk causal mask — the generalisation of :func:`prefill_suffix`
    where the prefix context stays paged (and may be empty: an all-zero-
    block ``prefix_blocks`` of shape (0,) is the first chunk of a fresh
    prompt, equivalent to a plain :func:`prefill` over the chunk).

    batch["tokens"]: (1, C) — the chunk's tokens (B must be 1, the serving
    prefill shape); k_pool/v_pool: HEAD-MAJOR (L, Hkv, num_blocks, bs, hd)
    — the PagedKVCache pools by reference; prefix_blocks: (nb,) int32 pool
    ids of this sequence's first nb blocks, all fully written
    (P = nb·bs tokens). Chunk queries sit at global positions [P, P+C).

    On the jnp backend each layer gathers its own prefix slice dense (peak
    context slab O(P) for ONE layer, not L·P) and runs the same blockwise
    scan as a one-shot prefill, so hidden states, chunk KV, and
    last-position logits are BIT-IDENTICAL to the corresponding slice of a
    full :func:`prefill` over prefix+chunk; ``backend="pallas"`` streams
    the prefix straight from the pool (no densify — see
    ``kernels/paged_prefill_attention.py``). Returns (last-position logits,
    {"k", "v", "len"}) with CHUNK-ONLY head-major KV (L, 1, Hkv, C, hd) and
    len = P + C — the slab ``PagedKVCache.write_prefill_chunk`` scatters.

    Dense/vlm/moe stacked-layer stacks only. NOTE: for MoE families the
    chunk boundary changes capacity-dispatch groups, so chunked outputs are
    NOT bit-stable against the one-shot prefill — the serving engine runs
    MoE prompts one-shot (same reason prefix sharing recomputes them).

    k_scale_pool/v_scale_pool: the int8 pool's fp32 scale sidecars
    (L, Hkv, num_blocks, bs), threaded per layer next to the value pools
    (int8 readback makes chunked outputs quantization-, not chunking-,
    dependent; chunked-vs-oneshot bit-stability is a bf16-pool contract)."""
    if cfg.family not in ("dense", "vlm", "moe"):
        raise ValueError("chunked paged prefill serves KV-cache dense "
                         f"stacks; got family={cfg.family}")
    if isinstance(params["layers"], (list, tuple)):
        raise ValueError("chunked paged prefill requires stacked layer "
                         "params (per-layer buffer layout is the dry-run "
                         "path)")
    if batch["tokens"].shape[0] != 1:
        raise ValueError("chunked paged prefill is per-request (B == 1); "
                         f"got B={batch['tokens'].shape[0]}")
    bs = k_pool.shape[3]
    P = prefix_blocks.shape[0] * bs
    x, positions, _ = _embed(params, cfg, batch)
    positions = positions + P           # chunk tokens sit at P + i
    pair = 2 if cfg.local_global else 1
    quant = k_scale_pool is not None
    # 5-tuple scan xs either way (dummy per-layer zeros when bf16) so the
    # scan tree structure is kv_dtype-independent
    ks_, vs_ = (k_scale_pool, v_scale_pool) if quant else (
        jnp.zeros((k_pool.shape[0],)), jnp.zeros((k_pool.shape[0],)))
    layers, kp, vp = params["layers"], k_pool, v_pool
    if pair == 2:
        layers, kp, vp, ks_, vs_ = jax.tree.map(
            lambda a: a.reshape((a.shape[0] // 2, 2) + a.shape[1:]),
            (layers, kp, vp, ks_, vs_))

    def body(carry, xs):
        h, aux = carry
        layer_p, kp_l, vp_l, ks_l, vs_l = xs
        caches = []
        for j in range(pair):
            p = _tree_index(layer_p, j) if pair == 2 else layer_p
            is_local = (j == 0) if cfg.local_global else False
            scales = None
            if quant:
                scales = (ks_l[j] if pair == 2 else ks_l,
                          vs_l[j] if pair == 2 else vs_l)
            h, c, a = blocks.dense_block(
                p, cfg, h, mode="prefill", positions=positions,
                is_local=is_local, backend=backend,
                paged_prefix=(kp_l[j] if pair == 2 else kp_l,
                              vp_l[j] if pair == 2 else vp_l,
                              prefix_blocks),
                paged_prefix_scales=scales)
            caches.append(c)
            aux = aux + a
        ys = jax.tree.map(lambda *c: jnp.stack(c), *caches) if pair == 2 \
            else caches[0]
        return (h, aux), ys

    (x, _), kv = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                              (layers, kp, vp, ks_, vs_),
                              unroll=cfg.lower_unrolled)
    if pair == 2:
        kv = jax.tree.map(
            lambda a: a.reshape((a.shape[0] * 2,) + a.shape[2:]), kv)
    cache = {"k": _hm(kv["k"], 2), "v": _hm(kv["v"], 2),
             "len": jnp.full((x.shape[0],), P + x.shape[1], jnp.int32)}
    return _head(params, cfg, x[:, -1]), cache


def _hm(kv: jax.Array, seq_axis: int = 1) -> jax.Array:
    """(…, S, Hkv, hd) -> head-major (…, Hkv, S, hd)."""
    return jnp.swapaxes(kv, seq_axis, seq_axis + 1)


def _pad_seq(kv: jax.Array, max_seq: int, axis: int = 2) -> jax.Array:
    """Pad/trim the sequence axis to max_seq (axis=2 for stacked (L,B,S,..),
    axis=1 for per-layer (B,S,..) buffers)."""
    S = kv.shape[axis]
    if S >= max_seq:
        idx = [slice(None)] * kv.ndim
        idx[axis] = slice(0, max_seq)
        return kv[tuple(idx)]
    pad = [(0, 0)] * kv.ndim
    pad[axis] = (0, max_seq - S)
    return jnp.pad(kv, pad)


# ===========================================================================
# Decode step (the paper's target phase)
# ===========================================================================
def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                cache: Dict, *, backend: str = "jnp",
                moe_group_size: int = 256) -> Tuple[jax.Array, Dict]:
    """One decoding iteration. tokens: (B,) int32 — the freshly sampled token.

    cache["len"] = tokens ALREADY stored (the new token is not in the cache);
    attention is combine(prefix partial, new-token partial) per §4.2.2.
    Returns (logits, updates): updates carries k_new/v_new (L, B, Hkv, hd)
    plus refreshed recurrent states and len+1 — KV *placement* is the memory
    pool's job (serving/kvcache.py) or apply_decode_updates for simple loops.
    """
    cur_len = cache["len"]
    new_len = cur_len + 1
    x = jnp.take(params["embed"], tokens[:, None], axis=0)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(jnp.sqrt(float(cfg.d_model)), x.dtype)
    fam = cfg.family
    # read-only buffers (prefix KV, cross KV) stay out of the outputs — the
    # memory pool owns them; only per-step updates flow back
    new_cache = {k: v for k, v in cache.items()
                 if k not in ("k", "v", "ck", "cv", "k_scale", "v_scale")}
    new_cache["len"] = new_len

    if isinstance(params["layers"], (list, tuple)):
        return _decode_step_listed(params, cfg, x, cache, cur_len, new_cache,
                                   backend=backend,
                                   moe_group_size=moe_group_size)

    if fam in ("dense", "vlm", "moe"):
        pair = 2 if cfg.local_global else 1
        layers = params["layers"]
        quant = cfg.kv_cache_bits == 8
        kc, vc = cache["k"], cache["v"]
        ks_, vs_ = (cache["k_scale"], cache["v_scale"]) if quant else \
            (jnp.zeros((kc.shape[0],)),) * 2
        if pair == 2:
            layers, kc, vc, ks_, vs_ = jax.tree.map(
                lambda a: a.reshape((a.shape[0] // 2, 2) + a.shape[1:]),
                (layers, kc, vc, ks_, vs_))

        def body(carry, xs):
            h, aux = carry
            layer_p, k_l, v_l, ks_l, vs_l = xs
            new_kv = []
            for j in range(pair):
                p = _tree_index(layer_p, j) if pair == 2 else layer_p
                kj = k_l[j] if pair == 2 else k_l
                vj = v_l[j] if pair == 2 else v_l
                lc = {"k": kj, "v": vj, "len": cur_len}
                if quant:
                    lc["k_scale"] = ks_l[j] if pair == 2 else ks_l
                    lc["v_scale"] = vs_l[j] if pair == 2 else vs_l
                is_local = (j == 0) if cfg.local_global else False
                h, c, a = blocks.dense_block(
                    p, cfg, h, mode="decode", is_local=is_local,
                    cache=lc, backend=backend,
                    moe_group_size=moe_group_size)
                new_kv.append(c)
                aux = aux + a
            ys = jax.tree.map(lambda *c: jnp.stack(c), *new_kv) if pair == 2 \
                else new_kv[0]
            return (h, aux), ys

        (x, _), kv = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                  (layers, kc, vc, ks_, vs_),
                                  unroll=cfg.lower_unrolled)
        if pair == 2:
            kv = jax.tree.map(
                lambda a: a.reshape((a.shape[0] * 2,) + a.shape[2:]), kv)
        new_cache["k_new"], new_cache["v_new"] = kv["k_new"], kv["v_new"]

    elif fam == "ssm":
        def body(h, xs):
            layer_p, st = xs
            h, new_st = blocks.rwkv_block(layer_p, cfg, h, mode="decode",
                                          state=st)
            return h, new_st

        states = {k: cache[k] for k in ("S", "x_tm", "x_cm")}
        x, new_states = jax.lax.scan(body, x, (params["layers"], states),
                                     unroll=cfg.lower_unrolled)
        new_cache.update(new_states)

    elif fam == "hybrid":
        n_super, period, tail = _zamba_split(cfg)

        def body(h, xs):
            layer_p, k_l, v_l, h_l, conv_l = xs
            h_x, attn_c, _ = blocks.dense_block(
                params["shared_attn"], cfg, h, mode="decode",
                cache={"k": k_l, "v": v_l, "len": cur_len}, backend=backend)
            h = h_x
            new_h, new_conv = [], []
            for i in range(period):
                h, st = blocks.mamba_block(
                    _tree_index(layer_p, i), cfg, h, mode="decode",
                    state={"h": h_l[i], "conv": conv_l[i]})
                new_h.append(st["h"])
                new_conv.append(st["conv"])
            return h, (attn_c["k_new"], attn_c["v_new"], jnp.stack(new_h),
                       jnp.stack(new_conv))

        x, (nk, nv, nh, nconv) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"], cache["h"],
                      cache["conv"]), unroll=cfg.lower_unrolled)
        new_cache.update({"k_new": nk, "v_new": nv, "h": nh, "conv": nconv})
        new_tail_h, new_tail_conv = [], []
        for i in range(tail):
            x, st = blocks.mamba_block(
                _tree_index(params["tail"], i), cfg, x, mode="decode",
                state={"h": cache["tail_h"][i], "conv": cache["tail_conv"][i]})
            new_tail_h.append(st["h"])
            new_tail_conv.append(st["conv"])
        if tail:
            new_cache["tail_h"] = jnp.stack(new_tail_h)
            new_cache["tail_conv"] = jnp.stack(new_tail_conv)

    elif fam == "audio":
        def body(h, xs):
            layer_p, k_l, v_l, ck_l, cv_l = xs
            h, c = blocks.decoder_block(
                layer_p, cfg, h, (ck_l, cv_l), mode="decode",
                cache={"k": k_l, "v": v_l, "len": cur_len}, backend=backend)
            return h, (c["k_new"], c["v_new"])

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"], cache["ck"],
                      cache["cv"]), unroll=cfg.lower_unrolled)
        new_cache["k_new"], new_cache["v_new"] = nk, nv
    else:
        raise ValueError(fam)

    logits = _head(params, cfg, x[:, 0])
    return logits, new_cache


def decode_step_paged(params: Params, cfg: ModelConfig, tokens: jax.Array,
                      k_pool: jax.Array, v_pool: jax.Array,
                      block_tables: jax.Array, cache_len: jax.Array, *,
                      backend: str = "jnp",
                      k_scale_pool: Optional[jax.Array] = None,
                      v_scale_pool: Optional[jax.Array] = None,
                      moe_group_size: int = 256) -> Tuple[jax.Array, Dict]:
    """One decoding iteration straight over the paged KV block pool — the
    serving engines' default hot path (no per-step dense gather/transposes).

    tokens: (B,) int32; k_pool/v_pool: HEAD-MAJOR (L, Hkv, num_blocks,
    block_size, hd) — the PagedKVCache pools passed by reference;
    block_tables: (B, nb) int32; cache_len: (B,) tokens ALREADY stored.
    Returns (logits, updates) with k_new/v_new (L, B, Hkv, hd) — placement
    stays the memory pool's job (PagedKVCache.write_tokens).

    k_scale_pool/v_scale_pool: the int8 pool's fp32 per-token scale sidecars
    (L, Hkv, num_blocks, block_size), threaded per layer next to the value
    pools so dequantization fuses into the attention kernels (no dense
    dequantized slab on this path — the tentpole invariant).
    """
    if cfg.family not in ("dense", "vlm", "moe"):
        raise ValueError("paged decode serves KV-cache dense stacks; "
                         f"got family={cfg.family}")
    if isinstance(params["layers"], (list, tuple)):
        raise ValueError("paged decode requires stacked layer params "
                         "(per-layer buffer layout uses the dense path)")
    cur_len = cache_len
    x = jnp.take(params["embed"], tokens[:, None], axis=0)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(jnp.sqrt(float(cfg.d_model)), x.dtype)

    pair = 2 if cfg.local_global else 1
    quant = k_scale_pool is not None
    # the scan xs keep a 5-tuple structure either way (dummy per-layer
    # zeros when bf16) so chunked/unchunked programs share one tree shape
    ks_, vs_ = (k_scale_pool, v_scale_pool) if quant else (
        jnp.zeros((k_pool.shape[0],)), jnp.zeros((k_pool.shape[0],)))
    layers, kp, vp = params["layers"], k_pool, v_pool
    if pair == 2:
        layers, kp, vp, ks_, vs_ = jax.tree.map(
            lambda a: a.reshape((a.shape[0] // 2, 2) + a.shape[1:]),
            (layers, kp, vp, ks_, vs_))

    def body(carry, xs):
        h, aux = carry
        layer_p, kp_l, vp_l, ks_l, vs_l = xs
        new_kv = []
        for j in range(pair):
            p = _tree_index(layer_p, j) if pair == 2 else layer_p
            lc = {"k_pool": kp_l[j] if pair == 2 else kp_l,
                  "v_pool": vp_l[j] if pair == 2 else vp_l,
                  "block_tables": block_tables, "len": cur_len}
            if quant:
                lc["k_scale_pool"] = ks_l[j] if pair == 2 else ks_l
                lc["v_scale_pool"] = vs_l[j] if pair == 2 else vs_l
            is_local = (j == 0) if cfg.local_global else False
            h, c, a = blocks.dense_block(
                p, cfg, h, mode="decode", is_local=is_local, cache=lc,
                backend=backend, moe_group_size=moe_group_size)
            new_kv.append(c)
            aux = aux + a
        ys = jax.tree.map(lambda *c: jnp.stack(c), *new_kv) if pair == 2 \
            else new_kv[0]
        return (h, aux), ys

    (x, _), kv = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                              (layers, kp, vp, ks_, vs_),
                              unroll=cfg.lower_unrolled)
    if pair == 2:
        kv = jax.tree.map(
            lambda a: a.reshape((a.shape[0] * 2,) + a.shape[2:]), kv)
    updates = {"k_new": kv["k_new"], "v_new": kv["v_new"],
               "len": cur_len + 1}
    logits = _head(params, cfg, x[:, 0])
    return logits, updates


def _decode_step_listed(params, cfg: ModelConfig, x, cache, cur_len,
                        new_cache, *, backend: str, moe_group_size: int):
    """Decode with per-layer buffer layout (see _dense_stack docstring)."""
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        k_new, v_new = [], []
        for i, p in enumerate(params["layers"]):
            is_local = (i % 2 == 0) if cfg.local_global else False
            lc = {"k": cache["k"][i], "v": cache["v"][i], "len": cur_len}
            if cfg.kv_cache_bits == 8:
                lc["k_scale"] = cache["k_scale"][i]
                lc["v_scale"] = cache["v_scale"][i]
            x, c, _ = blocks.dense_block(
                p, cfg, x, mode="decode", is_local=is_local,
                cache=lc, backend=backend, moe_group_size=moe_group_size)
            k_new.append(c["k_new"])
            v_new.append(c["v_new"])
        new_cache["k_new"], new_cache["v_new"] = k_new, v_new
    elif fam == "ssm":
        states = []
        for i, p in enumerate(params["layers"]):
            st = {key: cache[key][i] for key in ("S", "x_tm", "x_cm")}
            x, new_st = blocks.rwkv_block(p, cfg, x, mode="decode", state=st)
            states.append(new_st)
        for key in ("S", "x_tm", "x_cm"):
            new_cache[key] = [s[key] for s in states]
    elif fam == "hybrid":
        n_super, period, tail = _zamba_split(cfg)
        k_new, v_new, hs, convs = [], [], [], []
        for si, sup in enumerate(params["layers"]):
            x, c, _ = blocks.dense_block(
                params["shared_attn"], cfg, x, mode="decode",
                cache={"k": cache["k"][si], "v": cache["v"][si],
                       "len": cur_len}, backend=backend)
            k_new.append(c["k_new"])
            v_new.append(c["v_new"])
            sup_h, sup_conv = [], []
            for mi, mp in enumerate(sup):
                x, st = blocks.mamba_block(
                    mp, cfg, x, mode="decode",
                    state={"h": cache["h"][si][mi],
                           "conv": cache["conv"][si][mi]})
                sup_h.append(st["h"])
                sup_conv.append(st["conv"])
            hs.append(sup_h)
            convs.append(sup_conv)
        new_cache.update({"k_new": k_new, "v_new": v_new, "h": hs,
                          "conv": convs})
        tail_h, tail_conv = [], []
        for i, mp in enumerate(params.get("tail", []) if tail else []):
            x, st = blocks.mamba_block(
                mp, cfg, x, mode="decode",
                state={"h": cache["tail_h"][i],
                       "conv": cache["tail_conv"][i]})
            tail_h.append(st["h"])
            tail_conv.append(st["conv"])
        if tail:
            new_cache["tail_h"], new_cache["tail_conv"] = tail_h, tail_conv
    elif fam == "audio":
        k_new, v_new = [], []
        for i, p in enumerate(params["layers"]):
            x, c = blocks.decoder_block(
                p, cfg, x, (cache["ck"][i], cache["cv"][i]), mode="decode",
                cache={"k": cache["k"][i], "v": cache["v"][i],
                       "len": cur_len}, backend=backend)
            k_new.append(c["k_new"])
            v_new.append(c["v_new"])
        new_cache["k_new"], new_cache["v_new"] = k_new, v_new
    else:
        raise ValueError(fam)
    logits = _head(params, cfg, x[:, 0])
    return logits, new_cache


def apply_decode_updates(cache: Dict, updates: Dict) -> Dict:
    """Write the step's k_new/v_new into the dense cache at the old length
    and adopt refreshed recurrent state — the host-side placement used by
    simple generation loops and tests (serving engines use the paged pool)."""
    new_cache = dict(cache)
    if "k_new" in updates:
        B = updates["k_new"].shape[1]
        idx = cache["len"]  # position of the token just processed
        b = jnp.arange(B)
        # head-major cache (L, B, Hkv, S, hd): write one S-position per seq
        kn = jnp.swapaxes(updates["k_new"], 0, 1)  # (B, L, Hkv, hd)
        vn = jnp.swapaxes(updates["v_new"], 0, 1)
        if cache["k"].dtype == jnp.int8:
            from repro.models import kv_quant
            kn, kns = kv_quant.quantize_token(kn)
            vn, vns = kv_quant.quantize_token(vn)
            new_cache["k_scale"] = cache["k_scale"].at[:, b, :, idx].set(kns)
            new_cache["v_scale"] = cache["v_scale"].at[:, b, :, idx].set(vns)
        new_cache["k"] = cache["k"].at[:, b, :, idx].set(kn)
        new_cache["v"] = cache["v"].at[:, b, :, idx].set(vn)
    for key, val in updates.items():
        if key not in ("k_new", "v_new"):
            new_cache[key] = val
    return new_cache
