"""GQA attention: blockwise (flash-style) training/prefill path and a cached
single-token decode path.

The blockwise path carries running ``(max, denom, acc)`` statistics across KV
chunks — the same partial-softmax combine identity the paper exploits in
§4.2.2 (``core/combine.py``) and that the Pallas decode kernel uses on-chip.
Supports: causal masking, sliding windows (gemma2 local layers, llama3
sliding-window variant) and attention-logit soft-capping.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype=None) -> Dict[str, jax.Array]:
    dtype = dtype or cfg.dtype
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    params = {
        "wq": dense_init(kq, (cfg.d_model, cfg.num_heads, hd), dtype),
        "wk": dense_init(kk, (cfg.d_model, cfg.num_kv_heads, hd), dtype),
        "wv": dense_init(kv, (cfg.d_model, cfg.num_kv_heads, hd), dtype),
        "wo": dense_init(ko, (cfg.num_heads, hd, cfg.d_model), dtype),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.zeros((hd,), dtype)
        params["k_norm"] = jnp.zeros((hd,), dtype)
    return params


def qkv_project(params, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,Hkv,hd) with RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "q_norm" in params:
        from repro.models.common import rms_norm
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_project(params, attn_out: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", attn_out, params["wo"])


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention over a full sequence
# ---------------------------------------------------------------------------
def _expand_kv(k: jax.Array, group: int) -> jax.Array:
    """(B,S,Hkv,hd) -> (B,S,H,hd) by repeating each KV head `group` times."""
    if group == 1:
        return k
    return jnp.repeat(k, group, axis=2)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    sliding_window: int = 0,
    attention_sinks: int = 0,
    logit_softcap: float = 0.0,
    q_positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    block_size: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Memory-O(S·block) attention via lax.scan over KV blocks.

    q: (B, Sq, H, hd); k, v: (B, Skv, Hkv, hd). Returns (B, Sq, H, hd).
    Uses the running-softmax combine: for each new KV block the partial
    numerator/denominator are merged exactly as in core/combine.py.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    group = H // k.shape[2]
    k = _expand_kv(k, group)
    v = _expand_kv(v, group)
    if q_positions is None:
        q_positions = jnp.arange(Sq)[None, :].repeat(B, 0)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv)[None, :].repeat(B, 0)

    nb = -(-Skv // block_size)
    pad = nb * block_size - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=-1)
    k = k.reshape(B, nb, block_size, H, hd).transpose(1, 0, 2, 3, 4)
    v = v.reshape(B, nb, block_size, H, hd).transpose(1, 0, 2, 3, 4)
    kv_positions = kv_positions.reshape(B, nb, block_size).transpose(1, 0, 2)

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = q.astype(jnp.float32) * scale

    def step(carry, blk):
        m, l, acc = carry
        kb, vb, posb = blk  # (B, bs, H, hd), (B, bs)
        s = jnp.einsum("bqhk,bjhk->bhqj", qf, kb.astype(jnp.float32))
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        valid = posb[:, None, None, :] >= 0  # (B,1,1,bs)
        if causal:
            valid &= posb[:, None, None, :] <= q_positions[:, None, :, None]
        if sliding_window > 0:
            in_window = posb[:, None, None, :] > (
                q_positions[:, None, :, None] - sliding_window)
            if attention_sinks > 0:  # StreamingLLM: sinks stay attendable
                in_window |= posb[:, None, None, :] < attention_sinks
            valid &= in_window
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # rescale previous partials to the new max (combine identity)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqj,bjhk->bhqk", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    acc0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                  (k, v, kv_positions), unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, Sq, H, hd)


# ---------------------------------------------------------------------------
# Single-token decode with KV cache
# ---------------------------------------------------------------------------
# Backends compute the PARTIAL triple (a, s, m) over the *cached* tokens only
# (positions [0, cache_len)); the new token's k/v never touch the cache
# inside the step — its 1-token partial is merged with the paper-§4.2.2
# combine identity. This keeps the lowered serve_step free of cross-shard
# scatters into the sequence-sharded cache (which force involuntary full
# rematerialisation in GSPMD) and mirrors Lamina's ownership split: the
# memory pool places KV, the model program only reads it.
# 'jnp' is the oracle backend; 'pallas' (repro/kernels/ops.py) the TPU kernel.
# Each name has two registrations: the dense-cache partial (B, Hkv, S, hd)
# and the PAGED partial that attends over the serving engines' block pool
# (Hkv, num_blocks, block_size, hd) through a (B, nb) block table — the
# default decode hot path (no per-step dense gather).
_DECODE_BACKENDS = {}
_PAGED_DECODE_BACKENDS = {}


def register_decode_backend(name: str, fn) -> None:
    _DECODE_BACKENDS[name] = fn


def register_paged_decode_backend(name: str, fn) -> None:
    _PAGED_DECODE_BACKENDS[name] = fn


def decode_attention_partial_jnp(q, k_cache, v_cache, cache_len, *,
                                 sliding_window: int = 0,
                                 attention_sinks: int = 0,
                                 logit_softcap: float = 0.0,
                                 k_scale=None, v_scale=None,
                                 positions=None, window_total=None):
    """Partial attention over the cached prefix.

    q: (B, H, hd) (RoPE applied); caches: HEAD-MAJOR (B, Hkv, S, hd);
    cache_len: (B,) = number of tokens stored (the new token is NOT there).
    Window masks are computed w.r.t. total length cache_len + 1.
    Returns core.combine.Partial with fields shaped (B, H, hd)/(B, H).

    positions: optional (B, S) global sequence position per cache slot —
    block-sharded callers hold a NON-CONTIGUOUS subset of the sequence, so
    slot index ≠ position (foreign slots carry the POS_PAD sentinel and mask
    out). window_total: optional (B,) total length the sliding window is
    anchored to (defaults to cache_len + 1, the serving contract; the
    shard_map backends anchor to cache_len to match the dense oracle).

    §Perf iterations 1+3: the einsums contract the head-major cache in its
    native layout with fp32 accumulation via preferred_element_type — no
    cache-sized transposes/copies (XLA materialised four of them per layer
    in the original (B,S,Hkv,hd) layout) and no materialised fp32 KV cast.
    See EXPERIMENTS.md §Perf.
    """
    from repro.core import combine as C

    B, H, hd = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    kc = k_cache.astype(q.dtype) if k_cache.dtype == jnp.int8 else k_cache
    s = jnp.einsum("bhgk,bhsk->bhgs", (qg.astype(jnp.float32) * scale
                                       ).astype(q.dtype), kc,
                   preferred_element_type=jnp.float32)  # (B, Hkv, G, S) f32
    if k_scale is not None:  # int8 KV: fold per-token scales into scores
        s = s * k_scale[:, :, None, :]
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    pos = jnp.arange(S)[None, :] if positions is None else positions
    total = cache_len + 1 if window_total is None else window_total
    valid = pos < cache_len[:, None]
    if sliding_window > 0:
        in_window = pos >= (total[:, None] - sliding_window)
        if attention_sinks > 0:
            in_window |= pos < attention_sinks
        valid &= in_window
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe[..., None]), 0.0)
    denom = jnp.sum(p, axis=-1)
    if v_scale is not None:  # int8 KV: fold per-token scales into weights
        pv = (p * v_scale[:, :, None, :]).astype(q.dtype)
        vc = v_cache.astype(q.dtype)
    else:
        pv = p.astype(v_cache.dtype)
        vc = v_cache
    a = jnp.einsum("bhgs,bhsk->bhgk", pv, vc,
                   preferred_element_type=jnp.float32)
    return C.Partial(a=a.reshape(B, H, hd).astype(jnp.float32),
                     s=denom.reshape(B, H),
                     m=jnp.where(jnp.isfinite(m), m,
                                 -jnp.inf).reshape(B, H))


register_decode_backend("jnp", decode_attention_partial_jnp)


def paged_decode_attention_partial_jnp(q, k_pool, v_pool, block_tables,
                                       cache_len, *,
                                       k_scale=None, v_scale=None,
                                       sliding_window: int = 0,
                                       attention_sinks: int = 0,
                                       logit_softcap: float = 0.0):
    """Paged partial over the block pool — jnp reference path (CPU tests).

    q: (B, H, hd); pools HEAD-MAJOR (Hkv, num_blocks, block_size, hd);
    block_tables: (B, nb) int32; cache_len: (B,) stored tokens. Gathers the
    dense head-major view through the table (the copy the Pallas kernel
    avoids) and reuses the dense partial math, so 'jnp' and 'pallas' paged
    backends are bit-comparable. k_scale/v_scale: optional
    (Hkv, num_blocks, block_size) fp32 scale pools for int8 k_pool/v_pool —
    gathered through the same table and folded into the score/PV einsums
    (the dense reference may gather; only the kernels are bound by the
    no-dense-dequant invariant)."""
    from repro.kernels.paged_decode_attention import (paged_gather_dense,
                                                      paged_gather_scales)

    kc, vc = paged_gather_dense(k_pool, v_pool, block_tables)
    kw = {}
    if k_scale is not None:
        kw = {"k_scale": paged_gather_scales(k_scale, block_tables),
              "v_scale": paged_gather_scales(v_scale, block_tables)}
    return decode_attention_partial_jnp(
        q, kc, vc, cache_len, sliding_window=sliding_window,
        attention_sinks=attention_sinks, logit_softcap=logit_softcap, **kw)


register_paged_decode_backend("jnp", paged_decode_attention_partial_jnp)


def paged_decode_attention_partial_pos_jnp(q, k_pool, v_pool, block_tables,
                                           block_positions, cache_len, *,
                                           k_scale=None, v_scale=None,
                                           window_total=None,
                                           sliding_window: int = 0,
                                           attention_sinks: int = 0,
                                           logit_softcap: float = 0.0):
    """Positions-aware paged partial for BLOCK-SHARDED tables (jnp path).

    One shard of a cross-chip sequence split holds a non-contiguous subset of
    the sequence's blocks: block_tables (B, nb) are the shard's LOCAL pool
    ids and block_positions (B, nb) each slot's global base position (POS_PAD
    on slots the shard does not own, so they mask out entirely). A shard with
    zero live blocks yields the empty partial (s = 0, m = -inf) — the §4.2.2
    combine identity. window_total as in decode_attention_partial_jnp."""
    from repro.kernels.paged_decode_attention import paged_gather_dense

    B, nb = block_tables.shape
    bs = k_pool.shape[2]
    kc, vc = paged_gather_dense(k_pool, v_pool, block_tables)
    pos = (block_positions[:, :, None] +
           jnp.arange(bs, dtype=jnp.int32)[None, None, :]).reshape(B, nb * bs)
    kw = {}
    if k_scale is not None:
        from repro.kernels.paged_decode_attention import paged_gather_scales
        kw = {"k_scale": paged_gather_scales(k_scale, block_tables),
              "v_scale": paged_gather_scales(v_scale, block_tables)}
    return decode_attention_partial_jnp(
        q, kc, vc, cache_len, sliding_window=sliding_window,
        attention_sinks=attention_sinks, logit_softcap=logit_softcap,
        positions=pos, window_total=window_total, **kw)


def paged_decode_attention_partial_pos(q, k_pool, v_pool, block_tables,
                                       block_positions, cache_len, *,
                                       backend: str = "jnp",
                                       k_scale=None, v_scale=None,
                                       sliding_window: int = 0,
                                       attention_sinks: int = 0,
                                       logit_softcap: float = 0.0):
    """Backend dispatch for the positions-aware paged partial (serving
    contract: window anchored to cache_len + 1). 'pallas' streams the
    shard's pool slice through the paged kernel in place — no gather;
    'jnp' is the CPU gather reference. k_scale/v_scale: optional int8-pool
    scale pools, fused in-kernel on 'pallas' (no dense dequant)."""
    kw = dict(k_scale=k_scale, v_scale=v_scale,
              sliding_window=sliding_window, attention_sinks=attention_sinks,
              logit_softcap=logit_softcap)
    if backend == "pallas":
        from repro.kernels import ops
        return ops.pallas_paged_decode_partial_pos(
            q, k_pool, v_pool, block_tables, block_positions, cache_len, **kw)
    return paged_decode_attention_partial_pos_jnp(
        q, k_pool, v_pool, block_tables, block_positions, cache_len, **kw)


def _new_token_partial(q, k_new, v_new, *, logit_softcap: float = 0.0):
    """The freshly projected token's 1-token §4.2.2 partial (B, H, ·)."""
    from repro.core import combine as C

    B, H, hd = q.shape
    Hkv = k_new.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    p_new = C.partial_attention(qg, k_new[:, :, None, None],
                                v_new[:, :, None, None],
                                logit_softcap=logit_softcap)
    return C.Partial(a=p_new.a.reshape(B, H, hd),
                     s=p_new.s.reshape(B, H), m=p_new.m.reshape(B, H))


def paged_decode_attention_combine(q, k_pool, v_pool, block_tables,
                                   cache_len, k_new, v_new, *,
                                   backend: str = "jnp",
                                   k_scale=None, v_scale=None,
                                   sliding_window: int = 0,
                                   attention_sinks: int = 0,
                                   logit_softcap: float = 0.0) -> jax.Array:
    """Full paged decode attention = combine(pool partial, new-token partial).

    The pool is read in place through the block table — the decode step's KV
    traffic is exactly one pass over the live KV (paper §3's memory-bound
    operand) plus the wire-delivered k_new/v_new (B, Hkv, hd)."""
    from repro.core import combine as C

    if backend not in _PAGED_DECODE_BACKENDS and backend == "pallas":
        import repro.kernels.ops  # noqa: F401 — registers the kernel backend

    kw = {}
    if k_scale is not None:
        kw = {"k_scale": k_scale, "v_scale": v_scale}
    p_prev = _PAGED_DECODE_BACKENDS[backend](
        q, k_pool, v_pool, block_tables, cache_len,
        sliding_window=sliding_window, attention_sinks=attention_sinks,
        logit_softcap=logit_softcap, **kw)
    p_new = _new_token_partial(q, k_new, v_new, logit_softcap=logit_softcap)
    return C.finalize(C.combine(p_prev, p_new)).astype(q.dtype)


def decode_attention_combine(q, k_cache, v_cache, cache_len, k_new, v_new, *,
                             backend: str = "jnp", sliding_window: int = 0,
                             attention_sinks: int = 0,
                             logit_softcap: float = 0.0,
                             k_scale=None, v_scale=None) -> jax.Array:
    """Full decode attention = combine(prefix partial, new-token partial).

    k_new/v_new: (B, Hkv, hd) — the current token's keys/values."""
    from repro.core import combine as C

    if backend not in _DECODE_BACKENDS and backend == "pallas":
        import repro.kernels.ops  # noqa: F401 — registers the kernel backend

    kw = {}
    if k_scale is not None:
        kw = {"k_scale": k_scale, "v_scale": v_scale}
    p_prev = _DECODE_BACKENDS[backend](
        q, k_cache, v_cache, cache_len, sliding_window=sliding_window,
        attention_sinks=attention_sinks, logit_softcap=logit_softcap, **kw)
    p_new = _new_token_partial(q, k_new, v_new, logit_softcap=logit_softcap)
    return C.finalize(C.combine(p_prev, p_new)).astype(q.dtype)


def decode_attention_jnp(q, k_cache, v_cache, cache_len, *,
                         sliding_window: int = 0,
                         logit_softcap: float = 0.0) -> jax.Array:
    """Legacy oracle: cache ALREADY contains the new token at cache_len-1.
    Kept for kernel sweeps and the attention_parallel shard_map paths."""
    B, H, hd = q.shape
    S = k_cache.shape[1]
    group = H // k_cache.shape[2]
    kc = _expand_kv(k_cache, group).astype(jnp.float32)
    vc = _expand_kv(v_cache, group).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bhk,bjhk->bhj", q.astype(jnp.float32) * scale, kc)
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    pos = jnp.arange(S)[None, :]
    valid = pos < cache_len[:, None]
    if sliding_window > 0:
        valid &= pos >= (cache_len[:, None] - sliding_window)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhj,bjhk->bhk", p, vc)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Full layer entry points
# ---------------------------------------------------------------------------
def attention_forward(params, cfg: ModelConfig, x: jax.Array,
                      positions: jax.Array, *, is_local: bool = False,
                      block_size: int = 512,
                      prefix_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                      paged_prefix: Optional[Tuple[jax.Array, jax.Array,
                                                   jax.Array]] = None,
                      paged_prefix_scales: Optional[Tuple[jax.Array,
                                                          jax.Array]] = None,
                      backend: str = "jnp") -> jax.Array:
    """Full-sequence attention (train / prefill). x: (B, S, d).

    ``is_local`` is STATIC: alternating local/global stacks (gemma2) scan over
    layer *pairs* so each variant is traced once with its own static window.

    ``prefix_kv``: optional HEAD-MAJOR (B, Hkv, P, hd) K/V of an already-
    cached prompt prefix (the prefix-sharing suffix prefill). `x` then holds
    only the suffix tokens and `positions` their global positions (P + i);
    queries attend over concat(prefix, suffix) keys. Because every softmax
    row is computed over the same keys in the same scan order as a full
    prefill, suffix outputs are BIT-IDENTICAL to the corresponding rows of
    the unsliced prefill. Returned k/v cover the suffix only.

    ``paged_prefix``: the PAGED form of the same contract — this layer's
    ``(k_pool, v_pool, block_table)``: head-major pool slices
    (Hkv, num_blocks, bs, hd) plus the sequence's first ``nb`` block ids
    (P = nb·bs). Requires B == 1 (the serving prefill shape). With
    ``backend="pallas"`` the prefix is streamed straight from the pool
    (``ops.paged_prefill_chunk_attention`` — no dense gather); the jnp
    backend gathers this one layer's prefix dense (the reference copy) and
    falls into the ``prefix_kv`` concat path, staying bit-identical to the
    one-shot prefill. Mutually exclusive with ``prefix_kv``."""
    q, k, v = qkv_project(params, cfg, x, positions)
    window = cfg.sliding_window if (is_local or not cfg.local_global) else 0
    if paged_prefix is not None:
        assert prefix_kv is None, "pass prefix_kv OR paged_prefix, not both"
        if x.shape[0] != 1:
            raise ValueError("paged_prefix serves the per-request prefill "
                             f"shape (B == 1); got B={x.shape[0]}")
        kp_pool, vp_pool, table = paged_prefix
        ks_pool = vs_pool = None
        if paged_prefix_scales is not None:
            ks_pool, vs_pool = paged_prefix_scales
        if backend == "pallas":
            from repro.kernels import ops
            out = ops.paged_prefill_chunk_attention(
                q[0], kp_pool, vp_pool, table, k[0], v[0], backend="pallas",
                k_scale=ks_pool, v_scale=vs_pool,
                sliding_window=int(window),
                attention_sinks=cfg.attention_sinks if window else 0,
                logit_softcap=cfg.attn_logit_softcap)[None]
            return out_project(params, out), k, v
        Hkv, _, bs, hd = kp_pool.shape
        P = table.shape[0] * bs
        kp_d = kp_pool[:, table].reshape(Hkv, P, hd)
        vp_d = vp_pool[:, table].reshape(Hkv, P, hd)
        if ks_pool is not None:  # int8 pool: dequantize the gathered copy
            ks_d = ks_pool[:, table].reshape(Hkv, P)
            vs_d = vs_pool[:, table].reshape(Hkv, P)
            kp_d = (kp_d.astype(jnp.float32) * ks_d[..., None]).astype(k.dtype)
            vp_d = (vp_d.astype(jnp.float32) * vs_d[..., None]).astype(v.dtype)
        prefix_kv = (kp_d[None], vp_d[None])
    k_all, v_all = k, v
    if prefix_kv is not None:
        pk, pv = prefix_kv           # head-major -> seq-major for blockwise
        k_all = jnp.concatenate([jnp.swapaxes(pk, 1, 2), k], axis=1)
        v_all = jnp.concatenate([jnp.swapaxes(pv, 1, 2), v], axis=1)
    # unrolled lowering (roofline cost pass) uses larger KV blocks so the
    # fully-unrolled chunk count stays small
    if cfg.lower_unrolled:
        block_size = max(block_size, x.shape[1] // 8)
    out = blockwise_attention(
        q, k_all, v_all, causal=True, sliding_window=int(window),
        attention_sinks=cfg.attention_sinks if window else 0,
        logit_softcap=cfg.attn_logit_softcap, q_positions=positions,
        block_size=block_size, unroll=cfg.lower_unrolled)
    return out_project(params, out), k, v


def attention_decode_step(params, cfg: ModelConfig, x: jax.Array,
                          k_cache: jax.Array, v_cache: jax.Array,
                          cache_len: jax.Array, *, is_local: bool = False,
                          backend: str = "jnp", k_scale=None, v_scale=None):
    """One-token decode. x: (B, 1, d); cache_len = tokens ALREADY stored.

    Returns (y, k_new, v_new) with k_new/v_new: (B, Hkv, hd) — the caller
    (serving engine / memory pool) owns KV placement; the step itself never
    scatters into the sharded cache (see module docstring + DESIGN.md §3).
    ``is_local`` is STATIC (see attention_forward)."""
    positions = cache_len[:, None]  # new token position, 0-based
    q, k, v = qkv_project(params, cfg, x, positions)
    window = cfg.sliding_window if (is_local or not cfg.local_global) else 0
    out = decode_attention_combine(
        q[:, 0], k_cache, v_cache, cache_len, k[:, 0], v[:, 0],
        backend=backend, sliding_window=int(window),
        attention_sinks=cfg.attention_sinks if window else 0,
        logit_softcap=cfg.attn_logit_softcap,
        k_scale=k_scale, v_scale=v_scale)
    y = out_project(params, out[:, None])
    return y, k[:, 0], v[:, 0]


def attention_decode_step_paged(params, cfg: ModelConfig, x: jax.Array,
                                k_pool: jax.Array, v_pool: jax.Array,
                                block_tables: jax.Array,
                                cache_len: jax.Array, *,
                                is_local: bool = False,
                                backend: str = "jnp",
                                k_scale=None, v_scale=None):
    """One-token decode straight over the paged block pool (the serving hot
    path — no dense per-step gather). x: (B, 1, d); pools HEAD-MAJOR
    (Hkv, num_blocks, block_size, hd); block_tables (B, nb);
    cache_len = tokens ALREADY stored. Returns (y, k_new, v_new) — KV
    placement stays the memory pool's job (serving/kvcache.py)."""
    positions = cache_len[:, None]  # new token position, 0-based
    q, k, v = qkv_project(params, cfg, x, positions)
    window = cfg.sliding_window if (is_local or not cfg.local_global) else 0
    out = paged_decode_attention_combine(
        q[:, 0], k_pool, v_pool, block_tables, cache_len, k[:, 0], v[:, 0],
        backend=backend, k_scale=k_scale, v_scale=v_scale,
        sliding_window=int(window),
        attention_sinks=cfg.attention_sinks if window else 0,
        logit_softcap=cfg.attn_logit_softcap)
    y = out_project(params, out[:, None])
    return y, k[:, 0], v[:, 0]
