"""Attention-free sequence mixers: Mamba2 (SSD) and RWKV6 (Finch).

Both are implemented as (a) a full-sequence training/prefill path using
``lax.scan`` over time (hot-spot Pallas kernels in ``repro/kernels`` replace
the inner recurrence where perf-critical), and (b) an O(1)-state single-token
decode step. State pytrees are head-sharded over the ``model`` mesh axis and
batch-sharded over ``data``.

Mamba2 follows the scalar-decay SSD formulation (one decay per head);
RWKV6 follows the Finch data-dependent-decay recurrence with token-shift
lerps and LoRA-modulated mixing.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, rms_norm


# ===========================================================================
# Mamba2
# ===========================================================================
def mamba_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba(key, cfg: ModelConfig, dtype=None) -> Dict:
    dtype = dtype or cfg.dtype
    d_inner, H, P, N = mamba_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    conv_ch = d_inner + 2 * N  # conv over x, B, C
    return {
        # in_proj -> [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
        "w_in": dense_init(ks[0], (d, 2 * d_inner + 2 * N + H), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_ch), dtype, 0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "out_norm": jnp.zeros((d_inner,), dtype),
        "w_out": dense_init(ks[2], (d_inner, d), dtype),
    }


def _mamba_project(params, cfg, x, conv_state=None):
    """Shared pre-recurrence math. x: (B, S, d).

    Returns (z, xh, Bm, Cm, dt, new_conv_state) with
      z, xh: (B, S, H, P); Bm, Cm: (B, S, N); dt: (B, S, H).
    """
    d_inner, H, P, N = mamba_dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z, xr, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    # causal depthwise conv over (x, B, C)
    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)  # (B, S, conv_ch)
    K = cfg.ssm_conv
    if conv_state is None:  # full sequence: pad left
        padded = jnp.pad(conv_in, ((0, 0), (K - 1, 0), (0, 0)))
        new_conv_state = conv_in[:, -(K - 1):, :] if conv_in.shape[1] >= K - 1 \
            else padded[:, -(K - 1):, :]
    else:  # decode: prepend cached last K-1 inputs
        padded = jnp.concatenate([conv_state, conv_in], axis=1)
        new_conv_state = padded[:, -(K - 1):, :]
    conv = sum(padded[:, i:i + conv_in.shape[1], :] * params["conv_w"][i]
               for i in range(K)) + params["conv_b"]
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xr, Bm, Cm = jnp.split(conv, [d_inner, d_inner + N], axis=-1)
    B_, S = x.shape[0], x.shape[1]
    xh = xr.reshape(B_, S, H, P)
    z = z.reshape(B_, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    return z, xh, Bm, Cm, dt, new_conv_state


def _mamba_finish(params, cfg, y, z, B_, S):
    d_inner, H, P, N = mamba_dims(cfg)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B_, S, d_inner)
    y = rms_norm(y, params["out_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["w_out"])


def mamba_forward(params: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Full-sequence Mamba2 mixer. x: (B, S, d) -> (B, S, d)."""
    B_, S, _ = x.shape
    d_inner, H, P, N = mamba_dims(cfg)
    z, xh, Bm, Cm, dt, _ = _mamba_project(params, cfg, x)
    decay = jnp.exp(-jnp.exp(params["a_log"]) * dt)  # (B, S, H)
    xdt = xh.astype(jnp.float32) * dt[..., None]  # (B, S, H, P)

    if cfg.use_pallas_kernels:
        from repro.kernels import ops as kops
        y = kops.ssm_scan(xdt, Bm.astype(jnp.float32),
                          Cm.astype(jnp.float32), decay)
        y = y + xh.astype(jnp.float32) * params["d_skip"][None, None, :, None]
        return _mamba_finish(params, cfg, y.astype(x.dtype), z, B_, S)

    def step(h, inp):
        xdt_t, B_t, C_t, decay_t = inp
        # h: (B, H, P, N)
        h = h * decay_t[:, :, None, None] + \
            xdt_t[..., None] * B_t[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    h0 = jnp.zeros((B_, H, P, N), jnp.float32)
    xs = (xdt.transpose(1, 0, 2, 3), Bm.astype(jnp.float32).transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2), decay.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)  # ys: (S, B, H, P)
    y = ys.transpose(1, 0, 2, 3) + \
        xh.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    return _mamba_finish(params, cfg, y.astype(x.dtype), z, B_, S)


def init_mamba_state(cfg: ModelConfig, batch: int) -> Dict:
    d_inner, H, P, N = mamba_dims(cfg)
    conv_ch = d_inner + 2 * N
    return {
        "h": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), cfg.dtype),
    }


def mamba_decode_step(params: Dict, cfg: ModelConfig, x: jax.Array,
                      state: Dict) -> Tuple[jax.Array, Dict]:
    """x: (B, 1, d) -> (y (B,1,d), new_state)."""
    B_, S, _ = x.shape
    z, xh, Bm, Cm, dt, conv_state = _mamba_project(
        params, cfg, x, conv_state=state["conv"])
    decay = jnp.exp(-jnp.exp(params["a_log"]) * dt)  # (B, 1, H)
    h = state["h"] * decay[:, 0, :, None, None] + \
        (xh.astype(jnp.float32) * dt[..., None])[:, 0, ..., None] * \
        Bm.astype(jnp.float32)[:, 0, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32)[:, 0])
    y = y[:, None] + xh.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    out = _mamba_finish(params, cfg, y.astype(x.dtype), z, B_, S)
    return out, {"h": h, "conv": conv_state}


# ===========================================================================
# RWKV6 (Finch)
# ===========================================================================
def rwkv_dims(cfg: ModelConfig):
    P = cfg.rwkv_head_dim
    H = cfg.d_model // P
    return H, P


_RWKV_MIX = ("r", "k", "v", "w", "g")


def init_rwkv_time_mix(key, cfg: ModelConfig, dtype=None) -> Dict:
    """Time-mix params. The five ddlerp loras and the four r/k/v/g
    projections are stored FUSED ((5, d, l) / (4, d, d)) so the stacked
    einsums in _rwkv_rkvwg touch the residual once and need no runtime
    restacking of differently-sharded weights (§Perf #5)."""
    dtype = dtype or cfg.dtype
    H, P = rwkv_dims(cfg)
    d = cfg.d_model
    lora = max(32, d // 64)
    ks = jax.random.split(key, 16)
    p: Dict = {}
    p["mu"] = jnp.zeros((len(_RWKV_MIX), d), dtype)
    p["lora_a"] = dense_init(ks[0], (len(_RWKV_MIX), d, lora), dtype, 0.1)
    p["lora_b"] = dense_init(ks[1], (len(_RWKV_MIX), lora, d), dtype, 0.1)
    p["w_rkvg"] = dense_init(ks[2], (4, d, d), dtype)
    p["w_o"] = dense_init(ks[14], (d, d), dtype)
    p["decay_base"] = jnp.full((d,), -6.0, jnp.float32)
    # small positive bonus so first-token wkv output is non-degenerate
    # (u=0 makes step-0 output exactly 0 -> rms_norm amplifies by 1/sqrt(eps))
    p["bonus_u"] = jnp.full((H, P), 0.5, jnp.float32)
    p["ln_x"] = jnp.zeros((d,), dtype)
    return p


def init_rwkv_channel_mix(key, cfg: ModelConfig, dtype=None) -> Dict:
    dtype = dtype or cfg.dtype
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), dtype),
        "mu_r": jnp.zeros((d,), dtype),
        "w_k": dense_init(ks[0], (d, cfg.d_ff), dtype),
        "w_v": dense_init(ks[1], (cfg.d_ff, d), dtype),
        "w_r": dense_init(ks[2], (d, d), dtype),
    }


def _token_shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """x: (B, S, d); last: (B, d) previous token (zeros at start)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _time_mix_inputs(params, x, x_prev):
    """Data-dependent lerps for r/k/v/w/g (RWKV6 ddlerp).

    §Perf #5: the five lora paths are FUSED into stacked einsums — the naive
    per-name loop touched the (B, S, d) residual ten times, which under
    hidden-sharded activations cost ~26 activation all-gathers per layer on
    the production mesh (EXPERIMENTS.md §Perf). One stacked read instead."""
    xx = x_prev - x
    lora = jnp.tanh(jnp.einsum("bsd,xdl->bxsl", xx, params["lora_a"]))
    mix = params["mu"][None, :, None, :] + jnp.einsum(
        "bxsl,xld->bxsd", lora, params["lora_b"])
    mixed = x[:, None] + xx[:, None] * mix  # (B, 5, S, d)
    # keep `mixed` in the residual's layout: without the pin GSPMD gathers
    # the full (B, 5, S, d) tensor instead of reduce-scattering the fused
    # projection output (§Perf #5)
    from repro.models.common import constrain_activation
    mixed = constrain_activation(mixed)
    return {nm: mixed[:, i] for i, nm in enumerate(_RWKV_MIX)}, mixed


def _rwkv_rkvwg(params, cfg, x, x_prev):
    H, P = rwkv_dims(cfg)
    B_, S, d = x.shape
    m, mixed = _time_mix_inputs(params, x, x_prev)
    # fused r/k/v/g projection: one (4, d, d) einsum over the mixed inputs.
    # _RWKV_MIX order is (r, k, v, w, g): the projected four are 0,1,2,4
    proj = jnp.einsum("bxsd,xde->bxse", mixed[:, jnp.array([0, 1, 2, 4])],
                      params["w_rkvg"])
    r = proj[:, 0].reshape(B_, S, H, P)
    k = proj[:, 1].reshape(B_, S, H, P)
    v = proj[:, 2].reshape(B_, S, H, P)
    # bf16 on the wire, fp32 only inside the recurrence step / accumulators:
    # fp32 activations here doubled every cross-chip gather (§Perf #5c)
    g = jax.nn.silu(proj[:, 3].astype(jnp.float32)).astype(x.dtype)
    # data-dependent decay: w in (0,1), per channel; reuse the "w" ddlerp
    # (index 3 of `mixed` is x + xx*mix_w; the decay lora consumes xx via
    # the fused lora tensors)
    wlog = params["decay_base"] + jnp.einsum(
        "bsl,ld->bsd",
        jnp.tanh(jnp.einsum("bsd,dl->bsl", x_prev - x,
                            params["lora_a"][3])),
        params["lora_b"][3]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog)).reshape(B_, S, H, P).astype(x.dtype)
    return r, k, v, g, w


def _rwkv_out(params, cfg, wkv, g, B_, S):
    d = cfg.d_model
    out = wkv.reshape(B_, S, d)
    out = rms_norm(out, params["ln_x"], cfg.norm_eps)
    out = out * g.reshape(B_, S, d).astype(out.dtype)
    return jnp.einsum("bse,ed->bsd", out, params["w_o"])


def rwkv_time_mix_forward(params: Dict, cfg: ModelConfig, x: jax.Array,
                          ) -> jax.Array:
    """Full-sequence RWKV6 time-mix. x: (B, S, d)."""
    H, P = rwkv_dims(cfg)
    B_, S, d = x.shape
    x_prev = _token_shift(x, jnp.zeros((B_, d), x.dtype))
    r, k, v, g, w = _rwkv_rkvwg(params, cfg, x, x_prev)
    u = params["bonus_u"]

    if cfg.use_pallas_kernels:
        from repro.kernels import ops as kops
        wkv = kops.rwkv6_scan(r, k, v, w, u).astype(x.dtype)
        return _rwkv_out(params, cfg, wkv, g, B_, S)

    # recurrence: S_h (B, H, P, P); y_t = r_t @ (S_h + u * k_t v_t^T)
    def step2(S_h, inp):
        r_t, k_t, v_t, w_t = [a.astype(jnp.float32) for a in inp]
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B, H, P, P)
        y = jnp.einsum("bhp,bhpq->bhq", r_t, S_h + u[..., None] * kv)
        S_h = w_t[..., :, None] * S_h + kv
        return S_h, y.astype(r.dtype)  # bf16 out of the loop (§Perf #5c)

    S0 = jnp.zeros((B_, H, P, P), jnp.float32)
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    _, ys = jax.lax.scan(step2, S0, xs)  # (S, B, H, P)
    wkv = ys.transpose(1, 0, 2, 3).astype(x.dtype)
    return _rwkv_out(params, cfg, wkv, g, B_, S)


def init_rwkv_state(cfg: ModelConfig, batch: int) -> Dict:
    H, P = rwkv_dims(cfg)
    return {
        "S": jnp.zeros((batch, H, P, P), jnp.float32),
        "x_tm": jnp.zeros((batch, cfg.d_model), cfg.dtype),  # time-mix shift
        "x_cm": jnp.zeros((batch, cfg.d_model), cfg.dtype),  # chan-mix shift
    }


def rwkv_time_mix_decode(params: Dict, cfg: ModelConfig, x: jax.Array,
                         state: Dict) -> Tuple[jax.Array, Dict]:
    """x: (B, 1, d) single token."""
    H, P = rwkv_dims(cfg)
    B_, S, d = x.shape
    x_prev = state["x_tm"][:, None, :]
    r, k, v, g, w = _rwkv_rkvwg(params, cfg, x, x_prev)
    u = params["bonus_u"]
    r_t, k_t, v_t, w_t = [a[:, 0].astype(jnp.float32) for a in (r, k, v, w)]
    kv = k_t[..., :, None] * v_t[..., None, :]
    y = jnp.einsum("bhp,bhpq->bhq", r_t, state["S"] + u[..., None] * kv)
    S_new = w_t[..., :, None] * state["S"] + kv
    out = _rwkv_out(params, cfg, y[:, None].astype(x.dtype), g, B_, S)
    new_state = dict(state)
    new_state["S"] = S_new
    new_state["x_tm"] = x[:, 0]
    return out, new_state


def rwkv_channel_mix_forward(params: Dict, cfg: ModelConfig, x: jax.Array,
                             last: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d); last: (B, d). Returns (y, new_last)."""
    x_prev = _token_shift(x, last)
    xk = x + (x_prev - x) * params["mu_k"]
    xr = x + (x_prev - x) * params["mu_r"]
    kk = jnp.einsum("bsd,df->bsf", xk, params["w_k"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    vv = jnp.einsum("bsf,fd->bsd", kk, params["w_v"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                   params["w_r"]).astype(jnp.float32))
    return (rr.astype(x.dtype) * vv), x[:, -1]
