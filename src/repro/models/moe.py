"""Mixture-of-Experts layer (GShard/Switch-style capacity dispatch).

Design notes (sized for the dry-run meshes — see DESIGN.md §6):

* Tokens are processed in fixed-size *groups* (default 256 tokens). With the
  dispatch einsum formulation, dispatch-tensor memory and FLOPs scale as
  ``T * group_size * k`` — independent of the expert count — so small groups
  keep the overhead at ~5-15% of useful expert FLOPs for the assigned
  128-expert (qwen3) and 384-expert (kimi-k2) configs.
* Experts are sharded over the ``model`` mesh axis (expert parallelism); the
  group axis shards over ``data``. GSPMD inserts the all-to-all at the
  dispatch/combine einsums — the router boundary the paper's §7 proposes to
  disaggregate.
* Over-capacity tokens are dropped (their combine weight is zero), standard
  for capacity-based MoE; the aux load-balance loss keeps routing even.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init

# Sharding hook (installed by the launcher like transformer._ACT_CONSTRAINT):
# pins the expert-parallel layout of the dispatch pipeline so GSPMD emits
# all-to-alls at the router boundary instead of all-gathering the routing
# tensors (EXPERIMENTS.md §Perf #4). fn(tensor, kind) -> tensor.
_SHARDING_HOOK = None


def set_sharding_hook(fn) -> None:
    global _SHARDING_HOOK
    _SHARDING_HOOK = fn


def _shard(x, kind: str):
    return _SHARDING_HOOK(x, kind) if _SHARDING_HOOK is not None else x


def init_moe(key, cfg: ModelConfig, dtype=None) -> Dict:
    dtype = dtype or cfg.dtype
    kr, kg, ku, kd = jax.random.split(key, 4)
    E, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff

    def expert(k, shape):
        keys = jax.random.split(k, E)
        return jax.vmap(lambda kk: dense_init(kk, shape, dtype))(keys)

    return {
        "router": dense_init(kr, (d, E), jnp.float32),
        "w_gate": expert(kg, (d, f)),
        "w_up": expert(ku, (d, f)),
        "w_down": expert(kd, (f, d)),
    }


def _capacity(group_size: int, k: int, num_experts: int,
              factor: float) -> int:
    cap = int(group_size * k * factor / num_experts) + 1
    # round up to a multiple of 4 for friendlier tiling
    return max(4, -(-cap // 4) * 4)


def moe_forward(params: Dict, cfg: ModelConfig, x: jax.Array,
                group_size: int = 256) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    Groups = (B*S)/group_size; requires B*S % group_size == 0 (configs ensure
    this; decode batches smaller than group_size use one group).
    """
    B, S, d = x.shape
    T = B * S
    gs = min(group_size, T)
    G = T // gs
    assert G * gs == T, f"tokens {T} not divisible by group size {gs}"
    E, k = cfg.num_experts, cfg.experts_per_token
    C = _capacity(gs, k, E, cfg.capacity_factor)

    xg = _shard(x.reshape(G, gs, d), "tokens")
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"])  # (G, gs, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    topk_probs, topk_idx = jax.lax.top_k(probs, k)  # (G, gs, k)
    topk_probs = topk_probs / jnp.maximum(
        jnp.sum(topk_probs, axis=-1, keepdims=True), 1e-9)

    # --- position of each (token, choice) within its expert's capacity ---
    onehot = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32)  # (G, gs, k, E)
    flat = onehot.reshape(G, gs * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # rank within expert, (G, gs*k, E)
    pos = pos.reshape(G, gs, k, E)
    keep = (pos < C).astype(jnp.float32) * onehot  # (G, gs, k, E)
    pos_i = pos.astype(jnp.int32)

    # Accumulate the (G, gs, E, C) dispatch/combine tensors one routing choice
    # at a time — materialising the full (G, gs, k, E, C) one-hot would be
    # O(T·k·E·C) bytes (≈400 GB at kimi-k2 train_4k scale).
    dtype = x.dtype
    dispatch = jnp.zeros((G, gs, E, C), jnp.float32)
    combine = jnp.zeros((G, gs, E, C), jnp.float32)
    for j in range(k):
        oh_c = jax.nn.one_hot(pos_i[:, :, j], C, dtype=jnp.float32)
        slot = keep[:, :, j, :, None] * oh_c  # (G, gs, E, C)
        dispatch = dispatch + slot
        combine = combine + slot * topk_probs[:, :, j, None, None]

    disp = _shard(dispatch.astype(dtype), "dispatch")
    # dispatch: (G, gs, E, C) x (G, gs, d) -> (G, E, C, d)   [all-to-all]
    xe = _shard(jnp.einsum("gtec,gtd->gecd", disp, xg), "expert_tokens")
    # expert FFN, batched over E (expert-parallel over `model` axis)
    h = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(dtype) * u
    ye = _shard(jnp.einsum("gecf,efd->gecd", h, params["w_down"]),
                "expert_tokens")
    # combine back: (G, gs, E, C) x (G, E, C, d) -> (G, gs, d) [all-to-all]
    y = jnp.einsum("gtec,gecd->gtd", _shard(combine.astype(dtype),
                                            "dispatch"), ye)

    # --- load-balance auxiliary loss (Switch-style) ---
    frac_tokens = jnp.mean(onehot.sum(axis=2), axis=(0, 1))  # (E,)
    mean_prob = jnp.mean(probs, axis=(0, 1))  # (E,)
    aux = E * jnp.sum(frac_tokens * mean_prob) / k

    return y.reshape(B, S, d), aux.astype(jnp.float32)
