"""Paged-context chunk-prefill GQA attention Pallas kernel.

Chunked prefill splits a prompt into block-aligned chunks; chunk k's queries
(positions [P, P+C), P = tokens already written to the pool) must attend over

  * the ALREADY-WRITTEN prefix — the sequence's first P/block_size pool
    blocks, read IN PLACE through the block table exactly like the paged
    flash-decode kernel (``paged_decode_attention.py``), and
  * the chunk itself, under the in-chunk causal mask (the chunk's K/V are
    freshly projected this layer and are not in the pool yet).

This is the prefill-axis counterpart of the decode kernel: peak prefill
memory becomes O(chunk) — the only dense KV materialised per call is the
chunk's own (the slab ``PagedKVCache.write_prefill_chunk`` scatters) —
instead of the O(prompt) slab a one-shot prefill builds, and the prefix
context is streamed HBM→VMEM block by block rather than gathered.

Mechanics (mirroring the decode kernel's conventions):
  * the pool is HEAD-MAJOR ``(Hkv, num_blocks, block_size, hd)`` per layer;
    ``block_table (nb,)`` rides in as a scalar-prefetch operand and drives
    the k/v BlockSpec index maps for the first ``nb`` grid steps;
  * the chunk's K/V ride in as a separate (padded) operand; grid steps
    ``nb .. nb+nc`` walk them. Because the prefix is the sequence's
    CONTIGUOUS first P tokens, key position is uniformly
    ``step·block_size + offset`` across both operands;
  * per step the kernel folds the block's partial into the running
    (acc, max, denom) triple with the §4.2.2 combine identity — the same
    math as ``models.attention.blockwise_attention``, so the kernel is
    parity-testable against the jnp reference below;
  * masks are PER QUERY ROW (unlike decode's single position): causal
    ``pos_k <= pos_q``, sliding window ``pos_k > pos_q - window``, and
    StreamingLLM sinks ``pos_k < attention_sinks`` — identical to the
    blockwise prefill masks, so gemma2 local layers chunk exactly.

The jnp reference gathers the prefix dense through the table (the copy the
kernel avoids) and reuses ``blockwise_attention`` over the concatenation —
bit-identical to the corresponding rows of a one-shot prefill (same scan
boundaries; masked future blocks are exact no-ops). The engines' default
jnp path routes through that reference; the Pallas path is the TPU
no-densify hot path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_prefill_chunk_kernel(bt_ref, q_ref, k_ref, v_ref, kc_ref, vc_ref,
                                o_ref, acc_ref, m_ref, l_ref, *,
                                block_size: int, chunk_len: int,
                                prefix_blocks: int, total_len: int,
                                sliding_window: int, attention_sinks: int,
                                logit_softcap: float, nsteps: int):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)              # (G·C, hd)
    rows = q.shape[0]
    # operand select: the first `prefix_blocks` steps stream pool blocks
    # through the prefetched table; the rest walk the padded chunk K/V
    is_prefix = kb < prefix_blocks
    k_pool_blk = k_ref[0, 0].astype(jnp.float32)  # (block_size, hd)
    v_pool_blk = v_ref[0, 0].astype(jnp.float32)
    k_chk_blk = kc_ref[0, 0].astype(jnp.float32)
    v_chk_blk = vc_ref[0, 0].astype(jnp.float32)
    k = jnp.where(is_prefix, k_pool_blk, k_chk_blk)
    v = jnp.where(is_prefix, v_pool_blk, v_chk_blk)

    # key positions: prefix is the sequence's contiguous first P tokens and
    # the chunk follows immediately, so every step's base is kb·block_size
    pos_k = kb * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)[0]         # (block_size,)
    col_valid = pos_k < total_len                 # kills chunk padding
    # query positions: row r = g·C + t holds chunk token t at P + t
    pos_q = (prefix_blocks * block_size +
             jax.lax.broadcasted_iota(jnp.int32, (rows, block_size), 0)
             % chunk_len)                         # (rows, block_size)

    valid = col_valid[None, :] & (pos_k[None, :] <= pos_q)
    if sliding_window > 0:
        in_window = pos_k[None, :] > (pos_q - sliding_window)
        if attention_sinks > 0:   # StreamingLLM sinks stay attendable
            in_window |= jnp.broadcast_to(pos_k[None, :] < attention_sinks,
                                          valid.shape)
        valid &= in_window
    # padded chunk rows may hold anything — zero v under the column mask so
    # the weighted sum can never see Inf/NaN through a 0-weight column
    v = jnp.where(col_valid[:, None], v, 0.0)

    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (rows, bs)
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    s = jnp.where(valid, s, NEG_INF)

    # §4.2.2 running combine, per query row
    m_prev = m_ref[...]                            # (rows, 128) lane bcast
    m_cur = jnp.max(s, axis=-1, keepdims=True)     # (rows, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])  # (rows, 1)
    p = jnp.exp(s - m_new[:, :1])
    p = jnp.where(valid, p, 0.0)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kb == nsteps - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def _paged_prefill_chunk_kernel_int8(bt_ref, q_ref, k_ref, v_ref,
                                     ks_ref, vs_ref, kc_ref, vc_ref,
                                     o_ref, acc_ref, m_ref, l_ref, *,
                                     block_size: int, chunk_len: int,
                                     prefix_blocks: int, total_len: int,
                                     sliding_window: int,
                                     attention_sinks: int,
                                     logit_softcap: float, nsteps: int):
    """int8-pool variant of :func:`_paged_prefill_chunk_kernel`: the
    ALREADY-WRITTEN prefix streams in quantized with per-token fp32 scale
    tiles on the same table walk; the chunk's own K/V are freshly projected
    this layer (not yet in the pool) and stay full precision — their scale
    is the exact multiplicative identity 1.0, selected by the same operand
    switch that picks the chunk tile. Dequant fuses into the score / PV
    products as one broadcast multiply per tile (k scale before softcap, v
    scale into p); no dequantized slab is ever built."""
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)              # (G·C, hd)
    rows = q.shape[0]
    is_prefix = kb < prefix_blocks
    k_pool_blk = k_ref[0, 0].astype(jnp.float32)  # (block_size, hd) int8
    v_pool_blk = v_ref[0, 0].astype(jnp.float32)
    k_chk_blk = kc_ref[0, 0].astype(jnp.float32)
    v_chk_blk = vc_ref[0, 0].astype(jnp.float32)
    k = jnp.where(is_prefix, k_pool_blk, k_chk_blk)
    v = jnp.where(is_prefix, v_pool_blk, v_chk_blk)
    one = jnp.ones((block_size,), jnp.float32)    # chunk steps: ×1.0 exact
    ks = jnp.where(is_prefix, ks_ref[0, 0], one)
    vs = jnp.where(is_prefix, vs_ref[0, 0], one)

    pos_k = kb * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)[0]         # (block_size,)
    col_valid = pos_k < total_len
    pos_q = (prefix_blocks * block_size +
             jax.lax.broadcasted_iota(jnp.int32, (rows, block_size), 0)
             % chunk_len)                         # (rows, block_size)

    valid = col_valid[None, :] & (pos_k[None, :] <= pos_q)
    if sliding_window > 0:
        in_window = pos_k[None, :] > (pos_q - sliding_window)
        if attention_sinks > 0:
            in_window |= jnp.broadcast_to(pos_k[None, :] < attention_sinks,
                                          valid.shape)
        valid &= in_window
    v = jnp.where(col_valid[:, None], v, 0.0)

    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (rows, bs)
    s = s * ks[None, :]                           # fused k-dequant (pre-cap)
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
    p = jnp.exp(s - m_new[:, :1])
    p = jnp.where(valid, p, 0.0)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p * vs[None, :], v, (((1,), (0,)), ((), ())),  # fused v-dequant
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kb == nsteps - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sliding_window",
                                             "attention_sinks",
                                             "logit_softcap", "interpret"))
def paged_prefill_chunk_attention(q, k_pool, v_pool, block_table,
                                  k_chunk, v_chunk, *,
                                  k_scale=None, v_scale=None,
                                  sliding_window: int = 0,
                                  attention_sinks: int = 0,
                                  logit_softcap: float = 0.0,
                                  interpret: bool = False):
    """q: (C, H, hd) — one chunk's RoPE'd queries at global positions
    [P, P+C) where P = len(block_table)·block_size; k_pool/v_pool:
    HEAD-MAJOR (Hkv, num_blocks, block_size, hd); block_table: (nb,) int32
    pool ids of the sequence's ALREADY-WRITTEN first nb blocks (the
    block-aligned prefix); k_chunk/v_chunk: (C, Hkv, hd) — this chunk's
    freshly projected K/V (not yet in the pool). k_scale/v_scale: optional
    (Hkv, num_blocks, block_size) fp32 per-token scale pools for an int8
    k_pool/v_pool — the int8 kernel variant fuses dequant into the
    score/PV products; the chunk's own K/V stay full precision.
    Returns (C, H, hd).

    Per-call HBM traffic over the context is exactly one streamed read of
    the live prefix KV; nothing is gathered into a dense slab first."""
    C, H, hd = q.shape
    Hkv, _, block_size, _ = k_pool.shape
    G = H // Hkv
    nb = block_table.shape[0]
    nc = -(-C // block_size)
    pad = nc * block_size - C
    # (C, Hkv, hd) -> head-major (Hkv, nc·bs, hd), zero-padded chunk tail
    kc = jnp.swapaxes(k_chunk, 0, 1)
    vc = jnp.swapaxes(v_chunk, 0, 1)
    if pad:
        kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0)))
    # (C, Hkv, G, hd) -> (Hkv, G·C, hd): row r = g·C + t
    qg = q.reshape(C, Hkv, G, hd).transpose(1, 2, 0, 3).reshape(
        Hkv, G * C, hd)
    # the pool BlockSpec must stay in-bounds on chunk steps (and with an
    # empty prefix): pad the table to ≥1 slot and clamp the walk index
    bt = block_table.astype(jnp.int32)
    if nb == 0:
        bt = jnp.zeros((1,), jnp.int32)
    nsteps = nb + nc

    quantized = k_scale is not None
    kernel = functools.partial(
        _paged_prefill_chunk_kernel_int8 if quantized
        else _paged_prefill_chunk_kernel,
        block_size=block_size, chunk_len=C,
        prefix_blocks=nb, total_len=nb * block_size + C,
        sliding_window=sliding_window, attention_sinks=attention_sinks,
        logit_softcap=logit_softcap, nsteps=nsteps)
    clamp = max(nb - 1, 0)
    pool_spec = pl.BlockSpec(
        (1, 1, block_size, hd),
        lambda h, kb, bt: (h, bt[jnp.minimum(kb, clamp)], 0, 0))
    # scale tiles ride the same clamped table walk as their value tiles
    scale_spec = pl.BlockSpec(
        (1, 1, block_size),
        lambda h, kb, bt: (h, bt[jnp.minimum(kb, clamp)], 0))
    chunk_spec = pl.BlockSpec(
        (1, 1, block_size, hd),
        lambda h, kb, bt: (h, jnp.maximum(kb - nb, 0), 0, 0))
    in_specs = [pl.BlockSpec((1, G * C, hd), lambda h, kb, bt: (h, 0, 0)),
                pool_spec, pool_spec]
    if quantized:
        in_specs += [scale_spec, scale_spec]
    in_specs += [chunk_spec, chunk_spec]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,    # block_table
        grid=(Hkv, nsteps),       # kb innermost: scratch carries the combine
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, G * C, hd), lambda h, kb, bt: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G * C, hd), jnp.float32),    # acc
            pltpu.VMEM((G * C, 128), jnp.float32),   # running max
            pltpu.VMEM((G * C, 128), jnp.float32),   # running denom
        ],
    )
    operands = (bt, qg, k_pool, v_pool)
    if quantized:
        operands += (k_scale, v_scale)
    operands += (kc.reshape(Hkv, nc, block_size, hd),
                 vc.reshape(Hkv, nc, block_size, hd))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, G * C, hd), q.dtype),
        interpret=interpret,
    )(*operands)
    # (Hkv, G·C, hd) -> (C, H, hd)
    return out.reshape(Hkv, G, C, hd).transpose(2, 0, 1, 3).reshape(C, H, hd)


def gather_prefix_dense(k_pool, v_pool, block_table):
    """Block-table gather of a contiguous prefix into seq-major dense
    (P, Hkv, hd) views — the jnp reference data path (and exactly the bytes
    the chunk kernel streams in place instead)."""
    Hkv, _, bs, hd = k_pool.shape
    nb = block_table.shape[0]
    kp = jnp.swapaxes(k_pool[:, block_table], 0, 1)  # (nb, Hkv, bs, hd)
    vp = jnp.swapaxes(v_pool[:, block_table], 0, 1)
    kp = jnp.swapaxes(kp, 1, 2).reshape(nb * bs, Hkv, hd)
    vp = jnp.swapaxes(vp, 1, 2).reshape(nb * bs, Hkv, hd)
    return kp, vp


def gather_prefix_scales(scale_pool, block_table):
    """Block-table gather of a (Hkv, num_blocks, bs) scale pool into the
    seq-major (P, Hkv) per-token view — reference data path only."""
    Hkv, _, bs = scale_pool.shape
    nb = block_table.shape[0]
    s = scale_pool[:, block_table]            # (Hkv, nb, bs)
    return s.reshape(Hkv, nb * bs).T          # (P, Hkv)


def paged_prefill_chunk_attention_jnp(q, k_pool, v_pool, block_table,
                                      k_chunk, v_chunk, *,
                                      k_scale=None, v_scale=None,
                                      sliding_window: int = 0,
                                      attention_sinks: int = 0,
                                      logit_softcap: float = 0.0):
    """Pure-jnp reference for the chunk kernel: gathers the prefix dense
    through the table and runs ``blockwise_attention`` over the
    concatenation — the SAME scan boundaries (512-key blocks from position
    0) as a one-shot prefill, so the result is bit-identical to the
    corresponding query rows of the unchunked prefill (masked-out future
    blocks are exact no-ops in the running combine). int8 pools pass the
    scale pools; the gathered prefix is dequantized dense here (the
    reference path is ALLOWED to densify — the kernel is not)."""
    from repro.models.attention import blockwise_attention

    C = q.shape[0]
    bs = k_pool.shape[2]
    P = block_table.shape[0] * bs
    kp, vp = gather_prefix_dense(k_pool, v_pool, block_table)
    if k_scale is not None:
        kp = (kp.astype(jnp.float32) *
              gather_prefix_scales(k_scale, block_table)[:, :, None]
              ).astype(k_chunk.dtype)
        vp = (vp.astype(jnp.float32) *
              gather_prefix_scales(v_scale, block_table)[:, :, None]
              ).astype(v_chunk.dtype)
    k_all = jnp.concatenate([kp, k_chunk], axis=0)[None]  # (1, P+C, Hkv, hd)
    v_all = jnp.concatenate([vp, v_chunk], axis=0)[None]
    q_pos = (P + jnp.arange(C, dtype=jnp.int32))[None]
    out = blockwise_attention(
        q[None], k_all, v_all, causal=True,
        sliding_window=int(sliding_window),
        attention_sinks=int(attention_sinks),
        logit_softcap=logit_softcap, q_positions=q_pos)
    return out[0]
