"""RWKV6 (Finch) recurrence Pallas kernel.

TPU adaptation of the data-dependent-decay linear-attention scan: the
sequence is tiled into `chunk` blocks streamed into VMEM; the (P, P)
per-head state lives in fp32 VMEM scratch and carries across chunk blocks
(innermost grid dim), so HBM traffic is O(S·P) instead of O(S·P²). Inside a
chunk the recurrence is a fori_loop over timesteps on VMEM-resident data:

    y_t = r_t · S + (r_t · (u ⊙ k_t)) v_t
    S  <- diag(w_t) S + k_t ⊗ v_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_ref, *,
                  chunk: int, n_chunks: int):
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0].astype(jnp.float32)  # (P,)

    def step(t, state):
        r_t = r_ref[0, t, 0, :].astype(jnp.float32)
        k_t = k_ref[0, t, 0, :].astype(jnp.float32)
        v_t = v_ref[0, t, 0, :].astype(jnp.float32)
        w_t = w_ref[0, t, 0, :].astype(jnp.float32)
        # y = r·S + (r·(u⊙k)) v   (avoids materialising u⊙k⊗v)
        y = jnp.einsum("p,pq->q", r_t, state,
                       preferred_element_type=jnp.float32)
        y = y + jnp.sum(r_t * u * k_t) * v_t
        y_ref[0, t, 0, :] = y.astype(y_ref.dtype)
        return w_t[:, None] * state + k_t[:, None] * v_t[None, :]

    state_ref[...] = jax.lax.fori_loop(0, chunk, step, state_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, *, chunk: int = 128,
               interpret: bool = False) -> jax.Array:
    """r/k/v/w: (B, S, H, P); u: (H, P). Returns y: (B, S, H, P) fp32."""
    B, S, H, P = r.shape
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        padc = [(0, 0), (0, pad), (0, 0), (0, 0)]
        r, k, v = (jnp.pad(a, padc) for a in (r, k, v))
        w = jnp.pad(w, padc, constant_values=1.0)

    kernel = functools.partial(_rwkv6_kernel, chunk=chunk, n_chunks=n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0))
    y = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, P), lambda b, h, c: (h, 0))],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((B, n_chunks * chunk, H, P),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, P), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y[:, :S]
