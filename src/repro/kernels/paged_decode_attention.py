"""Paged flash-decode GQA attention Pallas kernel.

PagedAttention-style (the paper's baseline [28]) counterpart of
``decode_attention.py``: instead of a dense per-batch KV slab, the kernel
consumes the serving engine's block pool *in place* through a per-sequence
block table, so a decode step moves exactly one read of the live KV plus one
token write — no per-step dense gather, no pool-sized transposes (the
`kill-the-gather` tentpole; the paper's whole premise is that decode
attention is memory-bound, §3).

Mechanics:
  * the pool is HEAD-MAJOR ``(Hkv, num_blocks, block_size, hd)`` per layer,
    so one (head, block) tile is a contiguous ``(block_size, hd)`` DMA;
  * ``block_tables (B, nb)`` + ``cache_len (B,)`` ride in as scalar-prefetch
    operands (``PrefetchScalarGridSpec``) and drive the k/v BlockSpec index
    maps — the grid's KV dimension walks the table, streaming pool blocks
    HBM→VMEM;
  * ``block_positions (B, nb)`` (optional third prefetch operand) carries
    each table slot's global base position. For a contiguous table the
    default ``slot·block_size`` is implied; a BLOCK-SHARDED table (one shard
    of a cross-chip sequence split, ``core/attention_parallel.py``) walks a
    non-contiguous subset of the sequence's blocks, and the positions keep
    causal/window/sink masks exact. Slots a shard does not own carry the
    ``POS_PAD`` sentinel so every row masks out — the shard then yields the
    empty partial (l = 0, m = NEG_INF) the §4.2.2 combine treats as identity;
  * per block the kernel computes the partial (acc, denom, max) triple and
    merges it with the running state using the paper-§4.2.2 combine identity
    (``core/combine.py``) — identical math to ``decode_attention.py``, so the
    two backends are interchangeable and parity-testable;
  * table slots past a sequence's live blocks may point anywhere (the engine
    pads with block 0); their positions are ≥ cache_len so the masks kill
    them, and v is zero-filled under the mask so stale pool garbage can never
    poison the accumulator (0·Inf/NaN).

This layout is what the cross-chip block partition shards by: blocks, not
dense slabs (``block_parallel_paged_decode_attention`` runs this kernel with
``return_partials=True`` per device and psum-combines the triples).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
# Base-position sentinel for table slots a shard does not own (or pure pad):
# far beyond any real cache_len, so every mask (causal, window, sink) kills
# the whole block while staying comfortably inside int32.
POS_PAD = 1 << 30


def _paged_decode_kernel(bt_ref, bp_ref, len_ref, q_ref, k_ref, v_ref,
                         o_ref, lo_ref, mo_ref,
                         acc_ref, m_ref, l_ref, *,
                         block_size: int, sliding_window: int,
                         attention_sinks: int, logit_softcap: float, nb: int):
    b = pl.program_id(0)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_size, hd) pool block
    v = v_ref[0, 0].astype(jnp.float32)
    cache_len = len_ref[b]

    # global sequence positions of this pool block's rows: the prefetched
    # per-slot base (slot·block_size for contiguous tables; arbitrary —
    # including POS_PAD — for block-sharded ones)
    pos = bp_ref[b, kb] + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)[0]        # (block_size,)
    row_valid = pos < cache_len
    if sliding_window > 0:
        in_window = pos >= (cache_len - sliding_window)
        if attention_sinks > 0:  # StreamingLLM sinks stay attendable
            in_window |= pos < attention_sinks
        row_valid &= in_window
    # stale pool blocks may hold anything — zero v under the mask so the
    # weighted sum can never see Inf/NaN through a 0-weight column
    v = jnp.where(row_valid[:, None], v, 0.0)

    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    valid = jnp.broadcast_to(row_valid[None, :], s.shape)
    s = jnp.where(valid, s, NEG_INF)

    # paper §4.2.2 combine: rebase running (acc, l) onto the new max
    m_prev = m_ref[...]                           # (G, 128) broadcast lanes
    m_cur = jnp.max(s, axis=-1, keepdims=True)    # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])  # (G, 1)
    p = jnp.exp(s - m_new[:, :1])                  # (G, block_size)
    p = jnp.where(valid, p, 0.0)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kb == nb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)
        lo_ref[0, 0] = l_ref[...]   # partial denominator (§4.2.2 combine)
        mo_ref[0, 0] = m_ref[...]   # partial max


def _paged_decode_kernel_int8(bt_ref, bp_ref, len_ref, q_ref, k_ref, v_ref,
                              ks_ref, vs_ref, o_ref, lo_ref, mo_ref,
                              acc_ref, m_ref, l_ref, *,
                              block_size: int, sliding_window: int,
                              attention_sinks: int, logit_softcap: float,
                              nb: int):
    """int8-pool variant of :func:`_paged_decode_kernel`: k/v tiles arrive
    quantized with per-token fp32 scale tiles ``(block_size,)`` riding the
    same block-table walk, and dequantization fuses into the score / PV
    products as ONE broadcast multiply per (G, block_size) tile — the k
    scale folds into ``s`` right after the QK product (before softcap, where
    the dense int8 reference applies it), the v scale folds into ``p``
    before the PV product. No dequantized (block_size, hd) slab is ever
    built; the bf16 kernel above is untouched."""
    b = pl.program_id(0)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_size, hd) int8->f32
    v = v_ref[0, 0].astype(jnp.float32)
    ks = ks_ref[0, 0]                            # (block_size,) fp32 scales
    vs = vs_ref[0, 0]
    cache_len = len_ref[b]

    pos = bp_ref[b, kb] + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)[0]        # (block_size,)
    row_valid = pos < cache_len
    if sliding_window > 0:
        in_window = pos >= (cache_len - sliding_window)
        if attention_sinks > 0:
            in_window |= pos < attention_sinks
        row_valid &= in_window
    # int8 loads are always finite, but stale scales are arbitrary (finite)
    # numbers — zero v under the mask exactly like the bf16 kernel so the
    # masked columns contribute exact zeros through the zeroed p
    v = jnp.where(row_valid[:, None], v, 0.0)

    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)
    s = s * ks[None, :]                          # fused k-dequant (pre-cap)
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    valid = jnp.broadcast_to(row_valid[None, :], s.shape)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
    p = jnp.exp(s - m_new[:, :1])
    p = jnp.where(valid, p, 0.0)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p * vs[None, :], v, (((1,), (0,)), ((), ())),  # fused v-dequant
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kb == nb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)
        lo_ref[0, 0] = l_ref[...]
        mo_ref[0, 0] = m_ref[...]


def default_block_positions(B: int, nb: int, block_size: int) -> jax.Array:
    """Contiguous-table base positions: slot j starts at j·block_size."""
    return jnp.broadcast_to(
        jnp.arange(nb, dtype=jnp.int32)[None, :] * block_size, (B, nb))


@functools.partial(jax.jit, static_argnames=("sliding_window",
                                             "attention_sinks",
                                             "logit_softcap", "interpret",
                                             "return_partials"))
def paged_decode_attention(q, k_pool, v_pool, block_tables, cache_len, *,
                           block_positions=None,
                           k_scale=None, v_scale=None,
                           sliding_window: int = 0, attention_sinks: int = 0,
                           logit_softcap: float = 0.0,
                           interpret: bool = False,
                           return_partials: bool = False):
    """q: (B, Hkv, G, hd); k_pool/v_pool: HEAD-MAJOR
    (Hkv, num_blocks, block_size, hd); block_tables: (B, nb) int32 pool-block
    ids per sequence (pad slots with any valid id — masked); cache_len: (B,)
    live tokens. block_positions: optional (B, nb) int32 global base position
    per table slot (defaults to the contiguous slot·block_size; block-sharded
    callers pass their shard's true positions, POS_PAD on foreign slots).
    k_scale/v_scale: optional (Hkv, num_blocks, block_size) fp32 per-token
    scale pools for an int8 k_pool/v_pool — when given, the int8 kernel
    variant streams the scale tiles through the SAME block-table walk and
    fuses dequantization into the score/PV products (no dense dequantized
    slab, in VMEM or HBM).
    Returns (B, Hkv, G, hd), or the (o, l, m) §4.2.2 triple over the cached
    subset when return_partials — mergeable with other partials (e.g. across
    the pool mesh axis via ``core.combine.psum_combine``).

    Per-step HBM traffic is exactly the live KV: each (head, block) tile is
    one contiguous (block_size, hd) DMA addressed through the prefetched
    block table; nothing is gathered into a dense slab first.
    """
    B, Hkv, G, hd = q.shape
    block_size = k_pool.shape[2]
    nb = block_tables.shape[1]
    if block_positions is None:
        block_positions = default_block_positions(B, nb, block_size)
    block_positions = block_positions.astype(jnp.int32)
    quantized = k_scale is not None

    kernel = functools.partial(
        _paged_decode_kernel_int8 if quantized else _paged_decode_kernel,
        block_size=block_size,
        sliding_window=sliding_window, attention_sinks=attention_sinks,
        logit_softcap=logit_softcap, nb=nb)
    kv_spec = pl.BlockSpec((1, 1, block_size, hd),
                           lambda b, h, kb, bt, bp, ln: (h, bt[b, kb], 0, 0))
    # scale tiles ride the same prefetched table walk as their value tiles
    scale_spec = pl.BlockSpec((1, 1, block_size),
                              lambda b, h, kb, bt, bp, ln: (h, bt[b, kb], 0))
    in_specs = [
        pl.BlockSpec((1, 1, G, hd),
                     lambda b, h, kb, bt, bp, ln: (b, h, 0, 0)),
        kv_spec, kv_spec,
    ] + ([scale_spec, scale_spec] if quantized else [])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,   # block_tables, block_positions, cache_len
        grid=(B, Hkv, nb),       # kb innermost: scratch carries the combine
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, h, kb, bt, bp, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 128),
                         lambda b, h, kb, bt, bp, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 128),
                         lambda b, h, kb, bt, bp, ln: (b, h, 0, 0)),
        ),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),    # acc
            pltpu.VMEM((G, 128), jnp.float32),   # running max (lane bcast)
            pltpu.VMEM((G, 128), jnp.float32),   # running denom
        ],
    )
    operands = (block_tables, block_positions, cache_len, q, k_pool, v_pool)
    if quantized:
        operands += (k_scale, v_scale)
    out, l_out, m_out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, 128), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G, 128), jnp.float32),
        ),
        interpret=interpret,
    )(*operands)
    if return_partials:
        return out, l_out[..., 0], m_out[..., 0]
    return out


def paged_gather_dense(k_pool, v_pool, block_tables):
    """Block-table gather into head-major dense (B, Hkv, nb·bs, hd) views —
    the jnp reference data path (and the bytes the paged kernel avoids)."""
    Hkv, _, bs, hd = k_pool.shape
    B, nb = block_tables.shape
    kc = jnp.swapaxes(k_pool[:, block_tables], 0, 1)  # (B, Hkv, nb, bs, hd)
    vc = jnp.swapaxes(v_pool[:, block_tables], 0, 1)
    return (kc.reshape(B, Hkv, nb * bs, hd), vc.reshape(B, Hkv, nb * bs, hd))


def paged_gather_scales(scale_pool, block_tables):
    """Block-table gather of a (Hkv, num_blocks, bs) scale pool into the
    dense (B, Hkv, nb·bs) per-token view the dense int8 references fold into
    the score/PV einsums — reference data path only."""
    Hkv, _, bs = scale_pool.shape
    B, nb = block_tables.shape
    s = jnp.swapaxes(scale_pool[:, block_tables], 0, 1)  # (B, Hkv, nb, bs)
    return s.reshape(B, Hkv, nb * bs)


def paged_decode_attention_jnp(q, k_pool, v_pool, block_tables, cache_len, *,
                               k_scale=None, v_scale=None,
                               sliding_window: int = 0,
                               attention_sinks: int = 0,
                               logit_softcap: float = 0.0):
    """Pure-jnp reference for the paged kernel (CPU tests): gathers the dense
    view through the block table and runs the dense oracle math (int8 pools
    additionally gather the scale pools and fold them into the einsums)."""
    from repro.kernels import ref

    kc, vc = paged_gather_dense(k_pool, v_pool, block_tables)
    kw = {}
    if k_scale is not None:
        kw = {"k_scale": paged_gather_scales(k_scale, block_tables),
              "v_scale": paged_gather_scales(v_scale, block_tables)}
    return ref.decode_attention_ref(q, kc, vc, cache_len,
                                    sliding_window=sliding_window,
                                    attention_sinks=attention_sinks,
                                    logit_softcap=logit_softcap, **kw)
