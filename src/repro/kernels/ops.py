"""jit'd dispatch wrappers around the Pallas kernels.

On CPU (this container) the kernels run with interpret=True; on TPU the same
call sites compile the Mosaic kernels. ``repro.models.attention`` registers
the decode kernel as the "pallas" backend so any model's serve path can
switch with ``DisaggConfig.decode_backend``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import paged_decode_attention as _pda
from repro.kernels import paged_prefill_attention as _ppa
from repro.kernels import rwkv6_scan as _rw
from repro.kernels import ssm_scan as _ssm

_INTERPRET = jax.default_backend() == "cpu"


def decode_attention(q, k_cache, v_cache, cache_len, *, block_k: int = 512,
                     sliding_window: int = 0, logit_softcap: float = 0.0):
    """q: (B, H, hd); caches HEAD-MAJOR (B, Hkv, S, hd) (kernels/ref.py)."""
    B, H, hd = q.shape
    Hkv = k_cache.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    out = _da.decode_attention(qg, k_cache, v_cache, cache_len,
                               block_k=block_k, sliding_window=sliding_window,
                               logit_softcap=logit_softcap,
                               interpret=_INTERPRET)
    return out.reshape(B, H, hd)


def paged_prefill_chunk_attention(q, k_pool, v_pool, block_table,
                                  k_chunk, v_chunk, *, backend: str = "jnp",
                                  k_scale=None, v_scale=None,
                                  sliding_window: int = 0,
                                  attention_sinks: int = 0,
                                  logit_softcap: float = 0.0):
    """Paged-context chunk-prefill attention — backend dispatch.

    One prefill chunk's queries ``q (C, H, hd)`` (positions [P, P+C), with
    P = len(block_table)·block_size tokens already written to the pool)
    attend over the prefix pool blocks plus the in-chunk causal mask
    (``k_chunk/v_chunk (C, Hkv, hd)`` are this chunk's freshly projected
    K/V). 'pallas' streams the prefix HBM→VMEM through the block table in
    place — peak context memory O(block); 'jnp' is the gather reference
    whose math is bit-identical to the corresponding rows of a one-shot
    prefill (the serving engines' default path — see
    ``kernels/paged_prefill_attention.py``)."""
    kw = dict(k_scale=k_scale, v_scale=v_scale,
              sliding_window=sliding_window, attention_sinks=attention_sinks,
              logit_softcap=logit_softcap)
    if backend == "pallas":
        return _ppa.paged_prefill_chunk_attention(
            q, k_pool, v_pool, block_table, k_chunk, v_chunk,
            interpret=_INTERPRET, **kw)
    return _ppa.paged_prefill_chunk_attention_jnp(
        q, k_pool, v_pool, block_table, k_chunk, v_chunk, **kw)


def rwkv6_scan(r, k, v, w, u, *, chunk: int = 128):
    return _rw.rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=_INTERPRET)


def ssm_scan(x, B_in, C_in, decay, *, chunk: int = 128):
    return _ssm.ssm_scan(x, B_in, C_in, decay, chunk=chunk,
                         interpret=_INTERPRET)


# --- register the Pallas decode backend with the model layer --------------
def _serving_window(sliding_window: int, attention_sinks: int, cache_len):
    """Map the model-layer window contract (anchored to total length
    cache_len + 1 — the incoming token counts) onto the kernels' (anchored
    to cache_len): the kernel window shrinks by one. sliding_window == 1
    covers ONLY the incoming token, which the kernels cannot express as a
    window (0 means "no window"), so the stored prefix is clamped to the
    always-attendable sinks instead. Returns (kernel_sw, kernel_sinks,
    kernel_cache_len)."""
    if sliding_window == 1:
        return 0, 0, jnp.minimum(cache_len, attention_sinks)
    sw = max(sliding_window - 1, 0) if sliding_window > 0 else 0
    return sw, attention_sinks, cache_len


def _triple_to_partial(o, l, m, B, H, hd):
    from repro.core.combine import Partial

    return Partial(a=o.astype(jnp.float32).reshape(B, H, hd) *
                   l.reshape(B, H)[..., None],
                   s=l.reshape(B, H), m=m.reshape(B, H))


def _pallas_decode_partial_backend(q, k_cache, v_cache, cache_len, *,
                                   sliding_window: int = 0,
                                   attention_sinks: int = 0,
                                   logit_softcap: float = 0.0):
    """Partial triple over the cached prefix (model-layer backend contract:
    cache_len = stored tokens, window is w.r.t. total length cache_len+1)."""
    B, H, hd = q.shape
    Hkv = k_cache.shape[1]  # head-major cache (B, Hkv, S, hd)
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    sw, sinks, clen = _serving_window(sliding_window, attention_sinks,
                                      cache_len)
    o, l, m = _da.decode_attention(
        qg, k_cache, v_cache, clen, sliding_window=sw,
        attention_sinks=sinks, logit_softcap=logit_softcap,
        interpret=_INTERPRET, return_partials=True)
    return _triple_to_partial(o, l, m, B, H, hd)


def _pallas_paged_decode_partial_backend(q, k_pool, v_pool, block_tables,
                                         cache_len, *,
                                         k_scale=None, v_scale=None,
                                         sliding_window: int = 0,
                                         attention_sinks: int = 0,
                                         logit_softcap: float = 0.0):
    """Paged partial triple over the block pool (same backend contract as
    the dense variant: cache_len = stored tokens, window w.r.t. total length
    cache_len+1) — the serving engines' TPU hot path."""
    B, H, hd = q.shape
    Hkv = k_pool.shape[0]  # head-major pool (Hkv, num_blocks, bs, hd)
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    sw, sinks, clen = _serving_window(sliding_window, attention_sinks,
                                      cache_len)
    o, l, m = _pda.paged_decode_attention(
        qg, k_pool, v_pool, block_tables, clen,
        k_scale=k_scale, v_scale=v_scale, sliding_window=sw,
        attention_sinks=sinks, logit_softcap=logit_softcap,
        interpret=_INTERPRET, return_partials=True)
    return _triple_to_partial(o, l, m, B, H, hd)


def pallas_paged_decode_partial_pos(q, k_pool, v_pool, block_tables,
                                    block_positions, cache_len, *,
                                    k_scale=None, v_scale=None,
                                    sliding_window: int = 0,
                                    attention_sinks: int = 0,
                                    logit_softcap: float = 0.0):
    """Positions-aware paged partial for BLOCK-SHARDED local tables (same
    serving contract) — runs the kernel in place over one shard's pool
    slice; the block-partition AttentionWorkerPool's TPU hot path."""
    B, H, hd = q.shape
    Hkv = k_pool.shape[0]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    sw, sinks, clen = _serving_window(sliding_window, attention_sinks,
                                      cache_len)
    o, l, m = _pda.paged_decode_attention(
        qg, k_pool, v_pool, block_tables, clen,
        block_positions=block_positions,
        k_scale=k_scale, v_scale=v_scale, sliding_window=sw,
        attention_sinks=sinks, logit_softcap=logit_softcap,
        interpret=_INTERPRET, return_partials=True)
    return _triple_to_partial(o, l, m, B, H, hd)


def register():
    from repro.models.attention import (register_decode_backend,
                                        register_paged_decode_backend)
    register_decode_backend("pallas", _pallas_decode_partial_backend)
    register_paged_decode_backend("pallas", _pallas_paged_decode_partial_backend)


register()
