"""Pure-jnp oracles for every Pallas kernel (the correctness contract the
shape/dtype sweep tests assert against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, cache_len, *,
                         sliding_window: int = 0, attention_sinks: int = 0,
                         logit_softcap: float = 0.0,
                         k_scale=None, v_scale=None) -> jax.Array:
    """q: (B, Hkv, G, hd); caches: HEAD-MAJOR (B, Hkv, S, hd); cache_len:
    (B,). Returns (B, Hkv, G, hd). fp32 math throughout.

    int8 caches pass per-token ``k_scale``/``v_scale`` (B, Hkv, S): the k
    scale folds into the scores right after the QK einsum (before softcap),
    the v scale into the probabilities before the PV einsum — the fused
    dequant convention every int8 backend (kernel and jnp) follows."""
    B, Hkv, G, hd = q.shape
    S = k_cache.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bhgk,bhsk->bhgs", q.astype(jnp.float32) * scale,
                   k_cache.astype(jnp.float32))
    if k_scale is not None:
        s = s * k_scale[:, :, None, :].astype(jnp.float32)
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    pos = jnp.arange(S)[None, :]
    valid = pos < cache_len[:, None]
    if sliding_window > 0:
        in_window = pos >= (cache_len[:, None] - sliding_window)
        if attention_sinks > 0:
            in_window |= pos < attention_sinks
        valid &= in_window
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p = p * v_scale[:, :, None, :].astype(jnp.float32)
    out = jnp.einsum("bhgs,bhsk->bhgk", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, cache_len, *,
                               sliding_window: int = 0,
                               attention_sinks: int = 0,
                               logit_softcap: float = 0.0) -> jax.Array:
    """Oracle for the paged flash-decode kernel: gather the dense head-major
    view through the block table, then the dense oracle math.

    q: (B, Hkv, G, hd); k_pool/v_pool: HEAD-MAJOR (Hkv, num_blocks,
    block_size, hd); block_tables: (B, nb) int32; cache_len: (B,)."""
    from repro.kernels.paged_decode_attention import paged_gather_dense

    kc, vc = paged_gather_dense(k_pool, v_pool, block_tables)
    return decode_attention_ref(q, kc, vc, cache_len,
                                sliding_window=sliding_window,
                                attention_sinks=attention_sinks,
                                logit_softcap=logit_softcap)


def paged_decode_attention_int8_ref(q, k_pool, v_pool, k_scale, v_scale,
                                    block_tables, cache_len, *,
                                    block_positions=None,
                                    sliding_window: int = 0,
                                    attention_sinks: int = 0,
                                    logit_softcap: float = 0.0) -> jax.Array:
    """BIT-PARITY oracle for the int8 paged flash-decode kernel: replays the
    kernel's exact op sequence (same lax primitives, same order, same fp32
    intermediates, fused scale multiplies in the same places) per (b, h)
    grid cell in a host loop — interpret-mode Pallas executes the identical
    XLA ops, so the contract is ``assert_array_equal``, not allclose.

    q: (B, Hkv, G, hd); k_pool/v_pool: int8 (Hkv, num_blocks, bs, hd);
    k_scale/v_scale: fp32 (Hkv, num_blocks, bs); block_tables: (B, nb).
    Test-scale only (python grid loop)."""
    from repro.kernels.paged_decode_attention import (NEG_INF,
                                                      default_block_positions)

    B, Hkv, G, hd = q.shape
    bs = k_pool.shape[2]
    nb = block_tables.shape[1]
    if block_positions is None:
        block_positions = default_block_positions(B, nb, bs)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    out = []
    for b in range(B):
        heads = []
        for h in range(Hkv):
            qf = q[b, h].astype(jnp.float32)                  # (G, hd)
            acc = jnp.zeros((G, hd), jnp.float32)
            m = jnp.full((G, 1), NEG_INF, jnp.float32)
            ell = jnp.zeros((G, 1), jnp.float32)
            for kb in range(nb):
                blk = block_tables[b, kb]
                k = k_pool[h, blk].astype(jnp.float32)        # (bs, hd)
                v = v_pool[h, blk].astype(jnp.float32)
                ks = k_scale[h, blk]                          # (bs,)
                vs = v_scale[h, blk]
                pos = block_positions[b, kb] + jax.lax.broadcasted_iota(
                    jnp.int32, (1, bs), 1)[0]
                row_valid = pos < cache_len[b]
                if sliding_window > 0:
                    in_window = pos >= (cache_len[b] - sliding_window)
                    if attention_sinks > 0:
                        in_window |= pos < attention_sinks
                    row_valid &= in_window
                v = jnp.where(row_valid[:, None], v, 0.0)
                s = jax.lax.dot_general(
                    qf * scale, k, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)       # (G, bs)
                s = s * ks[None, :]
                if logit_softcap > 0.0:
                    s = logit_softcap * jnp.tanh(s / logit_softcap)
                valid = jnp.broadcast_to(row_valid[None, :], s.shape)
                s = jnp.where(valid, s, NEG_INF)
                m_cur = jnp.max(s, axis=-1, keepdims=True)
                m_new = jnp.maximum(m, jnp.broadcast_to(m_cur, m.shape))
                alpha = jnp.exp(m[:, :1] - m_new[:, :1])
                p = jnp.exp(s - m_new[:, :1])
                p = jnp.where(valid, p, 0.0)
                ell = ell * alpha + jnp.sum(p, axis=-1, keepdims=True)
                acc = acc * alpha + jax.lax.dot_general(
                    p * vs[None, :], v, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                m = m_new
            denom = jnp.maximum(ell[:, :1], 1e-30)
            heads.append((acc / denom).astype(q.dtype))
        out.append(jnp.stack(heads))
    return jnp.stack(out)                                     # (B,Hkv,G,hd)


def paged_prefill_chunk_attention_int8_ref(q, k_pool, v_pool,
                                           k_scale, v_scale, block_table,
                                           k_chunk, v_chunk, *,
                                           sliding_window: int = 0,
                                           attention_sinks: int = 0,
                                           logit_softcap: float = 0.0
                                           ) -> jax.Array:
    """BIT-PARITY oracle for the int8 paged chunk-prefill kernel — the same
    exact-op-replay contract as :func:`paged_decode_attention_int8_ref`,
    per (h, step) grid cell. q: (C, H, hd); k_chunk/v_chunk: (C, Hkv, hd)
    full precision (chunk scale is the exact identity 1.0)."""
    from repro.kernels.paged_prefill_attention import NEG_INF

    C, H, hd = q.shape
    Hkv, _, bs, _ = k_pool.shape
    G = H // Hkv
    nb = block_table.shape[0]
    nc = -(-C // bs)
    pad = nc * bs - C
    kc = jnp.swapaxes(k_chunk, 0, 1)
    vc = jnp.swapaxes(v_chunk, 0, 1)
    if pad:
        kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0)))
    kc = kc.reshape(Hkv, nc, bs, hd)
    vc = vc.reshape(Hkv, nc, bs, hd)
    qg = q.reshape(C, Hkv, G, hd).transpose(1, 2, 0, 3).reshape(
        Hkv, G * C, hd)
    rows = G * C
    total_len = nb * bs + C
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    one = jnp.ones((bs,), jnp.float32)
    outs = []
    for h in range(Hkv):
        qf = qg[h].astype(jnp.float32)                        # (rows, hd)
        acc = jnp.zeros((rows, hd), jnp.float32)
        m = jnp.full((rows, 1), NEG_INF, jnp.float32)
        ell = jnp.zeros((rows, 1), jnp.float32)
        for kb in range(nb + nc):
            if kb < nb:
                blk = block_table[kb]
                k = k_pool[h, blk].astype(jnp.float32)
                v = v_pool[h, blk].astype(jnp.float32)
                ks, vs = k_scale[h, blk], v_scale[h, blk]
            else:
                k = kc[h, kb - nb].astype(jnp.float32)
                v = vc[h, kb - nb].astype(jnp.float32)
                ks = vs = one
            pos_k = kb * bs + jax.lax.broadcasted_iota(
                jnp.int32, (1, bs), 1)[0]
            col_valid = pos_k < total_len
            pos_q = (nb * bs + jax.lax.broadcasted_iota(
                jnp.int32, (rows, bs), 0) % C)
            valid = col_valid[None, :] & (pos_k[None, :] <= pos_q)
            if sliding_window > 0:
                in_window = pos_k[None, :] > (pos_q - sliding_window)
                if attention_sinks > 0:
                    in_window |= jnp.broadcast_to(
                        pos_k[None, :] < attention_sinks, valid.shape)
                valid &= in_window
            v = jnp.where(col_valid[:, None], v, 0.0)
            s = jax.lax.dot_general(
                qf * scale, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            s = s * ks[None, :]
            if logit_softcap > 0.0:
                s = logit_softcap * jnp.tanh(s / logit_softcap)
            s = jnp.where(valid, s, NEG_INF)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, jnp.broadcast_to(m_cur, m.shape))
            alpha = jnp.exp(m[:, :1] - m_new[:, :1])
            p = jnp.exp(s - m_new[:, :1])
            p = jnp.where(valid, p, 0.0)
            ell = ell * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jax.lax.dot_general(
                p * vs[None, :], v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m = m_new
        denom = jnp.maximum(ell[:, :1], 1e-30)
        outs.append((acc / denom).astype(q.dtype))
    out = jnp.stack(outs)                                     # (Hkv,G·C,hd)
    return out.reshape(Hkv, G, C, hd).transpose(2, 0, 1, 3).reshape(C, H, hd)


def rwkv6_scan_ref(r, k, v, w, u) -> jax.Array:
    """RWKV6 recurrence oracle.

    r, k, v, w: (B, S, H, P) (w = per-step decay in (0,1), fp32 math);
    u: (H, P) bonus. Returns y: (B, S, H, P), fp32.
      y_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t);  S_t = w_t ⊙ S_{t-1} + k_t ⊗ v_t
    """
    B, S, H, P = r.shape
    rf, kf, vf, wf = [a.astype(jnp.float32) for a in (r, k, v, w)]
    uf = u.astype(jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, P)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B, H, P, P)
        y = jnp.einsum("bhp,bhpq->bhq", r_t, state + uf[..., None] * kv)
        return w_t[..., :, None] * state + kv, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, wf))
    _, ys = jax.lax.scan(step, jnp.zeros((B, H, P, P), jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3)


def ssm_scan_ref(x, dt, B_in, C_in, decay) -> jax.Array:
    """Mamba2 scalar-decay SSD oracle.

    x: (B, S, H, P) (already dt-scaled inputs), dt unused placeholder kept
    for API parity; B_in, C_in: (B, S, N); decay: (B, S, H) in (0,1].
    Returns y: (B, S, H, P) fp32:  h_t = decay_t h_{t-1} + x_t ⊗ B_t;
    y_t = h_t · C_t.
    """
    Bb, S, H, P = x.shape
    N = B_in.shape[-1]

    def step(h, inp):
        x_t, b_t, c_t, a_t = inp
        h = h * a_t[:, :, None, None] + x_t[..., None] * b_t[:, None, None, :]
        return h, jnp.einsum("bhpn,bn->bhp", h, c_t)

    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          B_in.astype(jnp.float32).transpose(1, 0, 2),
          C_in.astype(jnp.float32).transpose(1, 0, 2),
          decay.astype(jnp.float32).transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, jnp.zeros((Bb, H, P, N), jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3)
